"""Durability benchmarks: what the segment log buys on restart (§14).

Two lanes, twin clusters driven by the same seed:

* **Warm vs cold recovery** — a 5-node sharded cluster takes a keyed load,
  one node crashes, the survivors keep writing (the *divergence* knob: the
  fraction of keys rewritten during the outage), then the node comes back.
  ``warm`` replays its own log and runs one digest-diffed pull+push delta
  pass per peer (``restart_node``); ``cold`` is the PR-4 baseline — the
  returnee is re-admitted empty and ``bootstrap_node`` ships it the full
  payload.  Reported per divergence level: resync wire bytes (payload +
  digest phases) for both paths and their ratio.  The claim: at ≤10%
  divergence the warm path moves ≥5x fewer bytes, because the log made
  recovery O(divergence) instead of O(store).

* **Log overhead** — what durability costs while running: bytes appended
  per payload byte written (write amplification over the whole load), the
  manifest-referenced footprint after snapshots compact the prefix, and
  the replay profile of the final restart (records, snapshot vs tail
  bytes).
"""
from __future__ import annotations

import json
import random
import shutil
import tempfile
from typing import Dict, List, Optional, Tuple

from repro.core import DVV_MECHANISM
from repro.store import CrashFS, KVCluster

NODES = tuple(f"n{i}" for i in range(5))
VICTIM = "n2"
N_KEYS = 240
DIVERGENCE = (0.02, 0.05, 0.10)


def _loaded_cluster(tmp: str, seed: int,
                    fs: Optional[CrashFS] = None) -> Tuple[KVCluster,
                                                           random.Random]:
    c = KVCluster(NODES, DVV_MECHANISM, packed=True, shards=4,
                  replication=3, write_quorum=2, seed=seed, wal_dir=tmp,
                  wal_fs={VICTIM: fs} if fs else None)
    rng = random.Random(seed * 31 + 5)
    for i in range(N_KEYS):
        via = NODES[rng.randrange(len(NODES))]
        c.put(f"k{i:04d}", f"value-{i:04d}-" + "x" * 48, via=via,
              coordinator=via)
        if i % 8 == 7:
            c.deliver_replication()
    c.deliver_replication()
    for _ in range(3):
        c.delta_antientropy_round()
    return c, rng


def _diverge(c: KVCluster, rng: random.Random, frac: float) -> int:
    """Crash the victim, rewrite ``frac`` of the keyspace without it."""
    c.network.fail_node(VICTIM)
    c.wal[VICTIM].detach()
    n = int(N_KEYS * frac)
    for i in rng.sample(range(N_KEYS), n):
        via = NODES[0]
        k = f"k{i:04d}"
        # read-modify-write (the paper's get -> put context flow): the
        # revision supersedes instead of siblinging
        c.put(k, f"revised-{i:04d}-" + "y" * 48, via=via, coordinator=via,
              context=c.get(k, via=via).context)
    c.deliver_replication()
    return n


def _wire(stats) -> int:
    return sum(s.payload_bytes + s.digest_bytes for s in stats)


def recovery_cell(frac: float, seed: int = 0) -> Dict:
    """Twin runs (same seed, same schedule): warm log-replay restart vs
    cold full-payload bootstrap of the same post-outage cluster."""
    tmp = tempfile.mkdtemp(prefix="durable-bench-")
    try:
        c, rng = _loaded_cluster(f"{tmp}/warm", seed)
        rewritten = _diverge(c, rng, frac)
        c.network.recover_node(VICTIM)
        warm = _wire(c.restart_node(VICTIM))
        replay = c.last_replay

        c2, rng2 = _loaded_cluster(f"{tmp}/cold", seed)
        _diverge(c2, rng2, frac)
        c2.network.recover_node(VICTIM)
        c2.remove_node(VICTIM, handoff=False)
        cold = _wire(c2.add_node(VICTIM))
        return {
            "divergence": frac,
            "keys_rewritten": rewritten,
            "warm_resync_bytes": warm,
            "cold_bootstrap_bytes": cold,
            "ratio": round(cold / max(warm, 1), 2),
            "replayed_records": replay.records,
            "replay_snapshot_bytes": replay.snapshot_bytes,
            "replay_tail_bytes": replay.tail_bytes,
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def overhead_cell(seed: int = 0) -> Dict:
    """Durability's running cost on the victim node: append traffic per
    payload byte, and the manifest footprint snapshots leave behind."""
    import pickle
    tmp = tempfile.mkdtemp(prefix="durable-bench-")
    try:
        fs = CrashFS(None)                      # recording mode: no crashes
        c, _ = _loaded_cluster(f"{tmp}/ovh", seed, fs=fs)
        live = len(pickle.dumps(c.nodes[VICTIM].antientropy_payload(), 4))
        appended = sum(e - s for op, _, s, e in fs.extents
                       if op == "append")
        return {
            "node": VICTIM,
            "live_payload_bytes": live,
            "wal_appended_bytes": appended,
            "write_amplification": round(appended / max(live, 1), 2),
            "log_footprint_bytes": c.wal[VICTIM].log_bytes(),
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def durable_rows(json_path: Optional[str] = "BENCH_durable.json",
                 seed: int = 0) -> List[str]:
    cells = [recovery_cell(f, seed=seed) for f in DIVERGENCE]
    ovh = overhead_cell(seed=seed)
    worst = cells[-1]                           # 10% divergence
    out = [
        f"durable_warm_restart,{worst['warm_resync_bytes']},"
        f"cold={worst['cold_bootstrap_bytes']};"
        f"ratio={worst['ratio']}x@{int(worst['divergence'] * 100)}pct",
        f"durable_replay,{worst['replayed_records']},"
        f"snap={worst['replay_snapshot_bytes']}B;"
        f"tail={worst['replay_tail_bytes']}B",
        f"durable_log_overhead,{ovh['log_footprint_bytes']},"
        f"amp={ovh['write_amplification']}x",
    ]
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "durable",
                "note": ("Recovery lane: 5 nodes, shards=4, replication=3, "
                         "write_quorum=2, 240 keys loaded, one crash, a "
                         "divergence fraction of the keyspace rewritten "
                         "during the outage, then recovery.  warm = "
                         "restart_node (log replay + one pull+push delta "
                         "pass per peer); cold = re-admitted empty + "
                         "bootstrap_node full payload (the PR-4 baseline). "
                         "Bytes are payload + digest phases of the delta "
                         "rounds.  Overhead lane: append traffic recorded "
                         "by a CrashFS in recording mode on one node over "
                         "the whole load; footprint is what the manifests "
                         "still reference after snapshot compaction."),
                "config": {"nodes": len(NODES), "shards": 4, "keys": N_KEYS,
                           "replication": 3, "write_quorum": 2},
                "recovery": cells,
                "overhead": ovh,
                "summary": {
                    "warm_vs_cold_ratio_at_10pct": worst["ratio"],
                    "warm_resync_bytes_at_10pct":
                        worst["warm_resync_bytes"],
                    "cold_bootstrap_bytes": worst["cold_bootstrap_bytes"],
                    "write_amplification": ovh["write_amplification"],
                }}, f, indent=1)
    return out


def rows() -> List[str]:
    """Benchmark-harness hook (`make bench-durable` writes the JSON)."""
    return durable_rows(json_path=None)


if __name__ == "__main__":
    print("\n".join(durable_rows()))
