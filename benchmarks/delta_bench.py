"""Divergence-sweep benchmark: delta anti-entropy vs the full-payload round.

The paper's argument is *concise* causality metadata; DESIGN.md §6 extends
it to the protocol: a steady-state round should cost O(divergence), not
O(store).  This sweep holds the store size fixed and varies the divergent
key fraction (0.1% → 100%), measuring, per cell:

  * the one-shot full-payload array round (``payload()`` + ``apply_payload``
    — the PR-1 steady state, now the fallback),
  * the two-phase delta round (digest diff → ranked divergent ranges →
    sliced payload apply),
  * wire bytes for both phases of each round, and
  * the shape-bucketed jit cache: a warm bucketed ``sync_mask`` call vs a
    fresh-trace (uncached) call at the very [N, K, R] shape the delta
    round produced.

CPU wall-times are indicative (single-core container); the structural wins
— payload ∝ divergence and zero re-tracing — are what transfer to TPU.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import batched as B
from repro.store.bulk import delta_plan
from repro.store.packed import PackedPayload, PackedVersionStore


def _bulk_store(n_keys: int, n_replicas: int = 8, seed: int = 0
                ) -> PackedVersionStore:
    """Vectorized store construction: one synthetic payload, one apply."""
    rng = np.random.default_rng(seed)
    universe = tuple(f"r{i}" for i in range(n_replicas))
    keys = tuple(f"key{i}" for i in range(n_keys))
    vv = rng.integers(0, 5, (n_keys, n_replicas)).astype(np.int32)
    dot_id = rng.integers(0, n_replicas, n_keys).astype(np.int32)
    dot_n = (vv[np.arange(n_keys), dot_id] + 1).astype(np.int32)
    store = PackedVersionStore()
    for r in universe:
        store.intern_replica(r)
    store.apply_payload(PackedPayload(
        universe, keys, vv, dot_id, dot_n,
        np.arange(n_keys, dtype=np.int32),
        tuple(f"B{i}" for i in range(n_keys))))
    return store


def _diverge(local: PackedVersionStore, divergence: float, seed: int = 1
             ) -> Tuple[PackedVersionStore, int]:
    """Clone ``local`` and advance a ``divergence`` fraction of its keys on
    the clone (each new version dominates the resident one)."""
    rng = np.random.default_rng(seed)
    remote = local.clone()
    n_keys = len(local.keys)
    n_div = max(1, int(round(n_keys * divergence)))
    div = np.sort(rng.choice(n_keys, n_div, replace=False))
    R = local.n_replicas
    rows = np.flatnonzero(local.valid[: local.n_slots])
    by_key = np.full(n_keys, -1, np.int64)
    by_key[local.key_ix[rows]] = rows          # one live slot per key here
    src = by_key[div]
    vv = local.vv[src, :R].copy()
    old_dot = local.dot_id[src]
    # fold the old dot in (n = m+1 is contiguous), then mint a fresh dot
    vv[np.arange(n_div), old_dot] = local.dot_n[src]
    dot_id = rng.integers(0, R, n_div).astype(np.int32)
    dot_n = (vv[np.arange(n_div), dot_id] + 1).astype(np.int32)
    remote.apply_payload(PackedPayload(
        tuple(local.replica_ids), tuple(local.keys[int(k)] for k in div),
        vv, dot_id, dot_n, np.arange(n_div, dtype=np.int32),
        tuple(f"D{int(k)}" for k in div)))
    return remote, n_div


def _timed(fn, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def _mask_shape_probe(local: PackedVersionStore, remote: PackedVersionStore
                      ) -> Tuple[int, int, int]:
    """The grouped [N, K, R] shape a delta round hands to sync_mask."""
    ranked, width, _ = delta_plan(remote, local.sync_digest())
    payload = remote.payload(key_ranges=ranked, ranges_width=width)
    n = len(set(payload.keys))
    return max(n, 1), 2, local.n_replicas


def _jit_cache_cell(shape: Tuple[int, int, int], reps: int,
                    warm: B.BucketedSyncMask) -> Tuple[float, float]:
    """(uncached_us, warm_us) for a sync_mask call at ``shape``.

    Uncached = a fresh ``jax.jit`` instance per call, the retrace every
    fresh-shaped delta round pays without bucketing.  Warm = the shared
    bucketed cache, second call onward.
    """
    rng = np.random.default_rng(0)
    N, K, R = shape
    vvs = rng.integers(0, 5, (N, K, R)).astype(np.int32)
    dids = rng.integers(-1, R, (N, K)).astype(np.int32)
    dns = np.where(dids >= 0, vvs[..., 0] + 1, 0).astype(np.int32)
    valid = np.ones((N, K), bool)

    def uncached():
        fn = jax.jit(B.sync_mask)          # fresh trace, like a fresh shape
        np.asarray(fn(vvs, dids, dns, valid))

    uncached_us = _timed(uncached, max(1, reps - 1))
    warm(vvs, dids, dns, valid)            # populate the bucket
    warm_us = _timed(lambda: warm(vvs, dids, dns, valid), reps)
    return uncached_us, warm_us


def delta_sync_rows(n_keys_list: Sequence[int] = (1000, 10_000, 100_000),
                    divergences: Sequence[float] = (0.001, 0.01, 0.1, 1.0),
                    json_path: Optional[str] = "BENCH_delta_sync.json",
                    reps: int = 3) -> List[str]:
    """One row per (store size, divergent fraction); writes the JSON trace."""
    out, trace = [], []
    warm_cache = B.BucketedSyncMask()
    for n_keys in n_keys_list:
        local = _bulk_store(n_keys)
        for divergence in divergences:
            remote, n_div = _diverge(local, divergence)
            full_payload = remote.payload()

            clones = [local.clone() for _ in range(reps)]
            it = iter(clones)
            full_us = _timed(lambda: next(it).apply_payload(full_payload),
                             reps)

            def delta_round(dst):
                ranked, width, _ = delta_plan(remote, dst.sync_digest())
                payload = remote.payload(key_ranges=ranked,
                                         ranges_width=width)
                dst.apply_payload(payload)
                return payload

            clones_d = [local.clone() for _ in range(reps)]
            it_d = iter(clones_d)
            delta_us = _timed(lambda: delta_round(next(it_d)), reps)

            # wire accounting + convergence sanity on fresh clones
            probe = local.clone()
            delta_payload = delta_round(probe)
            ref = local.clone()
            ref.apply_payload(full_payload)
            assert probe.total_versions() == ref.total_versions(), \
                (probe.total_versions(), ref.total_versions())
            assert len(probe.sync_digest().diff(remote.sync_digest())) == 0

            digest_bytes = (remote.sync_digest().fold(
                min(remote.n_buckets, local.n_buckets)).nbytes()) * 2
            shape = _mask_shape_probe(local, remote)
            uncached_us, warm_us = _jit_cache_cell(shape, reps, warm_cache)

            row = {
                "n_keys": n_keys,
                "divergence": divergence,
                "divergent_keys": n_div,
                "full_round_us": round(full_us, 1),
                "delta_round_us": round(delta_us, 1),
                "speedup_delta_vs_full": round(full_us / max(delta_us, 1e-9),
                                               2),
                "payload_slots_full": len(full_payload),
                "payload_slots_delta": len(delta_payload),
                "payload_bytes_full": full_payload.nbytes(),
                "payload_bytes_delta": delta_payload.nbytes(),
                "digest_bytes": digest_bytes,
                "mask_shape": list(shape),
                "uncached_mask_us": round(uncached_us, 1),
                "warm_mask_us": round(warm_us, 1),
                "speedup_warm_vs_uncached": round(
                    uncached_us / max(warm_us, 1e-9), 2),
            }
            trace.append(row)
            pct = divergence * 100
            out.append(
                f"delta_sync_n{n_keys}_d{pct:g}pct,{delta_us:.0f},"
                f"speedup_vs_full={full_us / max(delta_us, 1e-9):.1f}x;"
                f"bytes={delta_payload.nbytes() + digest_bytes}"
                f"/{full_payload.nbytes()}")
            out.append(
                f"delta_mask_warm_n{n_keys}_d{pct:g}pct,{warm_us:.0f},"
                f"speedup_vs_uncached="
                f"{uncached_us / max(warm_us, 1e-9):.1f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "delta_sync",
                "note": ("CPU wall-times, single core. Delta round = digest "
                         "diff + ranked divergent ranges + sliced apply; "
                         "full round = the PR-1 whole-store array path "
                         "(kept as fallback). warm/uncached = shape-"
                         "bucketed cached sync_mask vs a fresh jit trace "
                         "at the delta round's grouped shape."),
                "bucket_cache": warm_cache.cache_info(),
                "rows": trace}, f, indent=1)
    return out


def rows() -> List[str]:
    """The benchmark-harness hook (kept small; `make bench-delta` sweeps)."""
    return delta_sync_rows((1000, 10_000), (0.01, 1.0), json_path=None,
                           reps=2)
