"""Render the §Roofline markdown table from dryrun_results.json."""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def fmt_s(x: float) -> str:
    return f"{x:.2e}"


def load(path: str = "dryrun_results.json") -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def table(results: List[Dict], mesh: str = "single") -> str:
    rows = []
    header = ("| arch | shape | compute_s | memory_s | collective_s | bound "
              "| useful_ratio | roofline_frac | peak GiB/dev |")
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    for r in results:
        if r.get("mesh") != mesh or "roofline" not in r:
            continue
        rl = r["roofline"]
        mem = r["memory"]
        peak = (mem.get("peak_bytes") or 0) / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} "
            f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
            f"| **{rl['bound']}** | {rl['useful_flops_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.3f} | {peak:.1f} |")
    return "\n".join(rows)


def summarize(results: List[Dict]) -> str:
    """Pick the hillclimb candidates: worst roofline fraction (train),
    most collective-bound, most paper-representative."""
    singles = [r for r in results
               if r.get("mesh") == "single" and "roofline" in r]
    worst = min((r for r in singles if r["shape"] == "train_4k"),
                key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(singles, key=lambda r: (
        r["roofline"]["collective_s"]
        / max(max(r["roofline"]["compute_s"],
                  r["roofline"]["memory_s"]), 1e-30)))
    out = [f"worst-train-roofline: {worst['arch']} × {worst['shape']} "
           f"(frac={worst['roofline']['roofline_fraction']:.3f})",
           f"most-collective-bound: {coll['arch']} × {coll['shape']} "
           f"(coll/max={coll['roofline']['collective_s'] / max(max(coll['roofline']['compute_s'], coll['roofline']['memory_s']), 1e-30):.2f})"]
    return "\n".join(out)


if __name__ == "__main__":
    res = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
    print("## single-pod (16×16 = 256 chips)\n")
    print(table(res, "single"))
    print("\n## multi-pod (2×16×16 = 512 chips)\n")
    print(table(res, "multi"))
    print("\n## hillclimb candidates\n")
    print(summarize(res))
