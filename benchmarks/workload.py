"""Shared workload driver: run identical schedules against a mechanism and
the causal-history oracle, measuring the paper's quality metrics.

Metrics per run:
  * lost_updates      — values the oracle retains (still relevant: not
                        superseded) that the mechanism dropped;
  * false_dominance   — version pairs the mechanism orders that are truly
                        concurrent (plausible-clock linearization, §3.2);
  * siblings_max      — max concurrent versions held per key;
  * metadata_ints     — max integers stored in clocks per key (the paper's
                        space metric, §6/§7).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import ALL_MECHANISMS
from repro.core.kernel import ORACLE_MECHANISM
from repro.store import KVCluster, SimNetwork, Unavailable


@dataclass
class WorkloadConfig:
    n_replicas: int = 3
    n_clients: int = 10
    n_keys: int = 2
    n_ops: int = 200
    seed: int = 0
    p_blind_put: float = 0.2        # PUT without context (new client session)
    p_antientropy: float = 0.05
    p_deliver: float = 0.3
    client_affinity: bool = False   # clients stick to one replica?


@dataclass
class WorkloadResult:
    mechanism: str
    lost_updates: int
    false_dominance: int
    siblings_max: int
    metadata_ints_max: int
    ops: int


def run_workload(mech_name: str, cfg: WorkloadConfig) -> WorkloadResult:
    rng = random.Random(cfg.seed)
    replicas = [f"r{i}" for i in range(cfg.n_replicas)]
    clients = [f"c{i}" for i in range(cfg.n_clients)]
    keys = [f"k{i}" for i in range(cfg.n_keys)]

    mech = ALL_MECHANISMS[mech_name]
    sut = KVCluster(replicas, mech, network=SimNetwork(seed=cfg.seed))
    oracle = KVCluster(replicas, ORACLE_MECHANISM,
                       network=SimNetwork(seed=cfg.seed))

    contexts_s: Dict = {}
    contexts_o: Dict = {}
    counters: Dict[str, int] = {}
    sessions = {c: 0 for c in clients}
    affinity = {c: rng.choice(replicas) for c in clients}
    value_id = 0
    meta_max = 0
    siblings_max = 0

    for _ in range(cfg.n_ops):
        client = rng.choice(clients)
        key = rng.choice(keys)
        node = affinity[client] if cfg.client_affinity else rng.choice(replicas)
        op = rng.random()
        if op < cfg.p_antientropy:
            a, b = rng.sample(replicas, 2)
            try:
                sut.antientropy(a, b)
                oracle.antientropy(a, b)
            except Unavailable:
                pass
        elif op < cfg.p_antientropy + cfg.p_deliver:
            sut.deliver_replication(max_messages=5)
            oracle.deliver_replication(max_messages=5)
        elif op < cfg.p_antientropy + cfg.p_deliver + 0.3:
            try:
                rs = sut.get(key, via=node)
                ro = oracle.get(key, via=node)
                contexts_s[(client, key)] = rs.context
                contexts_o[(client, key)] = ro.context
                siblings_max = max(siblings_max, rs.siblings)
            except Unavailable:
                pass
        else:
            value_id += 1
            blind = rng.random() < cfg.p_blind_put
            if blind:
                # A context-free PUT models a NEW thread of activity (paper
                # §3.3): per-client mechanisms need a fresh entry for it —
                # that is exactly why their metadata grows with the client/
                # session population.
                sessions[client] += 1
            session_id = f"{client}#s{sessions[client]}"
            counters[session_id] = counters.get(session_id, 0) + 1
            cs = frozenset() if blind else contexts_s.get((client, key), frozenset())
            co = frozenset() if blind else contexts_o.get((client, key), frozenset())
            wall = sut.clock_time + 1.0
            try:
                sut.put(key, f"v{value_id}", context=cs, via=node,
                        coordinator=node, client_id=session_id,
                        client_counter=counters[session_id], wall_time=wall)
                oracle.put(key, f"v{value_id}", context=co, via=node,
                           coordinator=node, client_id=session_id,
                           wall_time=wall)
                # Read-your-writes session guarantee: refresh the context
                # through the SAME coordinator (paper §3.3 / §5.4 — DVV
                # contexts must be server-produced downsets; clients never
                # compose individual clocks themselves).
                contexts_s[(client, key)] = sut.get(key, via=node).context
                contexts_o[(client, key)] = oracle.get(key, via=node).context
            except Unavailable:
                pass
        for k in keys:
            meta_max = max(meta_max, max(sut.metadata_size(k).values()))

    # converge fully, then compare
    sut.deliver_replication()
    oracle.deliver_replication()
    for _ in range(2):
        sut.antientropy_round()
        oracle.antientropy_round()

    lost = 0
    false_dom = 0
    for k in keys:
        sut_vals = sut.all_values(k)
        oracle_vals = oracle.all_values(k)
        lost += len(oracle_vals - sut_vals)
        # false dominance: pairs oracle keeps as siblings that the mechanism
        # ordered (and hence discarded one of) — count via surviving sets
        node0 = replicas[0]
        o_clocks = {v.value: v.clock
                    for v in oracle.nodes[node0].versions(k)}
        s_vals = {v.value for v in sut.nodes[node0].versions(k)}
        for val, oc in o_clocks.items():
            for val2, oc2 in o_clocks.items():
                if val < val2 and oc.concurrent(oc2):
                    if (val in s_vals) != (val2 in s_vals):
                        false_dom += 1
    return WorkloadResult(
        mechanism=mech_name, lost_updates=lost, false_dominance=false_dom,
        siblings_max=siblings_max, metadata_ints_max=meta_max,
        ops=cfg.n_ops)
