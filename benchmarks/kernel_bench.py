"""Kernel-layer throughput: batched DVV algebra (pure Python vs jnp vs
Pallas-interpret) and flash-attention/SSD vs their jnp references.

CPU wall-times are indicative only (the container has one core and
interpret-mode executes kernel bodies in Python); the structural win —
one vectorized comparison per key instead of a Python object walk — is
the measurement that transfers to TPU.
"""
from __future__ import annotations

import random
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DVV
from repro.core import batched as B
from repro.core.batched import leq as jnp_leq
from repro.kernels.dvv_ops import dvv_leq


def _clocks(n, universe, seed=0):
    rng = random.Random(seed)
    out = []
    for _ in range(n):
        comps = []
        for r in universe:
            if rng.random() < 0.6:
                m = rng.randint(0, 6)
                if m > 0:
                    comps.append([r, m, 0])
        if comps and rng.random() < 0.7:
            i = rng.randrange(len(comps))
            comps[i][2] = comps[i][1] + rng.randint(1, 3)
        out.append(DVV(tuple(tuple(c) for c in comps if c[1] or c[2])))
    return out


def _time(fn, reps=5) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def rows() -> List[str]:
    out = []
    universe = [f"r{i}" for i in range(4)]
    for n in (1024, 16384):
        xs = _clocks(n, universe, seed=1)
        ys = _clocks(n, universe, seed=2)
        vx, ix, nx = B.encode_batch(xs, universe)
        vy, iy, ny = B.encode_batch(ys, universe)
        args = [jnp.asarray(a) for a in (vx, ix, nx, vy, iy, ny)]

        us_py = _time(lambda: [x.leq(y) for x, y in zip(xs, ys)], reps=3)
        f_jnp = jax.jit(jnp_leq)
        us_jnp = _time(lambda: jax.block_until_ready(f_jnp(*args)))
        us_pl = _time(lambda: jax.block_until_ready(dvv_leq(*args)), reps=2)
        out.append(f"dvv_leq_python_n{n},{us_py:.0f},per_key_ns="
                   f"{us_py * 1000 / n:.0f}")
        out.append(f"dvv_leq_jnp_n{n},{us_jnp:.0f},per_key_ns="
                   f"{us_jnp * 1000 / n:.0f};speedup_vs_py="
                   f"{us_py / max(us_jnp, 1e-9):.1f}x")
        out.append(f"dvv_leq_pallas_interp_n{n},{us_pl:.0f},per_key_ns="
                   f"{us_pl * 1000 / n:.0f}")

    # attention: jnp chunked vs naive (flash-interpret is Python-slow on CPU;
    # report it at a small shape only, for completeness)
    from repro.models.attention import (
        AttnSpec, _attend_chunked, _attend_naive, _group_q,
    )
    rng = np.random.default_rng(0)
    Bn, S, H, KV, D = 2, 1024, 8, 2, 64
    spec = AttnSpec(n_heads=H, n_kv_heads=KV, head_dim=D)
    q = jnp.asarray(rng.normal(size=(Bn, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.bfloat16)
    pos = jnp.arange(S, dtype=jnp.int32)
    qg = _group_q(q, KV)
    f_naive = jax.jit(
        lambda qg, k, v, pos: _attend_naive(qg, k, v, pos, pos, spec))
    f_chunk = jax.jit(
        lambda qg, k, v, pos: _attend_chunked(qg, k, v, pos, pos, spec, 256))
    us_n = _time(lambda: jax.block_until_ready(f_naive(qg, k, v, pos)))
    us_c = _time(lambda: jax.block_until_ready(f_chunk(qg, k, v, pos)))
    out.append(f"attn_naive_s{S},{us_n:.0f},GBpeak~S2")
    out.append(f"attn_chunked_s{S},{us_c:.0f},ratio_vs_naive="
               f"{us_c / max(us_n, 1e-9):.2f}")

    # ssd: jnp chunked scan at a train-ish shape
    from repro.models.ssm import ssd_chunked
    Bn, S, H, P, N = 2, 2048, 8, 64, 64
    xh = jnp.asarray(rng.normal(size=(Bn, S, H, P)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(Bn, S, H)), jnp.bfloat16)
    A = -jnp.asarray(rng.uniform(0.5, 2, size=(H,)), jnp.bfloat16)
    Bc = jnp.asarray(rng.normal(size=(Bn, S, N)), jnp.bfloat16)
    Cc = jnp.asarray(rng.normal(size=(Bn, S, N)), jnp.bfloat16)
    Dp = jnp.asarray(rng.normal(size=(H,)), jnp.bfloat16)
    f_ssd = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
    us_s = _time(lambda: jax.block_until_ready(
        f_ssd(xh, dt, A, Bc, Cc, Dp)))
    out.append(f"ssd_chunked_s{S},{us_s:.0f},tokens_per_s="
               f"{Bn * S / (us_s / 1e6):.0f}")
    return out
