"""Kernel-layer throughput: batched DVV algebra (pure Python vs jnp vs
Pallas-interpret) and flash-attention/SSD vs their jnp references.

CPU wall-times are indicative only (the container has one core and
interpret-mode executes kernel bodies in Python); the structural win —
one vectorized comparison per key instead of a Python object walk — is
the measurement that transfers to TPU.
"""
from __future__ import annotations

import json
import random
import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DVV
from repro.core import batched as B
from repro.core.batched import leq as jnp_leq
from repro.kernels.dvv_ops import dvv_leq, dvv_sync_mask
from repro.store import PackedVersionStore, Version
from repro.store.version import sync_versions


def _clocks(n, universe, seed=0):
    rng = random.Random(seed)
    out = []
    for _ in range(n):
        comps = []
        for r in universe:
            if rng.random() < 0.6:
                m = rng.randint(0, 6)
                if m > 0:
                    comps.append([r, m, 0])
        if comps and rng.random() < 0.7:
            i = rng.randrange(len(comps))
            comps[i][2] = comps[i][1] + rng.randint(1, 3)
        out.append(DVV(tuple(tuple(c) for c in comps if c[1] or c[2])))
    return out


def _time(fn, reps=5) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------
# bulk_sync: one anti-entropy round, object path vs array-resident vs fused
# Pallas kernel.  The object path is the pre-packed-store steady state
# (per-key Python DVV walks); the array paths are what ReplicaNode now runs.
# ---------------------------------------------------------------------------

def _diverged_stores(n_keys: int, n_replicas: int = 8, seed: int = 0
                     ) -> Tuple[PackedVersionStore, PackedVersionStore]:
    """Two packed stores sharing history but with divergent per-key tips."""
    rng = np.random.default_rng(seed)
    universe = [f"r{i}" for i in range(n_replicas)]
    local, remote = PackedVersionStore(), PackedVersionStore()
    for s in (local, remote):
        for r in universe:
            s.intern_replica(r)
    base = rng.integers(0, 5, (n_keys, n_replicas)).astype(np.int32)
    for i in range(n_keys):
        key = f"key{i}"
        d_l = int(rng.integers(0, n_replicas))
        d_r = int(rng.integers(0, n_replicas))
        vv_l = base[i].copy()
        vv_r = base[i].copy()
        kind = i % 3
        if kind == 0:           # remote strictly dominates local
            vv_r = vv_r + 1
            vv_r[d_l] = max(vv_r[d_l], vv_l[d_l] + 2)
        elif kind == 1:         # concurrent siblings survive on both sides
            vv_l[d_l] += 1
            vv_r[d_r] += 1 if d_r != d_l else 0
        # kind == 2: identical history both sides (dup — dedup path)
        if kind == 2:
            vv_r = vv_l.copy()
            d_r = d_l
        local.sync_key(key, vv_l[None, :], np.asarray([d_l], np.int32),
                       np.asarray([int(vv_l[d_l]) + 1], np.int32),
                       [f"L{i}"])
        remote.sync_key(key, vv_r[None, :], np.asarray([d_r], np.int32),
                        np.asarray([int(vv_r[d_r]) + 1
                                    + (2 if kind == 0 else 0)], np.int32),
                        [f"L{i}" if kind == 2 else f"R{i}"])
    return local, remote


def bulk_sync_rows(n_keys_list: Sequence[int] = (1000, 10_000),
                   json_path: str = "BENCH_bulk_sync.json",
                   reps: int = 3) -> List[str]:
    """Benchmark one anti-entropy round at each size; write the JSON trace."""
    out, trace = [], []
    for n_keys in n_keys_list:
        local, remote = _diverged_stores(n_keys)
        payload = remote.payload()

        # object baseline: decode both sides once (setup, untimed), then the
        # per-key Python walk the old ReplicaNode performed every round
        local_obj = {k: local.versions(k) for k in local.keys}
        remote_obj = {k: remote.versions(k) for k in remote.keys}

        def run_object():
            return {k: sync_versions(local_obj.get(k, frozenset()),
                                     remote_obj.get(k, frozenset()))
                    for k in remote_obj}

        def timed(fn, reps=reps):
            fn()  # warmup (jit/pallas compile)
            t0 = time.perf_counter()
            for _ in range(reps):
                fn()
            return (time.perf_counter() - t0) / reps * 1e6

        us_obj = timed(run_object)
        clones = [local.clone() for _ in range(reps + 1)]
        it = iter(clones)
        us_arr = timed(lambda: next(it).apply_payload(payload))
        clones_k = [local.clone() for _ in range(reps + 1)]
        it_k = iter(clones_k)
        us_pal = timed(
            lambda: next(it_k).apply_payload(payload, mask_fn=dvv_sync_mask))

        # sanity: all three paths agree on the surviving version count
        check = local.clone()
        check.apply_payload(payload)
        obj_total = sum(len(v) for v in run_object().values())
        assert check.total_versions() == obj_total, \
            (check.total_versions(), obj_total)

        row = {
            "n_keys": n_keys,
            "object_us": round(us_obj, 1),
            "array_us": round(us_arr, 1),
            "pallas_interpret_us": round(us_pal, 1),
            "speedup_array_vs_object": round(us_obj / max(us_arr, 1e-9), 2),
            "surviving_versions": check.total_versions(),
        }
        trace.append(row)
        out.append(f"bulk_sync_object_n{n_keys},{us_obj:.0f},per_key_ns="
                   f"{us_obj * 1000 / n_keys:.0f}")
        out.append(f"bulk_sync_array_n{n_keys},{us_arr:.0f},speedup_vs_obj="
                   f"{us_obj / max(us_arr, 1e-9):.1f}x")
        out.append(f"bulk_sync_pallas_interp_n{n_keys},{us_pal:.0f},"
                   f"per_key_ns={us_pal * 1000 / n_keys:.0f}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"bench": "bulk_sync",
                       "note": ("CPU wall-times; pallas runs interpret-mode "
                                "(kernel body in Python). The object→array "
                                "speedup is the structural win that "
                                "transfers to TPU."),
                       "rows": trace}, f, indent=1)
    return out


def rows() -> List[str]:
    out = []
    out += bulk_sync_rows()
    universe = [f"r{i}" for i in range(4)]
    for n in (1024, 16384):
        xs = _clocks(n, universe, seed=1)
        ys = _clocks(n, universe, seed=2)
        vx, ix, nx = B.encode_batch(xs, universe)
        vy, iy, ny = B.encode_batch(ys, universe)
        args = [jnp.asarray(a) for a in (vx, ix, nx, vy, iy, ny)]

        us_py = _time(lambda: [x.leq(y) for x, y in zip(xs, ys)], reps=3)
        f_jnp = jax.jit(jnp_leq)
        us_jnp = _time(lambda: jax.block_until_ready(f_jnp(*args)))
        us_pl = _time(lambda: jax.block_until_ready(dvv_leq(*args)), reps=2)
        out.append(f"dvv_leq_python_n{n},{us_py:.0f},per_key_ns="
                   f"{us_py * 1000 / n:.0f}")
        out.append(f"dvv_leq_jnp_n{n},{us_jnp:.0f},per_key_ns="
                   f"{us_jnp * 1000 / n:.0f};speedup_vs_py="
                   f"{us_py / max(us_jnp, 1e-9):.1f}x")
        out.append(f"dvv_leq_pallas_interp_n{n},{us_pl:.0f},per_key_ns="
                   f"{us_pl * 1000 / n:.0f}")

    # attention: jnp chunked vs naive (flash-interpret is Python-slow on CPU;
    # report it at a small shape only, for completeness)
    from repro.models.attention import (
        AttnSpec, _attend_chunked, _attend_naive, _group_q,
    )
    rng = np.random.default_rng(0)
    Bn, S, H, KV, D = 2, 1024, 8, 2, 64
    spec = AttnSpec(n_heads=H, n_kv_heads=KV, head_dim=D)
    q = jnp.asarray(rng.normal(size=(Bn, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.bfloat16)
    pos = jnp.arange(S, dtype=jnp.int32)
    qg = _group_q(q, KV)
    f_naive = jax.jit(
        lambda qg, k, v, pos: _attend_naive(qg, k, v, pos, pos, spec))
    f_chunk = jax.jit(
        lambda qg, k, v, pos: _attend_chunked(qg, k, v, pos, pos, spec, 256))
    us_n = _time(lambda: jax.block_until_ready(f_naive(qg, k, v, pos)))
    us_c = _time(lambda: jax.block_until_ready(f_chunk(qg, k, v, pos)))
    out.append(f"attn_naive_s{S},{us_n:.0f},GBpeak~S2")
    out.append(f"attn_chunked_s{S},{us_c:.0f},ratio_vs_naive="
               f"{us_c / max(us_n, 1e-9):.2f}")

    # ssd: jnp chunked scan at a train-ish shape
    from repro.models.ssm import ssd_chunked
    Bn, S, H, P, N = 2, 2048, 8, 64, 64
    xh = jnp.asarray(rng.normal(size=(Bn, S, H, P)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(Bn, S, H)), jnp.bfloat16)
    A = -jnp.asarray(rng.uniform(0.5, 2, size=(H,)), jnp.bfloat16)
    Bc = jnp.asarray(rng.normal(size=(Bn, S, N)), jnp.bfloat16)
    Cc = jnp.asarray(rng.normal(size=(Bn, S, N)), jnp.bfloat16)
    Dp = jnp.asarray(rng.normal(size=(H,)), jnp.bfloat16)
    f_ssd = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
    us_s = _time(lambda: jax.block_until_ready(
        f_ssd(xh, dt, A, Bc, Cc, Dp)))
    out.append(f"ssd_chunked_s{S},{us_s:.0f},tokens_per_s="
               f"{Bn * S / (us_s / 1e6):.0f}")
    return out
