"""Churn sweep: adaptive gossip vs fixed-cadence gossip under membership churn.

The gossip driver's claim (DESIGN.md §8, after Okapi): anti-entropy cost
should track *observed divergence*, not a fixed cadence.  This sweep runs
one realistic workload — bursty writes (active windows followed by calm
ones) with churn events (partition/heal, fail/recover, join-with-bootstrap,
depart) injected at a configurable rate — twice per churn rate:

  * **fixed**    — ``GossipDriver(adapt=False)``: every node fires at the
    base period with the base fanout/range budget forever (the classic
    fixed-cadence gossip baseline);
  * **adaptive** — the same driver with adaptation on: converged ticks back
    the interval off to a cheap digest heartbeat, divergence snaps it back,
    budget-saturating catch-up doubles the range budget (and widens fanout
    at the cap), then decays.

Both runs see byte-identical schedules (same seed, same writes, same churn
events — churn is driven by an independent rng stream so the two variants
cannot diverge in workload).  Reported per cell: total gossip wire bytes
(digest + payload phases), convergence lag after the workload stops, and
rounds/ticks.  The paper-level claim the JSON captures: **adaptive gossip
moves fewer wire bytes at equal (bounded) convergence time** across the
churn-rate sweep.
"""
from __future__ import annotations

import json
import random
from typing import Dict, List, Optional, Sequence

from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVCluster, SimNetwork, Unavailable,
                         cluster_converged)

N_NODES = 5
N_KEYS = 64
PERIOD = 10.0            # base gossip period (simulated seconds)
CYCLE = 250.0            # write-burst cycle: 80s active, 170s calm
ACTIVE = 80.0
WRITE_EVERY = 4.0        # one put per 4s while active
T_TOTAL = 1500.0
DT = 1.0
CONV_CAP = 2000.0        # quiesce deadline


def _churn_event(c: KVCluster, rng: random.Random, next_id: List[int]) -> str:
    """One membership/fault event, chosen and applied deterministically."""
    nodes = list(c.nodes)
    kind = rng.choice(("partition", "heal", "fail", "recover",
                       "add", "remove"))
    if kind == "partition":
        p = rng.randrange(1, 4)
        g1 = {n for i, n in enumerate(nodes) if (i + p) % 2}
        g2 = set(nodes) - g1
        if g1 and g2:
            c.network.partition(g1, g2)
    elif kind == "heal":
        c.network.heal()
    elif kind == "fail":
        if len(c.network.down) < len(nodes) - 2:
            c.network.fail_node(rng.choice(nodes))
    elif kind == "recover":
        if c.network.down:
            c.network.recover_node(rng.choice(sorted(c.network.down)))
    elif kind == "add":
        if len(c.nodes) < N_NODES + 2:
            c.add_node(f"x{next_id[0]}")
            next_id[0] += 1
    elif kind == "remove":
        if len(c.nodes) > 3:
            c.remove_node(rng.choice(nodes))
    return kind


def churn_cell(churn_per_1k: float, adaptive: bool, seed: int = 0) -> Dict:
    """One (churn rate, scheduler) cell.  ``churn_per_1k`` = expected churn
    events per 1000 simulated seconds."""
    net = SimNetwork(seed=seed)
    c = KVCluster(tuple(f"n{i}" for i in range(N_NODES)), DVV_MECHANISM,
                  network=net, seed=seed)
    driver = GossipDriver(c, period=PERIOD, max_period=8 * PERIOD,
                          adapt=adaptive, seed=seed)
    # independent streams so workload and churn are identical across the
    # fixed/adaptive variants whatever the driver does
    write_rng = random.Random(seed * 7 + 1)
    churn_rng = random.Random(seed * 7 + 2)
    next_id = [0]
    next_write = 0.0
    events = 0
    steps = int(T_TOTAL / DT)
    p_churn = churn_per_1k * DT / 1000.0
    for _ in range(steps):
        driver.run_for(DT)
        in_cycle = net.now % CYCLE
        if in_cycle < ACTIVE and net.now >= next_write:
            next_write = net.now + WRITE_EVERY
            nodes = list(c.nodes)
            node = write_rng.choice(nodes)
            key = f"k{write_rng.randrange(N_KEYS)}"
            try:
                c.put(key, f"v@{net.now:.0f}", via=node, coordinator=node)
            except Unavailable:
                pass
        if churn_rng.random() < p_churn:
            _churn_event(c, churn_rng, next_id)
            events += 1
    # workload over: quiesce and measure convergence lag + wire cost
    net.heal()
    for n in sorted(net.down):
        net.recover_node(n)
    c.deliver_replication()
    t0, wire0 = net.now, driver.wire_bytes()
    while not cluster_converged(c) and net.now - t0 < CONV_CAP:
        driver.run_for(DT)
    conv_time = net.now - t0
    converged = cluster_converged(c)
    # idle tail: the steady-state cost of keeping a converged cluster synced
    idle0 = driver.wire_bytes()
    driver.run_for(500.0)
    return {
        "churn_per_1k": churn_per_1k,
        "scheduler": "adaptive" if adaptive else "fixed",
        "churn_events": events,
        "final_nodes": len(c.nodes),
        "gossip_wire_bytes": driver.wire_bytes(),
        "digest_bytes": driver.digest_bytes,
        "payload_bytes": driver.payload_bytes,
        "catchup_bytes": wire0,
        "idle_bytes_per_100s": round((driver.wire_bytes() - idle0) / 5.0),
        "rounds": driver.rounds,
        "ticks": driver.ticks,
        "convergence_time_s": round(conv_time, 1),
        "converged": bool(converged),
    }


def churn_rows(churn_rates: Sequence[float] = (2.0, 8.0, 20.0),
               json_path: Optional[str] = "BENCH_churn.json",
               seed: int = 0) -> List[str]:
    """One (fixed, adaptive) pair per churn rate; writes the JSON trace."""
    out, trace, pairs = [], [], []
    for rate in churn_rates:
        fixed = churn_cell(rate, adaptive=False, seed=seed)
        adapt = churn_cell(rate, adaptive=True, seed=seed)
        trace += [fixed, adapt]
        saving = fixed["gossip_wire_bytes"] / max(adapt["gossip_wire_bytes"],
                                                 1)
        pairs.append({
            "churn_per_1k": rate,
            "wire_bytes_fixed": fixed["gossip_wire_bytes"],
            "wire_bytes_adaptive": adapt["gossip_wire_bytes"],
            "wire_savings": round(saving, 2),
            "conv_time_fixed_s": fixed["convergence_time_s"],
            "conv_time_adaptive_s": adapt["convergence_time_s"],
            "both_converged": fixed["converged"] and adapt["converged"],
        })
        out.append(
            f"churn_gossip_r{rate:g},{adapt['gossip_wire_bytes']},"
            f"wire_savings_vs_fixed={saving:.2f}x;"
            f"conv={adapt['convergence_time_s']}"
            f"/{fixed['convergence_time_s']}s")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "churn_gossip",
                "note": ("Simulated-time sweep: bursty writes + churn "
                         "events (partition/heal, fail/recover, "
                         "join+bootstrap, depart) at the given rate per "
                         "1000s, identical workload per pair.  wire bytes "
                         "= gossip digest+payload phases over the whole "
                         "run incl. a 500s idle tail; convergence time = "
                         "lag from workload stop to all-replica digest "
                         "equality."),
                "config": {"nodes": N_NODES, "keys": N_KEYS,
                           "period_s": PERIOD, "t_total_s": T_TOTAL},
                "pairs": pairs,
                "rows": trace}, f, indent=1)
    return out


def rows() -> List[str]:
    """Benchmark-harness hook (toy sweep; `make bench-churn` runs the full
    one and writes BENCH_churn.json)."""
    return churn_rows((4.0,), json_path=None)


if __name__ == "__main__":
    print("\n".join(churn_rows()))
