"""Client-API benchmark: batched ``put_many``/``get_many`` vs looped calls.

PR 2 made *bulk* anti-entropy O(divergence); the remaining Python-bound hot
edge was the per-PUT control plane — one ``sync_key`` walk, one replication
payload and R−1 messages per key.  ``put_many`` amortizes all of it: keys
grouped per coordinator run as ONE vectorized store update (grouped encode
→ one ``sync_mask`` sweep → one scatter) and ONE replication payload per
destination replica.

Sweep: for each batch size, time K looped ``KVClient.put`` calls vs one
``put_many`` on identically-seeded fresh clusters (same coordinators, same
wall-times, same minted clocks — conformance is asserted in
tests/test_client_api.py).  Also timed: looped ``get`` vs ``get_many`` on
the zero-decode packed read path, and the wire bytes both write paths
enqueue.  CPU wall-times (single-core container); the structural win —
one grouped kernel dispatch instead of K Python walks — is what transfers.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional, Sequence

from repro.core import DVV_MECHANISM
from repro.store import KVClient, KVCluster, SimNetwork

NODES = ("n0", "n1", "n2")


def _fresh(seed: int = 0) -> KVCluster:
    return KVCluster(NODES, DVV_MECHANISM, network=SimNetwork(seed=seed))


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def client_api_rows(batch_sizes: Sequence[int] = (100, 1000),
                    json_path: Optional[str] = "BENCH_client_api.json",
                    reps: int = 3) -> List[str]:
    """One row per batch size; writes the JSON trace."""
    out, trace = [], []
    for n_keys in batch_sizes:
        keys = [f"key{i}" for i in range(n_keys)]
        items = {k: (f"v-{k}", None) for k in keys}

        looped_us = []
        batched_us = []
        get_loop_us = []
        get_many_us = []
        wire = {}
        for rep in range(reps):
            c1 = _fresh(seed=rep)
            cl1 = KVClient(c1, "bench", via="n0")
            looped_us.append(_timed(
                lambda: [cl1.put(k, v_ctx[0]) for k, v_ctx in items.items()]))
            wire["looped_put_bytes"] = c1.network.bytes_sent

            c2 = _fresh(seed=rep)
            cl2 = KVClient(c2, "bench", via="n0")
            batched_us.append(_timed(lambda: cl2.put_many(items)))
            wire["put_many_bytes"] = c2.network.bytes_sent
            assert (c2.nodes["n0"].total_keys()
                    == c1.nodes["n0"].total_keys() == n_keys)

            get_loop_us.append(_timed(
                lambda: [cl2.get(k, quorum=1) for k in keys]))
            get_many_us.append(_timed(lambda: cl2.get_many(keys, quorum=1)))

        row = {
            "n_keys": n_keys,
            "looped_put_us": round(min(looped_us), 1),
            "put_many_us": round(min(batched_us), 1),
            "speedup_put_many_vs_looped": round(
                min(looped_us) / max(min(batched_us), 1e-9), 2),
            "looped_get_us": round(min(get_loop_us), 1),
            "get_many_us": round(min(get_many_us), 1),
            **wire,
        }
        trace.append(row)
        out.append(
            f"client_put_many_n{n_keys},{row['put_many_us']:.0f},"
            f"speedup_vs_looped={row['speedup_put_many_vs_looped']:.1f}x;"
            f"bytes={row['put_many_bytes']}/{row['looped_put_bytes']}")
        out.append(
            f"client_get_many_n{n_keys},{row['get_many_us']:.0f}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "client_api",
                "note": ("CPU wall-times, single core, min over reps. "
                         "put_many = coordinator-grouped vectorized update "
                         "(one grouped sync_mask + one replication payload "
                         "per destination) vs K looped KVClient.put calls. "
                         "GETs take the packed zero-object-decode read "
                         "path either way."),
                "rows": trace}, f, indent=1)
    return out


def rows() -> List[str]:
    """The benchmark-harness hook (kept small; `make bench-client` sweeps)."""
    return client_api_rows((64,), json_path=None, reps=2)


if __name__ == "__main__":
    print("\n".join(client_api_rows()))
