"""Serving-plane benchmark: coalesced vs per-session plane invocations.

The closed-loop engine (store/serving.py) drives the same zipfian
GET → think → PUT(token) workload twice per operating point — once with
every session op as its own synchronous plane call (``direct``), once
through the ``OpScheduler`` (``coalesced``) — and records what coalescing
buys and what it costs:

* **plane invocations per 1k ops** — the headline: one flush executes as
  one shared read sweep plus a handful of per-coordinator write groups,
  so the coalesced plane count must be ≥5x below direct's 1000/1k (the
  DESIGN.md §11 acceptance bar);
* **bytes per op** — coalesced put groups share per-destination payloads
  and the union read repairs each stale replica once, so wire bytes drop
  too (the workload's read-modify-write gap keeps sibling pressure — and
  with it payload sizes — honest in both modes);
* **p50/p99 op latency in sim ticks** — the queueing delay coalescing
  pays; p99 tracks ``max_delay`` by construction, which is the knob's
  meaning;
* **ops/sec (wall)** — simulator throughput, i.e. the CPU cost of the
  serving plane itself.

Three sections: the session-count sweep (10k → 1M logical sessions), the
flush-policy frontier (``max_delay`` x ``max_batch`` at 1M sessions), and
the §6.4 kernel-path leg reporting cross-flush shape-bucket cache hit
rates (``reset_stats`` before the measured window, ``cache_info`` after).

Run ``make bench-serving`` → ``BENCH_serving.json``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import DVV_MECHANISM
from repro.store import ClosedLoopEngine, KVCluster, SimNetwork

NODES = tuple(f"n{i}" for i in range(5))


def _run_mode(mode: str, sessions: int, steps: int, *, seed: int = 11,
              keys: int = 10_000, zipf_s: float = 0.9,
              concurrency: int = 256, think_time: float = 8.0,
              rmw_time: float = 1.0, max_batch: int = 256,
              max_delay: float = 2.0, use_kernel: bool = False
              ) -> Dict[str, Any]:
    """One engine run on a fresh cluster (5 nodes, replication 3,
    R=W=2, packed DVV store).  Same seed ⇒ both modes draw the same
    key/session/think sequences — the workloads are identical."""
    net = SimNetwork(seed=7, jitter=0.0)
    cluster = KVCluster(NODES, DVV_MECHANISM, replication=3, network=net,
                        read_quorum=2, write_quorum=2, seed=7)
    eng = ClosedLoopEngine(
        cluster, sessions=sessions, keys=keys, zipf_s=zipf_s,
        concurrency=concurrency, think_time=think_time, rmw_time=rmw_time,
        mode=mode, via="n0", seed=seed, read_repair=True,
        use_kernel=use_kernel, max_batch=max_batch, max_delay=max_delay)
    return eng.run(steps)


def _pair_row(section: str, d: Dict[str, Any], c: Dict[str, Any],
              **extra: Any) -> Dict[str, Any]:
    ratio = (d["plane_per_1k_ops"] / c["plane_per_1k_ops"]
             if c["plane_per_1k_ops"] else 0.0)
    row = {
        "section": section,
        "sessions": d["sessions"], "keys": d["keys"],
        "zipf_s": d["zipf_s"], "concurrency": d["concurrency"],
        "ops": d["ops"],
        "direct": {k: d[k] for k in (
            "plane_per_1k_ops", "bytes_per_op", "p50_latency_ticks",
            "p99_latency_ticks", "ops_per_sec_wall", "ops_failed")},
        "coalesced": {k: c[k] for k in (
            "plane_per_1k_ops", "bytes_per_op", "p50_latency_ticks",
            "p99_latency_ticks", "ops_per_sec_wall", "ops_failed")},
        "plane_ratio_direct_over_coalesced": round(ratio, 2),
        "bytes_per_op_saved": round(
            d["bytes_per_op"] - c["bytes_per_op"], 1),
        "scheduler": c.get("scheduler"),
        "codec_coalesced": c.get("codec"),
    }
    row.update(extra)
    return row


# ---------------------------------------------------------------------------
# Section 1: session-count sweep — the headline >=5x claim.
# ---------------------------------------------------------------------------

def session_sweep_rows(sessions_list: Sequence[int], steps: int,
                       trace: list, **wk: Any) -> List[str]:
    out = []
    for sessions in sessions_list:
        d = _run_mode("direct", sessions, steps, **wk)
        c = _run_mode("coalesced", sessions, steps, **wk)
        row = _pair_row("coalescing", d, c)
        trace.append(row)
        out.append(
            f"serving_s{sessions},{c['plane_per_1k_ops']:.0f},"
            f"ratio={row['plane_ratio_direct_over_coalesced']:.1f}x;"
            f"bytes/op={c['bytes_per_op']:.1f}vs{d['bytes_per_op']:.1f};"
            f"p99={c['p99_latency_ticks']:.2f}ticks")
    return out


# ---------------------------------------------------------------------------
# Section 2: flush-policy frontier — latency bought per plane call saved.
# ---------------------------------------------------------------------------

def policy_rows(points: Sequence[Tuple[float, int]], sessions: int,
                steps: int, trace: list, **wk: Any) -> List[str]:
    out = []
    d = _run_mode("direct", sessions, steps, **wk)
    for max_delay, max_batch in points:
        c = _run_mode("coalesced", sessions, steps,
                      max_delay=max_delay, max_batch=max_batch, **wk)
        row = _pair_row("flush_policy", d, c,
                        max_delay=max_delay, max_batch=max_batch)
        trace.append(row)
        out.append(
            f"serving_policy_d{max_delay}_b{max_batch},"
            f"{c['plane_per_1k_ops']:.0f},"
            f"ratio={row['plane_ratio_direct_over_coalesced']:.1f}x;"
            f"p99={c['p99_latency_ticks']:.2f}ticks")
    return out


# ---------------------------------------------------------------------------
# Section 3: kernel-path leg — cross-flush shape-bucket cache hit rates
# (DESIGN.md §6.4: coalesced flushes land in a handful of power-of-two
# buckets, so the compiled-kernel cache goes warm after the first flush).
# ---------------------------------------------------------------------------

def kernel_cache_rows(sessions: int, steps: int, trace: list,
                      **wk: Any) -> List[str]:
    from repro.core.batched import sync_mask_bucketed
    from repro.kernels.dvv_ops.ops import dvv_read_sweep_bucketed, \
        dvv_sync_mask_bucketed
    caches = {"read_sweep": dvv_read_sweep_bucketed,
              "sync_mask_kernel": dvv_sync_mask_bucketed,
              "sync_mask_jnp": sync_mask_bucketed}
    warm = _run_mode("coalesced", sessions, max(steps // 4, 50),
                     use_kernel=True, **wk)      # compile/warm the buckets
    for cache in caches.values():
        cache.reset_stats()
    c = _run_mode("coalesced", sessions, steps, use_kernel=True, **wk)
    info = {name: cache.cache_info() for name, cache in caches.items()}
    row = {
        "section": "kernel_bucket_cache",
        "sessions": sessions, "ops": c["ops"],
        "warmup_ops": warm["ops"],
        "plane_per_1k_ops": c["plane_per_1k_ops"],
        "flushes": c["scheduler"]["flushes"],
        "caches": info,
    }
    trace.append(row)
    used = {n: i for n, i in info.items() if i["hits"] + i["misses"]}
    return [
        "serving_kernel_cache,%d,%s" % (
            c["scheduler"]["flushes"],
            ";".join(f"{n}_hit_rate={i['hit_rate']:.3f}"
                     for n, i in used.items()) or "unused")]


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def serving_rows(sessions_list: Sequence[int] = (10_000, 100_000,
                                                 1_000_000),
                 steps: int = 1500,
                 policy_points: Sequence[Tuple[float, int]] = (
                     (1.0, 128), (2.0, 256), (4.0, 512)),
                 json_path: Optional[str] = "BENCH_serving.json",
                 kernel_leg: bool = True,
                 **wk: Any) -> List[str]:
    out, trace = [], []
    out += session_sweep_rows(sessions_list, steps, trace, **wk)
    out += policy_rows(policy_points, max(sessions_list), steps, trace,
                       **wk)
    if kernel_leg:
        out += kernel_cache_rows(max(sessions_list), steps, trace, **wk)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "serving",
                "note": ("Closed-loop zipfian GET->think->PUT(token) "
                         "workload on the simulated cluster (5 nodes, "
                         "replication 3, R=W=2, packed DVV, read-repair "
                         "on), identical seeds per mode. direct = one "
                         "plane invocation per session op; coalesced = "
                         "OpScheduler flushes (shared read sweep + "
                         "per-coordinator write groups). Latency is "
                         "simulated ticks of queueing delay; ops/sec is "
                         "simulator wall throughput; bytes/op is wire "
                         "bytes over ops. kernel_bucket_cache: "
                         "cross-flush shape-bucket hit rates on the "
                         "use_kernel=True path, stats reset after "
                         "warm-up."),
                "rows": trace}, f, indent=1)
    return out


def rows() -> List[str]:
    """The benchmark-harness smoke hook (`make bench-serving` sweeps)."""
    return serving_rows((2_000,), steps=120, policy_points=((2.0, 64),),
                        json_path=None, keys=500, concurrency=32)


if __name__ == "__main__":
    print("\n".join(serving_rows()))
