"""Geo tier benchmark: what the causal snapshot plane buys over the WAN.

Three claims (DESIGN.md §12), each its own section in ``BENCH_geo.json``:

* **Snapshot read latency** — a causally consistent ``snapshot_get`` is
  served entirely from the proxy's DC, so its modeled round-trip cost is
  LAN-bounded, while a quorum ``get`` wide enough to cross DCs pays the
  WAN.  The simulator executes reads synchronously, so per-op latency is
  *modeled* from the fabric's own link pricing: the proxy fans out to the
  contacted replicas in parallel and waits for the slowest, i.e.
  ``2 x max(link base + draw * jitter)`` over contacted links — the exact
  distribution ``SimNetwork.send`` would stamp on those messages.  The
  headline: snapshot p99 sits orders of magnitude under the cross-DC
  quorum p99 at identical key/replica state, with **zero** WAN messages
  on the snapshot path (asserted, not assumed).
* **Frontier staleness** — what snapshots give up.  With the
  ``WanShipper`` running on simulated time, the west frontier's lag
  behind the shared clock is sampled between write bursts at east; mean
  and max lag track the shipping period (the staleness/cost knob).
* **WAN wire bytes** — async digest-diffed delta shipping vs the naive
  baseline of synchronously replicating every write cross-DC (a plain
  cluster whose replica set spans *all* nodes, same latency classes,
  same read-modify-write workload).  Shipping is a regime trade, and the
  bench reports both sides of the crossover: under write **locality**
  (DC-sticky keys, coarse rounds) delta rounds coalesce overwrites —
  each key crosses the WAN once per round, not once per write — and
  shipped bytes land at a small fraction of the naive fan-out; under
  **uniform** cross-DC writes with fine-grained rounds the fixed digest
  tree per round plus bidirectional receiver-ahead re-ships cost *more*
  than naive, which is the same staleness/cost knob the frontier section
  measures, seen from the wire side.

Run ``make bench-geo`` → ``BENCH_geo.json``; the ``rows()`` hook gives
``benchmarks/run.py`` its toy-size smoke pass.
"""
from __future__ import annotations

import json
import random
from typing import Any, Dict, List, Optional, Sequence

from repro.core import DVV_MECHANISM
from repro.store import KVCluster, SimNetwork, Unavailable

DCS = {"east": ("e0", "e1", "e2"), "west": ("w0", "w1", "w2")}
NODES = tuple(n for ns in DCS.values() for n in ns)
LAN = (1.0, 0.5)
WAN = (40.0, 10.0)


def _geo_cluster(seed: int = 5, wan_period: float = 25.0) -> KVCluster:
    net = SimNetwork(seed=seed)
    net.set_latency_classes(lan=LAN, wan=WAN)
    return KVCluster(NODES, DVV_MECHANISM, network=net, seed=seed,
                     datacenters=DCS, wan_period=wan_period)


def _fanout_latency(net: SimNetwork, proxy: str, members: Sequence[str],
                    rng: random.Random) -> float:
    """Modeled round-trip for one fanned-out read: contact every member in
    parallel, wait for the slowest reply (2x the one-way draw, the same
    ``base + draw * jitter`` pricing ``send`` uses; the proxy's local read
    is free)."""
    worst = 0.0
    for r in members:
        if r == proxy:
            continue
        base, jit = net._link_params(proxy, r)
        worst = max(worst, base + rng.random() * jit)
    return 2.0 * worst


def _pct(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return 0.0
    ix = min(len(sorted_vals) - 1, int(p * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[ix]


def snapshot_latency_point(n_ops: int = 400, *, n_keys: int = 64,
                           seed: int = 5) -> Dict[str, Any]:
    """Snapshot vs cross-DC quorum read at identical state: same proxy,
    same keys, per-op modeled latency distributions + WAN message meter."""
    c = _geo_cluster(seed=seed)
    rng = random.Random(seed)
    for i in range(n_keys):
        c.put(f"k{i}", f"v{i}", via=NODES[i % len(NODES)])
    c.deliver_replication()
    c.geo.wan_round()
    quorum = c.geo.dc_size + 1            # forces >= 1 cross-DC contact
    snap_lat: List[float] = []
    quorum_lat: List[float] = []
    wan0 = c.network.wan_messages
    for i in range(n_ops):
        key = f"k{rng.randrange(n_keys)}"
        proxy = DCS["west"][i % len(DCS["west"])]
        members = c.geo.snapshot_members("west", key)
        c.snapshot_get(key, via=proxy)
        snap_lat.append(_fanout_latency(c.network, proxy, members, rng))
    assert c.network.wan_messages == wan0, "snapshot path touched the WAN"
    for i in range(n_ops):
        key = f"k{rng.randrange(n_keys)}"
        proxy = DCS["west"][i % len(DCS["west"])]
        chosen = c._reachable_replicas(proxy, key)[:quorum]
        c.get(key, via=proxy, quorum=quorum)
        quorum_lat.append(_fanout_latency(c.network, proxy, chosen, rng))
    snap_lat.sort()
    quorum_lat.sort()
    return {
        "section": "snapshot_latency",
        "ops": n_ops, "keys": n_keys, "quorum": quorum,
        "lan": LAN, "wan": WAN,
        "snapshot": {"p50": round(_pct(snap_lat, 0.5), 2),
                     "p99": round(_pct(snap_lat, 0.99), 2)},
        "cross_dc_quorum": {"p50": round(_pct(quorum_lat, 0.5), 2),
                            "p99": round(_pct(quorum_lat, 0.99), 2)},
        "p99_ratio": round(_pct(quorum_lat, 0.99)
                           / max(_pct(snap_lat, 0.99), 1e-9), 1),
        "snapshot_wan_messages": 0,
    }


def frontier_staleness_point(wan_period: float, *, bursts: int = 20,
                             burst_writes: int = 5, gap: float = 60.0,
                             seed: int = 9) -> Dict[str, Any]:
    """Write bursts at east with the WanShipper free-running on simulated
    time; sample west's frontier lag after every burst and mid-gap."""
    c = _geo_cluster(seed=seed, wan_period=wan_period)
    rng = random.Random(seed)
    lags: List[float] = []
    for b in range(bursts):
        for i in range(burst_writes):
            try:
                c.put(f"k{rng.randrange(16)}", f"b{b}.{i}",
                      via=DCS["east"][i % 3])
            except Unavailable:          # pragma: no cover - no faults here
                pass
        lags.append(c.geo.frontier_lag("west"))
        c.network.advance(gap / 2.0)
        lags.append(c.geo.frontier_lag("west"))
        c.network.advance(gap / 2.0)
    lags.sort()
    return {
        "section": "frontier_staleness",
        "wan_period": wan_period, "bursts": bursts,
        "writes": bursts * burst_writes,
        "lag_mean": round(sum(lags) / len(lags), 2),
        "lag_p50": round(_pct(lags, 0.5), 2),
        "lag_max": round(lags[-1], 2),
        "wan_ticks": c.geo.shipper.ticks,
    }


def wan_bytes_point(regime: str, *, n_writes: int = 900, n_keys: int = 8,
                    round_every: int = 300, value_pad: int = 256,
                    seed: int = 13) -> Dict[str, Any]:
    """Async delta shipping vs naive synchronous cross-DC replication.

    Both clusters run the same read-modify-write workload (get, then put
    with the returned context — so overwrites supersede instead of piling
    up siblings).  The naive baseline replicates every write to all six
    nodes synchronously, so each put mails ~3 cross-DC payloads; the geo
    cluster commits locally and lets hand-cranked digest-diffed mirror
    rounds carry the deltas (the WanShipper is stopped for an exact
    meter).  ``regime`` picks the workload shape:

    * ``"hot"`` — DC-sticky key ownership (each key written only from its
      home DC, the geo-partitioned pattern geo tiers are built for) with
      coarse rounds: overwrites between rounds coalesce to one shipped
      version per key per round.
    * ``"uniform"`` — every write from a random node in either DC with
      fine-grained rounds: little coalescing, and each direction re-ships
      receiver-ahead ranges, so the fixed digest tree per round puts geo
      *above* naive.  Reported deliberately: it bounds where async
      shipping pays.
    """
    if regime == "uniform":
        n_keys, round_every, value_pad = 2 * n_keys, round_every // 3, 160

    def workload(c: KVCluster) -> None:
        rng = random.Random(seed)
        pad = "x" * value_pad
        for i in range(n_writes):
            k = rng.randrange(n_keys)
            if regime == "hot":
                home = "east" if k % 2 == 0 else "west"
                via = DCS[home][rng.randrange(len(DCS[home]))]
            else:
                via = NODES[rng.randrange(len(NODES))]
            r = c.get(f"k{k}", via=via)
            c.put(f"k{k}", f"v{i}.{pad}", r.context, via=via)
            if i % round_every == round_every - 1:
                c.deliver_replication()
                if c.geo is not None:
                    c.geo.wan_round()
        c.deliver_replication()
        if c.geo is not None:
            for _ in range(2):
                c.geo.wan_round()

    geo = _geo_cluster(seed=seed)
    geo.geo.shipper.stop()               # hand-cranked rounds: exact meter
    workload(geo)

    naive_net = SimNetwork(seed=seed)
    naive_net.set_latency_classes(lan=LAN, wan=WAN)
    for dc, ns in DCS.items():
        for n in ns:
            naive_net.set_datacenter(n, dc)
    naive = KVCluster(NODES, DVV_MECHANISM, network=naive_net, seed=seed,
                      replication=len(NODES))
    workload(naive)

    geo_wan = geo.geo.ship_bytes + geo.network.wan_bytes
    return {
        "section": "wan_bytes",
        "regime": regime,
        "writes": n_writes, "keys": n_keys, "round_every": round_every,
        "value_bytes": value_pad,
        "geo_ship_bytes": geo.geo.ship_bytes,
        "geo_digest_bytes": geo.geo.ship_digest_bytes,
        "geo_payload_bytes": geo.geo.ship_payload_bytes,
        "geo_payload_slots": geo.geo.ship_payload_slots,
        "geo_ship_rounds": geo.geo.wan_rounds,
        "geo_wan_send_bytes": geo.network.wan_bytes,
        "naive_wan_bytes": naive_net.wan_bytes,
        "naive_wan_messages": naive_net.wan_messages,
        "savings": round(naive_net.wan_bytes / max(geo_wan, 1), 2),
    }


def geo_rows(*, n_ops: int = 400, n_writes: int = 900,
             wan_periods: Sequence[float] = (10.0, 25.0, 50.0),
             json_path: Optional[str] = "BENCH_geo.json") -> List[str]:
    cells: List[Dict[str, Any]] = [snapshot_latency_point(n_ops)]
    cells += [frontier_staleness_point(p) for p in wan_periods]
    cells += [wan_bytes_point("hot", n_writes=n_writes),
              wan_bytes_point("uniform", n_writes=n_writes)]
    out: List[str] = []
    for cell in cells:
        if cell["section"] == "snapshot_latency":
            out.append(
                f"geo_snapshot_read,{cell['snapshot']['p99']},"
                f"p99_vs_crossdc={cell['cross_dc_quorum']['p99']}"
                f";ratio={cell['p99_ratio']}x;wan_msgs=0")
        elif cell["section"] == "frontier_staleness":
            out.append(
                f"geo_frontier_p{cell['wan_period']:g},{cell['lag_p50']},"
                f"lag_mean={cell['lag_mean']};lag_max={cell['lag_max']}")
        else:
            out.append(
                f"geo_wan_bytes_{cell['regime']},{cell['geo_ship_bytes']},"
                f"naive={cell['naive_wan_bytes']}"
                f";savings={cell['savings']}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"description":
                       "geo tier: snapshot latency vs cross-DC quorum, "
                       "frontier staleness vs shipping period, async "
                       "delta WAN bytes vs naive sync replication "
                       "(hot + uniform regimes)",
                       "rows": cells}, f, indent=1)
    return out


def rows() -> List[str]:
    """The benchmark-harness smoke hook (toy sizes, no JSON)."""
    return geo_rows(n_ops=60, n_writes=120, wan_periods=(25.0,),
                    json_path=None)


if __name__ == "__main__":
    print("\n".join(geo_rows()))
