"""The paper's central scalability claim (§6/§7): DVV metadata is
O(#replica-nodes); per-client vectors are O(#clients); causal histories
are O(#updates).  Same seeded workload, swept along each axis.
"""
from __future__ import annotations

import time
from typing import List

from .workload import WorkloadConfig, run_workload

MECHS = ("dvv", "vv_client", "oracle", "vv_server")


def sweep_clients() -> List[str]:
    rows = []
    for n_clients in (5, 20, 80):
        for mech in MECHS:
            cfg = WorkloadConfig(n_replicas=3, n_clients=n_clients,
                                 n_keys=1, n_ops=60 + 4 * n_clients, seed=42)
            t0 = time.perf_counter()
            res = run_workload(mech, cfg)
            us = (time.perf_counter() - t0) * 1e6 / cfg.n_ops
            rows.append(
                f"scale_clients_{mech}_c{n_clients},{us:.1f},"
                f"metaInts={res.metadata_ints_max};lost={res.lost_updates};"
                f"falseDom={res.false_dominance}")
    return rows


def sweep_replicas() -> List[str]:
    rows = []
    for n_replicas in (2, 4, 8):
        for mech in MECHS:
            cfg = WorkloadConfig(n_replicas=n_replicas, n_clients=20,
                                 n_keys=1, n_ops=150, seed=43)
            t0 = time.perf_counter()
            res = run_workload(mech, cfg)
            us = (time.perf_counter() - t0) * 1e6 / cfg.n_ops
            rows.append(
                f"scale_replicas_{mech}_r{n_replicas},{us:.1f},"
                f"metaInts={res.metadata_ints_max};lost={res.lost_updates}")
    return rows


def sweep_updates() -> List[str]:
    rows = []
    for n_ops in (100, 400, 1600):
        for mech in ("dvv", "oracle"):
            cfg = WorkloadConfig(n_replicas=3, n_clients=10, n_keys=1,
                                 n_ops=n_ops, seed=44)
            t0 = time.perf_counter()
            res = run_workload(mech, cfg)
            us = (time.perf_counter() - t0) * 1e6 / n_ops
            rows.append(
                f"scale_updates_{mech}_n{n_ops},{us:.1f},"
                f"metaInts={res.metadata_ints_max}")
    return rows


def rows() -> List[str]:
    return sweep_clients() + sweep_replicas() + sweep_updates()
