"""Fault benchmarks: what self-driving membership buys (DESIGN.md §13).

Two lanes, both on simulated time:

* **Detection / unavailability** — a 5-node cluster with ``replication=3,
  write_quorum=3`` loses one node mid-workload.  Three operating modes:
  ``none`` (nobody removes it — every write whose replica set contains the
  corpse fails its quorum forever), ``manual`` (an operator oracle calls
  ``remove_node`` the instant the node dies — the hand-managed best case)
  and ``auto`` (the ``MembershipController`` evicts when accrual suspicion
  crosses the dead threshold).  Reported: detection latency (eviction time
  minus crash time) and the unavailability window (failed writes during
  the post-crash interval).  The claim: auto lands within a bounded
  ``dead_threshold × period`` of the oracle, and both are a step change
  from ``none``.

* **Flapping wire cost** — one node's links to every peer flap (down
  phases long enough for suspicion to engage), with and without the
  controller attached.  With suspicion-driven backoff the driver skips
  suspects in regular rotation/wakes and aims one capped probe round per
  tick instead, so redundant catch-up payload shipped to a peer that is
  about to vanish again shrinks; the digest phase (cheap, fixed-size) is
  unaffected.  Reported: total wire and payload-phase bytes for both
  variants, plus convergence after the flaps stop.
"""
from __future__ import annotations

import json
import random
from typing import Dict, List, Optional

from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVCluster, MembershipController,
                         SimNetwork, Unavailable, cluster_converged)

PERIOD = 10.0
T_FAIL = 200.0
T_END = 900.0
WRITE_EVERY = 2.0
N_KEYS = 24


def detection_cell(mode: str, seed: int = 0) -> Dict:
    """One (mode,) cell of the detection/unavailability lane.  ``mode`` is
    ``none`` | ``manual`` | ``auto``."""
    ids = tuple(f"n{i}" for i in range(5))
    victim = "n2"
    net = SimNetwork(seed=seed)
    c = KVCluster(ids, DVV_MECHANISM, network=net, seed=seed,
                  replication=3, write_quorum=3)
    driver = GossipDriver(c, period=PERIOD, seed=seed)
    mem = MembershipController(c, period=PERIOD, seed=seed, readmit=False) \
        if mode == "auto" else None
    wrng = random.Random(seed * 13 + 7)
    ok = failed = 0
    failed_after = 0
    evicted_at: Optional[float] = None
    crashed = False
    while net.now < T_END:
        driver.run_for(WRITE_EVERY)
        if not crashed and net.now >= T_FAIL:
            crashed = True
            net.fail_node(victim)
            if mode == "manual":            # the operator oracle
                c.remove_node(victim, handoff=True)
                evicted_at = net.now
        if mode == "auto" and evicted_at is None and victim not in c.nodes:
            evicted_at = mem._evicted[victim]
        live = [n for n in c.nodes if n not in net.down]
        node = live[wrng.randrange(len(live))]
        try:
            c.put(f"k{wrng.randrange(N_KEYS)}", f"v@{net.now:.0f}",
                  via=node)
            ok += 1
        except Unavailable:
            failed += 1
            if crashed:
                failed_after += 1
    window = T_END - T_FAIL
    return {
        "mode": mode,
        "ops_ok": ok,
        "ops_failed": failed,
        "failed_after_crash": failed_after,
        "unavailable_frac_after_crash": round(
            failed_after / max((ok + failed) * window / T_END, 1), 3),
        "detection_latency_s": (round(evicted_at - T_FAIL, 1)
                                if evicted_at is not None else None),
        "victim_evicted": victim not in c.nodes,
        "queued_to_victim": net.queued_for(victim),
    }


def flapping_cell(backoff: bool, seed: int = 4) -> Dict:
    """One (backoff,) cell of the flapping lane: same seed, same flap
    schedule, same writes — the only difference is whether a controller
    (suspicion source) is attached."""
    ids = ("a", "b", "c", "d", "e")
    flappy = "e"
    net = SimNetwork(seed=seed)
    c = KVCluster(ids, DVV_MECHANISM, network=net, seed=seed)
    driver = GossipDriver(c, period=PERIOD, seed=seed)
    if backoff:
        # dead_threshold out of reach: pure suspicion steering, no evictions
        MembershipController(c, period=PERIOD, seed=seed, dead_threshold=1e9)
    for peer in ids[:-1]:
        # down phases outlast 3x the clamped expected interval, so the
        # accrual detector actually marks the flapper suspect each cycle
        net.flap_link(flappy, peer, up_for=25.0, down_for=150.0)
    wrng = random.Random(99)
    t = 0.0
    while t < 3000.0:
        driver.run_for(5.0)
        t += 5.0
        node = ids[wrng.randrange(len(ids) - 1)]
        try:
            c.put(f"k{wrng.randrange(N_KEYS)}", f"v{t}", via=node,
                  coordinator=node)
        except Unavailable:
            pass
    net.stop_flaps()
    driver.run_for(400.0)
    c.deliver_replication()
    for _ in range(5):
        c.delta_antientropy_round()
    return {
        "backoff": backoff,
        "wire_bytes": driver.wire_bytes(),
        "payload_bytes": driver.payload_bytes,
        "digest_bytes": driver.digest_bytes,
        "rounds": driver.rounds,
        "suspect_probes": driver.suspect_probes,
        "converged": bool(cluster_converged(c)),
    }


def faults_rows(json_path: Optional[str] = "BENCH_faults.json",
                seed: int = 0) -> List[str]:
    out, det, flap = [], [], []
    for mode in ("none", "manual", "auto"):
        det.append(detection_cell(mode, seed=seed))
    off = flapping_cell(backoff=False)
    on = flapping_cell(backoff=True)
    flap = [off, on]
    auto = next(r for r in det if r["mode"] == "auto")
    none = next(r for r in det if r["mode"] == "none")
    manual = next(r for r in det if r["mode"] == "manual")
    wire_ratio = off["wire_bytes"] / max(on["wire_bytes"], 1)
    payload_ratio = off["payload_bytes"] / max(on["payload_bytes"], 1)
    out.append(
        f"faults_detect_auto,{auto['detection_latency_s']},"
        f"failed_after={auto['failed_after_crash']}"
        f"/manual={manual['failed_after_crash']}"
        f"/none={none['failed_after_crash']}")
    out.append(
        f"faults_flap_backoff,{on['wire_bytes']},"
        f"payload_savings={payload_ratio:.2f}x;"
        f"wire_savings={wire_ratio:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "faults",
                "note": ("Detection lane: 5 nodes, replication=3, "
                         "write_quorum=3, one crash at t=200s of a 900s "
                         "run, writes every 2s; unavailability = quorum "
                         "failures after the crash.  auto = accrual "
                         "controller (dead at 8 x 10s intervals), manual "
                         "= operator removes at crash instant, none = no "
                         "removal.  Flapping lane: one node's links flap "
                         "25s up / 150s down for 3000s under writes; "
                         "backoff = suspicion steering (suspects leave "
                         "the gossip rotation, one capped probe round "
                         "instead).  payload_bytes is the redundant-"
                         "catch-up metric; the digest phase is flat."),
                "config": {"period_s": PERIOD, "t_fail_s": T_FAIL,
                           "t_end_s": T_END, "keys": N_KEYS},
                "detection": det,
                "flapping": flap,
                "summary": {
                    "auto_detection_latency_s": auto["detection_latency_s"],
                    "failed_writes_none": none["failed_after_crash"],
                    "failed_writes_manual": manual["failed_after_crash"],
                    "failed_writes_auto": auto["failed_after_crash"],
                    "flap_wire_savings": round(wire_ratio, 3),
                    "flap_payload_savings": round(payload_ratio, 3),
                }}, f, indent=1)
    return out


def rows() -> List[str]:
    """Benchmark-harness hook (`make bench-faults` writes the JSON)."""
    return faults_rows(json_path=None)


if __name__ == "__main__":
    print("\n".join(faults_rows()))
