"""Sharded-store benchmark: placement cost, rebalance bytes, gossip planes.

Three claims from DESIGN.md §10, each measured:

* **Placement** — one blake2b-8 hash + one table index (the vnode ring is
  consulted O(shards) times per *membership change*, never per key) vs
  the retired per-key md5 full-sort (N md5 digests + an O(N log N) sort
  per key, memoised in an unbounded per-key dict).  The md5 leg is timed
  on a key subsample and reported as ns/key — at 1M keys the sort path
  also held 1M cache entries, which is exactly the bound we removed.
* **Rebalance** — bytes a joiner pulls under shard-filtered bootstrap
  (only the shards it now owns travel) vs the bytes of one full copy of
  the key space: the ratio must track replication/(N+1), not 1.0.
* **Gossip planes** — a converged anti-entropy round at S=64 (64 root
  probes, 32 B each) vs S=1 (one digest fold + diff) at 10k keys: the
  sharded heartbeat must not be slower, and a single hot shard's delta
  round must touch only that shard's tree.

Run ``make bench-shard`` → ``BENCH_sharding.json``.
"""
from __future__ import annotations

import gc
import hashlib
import json
import time
from collections import defaultdict, deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import DVV_MECHANISM
from repro.store import KVCluster, SimNetwork
from repro.store.packed import PackedPayload
from repro.store.sharding import shard_of_key, shard_point


def _timed(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6          # µs


def _timed_per_key(fn, keys: Sequence[str], reps: int) -> float:
    """ns/key for ``fn(key)`` swept over ``keys``, results discarded at C
    speed (deque maxlen=0) with the GC paused — measures placement, not
    the allocator churn of holding a million result lists."""
    consume = deque(maxlen=0)
    gc_was = gc.isenabled()
    gc.disable()
    try:
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            consume.extend(map(fn, keys))
            best = min(best, time.perf_counter() - t0)
    finally:
        if gc_was:
            gc.enable()
    return best * 1e9 / len(keys)


def _md5_sort_place(nodes: Sequence[str], key: str, n: int) -> List[str]:
    """The retired placement path, verbatim: N md5 digests + a full sort
    per key (plus, in the old cluster, one cache entry per key forever)."""
    ring = sorted(
        nodes, key=lambda nd: hashlib.md5(f"{nd}:{key}".encode()).hexdigest())
    return ring[:n]


def _shard_payloads(keys: Sequence[str], shards: int
                    ) -> Dict[int, PackedPayload]:
    """One synthetic single-writer payload per shard (delta_bench's bulk
    trick, split by shard) — replaying a shard's payload at every replica
    that owns it populates a cluster converged, in milliseconds."""
    by_shard = defaultdict(list)
    for k in keys:
        by_shard[shard_of_key(k, shards)].append(k)
    out = {}
    for s, ks in by_shard.items():
        m = len(ks)
        out[s] = PackedPayload(
            ("w",), tuple(ks), np.zeros((m, 1), np.int32),
            np.zeros(m, np.int32), np.ones(m, np.int32),
            np.arange(m, dtype=np.int32),
            tuple(f"v{j}" for j in range(m)))
    return out


def _populated_cluster(n_nodes: int, replication: int, shards: int,
                       keys: Sequence[str], seed: int = 0) -> KVCluster:
    c = KVCluster([f"n{i}" for i in range(n_nodes)], DVV_MECHANISM,
                  replication=replication, packed=True,
                  network=SimNetwork(seed=seed), seed=seed, shards=shards)
    payloads = _shard_payloads(keys, shards)
    for node_id, node in c.nodes.items():
        owned = c._owned.get(node_id) if shards > 1 else None
        for s, p in payloads.items():
            if owned is None or s in owned:
                node.shard_stores[s if shards > 1 else 0].apply_payload(p)
    return c


# ---------------------------------------------------------------------------
# Section 1: placement ns/key, ring vs md5 full-sort.
# ---------------------------------------------------------------------------

def placement_rows(n_keys_list: Sequence[int], shards: int, trace: list,
                   n_nodes: int = 16, replication: int = 3,
                   md5_sample: int = 50_000) -> List[str]:
    out = []
    nodes = [f"n{i}" for i in range(n_nodes)]
    c = KVCluster(nodes, DVV_MECHANISM, replication=replication,
                  packed=True, network=SimNetwork(seed=0), shards=shards)
    for n_keys in n_keys_list:
        keys = [f"key:{i}" for i in range(n_keys)]
        ring_ns = _timed_per_key(c.replicas_for, keys, reps=2)
        sample = keys[:min(md5_sample, n_keys)]
        md5_ns = _timed_per_key(
            lambda k: _md5_sort_place(nodes, k, replication), sample, reps=1)
        ring_us = ring_ns * n_keys / 1e3
        # correctness side-car: the table serves exactly the ring's answer
        # at each key's shard point (placement is shard-granular by design)
        probe = keys[:: max(1, n_keys // 257)]
        assert all(
            tuple(c.replicas_for(k)) == c._ring.replicas_for_hash(
                shard_point(shard_of_key(k, shards), shards), replication)
            for k in probe)
        row = {
            "section": "placement", "n_keys": n_keys, "shards": shards,
            "n_nodes": n_nodes, "replication": replication,
            "ring_ns_per_key": round(ring_ns, 1),
            "md5_sort_ns_per_key": round(md5_ns, 1),
            "md5_sample_keys": len(sample),
            "speedup_ring_vs_md5": round(md5_ns / max(ring_ns, 1e-9), 2),
            "placement_table_entries": len(c._placement),
        }
        trace.append(row)
        out.append(f"shard_place_n{n_keys}_s{shards},{ring_us:.0f},"
                   f"ns_per_key={ring_ns:.0f};"
                   f"speedup_vs_md5={row['speedup_ring_vs_md5']:.1f}x")
    return out


# ---------------------------------------------------------------------------
# Section 2: rebalance bytes on join — the K/N claim.
# ---------------------------------------------------------------------------

def rebalance_rows(n_keys_list: Sequence[int], trace: list,
                   n_nodes: int = 8, replication: int = 3,
                   shards: int = 64) -> List[str]:
    out = []
    for n_keys in n_keys_list:
        keys = [f"key:{i}" for i in range(n_keys)]
        c = _populated_cluster(n_nodes, replication, shards, keys)
        one_copy = sum(p.nbytes()
                       for p in _shard_payloads(keys, shards).values())
        t0 = time.perf_counter()
        stats = c.add_node(f"n{n_nodes}")
        join_us = (time.perf_counter() - t0) * 1e6
        payload = sum(s.payload_bytes for s in stats)
        digest = sum(s.digest_bytes for s in stats)
        pulled = sum(len(st.keys)
                     for st in c.nodes[f"n{n_nodes}"].shard_stores)
        share = payload / one_copy
        expect = replication / (n_nodes + 1)
        row = {
            "section": "rebalance", "n_keys": n_keys, "shards": shards,
            "n_nodes": n_nodes, "replication": replication,
            "join_us": round(join_us, 1),
            "moved_payload_bytes": payload,
            "digest_probe_bytes": digest,
            "one_copy_bytes": one_copy,
            "payload_share_of_copy": round(share, 4),
            "expected_share": round(expect, 4),
            "joiner_keys": pulled,
            "joiner_key_share": round(pulled / n_keys, 4),
        }
        trace.append(row)
        out.append(f"shard_rebalance_n{n_keys}_s{shards},{join_us:.0f},"
                   f"moved={payload}B+{digest}B_digest;share={share:.3f};"
                   f"key_share={pulled / n_keys:.3f};expect~{expect:.3f}")
    return out


# ---------------------------------------------------------------------------
# Section 3: gossip planes — converged & hot-shard rounds, S=64 vs S=1.
# ---------------------------------------------------------------------------

def gossip_rows(n_keys: int, trace: list, reps: int = 3) -> List[str]:
    out = []
    keys = [f"key:{i}" for i in range(n_keys)]
    cells = {}
    for shards in (1, 64):
        c = _populated_cluster(3, 3, shards, keys, seed=1)
        conv_us = _timed(lambda: c.delta_antientropy("n0", "n1"), reps)
        st0 = c.delta_antientropy("n0", "n1")
        # heat ONE shard at n0: bump 32 keys of one shard past n1's state
        hot = [k for k in keys
               if shard_of_key(k, max(shards, 64)) == 7][:32]
        empty = np.zeros(0, np.int32)
        for k in hot:
            c.nodes["n0"].store_for(k).update_key(k, empty, "n0", "hot")
        hot_us = _timed(lambda: c.delta_antientropy("n0", "n1"), 1)
        st1 = c.delta_antientropy("n0", "n1")     # now converged again
        cells[shards] = (conv_us, hot_us, st0, st1)
        row = {
            "section": "gossip", "n_keys": n_keys, "shards": shards,
            "converged_round_us": round(conv_us, 1),
            "converged_digest_bytes": st0.digest_bytes,
            "hot_shard_round_us": round(hot_us, 1),
            "hot_keys": len(hot),
        }
        trace.append(row)
        out.append(f"shard_gossip_n{n_keys}_s{shards},{conv_us:.0f},"
                   f"digest_bytes={st0.digest_bytes};"
                   f"hot_round_us={hot_us:.0f}")
    s1, s64 = cells[1], cells[64]
    trace.append({
        "section": "gossip_summary", "n_keys": n_keys,
        "converged_s64_vs_s1": round(s64[0] / max(s1[0], 1e-9), 3),
        "hot_s64_vs_s1": round(s64[1] / max(s1[1], 1e-9), 3),
    })
    return out


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def shard_rows(n_keys_list: Sequence[int] = (10_000, 100_000, 1_000_000),
               shards_list: Sequence[int] = (64, 256),
               json_path: Optional[str] = "BENCH_sharding.json"
               ) -> List[str]:
    out, trace = [], []
    for shards in shards_list:
        out += placement_rows(n_keys_list, shards, trace)
    out += rebalance_rows([n for n in n_keys_list if n <= 100_000], trace)
    out += gossip_rows(10_000, trace)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "sharding",
                "note": ("CPU wall-times, single core, min over reps. "
                         "placement: table-served vnode-ring lookup "
                         "(blake2b-8 hash + index; ring bisect only on "
                         "membership change) vs the retired per-key md5 "
                         "full-sort, ns/key (md5 leg timed on a key "
                         "subsample). rebalance: shard-filtered join "
                         "bootstrap bytes vs one full key-space copy — "
                         "share should track replication/(N+1). gossip: "
                         "converged and one-hot-shard delta rounds, 64 "
                         "shard planes vs one whole-store plane."),
                "rows": trace}, f, indent=1)
    return out


def rows() -> List[str]:
    """The benchmark-harness hook (kept small; `make bench-shard` sweeps)."""
    return shard_rows((10_000,), (64,), json_path=None)


if __name__ == "__main__":
    print("\n".join(shard_rows()))
