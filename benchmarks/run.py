"""Benchmark harness — one section per paper figure/claim.

  fig_run_*        — the canonical 3-client/2-replica run (paper Figs
                     1/2/3/4/7) per causality mechanism
  scale_*          — metadata growth along clients/replicas/updates
                     (the §6/§7 scalability claim)
  dvv_leq_* etc.   — kernel-layer throughput (TPU-adaptation layer)

Prints ``name,us_per_call,derived`` CSV.  Exits non-zero if any mechanism
deviates from the paper's qualitative outcome.
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import churn_bench, client_bench, delta_bench, kernel_bench, \
        paper_figures, read_bench, scalability

    rows = []
    rows += paper_figures.rows()
    rows += scalability.rows()
    rows += kernel_bench.rows()
    rows += delta_bench.rows()
    rows += client_bench.rows()
    rows += churn_bench.rows()
    rows += read_bench.rows()

    print("name,us_per_call,derived")
    for r in rows:
        print(r)

    failures = paper_figures.check_paper_claims()
    if failures:
        print("\nPAPER-CLAIM FAILURES:", file=sys.stderr)
        for f in failures:
            print("  " + f, file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
