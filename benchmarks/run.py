"""Benchmark harness — every bench target's smoke pass, one command.

``make bench`` runs each benchmark module's ``rows()`` hook (the same
toy-size smoke pass ``make bench-smoke`` exercises piecemeal), prints the
combined ``name,us_per_call,derived`` CSV, and merges each module's smoke
rows into its existing ``BENCH_*.json`` under a ``smoke`` key — replaced
wholesale on every run, so the full-sweep ``rows`` written by the
dedicated ``bench-<name>`` targets stay untouched and the file stays
bounded.  Sections:

  fig_run_*        — the canonical 3-client/2-replica run (paper Figs
                     1/2/3/4/7) per causality mechanism
  scale_*          — metadata growth along clients/replicas/updates
                     (the §6/§7 scalability claim)
  dvv_leq_* etc.   — kernel-layer throughput (TPU-adaptation layer)
  delta_/client_/churn_/read_/shard_/serving_/geo_/faults_*
                   — the store-plane suites (anti-entropy, batched
                     client API, churn, read path, sharding, coalescing
                     serving plane, geo-replication tier, fault matrix
                     + self-driving membership)

Exits non-zero if any mechanism deviates from the paper's qualitative
outcome (``paper_figures.check_paper_claims``).
"""
from __future__ import annotations

import json
import os
import sys


def _merge_smoke(json_path: str, rows: list) -> None:
    """Replace the ``smoke`` key of an existing BENCH_*.json with this
    run's rows.  Missing files are created as smoke-only shells (the
    dedicated full-sweep target fills in ``rows`` later); corrupt files
    are left alone — the smoke pass must never eat a full sweep."""
    doc = {}
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            print(f"  [skip merge: unreadable {json_path}]",
                  file=sys.stderr)
            return
        if not isinstance(doc, dict):
            print(f"  [skip merge: non-object {json_path}]",
                  file=sys.stderr)
            return
    doc["smoke"] = {"source": "benchmarks.run", "rows": rows}
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=1)


def main() -> None:
    from . import churn_bench, client_bench, delta_bench, durable_bench, \
        faults_bench, geo_bench, kernel_bench, paper_figures, read_bench, \
        scalability, serving_bench, shard_bench

    # (module, BENCH json its full sweep owns — None: prints rows only)
    targets = [
        (paper_figures, None),
        (scalability, None),
        (kernel_bench, "BENCH_bulk_sync.json"),
        (delta_bench, "BENCH_delta_sync.json"),
        (client_bench, "BENCH_client_api.json"),
        (churn_bench, "BENCH_churn.json"),
        (read_bench, "BENCH_read_path.json"),
        (shard_bench, "BENCH_sharding.json"),
        (serving_bench, "BENCH_serving.json"),
        (geo_bench, "BENCH_geo.json"),
        (faults_bench, "BENCH_faults.json"),
        (durable_bench, "BENCH_durable.json"),
    ]

    rows = []
    for module, json_path in targets:
        mod_rows = module.rows()
        rows += mod_rows
        if json_path:
            _merge_smoke(json_path, mod_rows)

    print("name,us_per_call,derived")
    for r in rows:
        print(r)

    failures = paper_figures.check_paper_claims()
    if failures:
        print("\nPAPER-CLAIM FAILURES:", file=sys.stderr)
        for f in failures:
            print("  " + f, file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
