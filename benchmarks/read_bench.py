"""Read-plane benchmark: one-sweep batched ``get_many`` vs looped ``get``.

PR 3 made the write side batched (one grouped update + one payload per
destination); the read side still ran one quorum merge per key — a union
replica universe rebuilt, a tiny ``[1, K, R]`` tensor padded and a
``sync_mask`` sweep dispatched *per key*.  ``quorum_merge_many`` amortizes
all of it: keys grouped by quorum set, one union-universe remap per store,
one stacked ``[N, K, R]`` survival sweep, one grouped §5.4 ceiling reduce.

Sweep: keys × divergence (the fraction of keys whose quorum members
disagree), looped ``KVCluster.get`` vs batched ``get_many`` on the same
cluster (reads are pure with repair off), plus the read-repair pass —
wire bytes/messages of the consolidated repair pushes on a diverged
quorum, and the zero-traffic invariant once converged.  CPU wall-times
(single-core container); the structural win — one grouped sweep instead
of K Python merges — is what transfers.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional, Sequence

from repro.core import DVV_MECHANISM
from repro.store import KVClient, KVCluster, SimNetwork

NODES = ("n0", "n1", "n2")
QUORUM = 2                    # the Dynamo-classic R=2 of N=3


def _build(n_keys: int, divergence: float, seed: int = 0):
    """A converged 3-replica cluster with ``divergence``·``n_keys`` keys
    forked on one side of a healed partition (replication dropped, so only
    reads can heal them)."""
    c = KVCluster(NODES, DVV_MECHANISM, network=SimNetwork(seed=seed))
    cl = KVClient(c, "bench", via="n0")
    keys = [f"key{i}" for i in range(n_keys)]
    cl.put_many({k: (f"base-{k}", None) for k in keys})
    c.deliver_replication()
    n_div = int(n_keys * divergence)
    if n_div:
        c.network.partition({"n0"}, {"n1", "n2"})
        cl.put_many({k: (f"fork-{k}", None) for k in keys[:n_div]})
        c.network.heal()
        c.network.queue.clear()
    return c, keys


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def read_path_rows(batch_sizes: Sequence[int] = (100, 1000),
                   divergences: Sequence[float] = (0.0, 0.1),
                   json_path: Optional[str] = "BENCH_read_path.json",
                   reps: int = 3) -> List[str]:
    """One row per (batch size, divergence); writes the JSON trace."""
    out, trace = [], []
    for n_keys in batch_sizes:
        for div in divergences:
            c, keys = _build(n_keys, div)
            looped_us, batched_us = [], []
            for _ in range(reps):
                looped_us.append(_timed(
                    lambda: [c.get(k, via="n0", quorum=QUORUM)
                             for k in keys]))
                batched_us.append(_timed(
                    lambda: c.get_many(keys, via="n0", quorum=QUORUM)))
            # conformance inside the bench too: same results either way
            ref = {k: c.get(k, via="n0", quorum=QUORUM) for k in keys}
            assert c.get_many(keys, via="n0", quorum=QUORUM) == ref

            # read-repair pass: full-quorum read so every member is checked
            b0, m0 = c.network.bytes_sent, c.network.pending()
            repair_us = _timed(lambda: c.get_many(
                keys, via="n0", quorum=len(NODES), repair=True))
            repair_bytes = c.network.bytes_sent - b0
            repair_msgs = c.network.pending() - m0
            c.deliver_replication()
            b1 = c.network.bytes_sent
            c.get_many(keys, via="n0", quorum=len(NODES), repair=True)
            quiescent_bytes = c.network.bytes_sent - b1

            row = {
                "n_keys": n_keys,
                "divergence": div,
                "read_quorum": QUORUM,
                "looped_get_us": round(min(looped_us), 1),
                "get_many_us": round(min(batched_us), 1),
                "speedup_get_many_vs_looped": round(
                    min(looped_us) / max(min(batched_us), 1e-9), 2),
                "repair_get_many_us": round(repair_us, 1),
                "repair_bytes": repair_bytes,
                "repair_msgs": repair_msgs,
                "repair_bytes_when_converged": quiescent_bytes,
            }
            trace.append(row)
            out.append(
                f"read_get_many_n{n_keys}_d{div},{row['get_many_us']:.0f},"
                f"speedup_vs_looped="
                f"{row['speedup_get_many_vs_looped']:.1f}x")
            out.append(
                f"read_repair_n{n_keys}_d{div},{row['repair_get_many_us']:.0f},"
                f"bytes={repair_bytes};msgs={repair_msgs};"
                f"converged_bytes={quiescent_bytes}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "bench": "read_path",
                "note": ("CPU wall-times, single core, min over reps. "
                         "get_many = quorum-set-grouped one-sweep merge "
                         "(union-universe remap per store + one stacked "
                         "sync_mask + one grouped ceiling reduce) vs K "
                         "looped KVCluster.get calls; both zero-decode "
                         "packed reads.  repair rows: consolidated "
                         "read-repair pushes on a diverged quorum "
                         "(divergence = fraction of keys forked), and the "
                         "zero-traffic invariant once converged."),
                "rows": trace}, f, indent=1)
    return out


def rows() -> List[str]:
    """The benchmark-harness hook (kept small; `make bench-read` sweeps)."""
    return read_path_rows((64,), (0.1,), json_path=None, reps=2)


if __name__ == "__main__":
    print("\n".join(read_path_rows()))
