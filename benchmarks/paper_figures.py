"""One benchmark per paper figure: the canonical 3-client/2-replica run
(Figures 1, 2, 3, 4, 7) executed under each causality mechanism, plus the
§5.2 same-id concurrency example.

Output: CSV rows ``name,us_per_call,derived`` where ``derived`` encodes the
figure's qualitative outcome (kept/lost siblings, detected concurrency).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import ALL_MECHANISMS
from repro.store import KVCluster, SimNetwork


def canonical_run(mech_name: str) -> Tuple[float, dict]:
    """The run of Figs 1/2/3/4/7: C1,C2 at Rb; C3 (then C1) at Ra."""
    mech = ALL_MECHANISMS[mech_name]
    t0 = time.perf_counter()
    c = KVCluster(("a", "b"), mech, network=SimNetwork(seed=0))
    # C1: PUT v @ Rb (no context)
    c.put("k", "v", via="b", coordinator="b", client_id="C1",
          client_counter=1, wall_time=1.0)
    # C2: PUT w @ Rb (no context) — concurrent with v, same coordinator
    c.put("k", "w", via="b", coordinator="b", client_id="C2",
          client_counter=1, wall_time=2.0)
    # C3: PUT x @ Ra; read; PUT y @ Ra (session)
    c.put("k", "x", via="a", coordinator="a", client_id="C3",
          client_counter=1, wall_time=3.0)
    ctx = c.get("k", via="a").context
    c.put("k", "y", context=ctx, via="a", coordinator="a", client_id="C3",
          client_counter=2, wall_time=4.0)
    # anti-entropy Rb -> Ra, then C2 reads Rb and writes z @ Ra
    c.antientropy("b", "a")
    ctx_b = c.get("k", via="b").context
    c.put("k", "z", context=ctx_b, via="a", coordinator="a", client_id="C2",
          client_counter=2, wall_time=5.0)
    us = (time.perf_counter() - t0) * 1e6

    final_a = c.get("k", via="a")
    derived = {
        "final_at_Ra": sorted(final_a.values),
        "siblings_at_Ra": final_a.siblings,
        # Fig 3's lost update: did v survive w's same-coordinator write?
        "v_survived": "v" in c.all_values("k"),
        "meta_ints": max(c.metadata_size("k").values()),
    }
    return us, derived


EXPECTED = {
    # mechanism -> (z and y both survive at Ra?, v survives w at Rb?)
    "oracle": (True, True),
    "dvv": (True, True),
    "vv_client": (True, True),       # stateful clients: accurate (§3.3)
    "vv_server": (False, False),     # Fig 3: w overwrites v; z overwrites y
    "wallclock_lww": (False, False),  # Fig 2: total order, one survivor
    "lamport": (False, False),
}


def rows() -> List[str]:
    out = []
    for mech in ("oracle", "dvv", "vv_server", "vv_client",
                 "vv_client_inferred", "lamport", "wallclock_lww"):
        us, derived = canonical_run(mech)
        zy_both = {"z", "y"} <= set(derived["final_at_Ra"])
        out.append(
            f"fig_run_{mech},{us:.1f},"
            f"finalRa={'|'.join(derived['final_at_Ra'])};"
            f"siblings={derived['siblings_at_Ra']};"
            f"vSurvived={derived['v_survived']};"
            f"zAndYConcurrent={zy_both};"
            f"metaInts={derived['meta_ints']}")
    return out


def check_paper_claims() -> List[str]:
    """Assert the qualitative outcomes the paper derives per mechanism."""
    failures = []
    for mech, (zy_expected, v_expected) in EXPECTED.items():
        _, derived = canonical_run(mech)
        zy = {"z", "y"} <= set(derived["final_at_Ra"])
        if zy != zy_expected:
            failures.append(f"{mech}: z&y-survive={zy} expected {zy_expected}")
        if derived["v_survived"] != v_expected:
            failures.append(f"{mech}: v-survived={derived['v_survived']} "
                            f"expected {v_expected}")
    return failures
