# Single gate every PR runs. `make test` is the tier-1 command from
# ROADMAP.md; `bench-smoke` exercises the benchmark harness at toy sizes;
# `lint` is a dependency-free syntax/bytecode pass (the container has no
# flake8/ruff baked in).

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench lint check

test:
	$(PY) -m pytest -x -q

bench-smoke:
	$(PY) -c "from benchmarks.kernel_bench import bulk_sync_rows; \
	          print('\n'.join(bulk_sync_rows((256,), json_path=None, reps=1)))"

bench:
	$(PY) -m benchmarks.run

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	$(PY) -m pyflakes src tests benchmarks 2>/dev/null || true

check: lint test
