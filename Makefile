# Single gate every PR runs. `make test` is the tier-1 command from
# ROADMAP.md (pytest.ini deselects `slow`-marked fuzz phases by default);
# `make test-all` runs everything including the slow phases;
# `make test-property` runs only the hypothesis property suites (their
# dedicated lane); `make test-churn` runs the membership/fault-injection
# conformance suite (pinned fast schedules + the slow hypothesis phase);
# `make test-read` runs the batched read-plane + read-repair suite
# (including its slow kernel/fuzz phases); `test-serving` runs the
# coalescing serving-plane suite (conformance + the slow scheduled-churn
# phase); `test-geo` runs the geo-replication tier (DC topology, HLC
# walls, causal snapshot plane, incl. its slow DC-partition fuzz phase);
# `test-faults` runs the fault-injection matrix + self-driving membership
# suite (pinned conformance lanes + the slow hypothesis phase);
# `test-durable` runs the segment-log durability suite (codec/segment
# units, warm-restart conformance, the crash-point fuzz incl. its slow
# every-extent sweep).
# `bench-smoke` exercises the benchmark harness at toy
# sizes; `bench-delta` runs the full divergence sweep and writes
# BENCH_delta_sync.json; `bench-client` sweeps batched put_many/get_many vs
# looped client calls and writes BENCH_client_api.json; `bench-read`
# sweeps the one-sweep read plane (keys x divergence, repair on/off) and
# writes BENCH_read_path.json; `bench-serving` runs the closed-loop
# coalescing sweep and writes BENCH_serving.json; `bench-geo` runs the
# geo tier sweep (snapshot latency, frontier staleness, WAN bytes) and
# writes BENCH_geo.json; `bench-faults` runs the detection-latency and
# flapping-wire-cost lanes and writes BENCH_faults.json; `bench-durable`
# runs the warm-vs-cold recovery and log-overhead lanes and writes
# BENCH_durable.json; `lint` is a
# dependency-free syntax/bytecode pass (the container has no flake8/ruff
# baked in).

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-all test-property test-churn test-read test-shard \
	test-serving test-geo test-faults test-durable bench-smoke bench \
	bench-delta bench-client bench-churn bench-read bench-shard \
	bench-serving bench-geo bench-faults bench-durable lint check

test:
	$(PY) -m pytest -x -q

test-all:
	$(PY) -m pytest -q -m ""

test-property:
	$(PY) -m pytest -q -m property

test-churn:
	$(PY) -m pytest -q -m churn

test-read:
	$(PY) -m pytest -q -m read

test-shard:
	$(PY) -m pytest -q -m shard

test-serving:
	$(PY) -m pytest -q -m serving

test-geo:
	$(PY) -m pytest -q -m geo

test-faults:
	$(PY) -m pytest -q -m faults

test-durable:
	$(PY) -m pytest -q -m durable

bench-smoke:
	$(PY) -c "from benchmarks.kernel_bench import bulk_sync_rows; \
	          print('\n'.join(bulk_sync_rows((256,), json_path=None, reps=1)))"
	$(PY) -c "from benchmarks.delta_bench import delta_sync_rows; \
	          print('\n'.join(delta_sync_rows((256,), (0.05,), \
	          json_path=None, reps=1)))"
	$(PY) -c "from benchmarks.client_bench import client_api_rows; \
	          print('\n'.join(client_api_rows((64,), json_path=None, reps=1)))"
	$(PY) -c "from benchmarks.read_bench import read_path_rows; \
	          print('\n'.join(read_path_rows((64,), (0.1,), \
	          json_path=None, reps=1)))"
	$(PY) -c "from benchmarks.serving_bench import rows; \
	          print('\n'.join(rows()))"
	$(PY) -c "from benchmarks.geo_bench import rows; \
	          print('\n'.join(rows()))"
	$(PY) -c "from benchmarks.faults_bench import rows; \
	          print('\n'.join(rows()))"
	$(PY) -c "from benchmarks.durable_bench import rows; \
	          print('\n'.join(rows()))"

bench:
	$(PY) -m benchmarks.run

bench-delta:
	$(PY) -c "from benchmarks.delta_bench import delta_sync_rows; \
	          print('\n'.join(delta_sync_rows()))"

bench-client:
	$(PY) -m benchmarks.client_bench

bench-churn:
	$(PY) -c "from benchmarks.churn_bench import churn_rows; \
	          print('\n'.join(churn_rows()))"

bench-read:
	$(PY) -m benchmarks.read_bench

bench-shard:
	$(PY) -m benchmarks.shard_bench

bench-serving:
	$(PY) -m benchmarks.serving_bench

bench-geo:
	$(PY) -m benchmarks.geo_bench

bench-faults:
	$(PY) -m benchmarks.faults_bench

bench-durable:
	$(PY) -m benchmarks.durable_bench

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	$(PY) -m pyflakes src tests benchmarks 2>/dev/null || true

check: lint test
