"""Durable segment log + crash-point-fuzzed warm restart (DESIGN.md §14).

Three layers, matching the recovery stack:

* **Codec + filesystem units** — the record framing is torn-tail-proof for
  *every* byte prefix and every single-byte corruption (exhaustive at this
  layer: this is where per-byte crash coverage lives, cheaply).  ``CrashFS``
  semantics: torn appends persist a prefix, interrupted atomic writes
  persist nothing.
* **Segment-log units** — sealing, snapshot compaction (manifest flip +
  orphan GC), on-disk torn-tail truncation, checksum verification.
* **Cluster warm restart** — ``restart_node`` rebuilds a crashed replica
  from disk and converges with ONE pull+push delta pass per peer; the
  membership controller re-admits an evicted node through the same path;
  and the crash-point fuzzer kills the writer mid-write and requires
  digest equality with an uncrashed run afterwards.

The cluster fuzz does not re-enumerate every byte: within one write extent
all interior kill offsets land in the same recovery class (append → one
torn record dropped; atomic → old content kept), and the codec layer
already proves per-byte tearing exhaustively.  Each extent is therefore
probed at its boundaries and midpoint — the tier-1 lane samples extents,
the ``slow`` lane sweeps all of them for both backends × shards ∈ {1, 4}.
"""
import os
import pickle
import shutil
import tempfile

import pytest

from repro.core import DVV_MECHANISM
from repro.ckpt.atomic import atomic_write_bytes
from repro.store import (CrashFS, CrashPoint, GossipDriver, KVCluster,
                         LocalFS, MembershipController, SegmentLog,
                         cluster_converged)
from repro.store.wal import (REC_COMPACT, REC_EPOCH, REC_KILL, REC_UPDATE,
                             decode_records, encode_record)

pytestmark = pytest.mark.durable

KEYS = ["alpha", "beta", "gamma", "delta", "epsilon"]


@pytest.fixture
def tmp():
    d = tempfile.mkdtemp(prefix="durable-")
    yield d
    shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# Record codec: exhaustive per-byte torn-tail + corruption coverage.
# ---------------------------------------------------------------------------

def _sample_records():
    return [
        (REC_UPDATE, pickle.dumps(("alpha", 1), 4)),
        (REC_KILL, pickle.dumps("beta", 4)),
        (REC_EPOCH, pickle.dumps((3, ("a", "b")), 4)),
        (REC_UPDATE, b"x" * 100),
        (REC_COMPACT, b""),                    # zero-length body
    ]


def test_record_roundtrip():
    recs = _sample_records()
    buf = b"".join(encode_record(k, b) for k, b in recs)
    out, good = decode_records(buf)
    assert out == recs and good == len(buf)
    assert decode_records(b"") == ([], 0)


def test_torn_tail_every_prefix():
    """Cutting the stream at EVERY byte offset yields exactly the complete
    record prefix, with ``good_bytes`` at the preceding record boundary —
    the per-byte guarantee the cluster fuzz builds on."""
    recs = _sample_records()
    frames = [encode_record(k, b) for k, b in recs]
    buf = b"".join(frames)
    boundaries = [0]
    for f in frames:
        boundaries.append(boundaries[-1] + len(f))
    for cut in range(len(buf) + 1):
        n_complete = sum(1 for b in boundaries[1:] if b <= cut)
        out, good = decode_records(buf[:cut])
        assert out == recs[:n_complete]
        assert good == boundaries[n_complete]


def test_every_single_byte_corruption_stops_replay():
    """Flipping ANY one byte makes replay stop at (or before) the record
    containing it — never decode garbage past a corruption."""
    recs = _sample_records()
    frames = [encode_record(k, b) for k, b in recs]
    buf = bytearray(b"".join(frames))
    owner = []                                 # byte offset -> record index
    for i, f in enumerate(frames):
        owner.extend([i] * len(f))
    for pos in range(len(buf)):
        corrupt = bytearray(buf)
        corrupt[pos] ^= 0x5A
        out, good = decode_records(bytes(corrupt))
        assert len(out) <= owner[pos]
        assert out == recs[:len(out)]


# ---------------------------------------------------------------------------
# Filesystem layer: atomic helper + CrashFS semantics.
# ---------------------------------------------------------------------------

def test_atomic_write_replaces_and_leaves_no_temps(tmp):
    path = os.path.join(tmp, "blob")
    atomic_write_bytes(path, b"first")
    atomic_write_bytes(path, b"second")
    with open(path, "rb") as f:
        assert f.read() == b"second"
    assert os.listdir(tmp) == ["blob"]         # no stray temp files


def test_crashfs_append_keeps_affordable_prefix(tmp):
    fs = CrashFS(budget=10)
    path = os.path.join(tmp, "log")
    fs.append(path, b"0123456")
    with pytest.raises(CrashPoint):
        fs.append(path, b"abcdefg")            # only 3 bytes left
    with open(path, "rb") as f:
        assert f.read() == b"0123456abc"       # torn: prefix persisted
    assert fs.crashed
    for op in (lambda: fs.append(path, b"x"),
               lambda: fs.read(path),
               lambda: fs.write_atomic(path, b"x"),
               lambda: fs.remove(path)):
        with pytest.raises(CrashPoint):        # crashed fs stays crashed
            op()


def test_crashfs_atomic_write_is_all_or_nothing(tmp):
    fs = CrashFS(budget=8)
    path = os.path.join(tmp, "manifest")
    fs.write_atomic(path, b"old-data")         # exactly spends the budget
    with pytest.raises(CrashPoint):
        fs.write_atomic(path, b"new-data!")
    with open(path, "rb") as f:
        assert f.read() == b"old-data"         # target untouched


def test_crashfs_recording_mode_tracks_extents(tmp):
    fs = CrashFS(None)
    fs.append(os.path.join(tmp, "a"), b"12345")
    fs.write_atomic(os.path.join(tmp, "b"), b"678")
    assert [(op, s, e) for op, _, s, e in fs.extents] == \
        [("append", 0, 5), ("atomic", 5, 8)]
    assert fs.written == 8 and not fs.crashed


# ---------------------------------------------------------------------------
# SegmentLog: seal, snapshot compaction, torn-tail truncation on disk.
# ---------------------------------------------------------------------------

def _fill(log, n, size=40):
    for i in range(n):
        log.append_record(REC_UPDATE, f"rec-{i:04d}-".encode() + b"p" * size)


def test_seal_rolls_segments_and_checksums_them(tmp):
    log = SegmentLog(tmp, "n1", 0, seal_bytes=120)
    _fill(log, 7)
    assert len(log.segments) >= 2              # sealed at least twice
    for seg in log.segments:
        assert seg["records"] > 0 and len(seg["checksum"]) == 16
    snap, records, stats = SegmentLog(tmp, "n1", 0, seal_bytes=120).load()
    assert snap is None and len(records) == 7 and stats.torn_bytes == 0
    assert [b for _, b in records] == \
        [f"rec-{i:04d}-".encode() + b"p" * 40 for i in range(7)]


def test_snapshot_compacts_and_gcs_old_files(tmp):
    log = SegmentLog(tmp, "n1", 0, snapshot_every=5, seal_bytes=120)
    state = {"snapshot": b""}
    log.snapshot_source = lambda: state["snapshot"]
    for i in range(12):
        state["snapshot"] = f"state-after-{i}".encode()
        log.append_record(REC_UPDATE, f"rec-{i}".encode())
    assert log.snapshot_rec is not None
    files = set(os.listdir(log.dir))
    # exactly one snapshot blob survives; orphaned segments are GC'd
    assert sum(f.startswith("snap-") for f in files) == 1
    referenced = {log.snapshot_rec.file, log.active, SegmentLog.MANIFEST} \
        | {s["file"] for s in log.segments}
    assert files == referenced
    snap, records, _ = SegmentLog(tmp, "n1", 0, snapshot_every=5,
                                  seal_bytes=120).load()
    # the snapshot subsumes the prefix; the tail replays the rest
    assert snap == state["snapshot"] or (
        pickle.loads(records[-1][1]) if records[-1][0] == REC_COMPACT
        else True)
    replayed = [b for k, b in records if k == REC_UPDATE]
    assert snap.decode().startswith("state-after-")
    subsumed = int(snap.decode().rsplit("-", 1)[1])
    assert replayed == [f"rec-{i}".encode() for i in range(subsumed + 1, 12)]


def test_load_truncates_torn_tail_on_disk(tmp):
    log = SegmentLog(tmp, "n1", 0)
    _fill(log, 3)
    active = os.path.join(log.dir, log.active)
    with open(active, "ab") as f:              # simulate a torn append
        f.write(encode_record(REC_UPDATE, b"torn")[:-2])
    reopened = SegmentLog(tmp, "n1", 0)
    snap, records, stats = reopened.load()
    assert len(records) == 3 and stats.torn_bytes > 0
    # the truncation is durable: a second reopen sees a clean tail
    _, records2, stats2 = SegmentLog(tmp, "n1", 0).load()
    assert len(records2) == 3 and stats2.torn_bytes == 0


def test_load_rejects_corrupted_sealed_segment(tmp):
    log = SegmentLog(tmp, "n1", 0, seal_bytes=120)
    _fill(log, 6)
    seg_file = os.path.join(log.dir, log.segments[0]["file"])
    data = bytearray(open(seg_file, "rb").read())
    data[len(data) // 2] ^= 0xFF
    with open(seg_file, "wb") as f:
        f.write(bytes(data))
    with pytest.raises(IOError, match="bad checksum"):
        SegmentLog(tmp, "n1", 0, seal_bytes=120).load()


# ---------------------------------------------------------------------------
# Cluster warm restart.
# ---------------------------------------------------------------------------

def _wal_cluster(tmp, packed, shards, fs=None, **kw):
    kw.setdefault("replication", 3)
    kw.setdefault("write_quorum", 2)
    return KVCluster(("a", "b", "c"), DVV_MECHANISM, packed=packed,
                     shards=shards, seed=7, wal_dir=tmp,
                     wal_snapshot_every=4, wal_seal_bytes=600,
                     wal_fs={"b": fs} if fs else None, **kw)


def _check_stores(c):
    for n in c.nodes.values():
        if n.is_packed:
            for st in n.shard_stores:
                st.check_digests()
                st.check_bucket_index()


@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("shards", [1, 4])
def test_warm_restart_converges_with_divergence(tmp, packed, shards):
    """Crash b, let the survivors diverge, warm-restart b: the log replay
    plus ONE pull+push delta pass per peer restores digest equality."""
    c = _wal_cluster(tmp, packed, shards)
    for i in range(10):
        via = ("a", "b", "c")[i % 3]
        c.put(KEYS[i % len(KEYS)], f"v{i}", via=via, coordinator=via)
        c.deliver_replication()
    c.network.fail_node("b")
    c.wal["b"].detach()
    for i in range(5):                         # b misses these
        c.put(KEYS[i % len(KEYS)], f"miss{i}", via="a", coordinator="a")
        c.deliver_replication()
    c.network.recover_node("b")
    stats = c.restart_node("b")
    c.deliver_replication()
    assert stats                                # delta passes actually ran
    _check_stores(c)
    assert cluster_converged(c)


@pytest.mark.parametrize("packed", [True, False])
def test_restart_pushes_unreplicated_coordinated_write(tmp, packed):
    """The WAL can be the ONLY surviving copy: b coordinates a write whose
    replication messages die with the crash.  Recovery must PUSH it back
    out — a pull-only resync would lose an acknowledged write."""
    c = _wal_cluster(tmp, packed, 1, write_quorum=1)
    c.put("alpha", "everywhere", via="a", coordinator="a")
    c.deliver_replication()
    c.put("alpha", "only-in-wal", via="b", coordinator="b",
          context=c.get("alpha", via="b").context)
    c.network.fail_node("b")                   # replication never delivered
    assert all("only-in-wal" not in {v.value for v in c.nodes[n]
               .versions("alpha")} for n in "ac")
    c.network.recover_node("b")
    c.restart_node("b")
    c.deliver_replication()
    assert cluster_converged(c)
    for n in "abc":
        assert {v.value for v in c.nodes[n].versions("alpha")} == \
            {"only-in-wal"}


def test_restart_bumps_incarnation_and_epoch(tmp):
    c = _wal_cluster(tmp, True, 1)
    inc, epoch = c.incarnation["b"], c.wal["b"].last_epoch
    c.restart_node("b")
    assert c.incarnation["b"] == inc + 1
    assert c.wal["b"].last_epoch > epoch


def test_restart_requires_wal(tmp):
    c = KVCluster(("a", "b"), DVV_MECHANISM, packed=True, seed=1)
    with pytest.raises(ValueError, match="durable log"):
        c.restart_node("b")


@pytest.mark.parametrize("packed", [True, False])
def test_controller_readmits_evicted_node_via_warm_restart(tmp, packed):
    """The closed loop: crash → accrual eviction → recovery → re-admission
    through ``restart_node`` (log replay + delta), NOT the cold full-payload
    bootstrap."""
    c = _wal_cluster(tmp, packed, 2, replication=2, write_quorum=1)
    driver = GossipDriver(c, period=5.0, seed=3)
    mem = MembershipController(c, period=5.0, seed=3)
    bootstraps = []
    real = c.bootstrap_node
    c.bootstrap_node = lambda *a, **k: (bootstraps.append(a),
                                        real(*a, **k))[1]
    for i in range(8):
        c.put(KEYS[i % len(KEYS)], f"v{i}", via="a", coordinator="a")
    driver.run_for(30.0)
    c.network.fail_node("b")
    driver.run_for(300.0)
    assert "b" not in c.nodes and mem.evictions == 1
    c.network.recover_node("b")
    driver.run_for(300.0)
    c.deliver_replication()
    assert "b" in c.nodes and mem.readmissions == 1
    assert not bootstraps                      # warm path, no cold bootstrap
    _check_stores(c)
    assert cluster_converged(c)
    for i in range(8):
        assert {v.value for v in c.nodes["b"].versions(KEYS[i % len(KEYS)])} \
            == {v.value for v in c.nodes["a"].versions(KEYS[i % len(KEYS)])}


# ---------------------------------------------------------------------------
# Crash-point fuzz: kill the writer mid-write, restart, demand equality.
# ---------------------------------------------------------------------------

def _fuzz_schedule(c):
    for i in range(10):
        via = ("a", "b", "c")[i % 3]
        c.put(KEYS[i % len(KEYS)], f"v{i}", via=via, coordinator=via)
        c.deliver_replication()


def _record_extents(packed, shards):
    """Recording pass: run the schedule uncrashed, return b's write extents
    relative to the post-boot baseline."""
    tmp = tempfile.mkdtemp(prefix="durable-rec-")
    try:
        fs = CrashFS(None)
        c = _wal_cluster(tmp, packed, shards, fs=fs)
        base = fs.written
        _fuzz_schedule(c)
        return [(s - base, e - base) for _, _, s, e in fs.extents
                if e > base], fs.written - base
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _fuzz_once(packed, shards, offset):
    """Boot with a byte budget, run until the crash (if it fires), then
    warm-restart b and demand digest equality with the survivors."""
    tmp = tempfile.mkdtemp(prefix="durable-fuzz-")
    try:
        fs = CrashFS(None)
        c = _wal_cluster(tmp, packed, shards, fs=fs)
        fs.budget = fs.written + offset        # arm AFTER the boot epoch
        try:
            _fuzz_schedule(c)
        except CrashPoint:
            pass
        c.network.fail_node("b")
        c.wal["b"].detach()
        for i in range(4):                     # divergence while b is down
            c.put(KEYS[i % len(KEYS)], f"miss{i}", via="a", coordinator="a")
            c.deliver_replication()
        c.network.recover_node("b")
        c.wal["b"].set_fs(LocalFS())           # fresh process, same bytes
        c.restart_node("b")
        c.deliver_replication()
        _check_stores(c)
        assert cluster_converged(c), \
            f"diverged: packed={packed} shards={shards} offset={offset}"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _boundary_offsets(extents, total, *, stride=1):
    """The distinct crash classes: extent start (nothing written), first
    byte (minimal tear), midpoint, last-but-one (maximal tear), plus the
    uncrashed run.  ``stride`` subsamples extents for the tier-1 lane."""
    offs = set()
    for s, e in extents[::stride]:
        offs.update(x for x in (s, s + 1, (s + e) // 2, e - 1) if s <= x < e)
    offs.add(total + 1)                        # budget never reached
    return sorted(offs)


@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("shards", [1, 4])
def test_crash_fuzz_sampled_extents(packed, shards):
    extents, total = _record_extents(packed, shards)
    for off in _boundary_offsets(extents, total, stride=4):
        _fuzz_once(packed, shards, off)


@pytest.mark.slow
@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("shards", [1, 4])
def test_crash_fuzz_every_extent(packed, shards):
    """The nightly sweep: every write extent of the recorded schedule, all
    four crash classes each."""
    extents, total = _record_extents(packed, shards)
    for off in _boundary_offsets(extents, total):
        _fuzz_once(packed, shards, off)
