"""Sharded packed stores on the vnode ring: placement, rebalance, planes.

Four fronts, mirroring DESIGN.md §10:

* **Ring properties** — determinism (placement is a pure function of the
  member set), O(shards) table size, and the consistent-hashing stability
  guarantee: a join/leave at N nodes remaps ~K/N keys, never O(K).  This
  is the regression test for replacing the per-key md5 full-sort (plus
  its unbounded ``_ring_cache``) with one bisect over vnode tokens.
* **Conformance** — the randomized churn schedules of ``test_churn`` run
  with the store split across 8 shards; packed and object backends must
  stay observationally equal (the object backend keeps one dict — shards
  must be physically invisible).
* **Rebalance** — after a join's shard-by-shard bootstrap, every shard's
  digest tree agrees across its holders and the incremental digests still
  verify against a rebuild.
* **Batched planes** — get_many/put_many admission stays atomic *across*
  shards: one unreachable shard fails the whole batch before any store
  (any shard, any node) is touched.
"""
import pytest

from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVCluster, PackedVersionStore,
                         SimNetwork, Unavailable, cluster_converged,
                         concat_payloads, key_hash64, shard_of_key,
                         split_payload)
from repro.store.sharding import (DEFAULT_PLACEMENT_SLICES, HashRing,
                                  moved_shards, owned_shards, shard_of_hash,
                                  shard_point)

from test_churn import _conformance, _random_ops

pytestmark = pytest.mark.shard

KEYS_10K = [f"key:{i}" for i in range(10_000)]


# ---------------------------------------------------------------------------
# Hashing + ring unit properties.
# ---------------------------------------------------------------------------

def test_key_hash64_stable_and_wide():
    # pinned: the wire/placement hash must never drift between versions
    assert key_hash64("k0") == int.from_bytes(
        __import__("hashlib").blake2b(b"k0", digest_size=8).digest(),
        "little")
    hs = {key_hash64(k) for k in KEYS_10K}
    assert len(hs) == len(KEYS_10K)          # no collisions at 10k keys


def test_shard_of_key_top_bits_and_validation():
    for shards in (1, 2, 8, 256):
        for k in ("a", "b", "zz"):
            s = shard_of_key(k, shards)
            assert 0 <= s < shards
            if shards > 1:
                assert s == shard_of_hash(key_hash64(k), shards)
                assert shard_point(s, shards) <= key_hash64(k)
    for bad in (0, 3, 12, -4):
        with pytest.raises(ValueError):
            shard_of_key("k", bad)
        with pytest.raises(ValueError):
            shard_of_hash(0, bad)


def test_shards_balance_keys():
    counts = [0] * 16
    for k in KEYS_10K:
        counts[shard_of_key(k, 16)] += 1
    assert min(counts) > 0.5 * (len(KEYS_10K) / 16)
    assert max(counts) < 1.5 * (len(KEYS_10K) / 16)


def test_ring_is_pure_function_of_membership():
    a = HashRing(["n2", "n0", "n1"])
    b = HashRing([])
    for n in ("n0", "n1", "n2"):
        b.add(n)
    assert a.placement_table(64, 2) == b.placement_table(64, 2)
    for k in ("x", "y", "z"):
        assert a.replicas_for_key(k, 2) == b.replicas_for_key(k, 2)


def test_ring_membership_errors():
    r = HashRing(["a", "b"])
    with pytest.raises(ValueError):
        r.add("a")
    with pytest.raises(KeyError):
        r.remove("c")
    assert "a" in r and len(r) == 2
    assert r.n_tokens == 2 * r.vnodes


def test_ring_replicas_distinct_and_capped():
    r = HashRing(["a", "b", "c"])
    for k in KEYS_10K[:200]:
        reps = r.replicas_for_key(k, 2)
        assert len(reps) == len(set(reps)) == 2
    assert len(r.replicas_for_key("k", 99)) == 3   # capped at member count


@pytest.mark.parametrize("n_nodes", [4, 8])
def test_placement_stability_on_join_and_leave(n_nodes):
    """The consistent-hashing guarantee the md5 full-sort never gave:
    membership change at N nodes remaps ~K/N keys (generous slack for
    vnode variance), not an arbitrary fraction of the key space."""
    nodes = [f"n{i}" for i in range(n_nodes)]
    ring = HashRing(nodes)
    K, R = 10_000, 2
    before = {k: ring.replicas_for_key(k, R) for k in KEYS_10K}

    ring.add("joiner")
    moved_join = sum(before[k] != ring.replicas_for_key(k, R)
                     for k in KEYS_10K)
    # a joiner takes ~R·K/(N+1) key-slots; allow 2.5x for vnode variance
    assert 0 < moved_join < 2.5 * R * K / (n_nodes + 1)

    ring.remove("joiner")                     # ring returns to `before`
    assert all(before[k] == ring.replicas_for_key(k, R) for k in KEYS_10K)

    ring.remove(nodes[0])
    moved_leave = sum(before[k] != ring.replicas_for_key(k, R)
                      for k in KEYS_10K)
    # only keys that had nodes[0] in their replica set may move
    affected = sum(nodes[0] in reps for reps in before.values())
    assert 0 < moved_leave <= affected
    assert affected < 2.5 * R * K / n_nodes


def test_moved_shards_is_exact_rebalance_set():
    ring = HashRing([f"n{i}" for i in range(5)])
    before = ring.placement_table(256, 3)
    ring.add("n5")
    after = ring.placement_table(256, 3)
    moved = moved_shards(before, after)
    assert 0 < len(moved) < 256               # some move, never all
    for s in moved:
        assert before[s] != after[s]
    for s in set(range(256)) - set(moved):
        assert before[s] == after[s]
    assert owned_shards(after, "n5") >= frozenset(
        s for s in moved if "n5" in after[s])


# ---------------------------------------------------------------------------
# Cluster placement: bounded table, no per-key cache.
# ---------------------------------------------------------------------------

def _cluster(shards=8, n=4, replication=2, seed=0, packed=True):
    return KVCluster([f"n{i}" for i in range(n)], DVV_MECHANISM,
                     replication=replication, packed=packed,
                     network=SimNetwork(seed=seed), seed=seed, shards=shards)


def test_cluster_placement_is_bounded():
    c = _cluster(shards=8)
    assert not hasattr(c, "_ring_cache")      # the unbounded dict is gone
    assert len(c._placement) == 8             # table is O(shards)...
    for k in KEYS_10K:                        # ...however many keys place
        reps = c.replicas_for(k)
        assert len(reps) == 2
        assert tuple(reps) == c._placement[shard_of_key(k, 8)]
    assert len(c._placement) == 8

    c1 = _cluster(shards=1)                   # unsharded: fixed slice count
    assert len(c1._placement) == DEFAULT_PLACEMENT_SLICES


def test_cluster_rejects_bad_shards():
    with pytest.raises(ValueError):
        _cluster(shards=6)


# ---------------------------------------------------------------------------
# Conformance: the churn schedules with sharding on.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [7, 23])
def test_sharded_churn_conformance(seed):
    _conformance(seed, _random_ops(seed), ("shard8", seed), shards=8)


def test_sharded_store_routes_by_shard():
    c = _cluster(shards=8, replication=4)
    for i in range(64):
        c.put(f"k{i}", f"v{i}")
    c.deliver_replication()
    n = c.nodes["n0"]
    assert len(n.shard_stores) == 8
    per_shard = [len(st.keys) for st in n.shard_stores]
    assert sum(per_shard) == 64
    assert sum(1 for x in per_shard if x) > 1  # keys actually spread
    for i in range(64):
        st = n.store_for(f"k{i}")
        assert st is n.shard_stores[n.shard_of(f"k{i}")]
        assert f"k{i}" in st.keys


# ---------------------------------------------------------------------------
# Rebalance: shard-local digests agree after join/leave.
# ---------------------------------------------------------------------------

def test_shard_digests_agree_after_join_rebalance():
    c = _cluster(shards=8, n=3, replication=3, seed=5)
    for i in range(300):
        c.put(f"k{i}", f"v{i}")
    c.deliver_replication()
    d = GossipDriver(c, period=5.0, seed=5)
    d.run_for(120.0)
    assert cluster_converged(c)

    stats = c.add_node("n3")                  # warm shard-by-shard pull
    assert stats and sum(s.changed for s in stats) > 0
    d.run_for(240.0)
    assert cluster_converged(c)
    ref = c.nodes["n0"]
    for other in ("n1", "n2", "n3"):
        for a, b in zip(ref.shard_stores, c.nodes[other].shard_stores):
            assert len(a.sync_digest().diff(b.sync_digest())) == 0
            assert a.value_root() == b.value_root()
    for node in c.nodes.values():
        for st in node.shard_stores:
            assert st.check_digests()         # incremental == rebuilt


def test_remove_handoff_moves_only_owned_shards():
    c = _cluster(shards=16, n=4, replication=2, seed=11)
    for i in range(400):
        c.put(f"k{i}", f"v{i}")
    c.deliver_replication()
    d = GossipDriver(c, period=5.0, seed=11)
    d.run_for(180.0)
    assert cluster_converged(c)
    stats = c.remove_node("n1")
    # every handoff round was shard-filtered: any shard that ran carries
    # its shard id, and converged shards cost only root-probe bytes
    assert stats                              # some survivor got a handoff
    for st in stats:
        assert st.digest_bytes > 0
        assert all(p.shard >= 0 for p in st.per_shard)
    d.run_for(240.0)
    assert cluster_converged(c)


# ---------------------------------------------------------------------------
# Batched planes: admission is atomic across shards.
# ---------------------------------------------------------------------------

def test_get_many_admission_atomic_across_shards(monkeypatch):
    import repro.store.cluster as cluster_mod
    c = _cluster(shards=8, n=3, replication=1, seed=3)
    keys = [f"p{i}" for i in range(24)]
    for k in keys:
        c.put(k, f"v-{k}")
    c.deliver_replication()
    owners = {k: c.replicas_for(k)[0] for k in keys}
    assert {"n0"} < set(owners.values())      # n0 owns some, not all
    merges = []
    real = cluster_mod.quorum_merge_many
    monkeypatch.setattr(
        cluster_mod, "quorum_merge_many",
        lambda *a, **kw: merges.append(1) or real(*a, **kw))
    c.network.partition({"n0"}, {"n1", "n2"})
    with pytest.raises(Unavailable):
        c.get_many(keys, via="n0", quorum=1, repair=True)
    assert merges == []                       # no shard's store was merged
    assert c.network.pending() == 0           # no repair pushes either
    mine = [k for k in keys if owners[k] == "n0"]
    got = c.get_many(mine, via="n0", quorum=1)
    assert all(got[k].values == (f"v-{k}",) for k in mine)


def test_put_many_admission_atomic_across_shards(monkeypatch):
    from repro.store.replica import ReplicaNode
    c = _cluster(shards=8, n=3, replication=1, seed=3)
    keys = [f"p{i}" for i in range(24)]
    owners = {k: c.replicas_for(k)[0] for k in keys}
    assert {"n0"} < set(owners.values())
    writes = []
    real = ReplicaNode.coordinate_updates
    monkeypatch.setattr(
        ReplicaNode, "coordinate_updates",
        lambda self, *a, **kw: writes.append(1) or real(self, *a, **kw))
    c.network.partition({"n0"}, {"n1", "n2"})
    with pytest.raises(Unavailable):
        c.put_many({k: (f"w-{k}", None) for k in keys}, via="n0")
    assert writes == []                       # nothing written anywhere
    mine = {k: (f"w-{k}", None) for k in keys if owners[k] == "n0"}
    acks = c.put_many(mine, via="n0")
    assert set(acks) == set(mine)
    assert writes                             # the admitted batch did run


# ---------------------------------------------------------------------------
# Payload plumbing: split/concat round-trips.
# ---------------------------------------------------------------------------

def _filled_store(n_keys=60, node="w"):
    import numpy as np
    st = PackedVersionStore()
    empty = np.zeros(0, np.int32)
    for i in range(n_keys):
        st.update_key(f"k{i}", empty, node, f"v{i}")
    return st


def test_split_payload_partitions_by_shard():
    st = _filled_store()
    full = st.payload()
    parts = split_payload(full, 8)
    got = [k for p in parts.values() for k in p.keys]
    assert sorted(got) == sorted(full.keys)   # partition, no dup/loss
    for s, p in parts.items():
        assert all(shard_of_key(k, 8) == s for k in p.keys)
    assert split_payload(full, 1) == {0: full}


def test_split_then_concat_roundtrips_through_stores():
    st = _filled_store()
    parts = split_payload(st.payload(), 4)
    # apply each part to its own shard store, as the sharded backend does
    stores = [PackedVersionStore() for _ in range(4)]
    for s, p in parts.items():
        assert stores[s].apply_payload(p) == len(p.keys)
    re = concat_payloads([stores[s].payload() for s in sorted(parts)])
    flat = PackedVersionStore()
    flat.apply_payload(re)
    for k in st.keys:
        assert flat.versions(k) == st.versions(k)
