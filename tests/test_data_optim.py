"""Unit tests: data pipeline determinism/resume and optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import MemmapTokens, PipelineConfig, SyntheticTokens
from repro.optim import (
    AdamWConfig, adamw_update, clip_by_global_norm, global_norm,
    init_opt_state, schedule_lr,
)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_across_instances():
    cfg = PipelineConfig(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    a, b = SyntheticTokens(cfg), SyntheticTokens(cfg)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_pipeline_restore_replays_exactly():
    cfg = PipelineConfig(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    p = SyntheticTokens(cfg)
    p.next_batch()
    cursor = p.state()
    want = p.next_batch()
    p2 = SyntheticTokens(cfg)
    p2.restore(cursor)
    got = p2.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_pipeline_dp_ranks_partition_the_global_batch():
    base = dict(vocab_size=100, seq_len=8, global_batch=8, seed=5)
    full = SyntheticTokens(PipelineConfig(**base)).next_batch()
    parts = []
    for rank in range(4):
        p = SyntheticTokens(PipelineConfig(**base, dp_rank=rank, dp_size=4))
        parts.append(p.next_batch()["tokens"])
    stacked = np.concatenate(parts, axis=0)
    np.testing.assert_array_equal(full["tokens"], stacked)


def test_pipeline_labels_are_shifted_tokens():
    cfg = PipelineConfig(vocab_size=100, seq_len=8, global_batch=2, seed=0)
    b = SyntheticTokens(cfg).next_batch()
    # tokens[:, 1:] == labels[:, :-1] (next-token prediction layout)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_memmap_pipeline_roundtrip(tmp_path):
    data = np.arange(10 * 9, dtype=np.int32)   # 10 sequences of len 9
    path = tmp_path / "toks.bin"
    data.tofile(path)
    cfg = PipelineConfig(vocab_size=1000, seq_len=8, global_batch=2, seed=0)
    p = MemmapTokens(cfg, str(path))
    b = p.next_batch()
    assert b["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b["tokens"][0], data[:8] % 1000)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_schedule_warmup_and_cosine_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine")
    assert float(schedule_lr(cfg, jnp.asarray(0))) == 0.0
    assert float(schedule_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule_lr(cfg, jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)
    mid = float(schedule_lr(cfg, jnp.asarray(60)))
    assert 0.4 < mid < 0.6


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    assert float(global_norm(tree)) == pytest.approx(10.0)
    clipped, norm = clip_by_global_norm(tree, 5.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(5.0)
    # below the threshold: untouched
    same, _ = clip_by_global_norm(tree, 20.0)
    np.testing.assert_allclose(same["a"], tree["a"])


def test_adamw_weight_decay_shrinks_params():
    params = {"w": jnp.ones((8,)) * 2.0}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0,
                      warmup_steps=0, total_steps=10, schedule="constant")
    state = init_opt_state(params, cfg)
    zeros = {"w": jnp.zeros((8,))}
    newp, _, _ = adamw_update(params, zeros, state, cfg)
    assert float(newp["w"][0]) < 2.0        # decay applies with zero grads


def test_adamw_step_counter_and_lr_metric():
    params = {"w": jnp.ones((2,))}
    cfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=10)
    state = init_opt_state(params, cfg)
    for i in range(3):
        params, state, m = adamw_update(
            params, {"w": jnp.ones((2,))}, state, cfg)
    assert int(state["step"]) == 3
    assert float(m["lr"]) > 0


def test_adamw_master_weights_state_roundtrip():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    cfg = AdamWConfig(lr=1e-2, master_weights=True, warmup_steps=0,
                      total_steps=5, schedule="constant")
    state = init_opt_state(params, cfg)
    assert state["master"]["w"].dtype == jnp.float32
    newp, newstate, _ = adamw_update(
        params, {"w": jnp.ones((4,), jnp.bfloat16)}, state, cfg)
    assert newp["w"].dtype == jnp.bfloat16
    # master tracks the true fp32 value the bf16 params are rounded from
    np.testing.assert_allclose(
        np.asarray(newp["w"], np.float32),
        np.asarray(newstate["master"]["w"]).astype(np.float32), rtol=1e-2)
