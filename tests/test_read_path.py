"""The batched quorum-read plane (one-sweep ``get_many``) + read-repair.

Covers the PR's acceptance surface:

* conformance — batched ``get_many`` is byte-identical to looped ``get``
  (values, contexts, resolution order, siblings) on both backends, across
  randomized partition/heal/divergence schedules, quorum sizes and
  proxies, with and without the shape-bucketed kernel mask;
* admission — reachability/quorum resolve for ALL keys up front; a failing
  key raises ``Unavailable`` before any store is merged;
* read-repair — a diverged quorum converges after ONE batched read (one
  consolidated ``("store", payload)`` push per stale member; digests agree
  after delivery) and a converged quorum generates ZERO repair traffic;
* the merged-read staleness signal (``MergedRead.stale``) and the
  ``track_stale`` fast path;
* ``dvv_read_sweep`` — the fused survival+ceiling kernel sweep equals the
  numpy reference (``sync_mask_np`` + ``grouped_ceiling_np``);
* a hypothesis fuzz phase over randomized schedules (slow/property lane).
"""
import random

import numpy as np
import pytest

from repro.core import DVV_MECHANISM
from repro.core import batched as B
from repro.store import (
    KVClient, KVCluster, SimNetwork, Unavailable, quorum_merge_many,
)
from repro.store.packed import PackedPayload, quorum_merge_key

pytestmark = pytest.mark.read

KEYS = tuple(f"k{i}" for i in range(8))
NODES = ("a", "b", "c", "d")


def _cluster(seed=0, packed=None, nodes=NODES, **kw):
    return KVCluster(nodes, DVV_MECHANISM, network=SimNetwork(seed=seed),
                     packed=packed, **kw)


def _drive(seed: int, packed, ops: int = 80) -> KVCluster:
    """Randomized put/partition/heal/deliver schedule ending healed (so a
    full-quorum read is admissible for every key)."""
    rng = random.Random(seed)
    c = _cluster(seed=seed, packed=packed)
    for i in range(ops):
        p = rng.random()
        key, node = rng.choice(KEYS), rng.choice(NODES)
        if p < 0.5:
            try:
                c.put(key, f"v{i}", via=node, coordinator=node)
            except Unavailable:
                pass
        elif p < 0.65:
            c.deliver_replication()
        elif p < 0.85:
            halves = set(rng.sample(NODES, 2))
            c.network.partition(halves, set(NODES) - halves)
        else:
            c.network.heal()
    c.network.heal()
    return c


def _assert_batched_equals_looped(c: KVCluster, keys, via, quorum):
    looped = {k: c.get(k, via=via, quorum=quorum) for k in keys}
    batched = c.get_many(keys, via=via, quorum=quorum)
    assert list(batched) == list(dict.fromkeys(keys))
    for k in keys:
        assert batched[k] == looped[k], (k, via, quorum)


# ---------------------------------------------------------------------------
# Conformance: batched == looped, byte-identical.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("seed", [0, 7, 42])
def test_get_many_equals_looped_get(seed, packed):
    c = _drive(seed, packed)
    for via in NODES:
        for quorum in (1, 2, len(NODES)):
            _assert_batched_equals_looped(c, list(KEYS), via, quorum)


def test_get_many_kernel_mask_equals_reference():
    """use_kernel=True routes the stacked sweep through the shape-bucketed
    Pallas mask; results must not change.  A low-sibling cluster keeps the
    interpret-mode kernel's K×K unroll cheap on the fast lane; the slow
    lane (`make test-read` / nightly) sweeps deep sibling sets below."""
    c, keys = _diverged(packed=True, n_keys=8)
    ref = c.get_many(keys, via="a", quorum=3)
    ker = c.get_many(keys, via="a", quorum=3, use_kernel=True)
    assert ref == ker
    assert any(r.siblings > 1 for r in ref.values())   # a real merge ran


@pytest.mark.slow
def test_get_many_kernel_mask_equals_reference_deep_siblings():
    c = _drive(3, packed=True)
    for quorum in (1, 2, len(NODES)):
        ref = c.get_many(list(KEYS), via="b", quorum=quorum)
        ker = c.get_many(list(KEYS), via="b", quorum=quorum,
                         use_kernel=True)
        assert ref == ker


def test_get_many_groups_by_quorum_set(monkeypatch):
    """Different keys contact different quorum sets through one proxy; the
    grouped merge must keep them apart (and still match looped get)."""
    import repro.store.cluster as cluster_mod
    c = _drive(11, packed=True)
    calls = []
    real = cluster_mod.quorum_merge_many

    def spy(stores_by_key, keys, **kw):
        calls.append(list(keys))
        return real(stores_by_key, keys, **kw)

    monkeypatch.setattr(cluster_mod, "quorum_merge_many", spy)
    _assert_batched_equals_looped(c, list(KEYS), "b", 2)
    # one grouped call for the whole batch, not one per key
    assert len(calls) == 1 and sorted(calls[0]) == sorted(KEYS)


def test_quorum_merge_key_is_one_key_view_of_many():
    c = _drive(5, packed=True)
    stores = [n.backend.packed for n in c.nodes.values()]
    for k in KEYS:
        values, walls, ckeys, entries = quorum_merge_key(stores, k)
        m = quorum_merge_many({k: stores}, [k])[k]
        assert (values, walls, ckeys, entries) == \
            (m.values, m.walls, m.clock_keys, m.entries)


def test_get_many_empty_and_absent_keys():
    c = _cluster(seed=2)
    assert c.get_many([]) == {}
    got = c.get_many(["nope", "nada"], quorum=2)
    for k in ("nope", "nada"):
        assert got[k].values == () and got[k].siblings == 0
        assert got[k].context.is_empty


# ---------------------------------------------------------------------------
# Admission: all keys resolved up front, no partial merges.
# ---------------------------------------------------------------------------

def test_get_many_admission_is_atomic(monkeypatch):
    """If ANY key cannot assemble its read quorum, ``Unavailable`` is
    raised before any store is touched — no partial merge, no repair."""
    import repro.store.cluster as cluster_mod
    c = KVCluster(("x", "y", "z"), DVV_MECHANISM, replication=1,
                  network=SimNetwork(seed=3))
    keys = [f"p{i}" for i in range(12)]
    for k in keys:
        c.put(k, f"v-{k}")
    c.deliver_replication()
    owners = {k: c.replicas_for(k)[0] for k in keys}
    assert {"x"} < set(owners.values())   # some keys at x, some elsewhere
    merges = []
    real = cluster_mod.quorum_merge_many
    monkeypatch.setattr(
        cluster_mod, "quorum_merge_many",
        lambda *a, **kw: merges.append(1) or real(*a, **kw))
    c.network.partition({"x"}, {"y", "z"})
    with pytest.raises(Unavailable):
        c.get_many(keys, via="x", quorum=1, repair=True)
    assert merges == []                   # raised before any merge
    assert c.network.pending() == 0       # and before any repair push
    # x-owned keys alone are admissible
    mine = [k for k in keys if owners[k] == "x"]
    got = c.get_many(mine, via="x", quorum=1)
    assert all(got[k].values == (f"v-{k}",) for k in mine)


def test_get_many_down_proxy():
    c = _cluster(seed=1)
    c.network.fail_node("a")
    with pytest.raises(Unavailable):
        c.get_many(list(KEYS), via="a")


# ---------------------------------------------------------------------------
# Read-repair: diverged quorums heal on the read path.
# ---------------------------------------------------------------------------

def _diverged(packed, seed=9, n_keys=30):
    """All replicas hold all keys; a partition plus dropped replication
    leaves the quorum diverged on a prefix of the keys."""
    nodes = ("a", "b", "c")
    c = _cluster(seed=seed, packed=packed, nodes=nodes)
    cl = KVClient(c, "t", via="a")
    keys = [f"k{i}" for i in range(n_keys)]
    cl.put_many({k: (f"base-{k}", None) for k in keys})
    c.deliver_replication()
    c.network.partition({"a"}, {"b", "c"})
    for k in keys[: n_keys // 2]:
        cl.put(k, f"fork-{k}", coordinator="a")
    c.network.heal()
    c.network.queue.clear()               # drop replication: reads must heal
    return c, keys


@pytest.mark.parametrize("packed", [True, False])
def test_read_repair_converges_in_one_batched_read(packed):
    c, keys = _diverged(packed)
    before = c.network.bytes_sent
    c.get_many(keys, via="a", quorum=3, repair=True)
    assert c.network.pending() > 0
    assert c.network.bytes_sent > before  # repair is priced on the wire
    c.deliver_replication()
    for n in c.nodes:
        for k in keys:
            assert c.nodes[n].versions(k) == c.nodes["a"].versions(k), (n, k)
    if packed:
        roots = {n.backend.packed.sync_digest().root
                 for n in c.nodes.values()}
        assert len(roots) == 1            # digests agree after repair
    # …and a converged quorum generates zero repair traffic
    b1 = c.network.bytes_sent
    c.get_many(keys, via="a", quorum=3, repair=True)
    assert c.network.bytes_sent == b1 and c.network.pending() == 0


def test_read_repair_one_consolidated_push_per_member():
    c, keys = _diverged(packed=True)
    c.get_many(keys, via="a", quorum=3, repair=True)
    # b and c each miss the fork writes: exactly one payload per member,
    # carrying ALL of its stale keys
    msgs = list(c.network.queue)
    assert sorted(m.dst for m in msgs) == ["b", "c"]
    for m in msgs:
        kind, payload = m.payload
        assert kind == "store" and isinstance(payload, PackedPayload)
        assert sorted(payload.keys) == sorted(keys[: len(keys) // 2])
        assert m.src == "a"               # the proxy coordinates repair


def test_read_repair_off_by_default_never_mutates():
    c, keys = _diverged(packed=True)
    before = c.network.bytes_sent
    c.get_many(keys, via="a", quorum=3)
    cl = KVClient(c, "s", via="a")
    cl.get_many(keys, quorum=3)           # session default is off too
    assert c.network.pending() == 0 and c.network.bytes_sent == before
    # sibling divergence is still visible (nothing healed behind our back)
    assert c.nodes["b"].versions(keys[0]) != c.nodes["a"].versions(keys[0])


def test_read_repair_client_session_default():
    c, keys = _diverged(packed=True)
    cl = KVClient(c, "healer", via="a", read_repair=True)
    cl.get_many(keys, quorum=3)
    c.deliver_replication()
    for n in c.nodes:
        for k in keys:
            assert c.nodes[n].versions(k) == c.nodes["a"].versions(k)
    # per-call override wins over the session default
    c2, keys2 = _diverged(packed=True)
    cl2 = KVClient(c2, "reader", via="a", read_repair=True)
    cl2.get_many(keys2, quorum=3, repair=False)
    assert c2.network.pending() == 0


def test_read_repair_stale_proxy_heals_locally():
    """When the proxy itself is a stale quorum member (the common case —
    local-read preference puts it first), repair applies the payload
    locally: no self-addressed message, no phantom wire bytes, and the
    proxy is healed immediately (not at the next delivery)."""
    c, keys = _diverged(packed=True)          # b and c missed a's forks
    b0 = c.network.bytes_sent
    c.get_many(keys, via="b", quorum=3, repair=True)
    msgs = list(c.network.queue)
    assert sorted(m.dst for m in msgs) == ["c"]    # only c gets a message
    sent = c.network.bytes_sent - b0
    from repro.store.network import payload_nbytes
    assert sent == sum(payload_nbytes(m.payload) for m in msgs)
    # b (the proxy) already holds the merged state, pre-delivery
    for k in keys:
        assert c.nodes["b"].versions(k) == c.nodes["a"].versions(k), k
    c.deliver_replication()
    for k in keys:
        assert c.nodes["c"].versions(k) == c.nodes["a"].versions(k), k
    b1 = c.network.bytes_sent
    c.get_many(keys, via="b", quorum=3, repair=True)
    assert c.network.bytes_sent == b1 and c.network.pending() == 0


def test_stale_detection_is_value_aware():
    """The §6.1 gap state — equal clocks, different values (impossible
    under the protocol, reachable via non-protocol bulk feeds) — must be
    FLAGGED stale, never read as converged.  Like the delta round's
    full-payload fallback, sync cannot reconcile it (the resident copy
    wins), so repaired reads keep flagging rather than masking it."""
    from repro.core.dvv import DVV
    from repro.store import Version
    from repro.store.bulk import bulk_receive_antientropy

    c = _cluster(seed=2, packed=True, nodes=("a", "b"))
    c.put("k", "v", coordinator="a")
    c.deliver_replication()
    clock = DVV((("rogue-writer", 0, 1),))
    bulk_receive_antientropy(c.nodes["a"],
                             {"rogue": frozenset({Version(clock, "X")})})
    bulk_receive_antientropy(c.nodes["b"],
                             {"rogue": frozenset({Version(clock, "Y")})})
    stores = [c.nodes["a"].backend.packed, c.nodes["b"].backend.packed]
    m = quorum_merge_many({"rogue": stores}, ["rogue"])["rogue"]
    assert m.stale == (1,)        # b's value diverges under an equal clock
    c.get_many(["rogue"], via="a", quorum=2, repair=True)
    assert c.network.pending() == 1         # flagged, not silently skipped
    c.deliver_replication()
    # …and, as documented, sync keeps the resident copy: the divergence
    # stays visible (and stays flagged) instead of being masked
    assert c.nodes["b"].versions("rogue") != c.nodes["a"].versions("rogue")


def test_merged_read_stale_signal():
    """``stale`` flags exactly the members whose row set differs from the
    survivors: behind members AND members holding dominated rows."""
    c, keys = _diverged(packed=True, n_keys=4)
    stores = {n: c.nodes[n].backend.packed for n in c.nodes}
    quorum = [stores["a"], stores["b"], stores["c"]]
    merged = quorum_merge_many({k: quorum for k in keys}, keys)
    for k in keys[:2]:                    # forked keys: b, c are stale
        assert merged[k].stale == (1, 2), k
    for k in keys[2:]:                    # converged keys: nobody is
        assert merged[k].stale == (), k
    # track_stale=False skips the bookkeeping but not the merge
    fast = quorum_merge_many({k: quorum for k in keys}, keys,
                             track_stale=False)
    for k in keys:
        assert fast[k].stale == ()
        assert fast[k].values == merged[k].values
        assert fast[k].entries == merged[k].entries


# ---------------------------------------------------------------------------
# dvv_read_sweep: fused survival + ceiling equals the numpy reference.
# ---------------------------------------------------------------------------

def test_dvv_read_sweep_matches_reference():
    from repro.kernels.dvv_ops import dvv_read_sweep

    rng = np.random.default_rng(0)
    N, K, R = 9, 4, 5
    vvs = rng.integers(0, 4, (N, K, R)).astype(np.int32)
    dot_ids = rng.integers(-1, R, (N, K)).astype(np.int32)
    has = dot_ids != B.NO_DOT
    dot_ns = np.where(
        has, np.take_along_axis(
            vvs, np.clip(dot_ids, 0, None)[..., None], axis=-1)[..., 0] + 1,
        0).astype(np.int32)
    valid = rng.random((N, K)) < 0.8
    mask, ceil = dvv_read_sweep(vvs, dot_ids, dot_ns, valid)
    mask, ceil = np.asarray(mask), np.asarray(ceil)
    want_mask = B.sync_mask_np(vvs, dot_ids, dot_ns, valid)
    assert np.array_equal(mask, want_mask)
    for n in range(N):
        s = np.flatnonzero(want_mask[n])
        want = B.grouped_ceiling_np(
            vvs[n][s], dot_ids[n][s], dot_ns[n][s],
            np.zeros(len(s), np.int64), 1)[0]
        assert np.array_equal(ceil[n], want), n


def test_grouped_ceiling_matches_per_key_reference():
    from repro.store.packed import ceiling_from_rows

    rng = np.random.default_rng(1)
    M, R, N = 40, 6, 7
    vvs = rng.integers(0, 5, (M, R)).astype(np.int32)
    dot_ids = rng.integers(-1, R, M).astype(np.int32)
    dot_ns = rng.integers(1, 9, M).astype(np.int32)
    dot_ns[dot_ids == B.NO_DOT] = 0
    groups = rng.integers(0, N, M)
    got = B.grouped_ceiling_np(vvs, dot_ids, dot_ns, groups, N)
    for g in range(N):
        s = np.flatnonzero(groups == g)
        assert np.array_equal(
            got[g], ceiling_from_rows(vvs[s], dot_ids[s], dot_ns[s])), g
    # empty input: all-zero ceilings, right shape
    assert B.grouped_ceiling_np(np.zeros((0, R), np.int32),
                                np.zeros(0, np.int32), np.zeros(0, np.int32),
                                np.zeros(0, np.int64), 3).shape == (3, R)


# ---------------------------------------------------------------------------
# Hypothesis fuzz (slow/property lane; see pytest.ini markers).
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @pytest.mark.slow
    @pytest.mark.property
    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=100_000), st.booleans(),
           st.sampled_from([1, 2, 4]))
    def test_get_many_conformance_fuzzed(seed, packed, quorum):
        c = _drive(seed, packed)
        _assert_batched_equals_looped(
            c, list(KEYS), random.Random(seed).choice(NODES), quorum)
        # repair leaves the read results themselves untouched…
        before = c.get_many(list(KEYS), via="a", quorum=quorum)
        repaired = c.get_many(list(KEYS), via="a", quorum=quorum,
                              repair=True)
        assert before == repaired
        c.deliver_replication()
        # …and a repaired+delivered quorum is read-quiescent
        again = c.get_many(list(KEYS), via="a", quorum=quorum, repair=True)
        assert c.network.pending() == 0
        assert again == repaired
except ImportError:     # deterministic seeds above still run
    pass
