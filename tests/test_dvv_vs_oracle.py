"""Property tests: DVV tracks causality *exactly* on arbitrary store schedules.

Strategy: hypothesis generates a random schedule of store operations
(puts with contexts from earlier gets, replication delivery, anti-entropy,
partitions).  The same schedule is executed in lockstep against

  * a cluster using dotted version vectors (the paper's mechanism), and
  * a cluster using explicit causal histories (the oracle, paper Fig. 1).

After every step we assert the paper's claims:

  1. every replica stores exactly the same *values* under both mechanisms
     (no lost updates, no spurious siblings);
  2. the DVV partial order of any two stored versions equals the inclusion
     order of their causal histories (lossless causality);
  3. the §5.4 downset invariant holds at every replica;
  4. the §4 sync conditions hold for DVV sync on observed version sets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Tuple

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

pytestmark = pytest.mark.property     # dedicated lane: `make test-property`

from repro.core import DVV_MECHANISM, downset, sync_conditions_hold
from repro.core.kernel import ORACLE_MECHANISM
from repro.core.dvv import sync as dvv_sync
from repro.store import KVCluster, SimNetwork, Unavailable

NODES = ("a", "b", "c")
CLIENTS = ("c1", "c2", "c3")
KEYS = ("k0", "k1")


@dataclass
class Op:
    kind: str
    args: Tuple = ()


def op_strategy():
    puts = st.tuples(
        st.sampled_from(CLIENTS), st.sampled_from(KEYS),
        st.sampled_from(NODES), st.booleans(),
    ).map(lambda t: Op("put", t))
    gets = st.tuples(st.sampled_from(CLIENTS), st.sampled_from(KEYS),
                     st.sampled_from(NODES)).map(lambda t: Op("get", t))
    deliver = st.just(Op("deliver"))
    ae = st.tuples(st.sampled_from(NODES), st.sampled_from(NODES)).map(
        lambda t: Op("antientropy", t))
    partition = st.sampled_from([
        Op("partition", (frozenset({"a"}), frozenset({"b", "c"}))),
        Op("partition", (frozenset({"a", "b"}), frozenset({"c"}))),
        Op("heal"),
    ])
    return st.lists(st.one_of(puts, gets, deliver, ae, partition),
                    min_size=1, max_size=25)


class LockstepRun:
    """Executes one schedule against both mechanisms simultaneously."""

    def __init__(self):
        self.dvv = KVCluster(NODES, DVV_MECHANISM, network=SimNetwork(seed=7))
        self.oracle = KVCluster(NODES, ORACLE_MECHANISM,
                                network=SimNetwork(seed=7))
        # last GET context per (client, key), per cluster
        self.ctx_dvv: Dict[Tuple[str, str], FrozenSet] = {}
        self.ctx_oracle: Dict[Tuple[str, str], FrozenSet] = {}
        self.counter = 0

    def execute(self, ops: List[Op]) -> None:
        for op in ops:
            getattr(self, f"_{op.kind}")(*op.args)
            self._check_invariants()

    # -- op handlers ---------------------------------------------------------
    def _put(self, client, key, node, use_context):
        self.counter += 1
        value = f"v{self.counter}"
        cd = self.ctx_dvv.get((client, key), frozenset()) if use_context else frozenset()
        co = self.ctx_oracle.get((client, key), frozenset()) if use_context else frozenset()
        try:
            self.dvv.put(key, value, context=cd, via=node, coordinator=node,
                         client_id=client)
            ok_d = True
        except Unavailable:
            ok_d = False
        try:
            self.oracle.put(key, value, context=co, via=node, coordinator=node,
                            client_id=client)
            ok_o = True
        except Unavailable:
            ok_o = False
        assert ok_d == ok_o

    def _get(self, client, key, node):
        try:
            rd = self.dvv.get(key, via=node)
            ro = self.oracle.get(key, via=node)
        except Unavailable:
            return
        assert rd.values == ro.values
        assert rd.siblings == ro.siblings
        self.ctx_dvv[(client, key)] = rd.context
        self.ctx_oracle[(client, key)] = ro.context

    def _deliver(self):
        self.dvv.deliver_replication()
        self.oracle.deliver_replication()

    def _antientropy(self, src, dst):
        if src == dst:
            return
        try:
            self.dvv.antientropy(src, dst)
            self.oracle.antientropy(src, dst)
        except Unavailable:
            pass

    def _partition(self, g1, g2):
        self.dvv.network.partition(set(g1), set(g2))
        self.oracle.network.partition(set(g1), set(g2))

    def _heal(self):
        self.dvv.network.heal()
        self.oracle.network.heal()

    # -- invariants ------------------------------------------------------------
    def _check_invariants(self):
        for node_id in NODES:
            nd = self.dvv.nodes[node_id]
            no = self.oracle.nodes[node_id]
            for key in KEYS:
                vd = nd.versions(key)
                vo = no.versions(key)
                # (1) identical value sets at every replica
                assert {v.value for v in vd} == {v.value for v in vo}, (
                    node_id, key, vd, vo)
                # (3) downset invariant
                assert downset(v.clock for v in vd)
                # (2) order agreement, matching versions by value
                by_val_o = {v.value: v.clock for v in vo}
                vd_list = list(vd)
                for i, x in enumerate(vd_list):
                    for y in vd_list[i + 1:]:
                        hx, hy = by_val_o[x.value], by_val_o[y.value]
                        assert x.clock.leq(y.clock) == hx.leq(hy)
                        assert y.clock.leq(x.clock) == hy.leq(hx)
                # cross-replica order agreement for this key
                for other_id in NODES:
                    if other_id == node_id:
                        continue
                    vo2 = {v.value: v.clock
                           for v in self.oracle.nodes[other_id].versions(key)}
                    vd2 = {v.value: v.clock
                           for v in self.dvv.nodes[other_id].versions(key)}
                    for x in vd_list:
                        for val2, c2 in vd2.items():
                            if val2 == x.value:
                                continue
                            ho = by_val_o[x.value]
                            h2 = vo2[val2]
                            assert x.clock.leq(c2) == ho.leq(h2), (
                                x, val2, c2, ho, h2)
                # (4) §4 sync conditions on the actual clock sets
                cd1 = frozenset(v.clock for v in vd)
                for other_id in NODES:
                    cd2 = frozenset(
                        v.clock for v in self.dvv.nodes[other_id].versions(key))
                    s = dvv_sync(cd1, cd2)
                    assert sync_conditions_hold(cd1, cd2, s)


@settings(max_examples=120, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(op_strategy())
def test_dvv_matches_causal_history_oracle(ops):
    LockstepRun().execute(ops)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(op_strategy())
def test_vv_client_stateful_matches_oracle_values(ops):
    """§3.3: per-client VV with *stateful* clients is also accurate (but its
    metadata grows with the client population — see benchmarks)."""
    from repro.core import VV_CLIENT_MECHANISM

    dvv = KVCluster(NODES, VV_CLIENT_MECHANISM, network=SimNetwork(seed=7))
    oracle = KVCluster(NODES, ORACLE_MECHANISM, network=SimNetwork(seed=7))
    counters = {c: 0 for c in CLIENTS}
    ctx_a: Dict[Tuple[str, str], FrozenSet] = {}
    ctx_b: Dict[Tuple[str, str], FrozenSet] = {}
    counter = 0
    for op in ops:
        if op.kind == "put":
            client, key, node, use_context = op.args
            counter += 1
            counters[client] += 1
            ca = ctx_a.get((client, key), frozenset()) if use_context else frozenset()
            cb = ctx_b.get((client, key), frozenset()) if use_context else frozenset()
            try:
                dvv.put(key, f"v{counter}", context=ca, via=node,
                        coordinator=node, client_id=client,
                        client_counter=counters[client])
                oracle.put(key, f"v{counter}", context=cb, via=node,
                           coordinator=node, client_id=client)
            except Unavailable:
                continue
        elif op.kind == "get":
            client, key, node = op.args
            try:
                ra = dvv.get(key, via=node)
                rb = oracle.get(key, via=node)
            except Unavailable:
                continue
            ctx_a[(client, key)] = ra.context
            ctx_b[(client, key)] = rb.context
            # NOTE: stateful per-client VV requires read-your-writes for
            # accuracy; our schedule satisfies it because a client's context
            # always comes from a get *after* its own put was coordinated.
        elif op.kind == "deliver":
            dvv.deliver_replication()
            oracle.deliver_replication()
        elif op.kind == "antientropy":
            src, dst = op.args
            if src != dst:
                try:
                    dvv.antientropy(src, dst)
                    oracle.antientropy(src, dst)
                except Unavailable:
                    pass
        elif op.kind == "partition":
            g1, g2 = op.args
            dvv.network.partition(set(g1), set(g2))
            oracle.network.partition(set(g1), set(g2))
        elif op.kind == "heal":
            dvv.network.heal()
            oracle.network.heal()
