"""Shared test configuration: hypothesis profiles.

The scheduled CI lane exports ``HYPOTHESIS_PROFILE=ci``; registering the
profile here keeps that opt-in from erroring and relaxes the health
checks for the long fault-injection schedules (per-test ``@settings``
still pin their own example budgets).  Everything guards on the import:
hypothesis is an optional dev dependency and the deterministic pinned
phases of every suite run without it.
"""
import os

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", deadline=None, print_blob=True,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much])
    if os.environ.get("HYPOTHESIS_PROFILE") == "ci":
        settings.load_profile("ci")
except ImportError:
    pass
