"""Conformance: the array-resident packed store is observationally equal to
the object store on randomized PUT/GET/sync/partition schedules.

Twin KVClusters — one with ``packed=True`` (int32 arrays resident, the
default for DVV), one with ``packed=False`` (Python ``DVV`` objects, the
reference semantics) — execute identical schedules; after every phase all
per-node version sets, values, sibling counts and metadata sizes must
match.  Schedules include *dynamic universe growth*: coordinators outside
the initial replica set join mid-run, forcing replica-id interning and
column growth in the packed store.

Runs deterministically on fixed seeds; when hypothesis is available the
same driver is additionally fuzzed.
"""
import random

import numpy as np
import pytest

from repro.core import DVV_MECHANISM
from repro.core import batched as B
from repro.store import (
    KVCluster, PackedPayload, PackedVersionStore, SimNetwork, Unavailable,
)
from repro.store.bulk import bulk_receive_antientropy, bulk_sync

KEYS = tuple(f"k{i}" for i in range(6))


# ---------------------------------------------------------------------------
# The schedule driver (shared by deterministic and hypothesis runs).
# ---------------------------------------------------------------------------

def _drive(packed: bool, seed: int, ops: int = 120, *,
           grow_universe: bool = True) -> KVCluster:
    """Run one randomized schedule; identical seeds ⇒ identical schedules."""
    rng = random.Random(seed)
    nodes = ("a", "b", "c", "d")
    # Universe growth: only the first two nodes coordinate for the first
    # half of the run; c and d appear later, growing every packed store's
    # replica universe mid-flight.
    c = KVCluster(nodes, DVV_MECHANISM, network=SimNetwork(seed=seed),
                  packed=packed)
    contexts = {}
    for i in range(ops):
        active = nodes if (not grow_universe or i > ops // 2) else nodes[:2]
        key, node = rng.choice(KEYS), rng.choice(active)
        p = rng.random()
        if p < 0.25:
            try:
                contexts[(node, key)] = c.get(key, via=node).context
            except Unavailable:
                pass
        elif p < 0.70:
            ctx = contexts.get((node, key), frozenset()) \
                if rng.random() < 0.6 else frozenset()
            c.put(key, f"v{i}", context=ctx, via=node, coordinator=node)
        elif p < 0.80:
            c.deliver_replication()
        elif p < 0.90:
            c.antientropy_round()
        elif p < 0.95:
            halves = set(rng.sample(nodes, 2))
            c.network.partition(halves, set(nodes) - halves)
        else:
            c.network.heal()
    c.network.heal()
    c.deliver_replication()
    c.antientropy_round()
    return c


def _assert_equal(c_packed: KVCluster, c_obj: KVCluster, tag) -> None:
    for n in c_packed.nodes:
        for k in KEYS:
            vp = c_packed.nodes[n].versions(k)
            vo = c_obj.nodes[n].versions(k)
            assert vp == vo, (tag, n, k, vp, vo)
            assert (c_packed.nodes[n].metadata_size(k)
                    == c_obj.nodes[n].metadata_size(k)), (tag, n, k)
        assert c_packed.nodes[n].is_packed
        assert not c_obj.nodes[n].is_packed


@pytest.mark.parametrize("seed", [0, 1, 7, 42, 1234])
def test_packed_equals_object_on_random_schedules(seed):
    c_packed = _drive(True, seed)
    c_obj = _drive(False, seed)
    _assert_equal(c_packed, c_obj, seed)


def test_universe_growth_mid_run():
    """New coordinators join mid-run; packed column growth must be exact."""
    c_packed = _drive(True, 99, ops=200, grow_universe=True)
    c_obj = _drive(False, 99, ops=200, grow_universe=True)
    _assert_equal(c_packed, c_obj, "grow")
    # all four replicas actually minted events
    some = c_packed.nodes["a"].backend.packed
    assert some.n_replicas >= 4


# ---------------------------------------------------------------------------
# Bulk anti-entropy: arrays in, arrays out; kernel path equals reference.
# ---------------------------------------------------------------------------

def _diverged(packed: bool, seed: int = 5) -> KVCluster:
    rng = random.Random(seed)
    nodes = ("a", "b", "c")
    c = KVCluster(nodes, DVV_MECHANISM, network=SimNetwork(seed=seed),
                  packed=packed)
    for i in range(60):
        c.put(rng.choice(KEYS), f"v{i}", via=rng.choice(nodes),
              coordinator=rng.choice(nodes))
    c.network.queue.clear()   # drop replication: maximum divergence
    return c


def test_packed_payload_roundtrip_and_equality():
    c = _diverged(True)
    p1 = c.nodes["a"].antientropy_payload()
    p2 = c.nodes["a"].antientropy_payload()
    assert isinstance(p1, PackedPayload)
    assert p1 == p2
    assert len(p1) == c.nodes["a"].backend.packed.total_versions()


@pytest.mark.parametrize("use_kernel", [False, True])
def test_bulk_antientropy_packed_matches_object(use_kernel):
    cp = _diverged(True)
    co = _diverged(False)
    # packed → arrays end to end; object → per-key object sync
    payload_p = cp.nodes["a"].antientropy_payload()
    payload_o = co.nodes["a"].antientropy_payload()
    changed_p = bulk_receive_antientropy(cp.nodes["b"], payload_p,
                                         use_kernel=use_kernel)
    changed_o = co.nodes["b"].receive_antientropy(payload_o)
    assert changed_p == changed_o
    for k in KEYS:
        assert cp.nodes["b"].versions(k) == co.nodes["b"].versions(k), k
    # convergence: re-applying the same payload changes nothing
    assert bulk_receive_antientropy(cp.nodes["b"],
                                    cp.nodes["a"].antientropy_payload(),
                                    use_kernel=use_kernel) == 0


def test_bulk_sync_object_entrypoint_empty_and_disjoint():
    assert bulk_sync({}, {}) == {}
    c = _diverged(False)
    only_local = {k: c.nodes["a"].versions(k) for k in KEYS[:2]}
    out = bulk_sync(only_local, {})
    for k in KEYS[:2]:
        assert out[k] == only_local[k]


def test_bulk_sync_empty_universe_zero_clock():
    """Dotless/zero clocks through the public bulk_sync must not crash on an
    empty replica universe (R=0 staging store)."""
    from repro.core.dvv import DVV
    from repro.store import Version

    z = Version(DVV.zero(), "a")
    out = bulk_sync({}, {"k": frozenset({z})})
    assert out["k"] == frozenset({z})
    out2 = bulk_sync({"k": frozenset({z})}, {"k": frozenset({z})})
    assert out2["k"] == frozenset({z})


def test_bulk_sync_prunes_dominated_locals_without_incoming():
    """sync() semantics hold per key even when a key has no incoming rows:
    an internally dominated local set is reduced to its antichain."""
    from repro.core.dvv import DVV
    from repro.store import Version

    low = Version(DVV((("a", 0, 1),)), "old")
    high = Version(DVV((("a", 1, 2),)), "new")
    out = bulk_sync({"k": frozenset({low, high})}, {})
    assert out["k"] == frozenset({high})
    # and mixed: one key with incoming, one without — both pruned
    out2 = bulk_sync({"k": frozenset({low, high}), "j": frozenset({low})},
                     {"j": frozenset({high})})
    assert out2["k"] == frozenset({high})
    assert out2["j"] == frozenset({high})


def test_apply_payload_with_duplicate_keys_does_not_double_insert():
    c = _diverged(True)
    store = c.nodes["a"].backend.packed
    dup = store.payload([KEYS[0], KEYS[0], KEYS[1]])
    dst = c.nodes["b"].backend.packed
    before = dst.total_versions()
    dst.apply_payload(dup)
    after = {k: dst.versions(k) for k in KEYS[:2]}
    dst.apply_payload(dup)   # idempotent — and no duplicate slots
    assert {k: dst.versions(k) for k in KEYS[:2]} == after
    for k in KEYS[:2]:
        assert len(dst.versions(k)) == len({v.clock for v in dst.versions(k)})
    assert dst.total_versions() <= before + len(dup)


def test_bulk_receive_on_object_backend_uses_batched_path():
    """Object-backend DVV nodes must honor use_kernel (batched sweep), and
    agree with the per-key object walk."""
    co = _diverged(False)
    ref = _diverged(False)
    payload = co.nodes["a"].antientropy_payload()
    changed_k = bulk_receive_antientropy(co.nodes["b"], payload,
                                         use_kernel=True)
    changed_o = ref.nodes["b"].receive_antientropy(
        ref.nodes["a"].antientropy_payload())
    assert changed_k == changed_o
    for k in KEYS:
        assert co.nodes["b"].versions(k) == ref.nodes["b"].versions(k), k


def test_steady_state_antientropy_is_array_native():
    """The acceptance criterion: zero per-key DVV encode/decode in the
    steady-state bulk path — verified by monkeypatching the codec."""
    import repro.core.batched as batched

    cp = _diverged(True)
    payload = cp.nodes["a"].antientropy_payload()
    assert isinstance(payload, PackedPayload)

    calls = {"encode": 0, "decode": 0}
    real_encode, real_decode = batched.encode, batched.decode
    enc = cp.nodes["b"].backend.packed.encode_clock

    def count_encode(*a, **kw):
        calls["encode"] += 1
        return real_encode(*a, **kw)

    def count_decode(*a, **kw):
        calls["decode"] += 1
        return real_decode(*a, **kw)

    batched.encode, batched.decode = count_encode, count_decode
    cp.nodes["b"].backend.packed.encode_clock = None  # would raise if used
    try:
        bulk_receive_antientropy(cp.nodes["b"], payload)
        bulk_receive_antientropy(cp.nodes["b"], payload, use_kernel=True)
    finally:
        batched.encode, batched.decode = real_encode, real_decode
        cp.nodes["b"].backend.packed.encode_clock = enc
    assert calls == {"encode": 0, "decode": 0}


# ---------------------------------------------------------------------------
# PackedVersionStore unit behaviour.
# ---------------------------------------------------------------------------

def test_compaction_preserves_state():
    c = _diverged(True, seed=11)
    store = c.nodes["a"].backend.packed
    before = {k: store.versions(k) for k in KEYS}
    store.compact(force=True)
    assert {k: store.versions(k) for k in KEYS} == before
    assert store.n_dead == 0
    assert store.valid[: store.n_slots].all()


def test_slot_capacity_and_column_growth():
    s = PackedVersionStore()
    # force growth well past both initial capacities
    for i in range(300):
        r = f"replica{i % 13}"
        rix = s.intern_replica(r)
        vv = np.zeros(s.n_replicas, np.int32)
        vv[rix] = i // 13
        s.sync_key(f"key{i % 7}", vv[None, :],
                   np.asarray([rix], np.int32),
                   np.asarray([i // 13 + 1], np.int32), [f"v{i}"])
    assert s.n_replicas == 13
    assert s.total_keys() == 7
    # every stored clock still satisfies the one-dot invariant n > m
    live = np.flatnonzero(s.valid[: s.n_slots])
    at = s.dot_id[live]
    assert (s.dot_n[live] > s.vv[live, at]).all()


def test_numpy_twin_matches_jnp_sync_mask():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    N, K, R = 23, 4, 5
    vvs = rng.integers(0, 6, (N, K, R)).astype(np.int32)
    dids = rng.integers(-1, R, (N, K)).astype(np.int32)
    dns = np.where(
        dids >= 0,
        np.take_along_axis(vvs, np.clip(dids, 0, None)[..., None],
                           axis=-1)[..., 0] + rng.integers(1, 4, (N, K)),
        0).astype(np.int32)
    valid = rng.random((N, K)) < 0.8
    ref = np.asarray(B.sync_mask(jnp.asarray(vvs), jnp.asarray(dids),
                                 jnp.asarray(dns), jnp.asarray(valid)))
    got = B.sync_mask_np(vvs, dids, dns, valid)
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# Hypothesis fuzzing of the same driver (optional dependency).
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=100_000),
           st.booleans())
    def test_packed_equals_object_fuzzed(seed, grow):
        c_packed = _drive(True, seed, grow_universe=grow)
        c_obj = _drive(False, seed, grow_universe=grow)
        _assert_equal(c_packed, c_obj, (seed, grow))
except ImportError:     # deterministic seeds above still run
    pass
