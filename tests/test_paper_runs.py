"""The paper's example runs (Figures 1, 2, 3, 4, 7) as executable tests.

Three clients concurrently modify the same key on two replica nodes
(Ra, Rb).  Each figure exercises one causality mechanism; the assertions
encode the outcome the paper derives for it — including the *failures* of
the baselines (lost updates, false dominance), which are the paper's
motivation for DVV.
"""
import pytest

from repro.core import (
    DVV, VV, CausalHistory, LamportClock, WallClock,
    sync, update, downset,
)
from repro.core.version_vector import (
    merge_all, sync_vv, update_per_server, update_per_client_inferred,
)
from repro.core.lww import lamport_update


# ---------------------------------------------------------------------------
# Figure 1 — causal histories (the oracle).
# ---------------------------------------------------------------------------

def test_fig1_causal_histories():
    # C1: PUT v at Rb with context {} -> {b1}
    v = CausalHistory.of(("b", 1))
    # C2: PUT w at Rb with context {} -> {b2}; Rb keeps both (concurrent)
    w = CausalHistory.of(("b", 2))
    assert v.concurrent(w)
    # C3: PUT x at Ra -> {a1}; then reads it and PUTs y -> {a1, a2}
    x = CausalHistory.of(("a", 1))
    y = CausalHistory.of(("a", 1), ("a", 2))
    assert x.lt(y)           # y supersedes x at Ra
    assert y.concurrent(v) and y.concurrent(w)


# ---------------------------------------------------------------------------
# Figure 2 — perfectly synchronized real-time clocks: total order, and
# a concurrent update is silently lost under last-writer-wins.
# ---------------------------------------------------------------------------

def test_fig2_wallclock_lww_loses_concurrent_update():
    v = (WallClock(1.0, "C1"), "v")
    w = (WallClock(2.0, "C2"), "w")
    # Rb applies LWW: w overwrites v although they are causally concurrent.
    kept = w if v[0].lt(w[0]) else v
    assert kept[1] == "w"        # v is lost — the paper's complaint
    assert not v[0].concurrent(w[0])  # total order admits no concurrency


def test_fig2_skewed_clock_always_loses():
    # A client whose clock is persistently behind never gets its write kept.
    slow = WallClock(0.5, "slow")       # real time was later, clock says 0.5
    fast = WallClock(10.0, "fast")
    assert slow.lt(fast)


# ---------------------------------------------------------------------------
# Figure 3 — version vectors with per-server entries (Dynamo).
# Cross-server concurrency is detected, same-server concurrency is NOT.
# ---------------------------------------------------------------------------

def test_fig3_vv_per_server():
    # C1: PUT v at Rb, context {} -> {(b,1)}
    v = update_per_server(VV.zero(), frozenset(), "b")
    assert v == VV.from_dict({"b": 1})
    Sb = frozenset({v})
    # C2: PUT w at Rb, context {} -> {(b,2)}: FALSELY dominates v.
    w = update_per_server(VV.zero(), Sb, "b")
    assert w == VV.from_dict({"b": 2})
    assert v.lt(w)                      # false dominance (should be concurrent)
    Sb = sync_vv(Sb, frozenset({w}))
    assert Sb == frozenset({w})         # v was silently lost
    # C3 at Ra: PUT x {} -> {(a,1)}; read; PUT y -> {(a,2)}
    x = update_per_server(VV.zero(), frozenset(), "a")
    Sa = frozenset({x})
    y = update_per_server(x, Sa, "a")
    assert y == VV.from_dict({"a": 2})
    # Cross-server concurrency IS detected: {(a,2)} || {(b,2)}
    assert y.concurrent(w)


# ---------------------------------------------------------------------------
# Figure 4 — per-client entries with stateless clients (inferred counter):
# switching replicas repeats a counter and loses an update.
# ---------------------------------------------------------------------------

def test_fig4_vv_per_client_inferred_loses_update():
    # C1: PUT v at Rb, context {} -> {(C1,1)}
    v = update_per_client_inferred(VV.zero(), frozenset(), "C1")
    assert v == VV.from_dict({"C1": 1})
    # C3: PUT x at Ra -> {(C3,1)}
    x = update_per_client_inferred(VV.zero(), frozenset(), "C3")
    Sa = frozenset({x})
    # C1 (no affinity) reads x's context from Ra and PUTs y at Ra.
    # Ra has never seen C1, so it re-issues (C1,1):
    y = update_per_client_inferred(x, Sa, "C1")
    assert y == VV.from_dict({"C1": 1, "C3": 1})
    # v now appears dominated by y although they are causally concurrent:
    assert v.lt(y)                      # the Fig. 4 lost update


# ---------------------------------------------------------------------------
# Lamport clocks (§3.1) — total order, no concurrency.
# ---------------------------------------------------------------------------

def test_lamport_total_order():
    c1 = lamport_update(frozenset(), frozenset(), "b")
    c2 = lamport_update(frozenset(), frozenset({c1}), "b")
    assert c1.lt(c2) and not c1.concurrent(c2)


# ---------------------------------------------------------------------------
# Figure 7 — dotted version vectors: full causality with per-server ids.
# ---------------------------------------------------------------------------

def test_fig7_dvv_full_run():
    empty = frozenset()
    # C1: PUT v at Rb, context {} -> (b,0,1)
    Sb = frozenset()
    cv = update(empty, Sb, "b")
    assert cv == DVV.from_dict({"b": (0, 1)})
    Sb = sync(Sb, frozenset({cv}))
    # C2: PUT w at Rb, context {} -> (b,0,2); concurrent sibling KEPT
    cw = update(empty, Sb, "b")
    assert cw == DVV.from_dict({"b": (0, 2)})
    assert cv.concurrent(cw)
    Sb = sync(Sb, frozenset({cw}))
    assert Sb == frozenset({cv, cw})    # no lost update (unlike Fig. 3)
    # C3: PUT x at Ra -> (a,0,1); read; PUT y -> (a,1,2) replacing x
    Sa = frozenset()
    cx = update(empty, Sa, "a")
    assert cx == DVV.from_dict({"a": (0, 1)})
    Sa = sync(Sa, frozenset({cx}))
    cy = update(frozenset({cx}), Sa, "a")
    assert cy == DVV.from_dict({"a": (1, 2)})
    Sa = sync(Sa, frozenset({cy}))
    assert Sa == frozenset({cy})
    # anti-entropy Rb -> Ra
    Sa = sync(Sa, Sb)
    assert Sa == frozenset({cy, cv, cw})
    # C2 reads {v,w} from Rb, writes z at Ra: z = {(a,0,3),(b,2)}
    cz = update(Sb, Sa, "a")
    assert cz == DVV.from_dict({"a": (0, 3), "b": (2,)})
    Sa = sync(Sa, frozenset({cz}))
    assert Sa == frozenset({cy, cz})    # z subsumed v,w; concurrent with y
    assert cz.concurrent(cy)
    assert downset(Sa) and downset(Sb)


def test_paper_52_example_same_server_concurrency():
    """§5.2: {(r,4)} || {(r,3,5)} — concurrency within one replica's id."""
    a = DVV.from_dict({"r": (4,)})
    b = DVV.from_dict({"r": (3, 5)})
    assert a.concurrent(b)
    # and their histories confirm it
    assert a.to_history().concurrent(b.to_history())


def test_dvv_semantics_examples():
    """§5.1: {(a,2),(b,1),(c,3,7)} represents {a1,a2,b1,c1,c2,c3,c7}."""
    c = DVV.from_dict({"a": (2,), "b": (1,), "c": (3, 7)})
    expected = CausalHistory.of(
        ("a", 1), ("a", 2), ("b", 1), ("c", 1), ("c", 2), ("c", 3), ("c", 7))
    assert c.to_history() == expected
