"""Per-architecture smoke tests: reduced config of the same family, one
forward + train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import (
    decode_step, forward, init_cache, init_params, loss_fn,
)
from repro.optim import AdamWConfig, adamw_update, init_opt_state

ARCHS = sorted(REGISTRY)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    if cfg.input_mode == "tokens":
        toks = rng.integers(0, cfg.vocab_size, size=(B, S + 1))
        batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    else:
        emb = rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        labels = rng.integers(0, cfg.vocab_size, size=(B, S))
        batch = {"embeddings": jnp.asarray(emb),
                 "labels": jnp.asarray(labels, jnp.int32)}
        if cfg.mrope:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
            batch["positions"] = pos
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = REGISTRY[arch].smoke()
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    B = batch["labels"].shape[0]
    S = batch["labels"].shape[1]
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = REGISTRY[arch].smoke()
    params = init_params(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = init_opt_state(params, opt_cfg)
    batch = _batch(cfg)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, loss, opt_metrics

    loss0 = None
    for _ in range(3):
        params, opt_state, loss, om = step(params, opt_state, batch)
        assert bool(jnp.isfinite(loss)), f"{arch}: loss diverged"
        assert bool(jnp.isfinite(om["grad_norm"]))
        if loss0 is None:
            loss0 = float(loss)
    # same batch thrice: loss must drop
    assert float(loss) < loss0, f"{arch}: no learning signal ({loss0} -> {loss})"


DECODER_ARCHS = [a for a in ARCHS if REGISTRY[a].is_decoder]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_smoke_decode(arch):
    cfg = REGISTRY[arch].smoke()
    params = init_params(jax.random.key(0), cfg)
    B, L = 2, 8
    cache = init_cache(cfg, B, L)
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
    for t in range(4):
        if cfg.input_mode == "tokens":
            tok = jnp.full((B,), t % cfg.vocab_size, jnp.int32)
        else:
            tok = jnp.ones((B, cfg.d_model), jnp.float32) * 0.01
        logits, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: decode NaN at {t}"


def test_registry_complete():
    assert len(REGISTRY) == 10
    families = {cfg.family for cfg in REGISTRY.values()}
    assert families == {"hybrid", "dense", "moe", "audio", "vlm", "ssm"}
