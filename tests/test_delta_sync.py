"""Conformance for delta anti-entropy (DESIGN.md §6).

Twin packed clusters execute identical randomized PUT/GET/partition/heal
schedules — one converges with digest-diffed *delta* rounds, the other with
the one-shot full-payload round (the conformance reference).  After every
schedule the stores must be byte-identical: equal version sets, metadata
sizes, and digest trees per node.  Schedules include mid-run
replica-universe growth, and a forced digest-collision probe documents the
probabilistic guarantee plus the full-round safety net.

Also covered here: the digest tree itself (incremental == recomputed,
width folding, diff descent), ``payload(key_ranges=...)`` slicing, and the
shape-bucketed jit-cached ``sync_mask`` (pad-row inertness, cache hits).
"""
import random

import numpy as np
import pytest

from repro.core import DVV_MECHANISM
from repro.core import batched as B
from repro.kernels.dvv_ops import dvv_sync_mask_bucketed
from repro.store import KVCluster, SimNetwork, Unavailable
from repro.store.bulk import bulk_receive_antientropy, delta_antientropy
from repro.store.packed import PackedPayload, PackedVersionStore, key_bucket

KEYS = tuple(f"k{i}" for i in range(6))


# ---------------------------------------------------------------------------
# Schedule driver: identical seeds ⇒ identical schedules; only the
# anti-entropy flavour differs between the twins.
# ---------------------------------------------------------------------------

def _drive(delta: bool, seed: int, ops: int = 120, *,
           grow_universe: bool = True, use_kernel: bool = False) -> KVCluster:
    rng = random.Random(seed)
    nodes = ("a", "b", "c", "d")
    c = KVCluster(nodes, DVV_MECHANISM, network=SimNetwork(seed=seed))

    def round_():
        if delta:
            c.delta_antientropy_round(use_kernel=use_kernel)
        else:
            c.antientropy_round()

    contexts = {}
    for i in range(ops):
        active = nodes if (not grow_universe or i > ops // 2) else nodes[:2]
        key, node = rng.choice(KEYS), rng.choice(active)
        p = rng.random()
        if p < 0.25:
            try:
                contexts[(node, key)] = c.get(key, via=node).context
            except Unavailable:
                pass
        elif p < 0.70:
            ctx = contexts.get((node, key), frozenset()) \
                if rng.random() < 0.6 else frozenset()
            c.put(key, f"v{i}", context=ctx, via=node, coordinator=node)
        elif p < 0.80:
            c.deliver_replication()
        elif p < 0.90:
            round_()
        elif p < 0.95:
            halves = set(rng.sample(nodes, 2))
            c.network.partition(halves, set(nodes) - halves)
        else:
            c.network.heal()
    c.network.heal()
    c.deliver_replication()
    round_()
    round_()          # both flavours need two push rounds for all-pairs
    return c


def _assert_byte_identical(c_delta: KVCluster, c_full: KVCluster, tag):
    for n in c_delta.nodes:
        sd = c_delta.nodes[n].backend.packed
        sf = c_full.nodes[n].backend.packed
        for k in KEYS:
            assert c_delta.nodes[n].versions(k) == \
                c_full.nodes[n].versions(k), (tag, n, k)
            assert c_delta.nodes[n].metadata_size(k) == \
                c_full.nodes[n].metadata_size(k), (tag, n, k)
        # digest trees agree (possibly at different widths — fold)
        w = min(sd.n_buckets, sf.n_buckets)
        assert len(sd.sync_digest().diff(sf.sync_digest())) == 0, (tag, n)
        np.testing.assert_array_equal(
            sd.sync_digest().fold(w).leaves, sf.sync_digest().fold(w).leaves)
        # and the incremental state matches a from-scratch recompute
        assert sd.check_digests(), (tag, n)
        assert sf.check_digests(), (tag, n)


@pytest.mark.parametrize("seed", [0, 3, 11, 42])
def test_delta_rounds_equal_full_rounds(seed):
    c_delta = _drive(True, seed)
    c_full = _drive(False, seed)
    _assert_byte_identical(c_delta, c_full, seed)


def test_delta_rounds_with_universe_growth_and_kernel():
    c_delta = _drive(True, 99, ops=160, grow_universe=True, use_kernel=True)
    c_full = _drive(False, 99, ops=160, grow_universe=True)
    _assert_byte_identical(c_delta, c_full, "grow+kernel")
    assert c_delta.nodes["a"].backend.packed.n_replicas >= 4


def test_converged_round_ships_nothing():
    c = _drive(True, 7)
    stats = c.delta_antientropy_round()
    assert stats and all(s.buckets_divergent == 0 for s in stats)
    assert all(s.payload_slots == 0 and s.payload_bytes == 0 for s in stats)
    assert all(not s.fallback for s in stats)


def test_delta_stats_accounting():
    """Divergence on one key ⇒ one divergent bucket, a sliced payload far
    below the full payload, and payload bytes reported."""
    c = _drive(True, 13)
    c.network.partition({"a"}, {"b", "c", "d"})
    c.put(KEYS[0], "lonely-write", via="a", coordinator="a")
    c.network.heal()
    full = c.nodes["a"].backend.packed.payload()
    st = c.delta_antientropy("a", "b")
    assert st.buckets_divergent == 1
    assert st.changed == 1
    assert 0 < st.payload_slots < len(full)
    assert 0 < st.payload_bytes < full.nbytes()
    assert st.digest_bytes > 0
    # dst converged; a second round is a pure digest no-op
    st2 = c.delta_antientropy("a", "b")
    assert (st2.buckets_divergent, st2.payload_slots, st2.changed) == (0, 0, 0)


def test_capped_bidirectional_rounds_converge():
    """``max_ranges`` caps one push, including on receiver-ahead ranges a
    push cannot fix — but bidirectional rounds drain those from the other
    side, so repeated capped rounds converge (bounded by bucket count)."""
    c = _drive(True, 17)
    c.network.partition({"a", "b"}, {"c", "d"})
    rng = random.Random(17)
    for i in range(20):
        side = ("a", "c")[i % 2]
        c.put(rng.choice(KEYS), f"cap{i}", via=side, coordinator=side)
    c.network.heal()
    c.deliver_replication()
    for _ in range(c.nodes["a"].backend.packed.n_buckets):
        stats = c.delta_antientropy_round(max_ranges=1)
        if all(s.buckets_divergent == 0 for s in stats):
            break
    else:
        pytest.fail("capped rounds did not converge")
    a = c.nodes["a"].backend.packed
    for n in ("b", "c", "d"):
        other = c.nodes[n].backend.packed
        assert len(a.sync_digest().diff(other.sync_digest())) == 0, n
        for k in KEYS:
            assert c.nodes[n].versions(k) == c.nodes["a"].versions(k), (n, k)


def test_delta_fallback_on_object_backend():
    c = KVCluster(("a", "b"), DVV_MECHANISM, packed=False,
                  network=SimNetwork(seed=5))
    for i in range(20):
        c.put(KEYS[i % 3], f"v{i}", via="a", coordinator="a")
    c.network.queue.clear()
    st = c.delta_antientropy("a", "b")
    assert st.fallback
    for k in KEYS[:3]:
        assert c.nodes["b"].versions(k) == c.nodes["a"].versions(k)


# ---------------------------------------------------------------------------
# Digest tree unit behaviour.
# ---------------------------------------------------------------------------

def _loaded_store(n_keys: int, seed: int = 0) -> PackedVersionStore:
    rng = np.random.default_rng(seed)
    s = PackedVersionStore()
    for i in range(4):
        s.intern_replica(f"r{i}")
    for i in range(n_keys):
        col = int(rng.integers(0, 4))
        vv = np.zeros(s.n_replicas, np.int32)
        vv[col] = int(rng.integers(0, 4))
        s.sync_key(f"key{i}", vv[None, :], np.asarray([col], np.int32),
                   np.asarray([vv[col] + 1], np.int32), [f"v{i}"])
    return s


def test_digest_incremental_matches_rebuild_through_kill_and_compact():
    s = _loaded_store(200)
    assert s.check_digests()
    # overwrite some keys (kills + inserts), then force compaction
    for i in range(0, 200, 3):
        vv = np.full(s.n_replicas, 7, np.int32)
        s.sync_key(f"key{i}", vv[None, :], np.asarray([0], np.int32),
                   np.asarray([8], np.int32), [f"w{i}"])
    assert s.check_digests()
    s.compact(force=True)
    assert s.check_digests()


def test_digest_is_representation_independent():
    """Same content, different interning order ⇒ identical digests."""
    a, b = PackedVersionStore(), PackedVersionStore()
    for r in ("r0", "r1", "r2"):
        a.intern_replica(r)
    for r in ("r2", "r0", "r1"):
        b.intern_replica(r)
    rng = np.random.default_rng(3)
    writes = []
    for i in range(50):
        col = ("r0", "r1", "r2")[int(rng.integers(0, 3))]
        m = int(rng.integers(0, 5))
        writes.append((f"key{i % 17}", col, m))
    for store, order in ((a, writes), (b, list(reversed(writes)))):
        for key, rid, m in order:
            cix = store.intern_replica(rid)
            vv = np.zeros(store.n_replicas, np.int32)
            vv[cix] = m
            store.sync_key(key, vv[None, :], np.asarray([cix], np.int32),
                           np.asarray([m + 1], np.int32), [f"{key}:{rid}:{m}"])
    assert len(a.sync_digest().diff(b.sync_digest())) == 0
    np.testing.assert_array_equal(a.sync_digest().leaves,
                                  b.sync_digest().leaves)


def test_digest_diff_locates_divergent_bucket():
    s = _loaded_store(64)
    t = s.clone()
    vv = np.zeros(t.n_replicas, np.int32)
    vv[1] = 50
    t.sync_key("key7", vv[None, :], np.asarray([1], np.int32),
               np.asarray([51], np.int32), ["div"])
    d = s.sync_digest().diff(t.sync_digest())
    assert list(d) == [key_bucket("key7", s.n_buckets)]


def test_digest_fold_and_cross_width_diff():
    s = _loaded_store(3000)            # wide (adaptive growth kicked in)
    assert s.n_buckets > 256
    # folding is exact: a store with the same content (whatever width its
    # own growth chose) projects to identical 256-wide leaves
    t = PackedVersionStore(n_buckets=256)
    t.apply_payload(s.payload())
    np.testing.assert_array_equal(t.sync_digest().fold(256).leaves,
                                  s.sync_digest().fold(256).leaves)
    assert len(s.sync_digest().diff(t.sync_digest())) == 0
    # a genuinely narrow peer (few keys, growth never triggers): the wide
    # store diffs against it and slices payloads at the narrow width
    small = PackedVersionStore(n_buckets=256)
    small.apply_payload(s.payload(s.keys[:5]))
    assert small.n_buckets == 256
    d = s.sync_digest().diff(small.sync_digest())
    assert len(d) > 0
    small.apply_payload(s.payload(key_ranges=d, ranges_width=256))
    assert len(s.sync_digest().diff(small.sync_digest())) == 0


def test_payload_key_ranges_equals_key_selection():
    s = _loaded_store(120, seed=9)
    buckets = sorted({int(key_bucket(k, s.n_buckets)) for k in s.keys[:10]})
    by_range = s.payload(key_ranges=buckets)
    want = [k for k in s.keys
            if key_bucket(k, s.n_buckets) in set(buckets) and s.key_slots(k)]
    by_keys = s.payload(sorted(want))
    from repro.store.replica import _as_object_payload
    assert _as_object_payload(by_range) == _as_object_payload(by_keys)


def test_digest_collision_probe():
    """Forced 64-bit collision: the delta round (correctly, per its
    probabilistic contract) ships nothing; the full-payload fallback
    converges; ``rebuild_digests`` repairs the poisoned state."""
    c = _drive(True, 21)
    c.network.partition({"a"}, {"b", "c", "d"})
    c.put(KEYS[2], "hidden-divergence", via="a", coordinator="a")
    c.network.heal()
    a = c.nodes["a"].backend.packed
    b = c.nodes["b"].backend.packed
    assert len(a.sync_digest().diff(b.sync_digest())) > 0
    # poison b's digest tree AND value root to collide with a's (a real
    # miss now requires both 64-bit structures to collide at once)
    b.digest = a.digest.copy()
    b._value_root = a.value_root()
    assert not b.check_digests()                 # detectable locally
    st = c.delta_antientropy("a", "b")
    assert st.payload_slots == 0                 # the miss, documented
    assert c.nodes["b"].versions(KEYS[2]) != c.nodes["a"].versions(KEYS[2])
    # safety net: the full round converges regardless of digest state
    changed = bulk_receive_antientropy(c.nodes["b"],
                                       c.nodes["a"].antientropy_payload())
    assert changed >= 1
    assert c.nodes["b"].versions(KEYS[2]) == c.nodes["a"].versions(KEYS[2])
    # repair, then delta rounds are trustworthy again
    b.rebuild_digests()
    assert b.check_digests()
    st2 = c.delta_antientropy("a", "b")
    assert st2.changed == 0 and b.check_digests()


# ---------------------------------------------------------------------------
# Shape-bucketed, jit-cached sync_mask.
# ---------------------------------------------------------------------------

def _random_grouped(N, K, R, seed=0):
    rng = np.random.default_rng(seed)
    vvs = rng.integers(0, 6, (N, K, R)).astype(np.int32)
    dids = rng.integers(-1, R, (N, K)).astype(np.int32)
    dns = np.where(
        dids >= 0,
        np.take_along_axis(vvs, np.clip(dids, 0, None)[..., None],
                           axis=-1)[..., 0] + rng.integers(1, 4, (N, K)),
        0).astype(np.int32)
    valid = rng.random((N, K)) < 0.8
    return vvs, dids, dns, valid


@pytest.mark.parametrize("shape", [(1, 1, 1), (5, 3, 4), (23, 4, 5),
                                   (9, 2, 8), (64, 5, 13)])
def test_bucketed_mask_matches_reference(shape):
    args = _random_grouped(*shape, seed=sum(shape))
    ref = B.sync_mask_np(*args)
    np.testing.assert_array_equal(B.sync_mask_bucketed(*args), ref)
    np.testing.assert_array_equal(dvv_sync_mask_bucketed(*args), ref)


def test_pad_rows_are_inert():
    """The bucket/padding invariant: zero-filled invalid pad rows/columns
    change nothing about the real region's survival mask."""
    args = _random_grouped(13, 3, 5, seed=4)
    ref = B.sync_mask_np(*args)
    for shape in [(16, 4, 8), (32, 8, 16), (128, 8, 128)]:
        padded = B.pad_sync_args(*args, shape)
        got = B.sync_mask_np(*padded)
        np.testing.assert_array_equal(got[:13, :3], ref, err_msg=str(shape))
        # pad rows themselves never survive (valid=False)
        assert not got[13:].any() and not got[:, 3:].any()


def test_bucket_cache_warm_across_shapes():
    m = B.BucketedSyncMask()
    m(*_random_grouped(5, 2, 3))       # -> bucket (8, 2, 8): miss
    m(*_random_grouped(7, 2, 5))       # same bucket: hit
    m(*_random_grouped(8, 2, 8))       # exact bucket shape: hit
    m(*_random_grouped(100, 2, 5))     # -> (128, 2, 8): miss
    info = m.cache_info()
    assert info["misses"] == 2 and info["hits"] == 2, info
    assert B.bucket_shape(5, 2, 3) in info["buckets"]


def test_bucket_shape_floors_and_pow2():
    assert B.bucket_shape(1, 1, 1) == (8, 2, 8)
    assert B.bucket_shape(9, 3, 9) == (16, 4, 16)
    assert B.bucket_shape(1024, 4, 128) == (1024, 4, 128)


# ---------------------------------------------------------------------------
# Satellite: the value-content digest gap (ROADMAP) is closed.
# ---------------------------------------------------------------------------

def test_value_content_gap_triggers_full_round_fallback():
    """Regression for the ROADMAP §6.1 gap: clock-equal/value-different
    versions — impossible under the protocol, reachable through arbitrary
    non-protocol ``bulk_sync``/``bulk_receive_antientropy`` dicts — are
    invisible to the clock+key digest tree.  The value root must route the
    delta round to the full-payload fallback, never silently report
    convergence."""
    from repro.core.dvv import DVV
    from repro.store import Version

    c = KVCluster(("a", "b"), DVV_MECHANISM, network=SimNetwork(seed=2))
    for i in range(12):
        c.put(KEYS[i % 3], f"v{i}", via="a", coordinator="a")
    c.deliver_replication()
    c.antientropy_round()
    # same clock, different values, one per side (a non-protocol injection)
    clock = DVV((("rogue-writer", 0, 1),))
    bulk_receive_antientropy(c.nodes["a"],
                             {"rogue": frozenset({Version(clock, "X")})})
    bulk_receive_antientropy(c.nodes["b"],
                             {"rogue": frozenset({Version(clock, "Y")})})
    a = c.nodes["a"].backend.packed
    b = c.nodes["b"].backend.packed
    assert len(a.sync_digest().diff(b.sync_digest())) == 0  # clocks collide
    assert a.value_root() != b.value_root()                 # content differs
    assert a.check_digests() and b.check_digests()          # roots are honest
    st = c.delta_antientropy("a", "b")
    assert st.fallback                                      # not a silent skip
    assert st.payload_slots > 0 and st.payload_bytes > 0
    assert st.buckets_divergent == 0                        # the gap, documented
    # the fallback cannot reconcile equal-clock values (resident copy wins);
    # the rounds keep flagging the divergence rather than masking it
    st2 = c.delta_antientropy("a", "b")
    assert st2.fallback
    assert c.nodes["b"].versions("rogue") != c.nodes["a"].versions("rogue")


def test_value_root_tracks_protocol_mutation():
    """Protocol stores never trip the value check: twin stores with equal
    content agree on the root through kills, compaction and growth."""
    s = _loaded_store(120, seed=5)
    t = PackedVersionStore(n_buckets=s.n_buckets)
    t.apply_payload(s.payload())
    assert s.value_root() == t.value_root()
    vv = np.full(s.n_replicas, 9, np.int32)
    for store in (s, t):
        store.sync_key("key3", vv[None, :], np.asarray([1], np.int32),
                       np.asarray([10], np.int32), ["overwrite"])
    s.compact(force=True)
    assert s.value_root() == t.value_root()
    assert s.check_digests() and t.check_digests()
    # same clocks, different value ⇒ roots split
    u = PackedVersionStore(n_buckets=s.n_buckets)
    p = s.payload()
    u.apply_payload(PackedPayload(
        p.replica_ids, p.keys, p.vv, p.dot_id, p.dot_n, p.key_ix,
        tuple("DIFFERENT" if i == 0 else v
              for i, v in enumerate(p.values)), wall=p.wall))
    assert len(s.sync_digest().diff(u.sync_digest())) == 0
    assert s.value_root() != u.value_root()


# ---------------------------------------------------------------------------
# Satellite: sparse object-backend deltas skip absent keys.
# ---------------------------------------------------------------------------

def test_object_backend_sparse_delta_skips_absent_keys(monkeypatch):
    src = KVCluster(("a", "b"), DVV_MECHANISM, packed=False,
                    network=SimNetwork(seed=8))
    for i, k in enumerate(KEYS):
        src.put(k, f"v{i}", via="a", coordinator="a")
    src.network.queue.clear()
    # dst knows only one key
    dst = src.nodes["b"]
    dst.apply_sync(KEYS[0], src.nodes["a"].versions(KEYS[0]))

    calls = []
    real = PackedVersionStore.sync_key_objects

    def counting(self, key, versions):
        calls.append(key)
        return real(self, key, versions)

    monkeypatch.setattr(PackedVersionStore, "sync_key_objects", counting)
    payload = src.nodes["a"].antientropy_payload()
    bulk_receive_antientropy(dst, payload)
    # staging encodes each incoming key once, plus the single present local
    # key — absent local keys are never staged
    assert len(calls) == len(payload) + 1, calls
    for k in KEYS:
        assert dst.versions(k) == src.nodes["a"].versions(k)


def test_compact_vectorized_remap_preserves_lists():
    s = _loaded_store(150, seed=2)
    # kill a scattered subset via dominating writes, then force compaction
    for i in range(0, 150, 2):
        vv = np.full(s.n_replicas, 9, np.int32)
        s.sync_key(f"key{i}", vv[None, :], np.asarray([1], np.int32),
                   np.asarray([10], np.int32), [f"w{i}"])
    before = {k: s.versions(k) for k in s.keys}
    s.compact(force=True)
    assert {k: s.versions(k) for k in s.keys} == before
    assert s.n_dead == 0
    for kix, slots in s._slots_by_key.items():
        for slot in slots:
            assert s.valid[slot] and s.key_ix[slot] == kix
    assert s.check_digests()


# ---------------------------------------------------------------------------
# Hypothesis fuzz of the delta-vs-full driver (slow phase; `make test-all`).
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @pytest.mark.slow
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=100_000), st.booleans(),
           st.booleans())
    def test_delta_equals_full_fuzzed(seed, grow, use_kernel):
        c_delta = _drive(True, seed, grow_universe=grow,
                         use_kernel=use_kernel)
        c_full = _drive(False, seed, grow_universe=grow)
        _assert_byte_identical(c_delta, c_full, (seed, grow, use_kernel))
except ImportError:     # deterministic seeds above still run
    pass
