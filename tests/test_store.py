"""Replicated store behaviour: protocol, quorums, partitions, anti-entropy."""
import pytest

from repro.core import ALL_MECHANISMS, DVV_MECHANISM, VV_SERVER_MECHANISM
from repro.store import KVCluster, SimNetwork, Unavailable


def make_cluster(mech="dvv", nodes=("a", "b", "c"), **kw):
    return KVCluster(nodes, ALL_MECHANISMS[mech], **kw)


def test_put_get_roundtrip():
    c = make_cluster()
    ack = c.put("k", "v0", via="a")
    c.deliver_replication()
    got = c.get("k", via="b", quorum=3)
    assert got.values == ("v0",)
    assert got.siblings == 1


def test_concurrent_puts_same_coordinator_kept_as_siblings():
    """The paper's headline capability: same-server concurrency survives."""
    c = make_cluster("dvv", nodes=("a", "b"))
    c.put("k", "v", context=frozenset(), coordinator="b")
    c.put("k", "w", context=frozenset(), coordinator="b")
    got = c.get("k", via="b")
    assert set(got.values) == {"v", "w"}
    assert got.siblings == 2


def test_vv_server_same_coordinator_loses_sibling():
    """And the Dynamo baseline drops one of them (Fig. 3)."""
    c = make_cluster("vv_server", nodes=("a", "b"))
    c.put("k", "v", context=frozenset(), coordinator="b")
    c.put("k", "w", context=frozenset(), coordinator="b")
    got = c.get("k", via="b")
    assert got.values == ("w",)   # v silently lost


def test_context_supersedes_siblings():
    c = make_cluster("dvv", nodes=("a", "b"))
    c.put("k", "v", coordinator="b")
    c.put("k", "w", coordinator="b")
    got = c.get("k", via="b")
    assert got.siblings == 2
    # client resolves the conflict: put with full context
    c.put("k", "merged", context=got.context, coordinator="b")
    got2 = c.get("k", via="b")
    assert got2.values == ("merged",)
    assert got2.siblings == 1


def test_read_own_write_through_any_replica_after_replication():
    c = make_cluster("dvv")
    ack = c.put("k", "v1", via="a")
    assert c.deliver_replication() > 0
    for n in ("a", "b", "c"):
        assert c.get("k", via=n).values == ("v1",)


def test_partition_then_heal_preserves_both_writes():
    """Divergence under partition; anti-entropy reconciles as siblings."""
    net = SimNetwork(seed=1)
    c = KVCluster(("a", "b"), DVV_MECHANISM, network=net)
    net.partition({"a"}, {"b"})
    c.put("k", "left", coordinator="a", via="a")
    c.put("k", "right", coordinator="b", via="b")
    net.heal()
    c.antientropy_round()
    got = c.get("k", via="a", quorum=1)
    assert set(got.values) == {"left", "right"}   # nothing lost
    # resolve
    c.put("k", "resolved", context=got.context, coordinator="a")
    c.antientropy_round()
    assert c.get("k", via="b").values == ("resolved",)


def test_down_node_and_recovery():
    net = SimNetwork(seed=2)
    c = KVCluster(("a", "b", "c"), DVV_MECHANISM, network=net)
    net.fail_node("c")
    c.put("k", "v", via="a")
    with pytest.raises(Unavailable):
        c.get("k", via="c")
    net.recover_node("c")
    c.deliver_replication()   # queued replication flows after recovery
    assert c.get("k", via="c", quorum=3).values == ("v",)


def test_write_quorum_unavailable_raises():
    net = SimNetwork(seed=3)
    c = KVCluster(("a", "b", "c"), DVV_MECHANISM, network=net,
                  write_quorum=3)
    net.partition({"a"}, {"b", "c"})
    with pytest.raises(Unavailable):
        c.put("k", "v", via="a")


def test_antientropy_converges_all_replicas():
    c = make_cluster("dvv", nodes=("a", "b", "c", "d"))
    for i in range(5):
        c.put(f"k{i}", f"v{i}", coordinator="a", via="a")
    # no replication delivery at all — rely on anti-entropy only
    c.network.queue.clear()
    c.antientropy_round()
    for n in ("b", "c", "d"):
        for i in range(5):
            assert c.get(f"k{i}", via=n).values == (f"v{i}",)


def test_replication_factor_subset_of_nodes():
    c = KVCluster([f"n{i}" for i in range(10)], DVV_MECHANISM,
                  replication=3)
    reps = c.replicas_for("some-key")
    assert len(reps) == 3
    c.put("some-key", "v", via="n0")
    c.deliver_replication()
    stored = [n for n, node in c.nodes.items() if node.versions("some-key")]
    assert set(stored) == set(reps)


def test_lww_mechanism_single_version_always():
    c = make_cluster("wallclock_lww", nodes=("a", "b"))
    c.put("k", "v", coordinator="b", wall_time=1.0, client_id="c1")
    c.put("k", "w", coordinator="b", wall_time=2.0, client_id="c2")
    got = c.get("k", via="b")
    assert got.values == ("w",)  # concurrent v lost — expected for LWW
    assert got.siblings == 1
