"""Straggler mitigation during data processing: work-stealing over the
DVV lease ledger guarantees every shard is processed exactly once even
when workers stall, die, or race through the same coordinator."""
import random

from repro.cluster import FailureDetector, WorkStealer
from repro.core import DVV_MECHANISM
from repro.store import KVCluster, SimNetwork

STORE = ("s1", "s2", "s3")


def test_stolen_shards_process_exactly_once():
    store = KVCluster(STORE, DVV_MECHANISM, network=SimNetwork(seed=0))
    shards = [f"shard-{i}" for i in range(12)]
    workers = {w: WorkStealer(store, w, lease_duration=5.0)
               for w in ("w0", "w1", "w2")}
    fd = FailureDetector(heartbeat_interval=1.0)
    processed = {}          # shard -> worker (the commit ledger)
    now = 0.0
    straggler = "w1"
    rng = random.Random(3)

    pending = set(shards)
    for round_ in range(40):
        now += 1.0
        for w, stealer in workers.items():
            if w == straggler and now > 3.0:
                continue            # w1 stalls forever after t=3
            fd.record(w, now)
            for shard in sorted(pending):
                owner = stealer.owner(shard, via=rng.choice(STORE))
                claimed = False
                if owner is None or owner == w:
                    claimed = stealer.try_claim(shard, now,
                                                via=rng.choice(STORE))
                elif owner in fd.suspects(now) or owner in fd.dead(now):
                    claimed = stealer.steal_expired(shard, now,
                                                    via=rng.choice(STORE))
                if claimed:
                    # process + commit (idempotence guard: the ledger is
                    # the source of truth, not the worker's belief)
                    if shard not in processed:
                        processed[shard] = w
                        pending.discard(shard)
                    break           # one shard per worker per tick
        if not pending:
            break

    assert not pending, f"unprocessed shards: {pending}"
    assert len(processed) == len(shards)
    # the straggler contributed at most its pre-stall work
    assert sum(1 for w in processed.values() if w == straggler) <= 3
    # live workers split the rest
    assert {w for w in processed.values()} <= {"w0", "w1", "w2"}


def test_concurrent_claims_during_partition_one_winner_after_heal():
    net = SimNetwork(seed=1)
    store = KVCluster(STORE, DVV_MECHANISM, network=net)
    w0 = WorkStealer(store, "w0", lease_duration=100.0)
    w1 = WorkStealer(store, "w1", lease_duration=100.0)
    net.partition({"s1"}, {"s2", "s3"})
    # both sides claim the same shard concurrently
    got0 = w0.try_claim("shard-X", now=0.0, via="s1")
    got1 = w1.try_claim("shard-X", now=0.0, via="s2")
    assert got0 and got1            # split brain: both believe they own it
    net.heal()
    store.antientropy_round()
    # after heal both leases surface as DVV siblings; the deterministic
    # resolver yields ONE owner everywhere
    owner_via_s1 = w0.owner("shard-X", via="s1")
    owner_via_s3 = w1.owner("shard-X", via="s3")
    assert owner_via_s1 == owner_via_s3
    assert owner_via_s1 in ("w0", "w1")
    # the loser observes it lost and cannot renew
    loser = "w1" if owner_via_s1 == "w0" else "w0"
    stealer = w1 if loser == "w1" else w0
    assert not stealer.renew("shard-X", now=1.0, via="s1")
