"""Control-plane tests: membership, leases, elastic scaling, checkpoints —
the framework-level payoff of DVV causality tracking."""
import numpy as np
import pytest

from repro.cluster import (
    ElasticController, FailureDetector, MembershipService, MemberView,
    NodeStatus, WorkStealer,
)
from repro.ckpt import CheckpointManager, Manifest, resolve_manifest_siblings
from repro.core import ALL_MECHANISMS, DVV_MECHANISM
from repro.store import KVCluster, SimNetwork

STORE_NODES = ("s1", "s2", "s3")


def fresh_store(seed=0, mech="dvv"):
    return KVCluster(STORE_NODES, ALL_MECHANISMS[mech],
                     network=SimNetwork(seed=seed))


# ---------------------------------------------------------------------------
# Membership
# ---------------------------------------------------------------------------

def test_membership_join_leave():
    store = fresh_store()
    svc = MembershipService(store, "s1")
    svc.join("w0")
    svc.join("w1")
    store.deliver_replication()
    view = svc.view()
    assert set(view.alive()) == {"w0", "w1"}
    svc.mark_dead("w1")
    assert set(svc.view().alive()) == {"w0"}


def test_membership_concurrent_joins_both_survive():
    """Two nodes join through different coordinators during a partition —
    with DVV both joins survive the heal (LWW would drop one)."""
    store = fresh_store(seed=1)
    net = store.network
    a = MembershipService(store, "s1")
    b = MembershipService(store, "s2")
    net.partition({"s1"}, {"s2", "s3"})
    a.join("w-left")
    b.join("w-right")
    net.heal()
    store.antientropy_round()
    merged = a.reconcile()
    assert set(merged.alive()) == {"w-left", "w-right"}
    # and the reconciliation converges: the merged view replaces siblings
    store.antientropy_round()
    assert set(b.view().alive()) == {"w-left", "w-right"}


def test_membership_concurrent_joins_lost_under_lww():
    """The same schedule under wall-clock LWW silently loses one join —
    the paper's §3.1 failure, at the framework level."""
    store = fresh_store(seed=1, mech="wallclock_lww")
    net = store.network
    a = MembershipService(store, "s1")
    b = MembershipService(store, "s2")
    net.partition({"s1"}, {"s2", "s3"})
    a.join("w-left")
    b.join("w-right")
    net.heal()
    store.antientropy_round()
    merged = a.reconcile()
    assert set(merged.alive()) != {"w-left", "w-right"}  # one join vanished


def test_member_view_merge_epoch_priority():
    v1 = MemberView.from_dict({"n": (int(NodeStatus.DEAD), 3)})
    v2 = MemberView.from_dict({"n": (int(NodeStatus.ALIVE), 4)})  # rejoined
    merged = MemberView.merge((v1, v2))
    assert merged.to_dict()["n"] == (int(NodeStatus.ALIVE), 4)


# ---------------------------------------------------------------------------
# Failure detector
# ---------------------------------------------------------------------------

def test_failure_detector_suspect_and_dead():
    fd = FailureDetector(heartbeat_interval=1.0)
    for t in range(5):
        fd.record("w0", float(t))
        fd.record("w1", float(t))
    # w1 goes silent
    fd.record("w0", 9.0)
    assert "w1" in fd.suspects(8.0)
    assert "w1" in fd.dead(14.0)
    assert "w0" in fd.alive(9.5)


# ---------------------------------------------------------------------------
# Work stealing / straggler mitigation
# ---------------------------------------------------------------------------

def test_concurrent_claims_same_coordinator_one_winner():
    store = fresh_store(seed=2)
    w1 = WorkStealer(store, "worker1")
    w2 = WorkStealer(store, "worker2")
    # both claim with empty context through the same coordinator — Fig. 3!
    got1 = w1.try_claim("shard-7", now=0.0, via="s1")
    got2 = w2.try_claim("shard-7", now=0.0, via="s1")
    assert got1 != got2 or not (got1 and got2)  # never both owners
    owner = w1.owner("shard-7", via="s1")
    assert owner in ("worker1", "worker2")


def test_steal_expired_lease():
    store = fresh_store(seed=3)
    w1 = WorkStealer(store, "worker1", lease_duration=5.0)
    w2 = WorkStealer(store, "worker2", lease_duration=5.0)
    assert w1.try_claim("shard-0", now=0.0, via="s1")
    # worker1 stalls; at t=6 its lease expired and worker2 steals
    assert not w2.try_claim("shard-0", now=3.0, via="s1")
    assert w2.steal_expired("shard-0", now=6.0, via="s1")
    assert w2.owner("shard-0", via="s1") == "worker2"
    # the straggler coming back cannot renew
    assert not w1.renew("shard-0", now=7.0, via="s1")


# ---------------------------------------------------------------------------
# Elastic controller
# ---------------------------------------------------------------------------

def test_elastic_plan_and_replan():
    ctl = ElasticController([
        ((2, 4), ("data", "model")),
        ((1, 4), ("data", "model")),
        ((1, 2), ("data", "model")),
    ])
    view = MemberView.from_dict(
        {f"w{i}": (int(NodeStatus.ALIVE), 0) for i in range(8)})
    plan = ctl.plan(view)
    assert plan.mesh_shape == (2, 4) and plan.size == 8
    # two nodes die -> shed data parallelism, keep model axis
    d = view.to_dict()
    d["w0"] = (int(NodeStatus.DEAD), 1)
    d["w1"] = (int(NodeStatus.DEAD), 1)
    new, changed = ctl.replan_on_failure(MemberView.from_dict(d), plan)
    assert changed and new.mesh_shape == (1, 4)


# ---------------------------------------------------------------------------
# Checkpoints
# ---------------------------------------------------------------------------

def _arrays(step):
    rng = np.random.default_rng(step)
    return {"layer/w": rng.normal(size=(4, 4)).astype(np.float32),
            "layer/b": rng.normal(size=(4,)).astype(np.float32)}


def test_checkpoint_save_restore_roundtrip(tmp_path):
    store = fresh_store(seed=4)
    mgr = CheckpointManager(store, str(tmp_path), "run0", "s1")
    arrays = _arrays(1)
    mgr.save(1, arrays, data_cursor=100, rng_seed=7, rng_fold=1,
             mesh_shape=(1, 1))
    res = CheckpointManager(store, str(tmp_path), "run0", "s2").restore(via="s1")
    assert res is not None and not res.had_conflict
    assert res.manifest.step == 1 and res.manifest.data_cursor == 100
    np.testing.assert_array_equal(res.arrays["layer/w"], arrays["layer/w"])


def test_checkpoint_conflicting_lineages_resolved_identically(tmp_path):
    """Partition → two coordinators finalize different step-2 manifests →
    every node restores the SAME lineage after heal."""
    store = fresh_store(seed=5)
    net = store.network
    m1 = CheckpointManager(store, str(tmp_path), "runX", "s1")
    m1.save(1, _arrays(1), data_cursor=10, rng_seed=7, rng_fold=1,
            mesh_shape=(1, 1), via="s1")
    store.antientropy_round()
    # both managers have read the step-1 manifest (shared causal context)
    m2 = CheckpointManager(store, str(tmp_path), "runX", "s2")
    assert m2.restore(via="s2").manifest.step == 1
    net.partition({"s1"}, {"s2", "s3"})
    m1.save(2, _arrays(21), data_cursor=20, rng_seed=7, rng_fold=2,
            mesh_shape=(1, 1), via="s1")
    m2.save(2, _arrays(22), data_cursor=21, rng_seed=7, rng_fold=2,
            mesh_shape=(1, 1), via="s2")
    net.heal()
    store.antientropy_round()
    r1 = CheckpointManager(store, str(tmp_path), "runX", "s1").restore(via="s1")
    r2 = CheckpointManager(store, str(tmp_path), "runX", "s3").restore(via="s3")
    assert r1.had_conflict  # the conflict was VISIBLE (not silent, unlike LWW)
    assert r1.manifest.checksum() == r2.manifest.checksum()  # same resolution
    np.testing.assert_array_equal(
        r1.arrays["layer/w"], r2.arrays["layer/w"])
    # after resolution the conflict is gone everywhere
    store.antientropy_round()
    r3 = CheckpointManager(store, str(tmp_path), "runX", "s2").restore(via="s2")
    assert not r3.had_conflict


def test_checkpoint_checksum_detects_corruption(tmp_path):
    store = fresh_store(seed=6)
    mgr = CheckpointManager(store, str(tmp_path), "runC", "s1")
    manifest = mgr.save(1, _arrays(1), data_cursor=0, rng_seed=0, rng_fold=0,
                        mesh_shape=(1,))
    # corrupt a shard on disk
    import os
    target = os.path.join(str(tmp_path), manifest.shards[0].file)
    data = np.load(target)
    data.flat[0] += 1.0
    with open(target, "wb") as f:
        np.save(f, data)
    store.deliver_replication()
    with pytest.raises(IOError):
        CheckpointManager(store, str(tmp_path), "runC", "s2").restore()


def test_resolve_manifest_siblings_deterministic():
    a = Manifest("r", 5, (), 0, 0, 0, (1,), "s1")
    b = Manifest("r", 6, (), 0, 0, 0, (1,), "s2")
    assert resolve_manifest_siblings((a, b)).step == 6
    assert resolve_manifest_siblings((b, a)).step == 6
