"""Fault-injection matrix + self-driving membership (DESIGN.md §13).

Three layers, one suite:

* **Detector regressions** — the three latent ``FailureDetector`` bugs the
  loop exposed: join-then-silent nodes invisible forever, partition gaps
  inflating the expected-interval mean, and departed-node state leaking
  across a remove/re-add cycle.
* **Fault matrix conformance** — the churn interpreter (imported from
  ``test_churn``) extended with asymmetric link cuts, slow-not-dead nodes,
  seeded duplication/reordering and flapping links; every mode must end
  with replica agreement and packed==object (trajectory included when the
  membership controller drives evictions).  Duplicated and reordered
  deliveries must never double-apply — DVV sync is a join, so re-applying
  a payload is a no-op.
* **The closed loop end-to-end** — zero hand-called ``remove_node``/
  ``add_node``: a failed node is auto-evicted (fabric queue purged), a
  falsely-suspected *reachable* node is evicted WITH handoff (its
  sole-copy quorum-1 write survives) and immediately re-admitted, and a
  recovered node re-enters through the warm digest-diffed bootstrap.

The hypothesis phase fuzzes fault schedules (``slow`` marker — the
``make test-faults`` lane / nightly CI are its home).
"""
import random

import pytest

from repro.core import DVV_MECHANISM
from repro.store import (FailureDetector, GossipDriver, KVCluster,
                         MembershipController, SimNetwork, cluster_converged)

from test_churn import KEYS, _assert_backends_agree, _assert_replicas_agree, \
    _conformance, _run_schedule

pytestmark = pytest.mark.faults


# ---------------------------------------------------------------------------
# FailureDetector regressions (the satellite bugfixes).
# ---------------------------------------------------------------------------

def test_registered_member_with_zero_beats_is_visible():
    """A node that joins and immediately goes silent must show up in
    ``suspects()``/``dead()`` — registration starts the clock; before the
    fix only nodes with a recorded beat were ever iterated."""
    fd = FailureDetector(heartbeat_interval=1.0)
    fd.register("ghost", now=0.0)
    assert fd.suspicion("ghost", 0.5) < fd.suspect_threshold
    assert "ghost" in fd.suspects(4.0)
    assert "ghost" in fd.dead(9.0)
    # registration is idempotent: it must not touch an existing beat
    fd.record("live", 0.0)
    fd.record("live", 1.0)
    fd.register("live", 100.0)
    assert fd.last_beat["live"] == 1.0


def test_expected_interval_resists_partition_inflation():
    """One long partition gap must not suppress suspicion after the heal.
    The old mean-based estimate let a single 80s outage gap drag the
    expected interval to ~3.2s, so 10 silent seconds scored under the
    dead threshold; the clamped median stays at the true 1s cadence."""
    fd = FailureDetector(heartbeat_interval=1.0)
    t = 0.0
    for _ in range(30):                       # steady 1s beats
        fd.record("n", t)
        t += 1.0
    t += 79.0                                 # 80s partition gap …
    fd.record("n", t)                         # … heals with one beat
    for _ in range(5):                        # cadence resumes
        t += 1.0
        fd.record("n", t)
    assert fd._expected_interval("n") <= fd.suspect_threshold
    # 10 silent seconds is 10 expected intervals: dead, promptly
    assert "n" in fd.dead(t + 10.0)
    # the control: with the historical mean the same silence scores ~3.1
    mean = sum(fd.history["n"]) / len(fd.history["n"])
    assert 10.0 / mean < fd.dead_threshold    # the bug this guards against


def test_forget_clears_departed_node_state():
    """``forget`` must drop both maps, and a re-added node starts with a
    fresh history instead of inheriting its previous life's gaps."""
    fd = FailureDetector(heartbeat_interval=1.0)
    for t in range(5):
        fd.record("n", float(t))
    assert "n" in fd.last_beat and "n" in fd.history
    fd.forget("n")
    assert "n" not in fd.last_beat and "n" not in fd.history
    assert fd.suspicion("n", 100.0) == float("inf")
    assert "n" not in fd.dead(100.0)          # unknown, not dead
    fd.register("n", 200.0)
    assert fd.history.get("n") is None        # fresh life, no stale gaps
    assert "n" in fd.alive(200.5)


# ---------------------------------------------------------------------------
# The closed loop, end to end — zero hand-called membership.
# ---------------------------------------------------------------------------

def _loop_cluster(packed, seed=3, period=5.0, **mem_kw):
    net = SimNetwork(seed=seed)
    c = KVCluster(("a", "b", "c", "d"), DVV_MECHANISM, packed=packed,
                  network=net, seed=seed)
    driver = GossipDriver(c, period=period, seed=seed)
    mem = MembershipController(c, period=period, seed=seed, **mem_kw)
    return net, c, driver, mem


@pytest.mark.parametrize("packed", [True, False])
def test_auto_evicts_failed_node_and_purges_queue(packed):
    """A crashed node leaves the replica set by itself: suspicion crosses
    the dead threshold, the controller evicts (purging queued messages
    toward the corpse — the fabric-leak bugfix), and the crash state
    survives the eviction (no bogus instant re-admission)."""
    net, c, driver, mem = _loop_cluster(packed)
    for i in range(6):
        c.put(f"k{i}", f"v{i}", via="a", coordinator="a")
    driver.run_for(30.0)
    # in-flight replication toward c when it crashes: held in the queue
    # (unreachable dst) — before the fix it sat there forever
    c.put("k0", "in-flight", via="a", coordinator="a")
    net.fail_node("c")
    assert net.queued_for("c") > 0
    driver.run_for(300.0)
    assert "c" not in c.nodes
    assert mem.evictions == 1 and mem.readmissions == 0
    assert net.queued_for("c") == 0           # purge on eviction
    assert "c" in net.down                    # the crash outlives eviction
    assert cluster_converged(c)
    # detection is bounded: dead_threshold intervals + one probe period
    bound = (mem.detector.dead_threshold + 2) * mem.period
    assert net.now <= 30.0 + 300.0 and bound < 300.0


@pytest.mark.parametrize("packed", [True, False])
def test_recovered_node_auto_readmitted_via_warm_bootstrap(packed):
    """Recovery re-admits through the warm digest-diffed bootstrap: the
    returnee holds full causal state (digest-equal to its peers), not an
    empty store."""
    net, c, driver, mem = _loop_cluster(packed)
    for i in range(8):
        c.put(f"k{i}", f"v{i}", via="a", coordinator="a")
    driver.run_for(30.0)
    net.fail_node("c")
    driver.run_for(300.0)
    assert "c" not in c.nodes
    net.recover_node("c")
    driver.run_for(300.0)
    c.deliver_replication()
    assert "c" in c.nodes
    assert mem.evictions == 1 and mem.readmissions == 1
    assert cluster_converged(c)
    for i in range(8):
        assert {v.value for v in c.nodes["c"].versions(f"k{i}")} == \
            {v.value for v in c.nodes["a"].versions(f"k{i}")}


@pytest.mark.parametrize("packed", [True, False])
def test_false_eviction_handoff_saves_sole_copy_write(packed):
    """The acceptance scenario: a node partitioned long enough to be
    nearly dead heals just before the threshold; another node's probe
    sweep then evicts it while it is *reachable* — so the final handoff
    push saves the quorum-1 write only it held — and the same sweep
    re-admits it warm.  (With jitter=0, probes fire at exact period
    multiples in arm order a, b, c — the window is deterministic.)"""
    net = SimNetwork(seed=11)
    c = KVCluster(("a", "b", "c"), DVV_MECHANISM, packed=packed,
                  network=net, seed=11)
    driver = GossipDriver(c, period=5.0, seed=11)
    mem = MembershipController(c, period=5.0, jitter=0.0, seed=11)
    c.put("warm", "w", via="a", coordinator="a")
    driver.run_for(30.0)                       # last c beat at t=30
    net.partition({"c"}, {"a", "b"})
    net.run_until(66.0)
    c.put("sole", "precious", via="c", coordinator="c", quorum=1)
    net.run_until(68.0)
    net.heal()                                 # susp(c)=7.6 < 8: no evict
    assert mem.evictions == 0
    net.run_until(90.0)                        # a's t=70 sweep: susp=8.0
    assert mem.evictions == 1 and mem.readmissions == 1
    driver.run_for(200.0)
    c.deliver_replication()
    assert list(c.nodes) == ["a", "b", "c"]
    for n in c.nodes:                          # handoff saved the sole copy
        assert {v.value for v in c.nodes[n].versions("sole")} == \
            {"precious"}, n
    assert cluster_converged(c)


def test_suspect_deprioritized_and_probed():
    """A slow-silenced node becomes suspect (not dead): quorum assembly
    sorts it last, and the driver aims dedicated probe rounds at it
    instead of regular rotation traffic."""
    net, c, driver, mem = _loop_cluster(True, period=5.0,
                                        dead_threshold=1e9)
    for i in range(4):
        c.put(f"k{i}", f"v{i}", via="a", coordinator="a")
    driver.run_for(30.0)
    # cut every OUTBOUND link of d: it hears everyone, nobody hears it —
    # the asymmetric mode a symmetric partition cannot express
    for peer in ("a", "b", "c"):
        net.cut_link("d", peer)
    driver.run_for(120.0)
    assert mem.is_suspect("d")
    assert "d" in mem.suspect_nodes()
    assert mem.evictions == 0                  # dead_threshold unreachable
    # quorum assembly puts the suspect last
    reachable = c._reachable_replicas("a", "k0")
    assert reachable[-1] == "d" and reachable[0] == "a"
    # and the write path avoids coordinating there
    assert c._pick_coordinator("b", "k0") != "d"
    assert driver.suspect_probes > 0           # targeted catch-up rounds
    for peer in ("a", "b", "c"):
        net.heal_link("d", peer)
    driver.run_for(60.0)
    assert not mem.is_suspect("d")             # beats resume, trust returns


def test_controller_rejects_degenerate_parameters():
    net = SimNetwork(seed=0)
    c = KVCluster(("a", "b"), DVV_MECHANISM, network=net, seed=0)
    with pytest.raises(ValueError):
        MembershipController(c, period=0.0)
    with pytest.raises(ValueError):
        MembershipController(c, jitter=1.0)
    with pytest.raises(ValueError):
        MembershipController(c, suspect_threshold=8.0, dead_threshold=3.0)
    geo = KVCluster(("e0", "w0"), DVV_MECHANISM, seed=0,
                    datacenters={"e": ("e0",), "w": ("w0",)})
    with pytest.raises(ValueError):
        MembershipController(geo)


def test_min_members_floor_blocks_eviction():
    """The controller never shrinks the cluster below ``min_members`` —
    a 2-node cluster keeps its dead peer rather than becoming a
    singleton (split-brain guard)."""
    net = SimNetwork(seed=5)
    c = KVCluster(("a", "b"), DVV_MECHANISM, network=net, seed=5)
    GossipDriver(c, period=5.0, seed=5)
    mem = MembershipController(c, period=5.0, seed=5, min_members=2)
    net.fail_node("b")
    net.advance(500.0)
    assert list(c.nodes) == ["a", "b"] and mem.evictions == 0


def test_controller_same_seed_identical_decisions():
    """Seed determinism for the control loop itself: same seed ⇒ same
    probe count, same eviction/re-admission trajectory, same timer
    totals."""
    def run():
        net, c, driver, mem = _loop_cluster(True, seed=7)
        for i in range(4):
            c.put(f"k{i}", f"v{i}", via="a", coordinator="a")
        driver.run_for(20.0)
        net.fail_node("b")
        driver.run_for(250.0)
        net.recover_node("b")
        driver.run_for(250.0)
        return (mem.probes, mem.evictions, mem.readmissions,
                list(c.nodes), net.timers_fired, net.bytes_sent)
    assert run() == run()


# ---------------------------------------------------------------------------
# Idempotence under duplication/reordering (apply is a join).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False])
def test_duplicate_apply_is_noop(packed):
    """Applying the same anti-entropy payload twice changes nothing the
    second time — the property that makes ``dup_rate`` safe."""
    net = SimNetwork(seed=0)
    c = KVCluster(("a", "b"), DVV_MECHANISM, packed=packed, network=net,
                  seed=0)
    for i in range(5):
        c.put(f"k{i}", f"v{i}", via="a", coordinator="a")
    payload = c.nodes["a"].antientropy_payload([f"k{i}" for i in range(5)])
    first = c.nodes["b"].receive_antientropy(payload)
    second = c.nodes["b"].receive_antientropy(payload)
    assert first > 0 and second == 0
    for i in range(5):
        assert c.nodes["b"].versions(f"k{i}") == \
            c.nodes["a"].versions(f"k{i}")


@pytest.mark.parametrize("packed", [True, False])
def test_duplicated_deliveries_never_double_apply(packed):
    """A run with every message duplicated ends in exactly the state of
    the dup-free twin: duplicates cost wire bytes, not state."""
    def run(dup):
        net = SimNetwork(seed=9)
        c = KVCluster(("a", "b", "c"), DVV_MECHANISM, packed=packed,
                      network=net, seed=9)
        if dup:
            net.set_duplication(1.0)
        for i in range(6):
            c.put(f"k{i}", f"v{i}", via="a", coordinator="a")
            c.put(f"k{i}", f"w{i}", via="b", coordinator="b")
        c.deliver_replication()
        return c, net

    c1, n1 = run(dup=False)
    c2, n2 = run(dup=True)
    assert n2.duplicated > 0
    assert n2.delivered == n1.delivered + n2.duplicated
    assert n2.bytes_sent > n1.bytes_sent      # duplicates are priced
    for i in range(6):
        for n in c1.nodes:
            assert c1.nodes[n].versions(f"k{i}") == \
                c2.nodes[n].versions(f"k{i}"), (n, i)


@pytest.mark.parametrize("packed", [True, False])
def test_reordered_deliveries_converge_to_same_state(packed):
    """Scrambled delivery order (fault-stream extra latency) cannot change
    the converged state — version-set join is order-independent."""
    def run(reorder):
        net = SimNetwork(seed=13)
        c = KVCluster(("a", "b", "c"), DVV_MECHANISM, packed=packed,
                      network=net, seed=13)
        if reorder:
            net.set_reorder(0.8, spread=50.0)
        for i in range(8):
            c.put(f"k{i % 4}", f"v{i}", via="a", coordinator="a")
            c.put(f"k{i % 4}", f"w{i}", via="c", coordinator="c")
        c.deliver_replication()
        return c, net

    c1, _ = run(reorder=False)
    c2, n2 = run(reorder=True)
    assert n2.reordered > 0
    for i in range(4):
        for n in c1.nodes:
            assert c1.nodes[n].versions(f"k{i}") == \
                c2.nodes[n].versions(f"k{i}"), (n, i)


def test_fault_knobs_off_keep_trace_byte_identical():
    """Installing the fault machinery must not shift the no-fault RNG
    stream: a run on the faulted fabric with all knobs at their defaults
    equals the run before this PR existed (regression canary: compare two
    identical configs through the full churn interpreter)."""
    from test_churn import _random_ops
    ops = _random_ops(21, 30)
    c1, d1 = _run_schedule(21, ops, packed=True)
    c2, d2 = _run_schedule(21, ops, packed=True)
    assert c1.network.bytes_sent == c2.network.bytes_sent
    assert c1.network.duplicated == 0 and c1.network.reordered == 0


# ---------------------------------------------------------------------------
# Matrix lanes: pinned schedules per fault mode, conformance asserted.
# ---------------------------------------------------------------------------

def _fault_ops(seed, n_ops=34, modes=("cut", "slow", "dup", "reorder",
                                      "flap")):
    """A pinned pseudo-random schedule mixing traffic with the requested
    fault modes (plus fail/recover/partition/heal) — and NO hand-called
    membership ops, so the same schedules drive the self-driving lanes."""
    rng = random.Random(seed)
    ops = []
    for _ in range(n_ops):
        p = rng.random()
        if p < 0.30:
            ops.append(("put", rng.randrange(8), rng.randrange(8),
                        rng.random() < 0.5))
        elif p < 0.42:
            ops.append(("get", rng.randrange(8), rng.randrange(8)))
        elif p < 0.50:
            ops.append(("advance", rng.randrange(1, 25)))
        elif p < 0.56:
            ops.append(("fail", rng.randrange(8)))
        elif p < 0.62:
            ops.append(("recover", rng.randrange(8)))
        elif p < 0.66:
            ops.append(("partition", rng.randrange(1, 6)))
        elif p < 0.70:
            ops.append(("heal",))
        elif p < 0.92:
            mode = modes[rng.randrange(len(modes))]
            if mode == "cut":
                ops.append(("cut", rng.randrange(8), rng.randrange(8)))
            elif mode == "slow":
                ops.append(("slow", rng.randrange(8),
                            rng.choice([1.0, 2.0, 8.0])))
            elif mode == "dup":
                ops.append(("dup", rng.choice([0.0, 0.3, 0.9])))
            elif mode == "reorder":
                ops.append(("reorder", rng.choice([0.0, 0.4, 0.8])))
            elif mode == "flap":
                ops.append(("flap", rng.randrange(8), rng.randrange(8)))
        elif p < 0.96:
            ops.append(("heal_link", rng.randrange(8), rng.randrange(8)))
        else:
            ops.append(("advance", rng.randrange(20, 60)))
    return ops


@pytest.mark.parametrize("mode", ["cut", "slow", "dup", "reorder", "flap"])
def test_fault_mode_conformance_pinned(mode):
    """Each fault mode alone: packed==object and replica agreement after
    quiescence."""
    _conformance(31, _fault_ops(31, modes=(mode,)), ("mode", mode))


@pytest.mark.parametrize("seed", [2, 37])
def test_fault_matrix_combined_conformance_pinned(seed):
    """All modes interleaved in one schedule."""
    _conformance(seed, _fault_ops(seed), ("matrix", seed))


@pytest.mark.parametrize("seed", [5, 43])
def test_fault_matrix_with_self_driving_membership_pinned(seed):
    """The full loop under the full matrix: the controller evicts and
    re-admits on its own (zero hand-called membership in the schedule),
    and the membership trajectory is part of the conformance check."""
    cp, co = _conformance(seed, _fault_ops(seed, n_ops=28),
                          ("auto-membership", seed), membership=True)
    assert list(cp.nodes) == list(co.nodes)


def test_fault_matrix_sharded_conformance_pinned():
    _conformance(17, _fault_ops(17), ("matrix-sharded", 17), shards=4)


# ---------------------------------------------------------------------------
# Hypothesis phase (`make test-faults` / nightly lane; slow-deselected
# from tier-1).
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    _fop = st.one_of(
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),               # twice: writes dominate
        st.tuples(st.just("get"), st.integers(0, 7), st.integers(0, 7)),
        st.tuples(st.just("cut"), st.integers(0, 7), st.integers(0, 7)),
        st.tuples(st.just("heal_link"), st.integers(0, 7),
                  st.integers(0, 7)),
        st.tuples(st.just("slow"), st.integers(0, 7),
                  st.sampled_from([1.0, 2.0, 8.0])),
        st.tuples(st.just("dup"), st.sampled_from([0.0, 0.3, 0.9])),
        st.tuples(st.just("reorder"), st.sampled_from([0.0, 0.4, 0.8])),
        st.tuples(st.just("flap"), st.integers(0, 7), st.integers(0, 7)),
        st.tuples(st.just("partition"), st.integers(1, 5)),
        st.tuples(st.just("heal")),
        st.tuples(st.just("fail"), st.integers(0, 7)),
        st.tuples(st.just("recover"), st.integers(0, 7)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("deliver")),
    )

    @pytest.mark.slow
    @settings(max_examples=120, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20),
           st.lists(_fop, min_size=4, max_size=26),
           st.booleans())
    def test_fault_matrix_conformance_fuzzed(seed, ops, membership):
        _conformance(seed, ops, (seed, len(ops), membership),
                     membership=membership)

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20))
    def test_fault_determinism_fuzzed(seed):
        """Same seed ⇒ identical wire totals and final state under the
        full matrix with the controller attached."""
        ops = _fault_ops(seed, 26)
        c1, d1 = _run_schedule(seed, ops, packed=True, membership=True)
        c2, d2 = _run_schedule(seed, ops, packed=True, membership=True)
        assert c1.network.bytes_sent == c2.network.bytes_sent
        assert c1.network.timers_fired == c2.network.timers_fired
        assert (c1.network.duplicated, c1.network.reordered) == \
            (c2.network.duplicated, c2.network.reordered)
        assert (c1.membership.probes, c1.membership.evictions,
                c1.membership.readmissions) == \
            (c2.membership.probes, c2.membership.evictions,
             c2.membership.readmissions)
        for k in KEYS:
            for n in c1.nodes:
                assert c1.nodes[n].versions(k) == c2.nodes[n].versions(k)
except ImportError:     # pinned lanes above still run
    pass
