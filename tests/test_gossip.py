"""Gossip scheduler + dynamic membership (DESIGN.md §8).

Covers the three layers the continuous-gossip subsystem added:

* ``SimNetwork`` timers — deterministic ``(fire_at, seq)`` firing inside
  ``advance``, lazy cancel, re-arming callbacks, ``forget`` purging a
  departed node from queue/down/partitions.
* ``KVCluster`` membership — ``add_node`` rehashes placement and
  bootstraps the newcomer warm via ranked digest-diffed catch-up;
  ``remove_node`` drops the replica without breaking the seeded gossip
  rotation of survivors (the just-removed-peer sampling edge case).
* ``GossipDriver`` — convergence with zero manual cranking, adaptive
  interval backoff / budget ramp+decay, down-node handling, and
  same-seed determinism of the whole control loop.
"""
import random

import pytest

from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVCluster, SimNetwork, Unavailable,
                         cluster_converged)

KEYS = tuple(f"k{i}" for i in range(8))


def _cluster(nodes=("a", "b", "c", "d"), seed=0, **kw):
    return KVCluster(nodes, DVV_MECHANISM, network=SimNetwork(seed=seed),
                     seed=seed, **kw)


def _write(c, n_ops=40, seed=0, nodes=None):
    rng = random.Random(seed)
    nodes = nodes or list(c.nodes)
    for i in range(n_ops):
        n = rng.choice(nodes)
        c.put(rng.choice(KEYS), f"v{i}", via=n, coordinator=n)


# ---------------------------------------------------------------------------
# SimNetwork timers.
# ---------------------------------------------------------------------------

def test_timers_fire_in_order_and_track_now():
    net = SimNetwork(seed=0)
    log = []
    net.schedule(5.0, lambda: log.append(("b", net.now)))
    net.schedule(2.0, lambda: log.append(("a", net.now)))
    net.schedule(2.0, lambda: log.append(("a2", net.now)))   # seq breaks tie
    net.advance(1.0)
    assert log == [] and net.timers_pending() == 3
    net.advance(10.0)
    assert log == [("a", 2.0), ("a2", 2.0), ("b", 5.0)]
    assert net.now == 11.0 and net.timers_pending() == 0
    assert net.timers_fired == 3


def test_timer_cancel_and_rearm():
    net = SimNetwork(seed=0)
    fired = []
    tid = net.schedule(1.0, lambda: fired.append("cancelled"))
    net.cancel(tid)

    def rearming():
        fired.append(net.now)
        if len(fired) < 3:
            net.schedule(2.0, rearming)

    net.schedule(2.0, rearming)
    net.run_until(10.0)
    assert fired == [2.0, 4.0, 6.0]          # cancelled timer never fired
    assert net.now == 10.0


def test_forget_purges_departed_node():
    net = SimNetwork(seed=0)
    net.send("a", "b", "m1")
    net.send("a", "c", "m2")
    net.send("b", "a", "m3")
    net.fail_node("b")
    net.partition({"a", "b"}, {"c"})
    purged = net.forget("b")
    assert purged == 1                           # only the message TO b
    # b's own in-flight send survives: its destination is alive, and it
    # may carry a quorum-acknowledged write
    assert [m.payload for m in net.queue] == ["m2", "m3"]
    assert "b" not in net.down
    # b stays in its partition group as a ghost so that kept send is
    # still deliverable to its in-group destination before any heal
    delivered = []
    net.deliver(lambda m: delivered.append(m.payload),
                until=net.now + 100.0)
    assert "m3" in delivered


def test_remove_node_preserves_its_acked_in_flight_writes():
    """A write acknowledged at full quorum must survive its coordinator's
    departure while the replication messages are still queued."""
    c = _cluster(nodes=("a", "b", "c"))
    ack = c.put("k0", "precious", via="a", coordinator="a", quorum=3)
    assert set(ack.replicated_to) == {"a", "b", "c"}
    c.remove_node("a")                           # replication still queued
    c.deliver_replication()
    for n in ("b", "c"):
        assert {v.value for v in c.nodes[n].versions("k0")} == {"precious"}


# ---------------------------------------------------------------------------
# Membership: add_node (bootstrap) / remove_node (placement + sampling).
# ---------------------------------------------------------------------------

def test_add_node_bootstraps_warm():
    c = _cluster()
    _write(c, 60)
    c.deliver_replication()
    stats = c.add_node("e")
    assert stats and any(s.payload_slots > 0 for s in stats)
    assert all(not s.fallback for s in stats)       # digest-diffed, ranked
    for k in KEYS:
        assert c.nodes["e"].versions(k) == c.nodes["a"].versions(k), k
    # the newcomer's digest tree agrees with every peer it pulled from
    e = c.nodes["e"].backend.packed
    a = c.nodes["a"].backend.packed
    assert len(e.sync_digest().diff(a.sync_digest())) == 0


def test_add_node_capped_bootstrap_converges():
    c = _cluster()
    _write(c, 60)
    c.deliver_replication()
    stats = c.add_node("e", bootstrap_ranges=2)
    assert all(s.buckets_sent <= 2 for s in stats)
    for k in KEYS:
        assert c.nodes["e"].versions(k) == c.nodes["a"].versions(k), k


def test_add_node_bootstrap_skips_unreachable_peers():
    c = _cluster()
    _write(c, 30)
    c.deliver_replication()
    c.network.partition({"a", "b", "e"}, {"c", "d"})
    c.add_node("e")
    assert c.nodes["e"].versions(KEYS[0]) == c.nodes["a"].versions(KEYS[0])
    with pytest.raises(ValueError):
        c.add_node("e")                      # already present


def test_add_node_rehashes_placement():
    c = _cluster(nodes=tuple(f"n{i}" for i in range(5)), replication=2)
    before = {k: tuple(c.replicas_for(k)) for k in KEYS}   # warms the cache
    c.add_node("n5", bootstrap=False)
    after = {k: tuple(c.replicas_for(k)) for k in KEYS}
    # placement equals a from-scratch ring over the grown membership
    fresh = _cluster(nodes=tuple(f"n{i}" for i in range(6)), replication=2)
    assert after == {k: tuple(fresh.replicas_for(k)) for k in KEYS}
    assert any(before[k] != after[k] for k in KEYS)        # keys moved


def test_remove_node_rehashes_and_purges():
    c = _cluster(replication=2)
    _write(c, 30)
    assert c.network.pending() > 0
    c.remove_node("b")
    assert "b" not in c.nodes
    # messages TO b are purged; b's own acked in-flight sends survive
    assert all(m.dst != "b" for m in c.network.queue)
    fresh = _cluster(nodes=("a", "c", "d"), replication=2)
    assert {k: tuple(c.replicas_for(k)) for k in KEYS} == \
        {k: tuple(fresh.replicas_for(k)) for k in KEYS}
    with pytest.raises(KeyError):
        c.remove_node("b")
    c.remove_node("c")
    c.remove_node("d")
    with pytest.raises(ValueError):
        c.remove_node("a")                   # never remove the last node


def test_remove_node_hands_off_sole_copy_writes():
    """A planned departure must not destroy writes it holds the only copy
    of (quorum-1 ack during a partition): the final handoff pushes them
    to reachable survivors.  ``handoff=False`` models the crash case."""
    c = _cluster(nodes=("a", "b", "c"))
    c.network.partition({"a"}, {"b", "c"})
    c.put("k0", "sole-copy", via="a", coordinator="a", quorum=1)
    c.network.heal()
    stats = c.remove_node("a")
    assert any(s.changed for s in stats)
    for n in ("b", "c"):
        assert {v.value for v in c.nodes[n].versions("k0")} == {"sole-copy"}
    # crash-style removal: no handoff, the sole copy is gone
    c2 = _cluster(nodes=("a", "b", "c"))
    c2.network.partition({"a"}, {"b", "c"})
    c2.put("k0", "lost", via="a", coordinator="a", quorum=1)
    c2.network.heal()
    assert c2.remove_node("a", handoff=False) == []
    assert not c2.nodes["b"].versions("k0")


def test_add_node_wakes_backed_off_driver():
    """A join is a topology change: the driver adopts the newcomer at the
    listener (not its next fire) and snaps backed-off cadences, so writes
    to the joiner propagate at base-period speed, not max_period."""
    c = _cluster(nodes=("a", "b"))
    d = GossipDriver(c, period=5.0, max_period=40.0)
    _write(c, 20)
    d.run_for(600.0)
    assert all(iv == 40.0 for iv in d.intervals().values())
    c.add_node("e")
    assert "e" in d.intervals()              # adopted immediately
    assert all(iv == 5.0 for iv in d.intervals().values())  # woken
    c.put(KEYS[0], "to-joiner", via="e", coordinator="e")
    c.network.queue.clear()
    d.run_for(60.0)                          # a few base periods suffice
    assert cluster_converged(c)
    assert c.nodes["a"].versions(KEYS[0]) == c.nodes["e"].versions(KEYS[0])


def test_fanout_round_right_after_remove_samples_only_live_peers():
    """The satellite edge case: a peer that was just removed must drop out
    of ``fanout=`` sampling — no KeyError, pushes only between live pairs,
    and survivors' rotation stays deterministic."""
    a, b = _cluster(seed=7), _cluster(seed=7)
    for c in (a, b):
        _write(c, 40, seed=7)
        c.network.queue.clear()      # gossip must do the work
    # run one round, then remove a node and keep going: every subsequent
    # round only touches live nodes, and twin clusters agree step for step
    for step in range(6):
        if step == 2:
            a.remove_node("c")
            b.remove_node("c")
        sa = a.delta_antientropy_round(fanout=1)
        sb = b.delta_antientropy_round(fanout=1)
        assert sa == sb, step
        assert len(sa) == len(a.nodes)
    for k in KEYS:
        ref = a.nodes["a"].versions(k)
        for n in a.nodes:
            assert a.nodes[n].versions(k) == ref, (n, k)


def test_gossip_tick_hand_cranked_cycles_peers():
    c = _cluster()
    _write(c, 30)
    c.network.queue.clear()
    seen = set()
    for _ in range(len(c.nodes) - 1):      # default per-node step counter
        for peer, st in c.gossip_tick("a"):
            seen.add(peer)
            assert st.buckets_sent <= c.delta_range_budget
    assert seen == set(c.nodes) - {"a"}


def test_gossip_peers_cycle_all_live_peers_after_churn():
    c = _cluster(nodes=tuple(f"n{i}" for i in range(6)))
    c.remove_node("n3")
    c.add_node("n9", bootstrap=False)
    live = set(c.nodes)
    seen = set()
    for step in range(len(live) - 1):
        seen |= set(c.gossip_peers("n0", 1, step))
    assert seen == live - {"n0"}


# ---------------------------------------------------------------------------
# GossipDriver: the continuous loop.
# ---------------------------------------------------------------------------

def test_driver_converges_without_manual_cranking():
    c = _cluster()
    d = GossipDriver(c, period=5.0)
    _write(c, 50)
    assert not cluster_converged(c)
    d.run_for(500.0)
    # driver drains replication AND runs delta gossip: full convergence
    assert cluster_converged(c)
    assert c.network.pending() == 0
    for k in KEYS:
        ref = c.nodes["a"].versions(k)
        assert all(c.nodes[n].versions(k) == ref for n in c.nodes), k


def test_driver_backs_off_when_converged_and_snaps_back():
    c = _cluster()
    d = GossipDriver(c, period=5.0, max_period=40.0)
    _write(c, 30)
    d.run_for(600.0)
    assert cluster_converged(c)
    assert all(iv == 40.0 for iv in d.intervals().values())  # fully backed off
    ticks_before = d.ticks
    d.run_for(400.0)
    idle_rate = (d.ticks - ticks_before) / 400.0
    assert idle_rate <= len(c.nodes) / 40.0 * 1.5            # cheap heartbeat
    # new divergence snaps the writer's interval back to the base period
    # (observed while stepping — it backs off again once re-converged)
    c.put(KEYS[0], "fresh", via="a", coordinator="a")
    c.network.queue.clear()                                  # only gossip
    snapped = False
    for _ in range(24):
        d.run_for(5.0)
        snapped = snapped or any(iv == 5.0 for iv in d.intervals().values())
    assert snapped
    d.run_for(400.0)
    assert cluster_converged(c)


def test_driver_ramps_budget_on_saturation_and_decays():
    c = _cluster(nodes=("a", "b"))
    d = GossipDriver(c, period=5.0, max_ranges=1, max_ranges_cap=64,
                     jitter=0.0)
    c.network.partition({"a"}, {"b"})
    _write(c, 80, nodes=["a"])                  # many divergent buckets at a
    c.network.heal()
    c.network.queue.clear()
    peak = 1
    for _ in range(12):                         # observe the ramp mid-flight
        d.run_for(5.0)
        peak = max(peak, d.node_state("a").max_ranges)
    assert peak > 1                             # saturation doubled it
    d.run_for(600.0)
    assert cluster_converged(c)
    assert d.node_state("a").max_ranges == 1    # decayed back to base
    assert d.node_state("a").fanout == 1


def test_driver_skips_down_node_and_resumes_on_recovery():
    c = _cluster()
    d = GossipDriver(c, period=5.0)
    _write(c, 30)
    c.network.fail_node("b")
    c.put(KEYS[1], "during-outage", via="a", coordinator="a")
    d.run_for(200.0)
    assert cluster_converged(c)                 # live majority converged
    assert c.nodes["b"].versions(KEYS[1]) != c.nodes["a"].versions(KEYS[1])
    c.network.recover_node("b")
    d.run_for(300.0)
    assert c.nodes["b"].versions(KEYS[1]) == c.nodes["a"].versions(KEYS[1])
    assert cluster_converged(c)


def test_driver_follows_membership_changes():
    c = _cluster()
    d = GossipDriver(c, period=5.0)
    _write(c, 30)
    d.run_for(300.0)
    c.add_node("e")
    c.remove_node("a")
    c.put(KEYS[2], "after-churn", via="e", coordinator="e")
    d.run_for(300.0)
    assert cluster_converged(c)
    assert "a" not in d.intervals() and "e" in d.intervals()
    for n in c.nodes:
        assert c.nodes[n].versions(KEYS[2]) == c.nodes["e"].versions(KEYS[2])


def test_driver_same_seed_same_schedule():
    def run():
        c = _cluster(seed=11)
        d = GossipDriver(c, period=4.0, seed=11)
        _write(c, 40, seed=11)
        c.add_node("e")
        d.run_for(120.0)
        c.remove_node("b")
        d.run_for(200.0)
        return c, d

    (c1, d1), (c2, d2) = run(), run()
    assert (d1.ticks, d1.rounds, d1.wire_bytes(), d1.fallbacks) == \
        (d2.ticks, d2.rounds, d2.wire_bytes(), d2.fallbacks)
    assert c1.network.timers_fired == c2.network.timers_fired
    assert d1.intervals() == d2.intervals()
    for k in KEYS:
        for n in c1.nodes:
            assert c1.nodes[n].versions(k) == c2.nodes[n].versions(k)


def test_driver_stop_silences_gossip_and_start_restarts_it():
    c = _cluster()
    d = GossipDriver(c, period=5.0)
    _write(c, 20)
    d.run_for(100.0)
    d.stop()
    ticks = d.ticks
    c.network.advance(200.0)
    assert d.ticks == ticks
    assert c.network.timers_pending() == 0
    # restart re-arms every live node and gossip resumes
    d.start()
    assert c.network.timers_pending() == len(c.nodes)
    c.put(KEYS[0], "post-restart", via="a", coordinator="a")
    c.network.queue.clear()
    d.run_for(400.0)
    assert d.ticks > ticks
    assert cluster_converged(c)


def test_driver_readopts_node_removed_while_stopped():
    """remove while stopped leaves a stale disarmed state entry; a later
    re-add of the same node id must get a fresh armed timer, not be
    shadowed by the stale entry."""
    c = _cluster()
    d = GossipDriver(c, period=5.0)
    _write(c, 20)
    d.run_for(50.0)
    d.stop()
    c.remove_node("b")
    d.start()
    assert "b" not in d.intervals()          # stale entry pruned
    c.add_node("b")
    assert d.node_state("b").timer is not None
    c.put(KEYS[0], "re-added", via="b", coordinator="b")
    c.network.queue.clear()
    d.run_for(400.0)
    assert cluster_converged(c)


def test_driver_rejects_degenerate_parameters():
    c = _cluster()
    for kw in ({"period": 0.0}, {"period": -1.0}, {"jitter": 1.0},
               {"jitter": -0.1}, {"backoff": 0.5},
               {"period": 10.0, "max_period": 5.0}):
        with pytest.raises(ValueError):
            GossipDriver(c, autostart=False, **kw)


def test_cluster_converged_object_backend():
    c = KVCluster(("a", "b"), DVV_MECHANISM, packed=False,
                  network=SimNetwork(seed=1))
    c.put(KEYS[0], "x", via="a", coordinator="a")
    c.network.queue.clear()
    assert not cluster_converged(c)
    c.antientropy_round()
    assert cluster_converged(c)


def test_driver_backs_off_on_object_backend():
    """Object backends run every round as a full-payload fallback; a
    fallback that changed nothing must count as convergence so the
    cadence still decays to the heartbeat instead of shipping the whole
    store every base period forever."""
    c = KVCluster(("a", "b", "c"), DVV_MECHANISM, packed=False,
                  network=SimNetwork(seed=3), seed=3)
    d = GossipDriver(c, period=5.0, max_period=40.0)
    _write(c, 20, seed=3)
    d.run_for(600.0)
    assert cluster_converged(c)
    assert all(iv == 40.0 for iv in d.intervals().values())
