"""End-to-end fault tolerance: crash/restart with bitwise-identical resume,
optimizer-state recovery, and control-plane conflict handling during
training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import DVV_MECHANISM
from repro.data import PipelineConfig
from repro.models import LayerSpec, ModelConfig
from repro.optim import AdamWConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.store import KVCluster, SimNetwork

STORE_NODES = ("s1", "s2", "s3")


def tiny_model():
    return ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128, remat=False)


def make_trainer(tmp_path, store=None, run_id="run0", node="s1",
                 total=30, ckpt_every=10, master_weights=False):
    store = store or KVCluster(STORE_NODES, DVV_MECHANISM,
                               network=SimNetwork(seed=0))
    ckpt = CheckpointManager(store, str(tmp_path), run_id, node)
    trainer = Trainer(
        tiny_model(),
        AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=total,
                    master_weights=master_weights),
        PipelineConfig(vocab_size=128, seq_len=16, global_batch=4, seed=1),
        TrainerConfig(total_steps=total, ckpt_every=ckpt_every, log_every=5),
        ckpt)
    return trainer, store


def test_crash_restart_bitwise_resume(tmp_path):
    # uninterrupted reference run
    ref, _ = make_trainer(tmp_path / "ref")
    ref.init_fresh()
    ref.run()
    ref_fp = ref.state_fingerprint()

    # crashing run: dies at step 17 (last checkpoint at 10)
    t1, store = make_trainer(tmp_path / "crash")
    t1.init_fresh()
    with pytest.raises(RuntimeError):
        t1.run(crash_at=17)

    # a fresh process restores from the DVV store and finishes
    t2, _ = make_trainer(tmp_path / "crash", store=store)
    assert t2.try_restore()
    assert t2.step == 10                      # resumed from the checkpoint
    t2.run()
    assert t2.step == 30
    assert t2.state_fingerprint() == ref_fp   # bitwise-identical final state


def test_restore_resumes_data_cursor_exactly(tmp_path):
    t1, store = make_trainer(tmp_path)
    t1.init_fresh()
    t1.run(steps=10)
    cursor = t1.pipeline.state()
    t1.save()
    t2, _ = make_trainer(tmp_path, store=store)
    assert t2.try_restore()
    assert t2.pipeline.state() == cursor


def test_no_checkpoint_returns_false(tmp_path):
    t, _ = make_trainer(tmp_path)
    assert not t.try_restore()


def test_checkpoint_under_partition_converges(tmp_path):
    """Checkpoints written while the control plane is partitioned are
    reconciled: both halves restore the same lineage after heal."""
    t1, store = make_trainer(tmp_path, node="s1")
    t1.init_fresh()
    t1.run(steps=10)
    t1.save()
    store.antientropy_round()

    net = store.network
    net.partition({"s1"}, {"s2", "s3"})
    # two divergent continuation checkpoints at step 20
    t1.run(steps=10)
    t1.save()
    tb, _ = make_trainer(tmp_path, store=store, node="s2")
    assert tb.try_restore()       # restores step-10 state on the other side
    tb.run(steps=10)
    tb.save()
    net.heal()
    store.antientropy_round()

    ra, _ = make_trainer(tmp_path, store=store, node="s1")
    rb, _ = make_trainer(tmp_path, store=store, node="s3")
    assert ra.try_restore() and rb.try_restore()
    assert ra.step == rb.step == 20
    assert ra.state_fingerprint() == rb.state_fingerprint()


def test_master_weights_matches_fp32_training():
    """bf16 storage + fp32 master (the §Perf-1 optimization) must track
    fp32 training: losses equal within bf16 rounding."""
    from repro.models import init_params, loss_fn
    from repro.optim import adamw_update, init_opt_state

    cfg32 = tiny_model()
    cfg16 = ModelConfig(**{**cfg32.__dict__, "name": "tiny16",
                           "param_dtype": "bfloat16"})
    batch = {
        "tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, 128, (4, 16)), jnp.int32),
        "labels": jnp.asarray(
            np.random.default_rng(1).integers(0, 128, (4, 16)), jnp.int32),
    }
    losses = {}
    for cfg, mw in ((cfg32, False), (cfg16, True)):
        params = init_params(jax.random.key(0), cfg)
        opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=20,
                              master_weights=mw)
        opt = init_opt_state(params, opt_cfg)
        cur = []
        for _ in range(8):
            (loss, _), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
            params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
            cur.append(float(loss))
        losses[cfg.name] = cur
    np.testing.assert_allclose(losses["tiny"], losses["tiny16"],
                               rtol=0.05)
    # both must actually learn
    assert losses["tiny"][-1] < losses["tiny"][0]
    assert losses["tiny16"][-1] < losses["tiny16"][0]
