"""Geo-replication tier: DC topology, HLC walls, causal snapshot reads.

Four contract families (DESIGN.md §12):

* **Flat-default byte identity** — an untagged/single-DC cluster on the
  geo-aware fabric produces the exact pre-geo behaviour: one RNG draw per
  successful send with the flat ``base + draw * jitter`` arithmetic,
  integer walls equal to the shared clock, and context tokens without the
  HLC flag byte.
* **HLC robustness** — a stalled or backwards-stepping physical clock
  still mints strictly increasing walls per coordinator (pre-geo code
  trusted ``clock_time`` raw).
* **Causal snapshots** — ``snapshot_get*`` is causally consistent on both
  backends under WAN cuts and randomized schedules, serves entirely from
  the local DC (zero WAN messages), and conforms packed==object and
  scheduled==direct.
* **Topology plumbing** — latency classes and per-link overrides resolve
  override > class > flat; geo constructor validation; frozen membership.
"""
import random

import pytest

from repro.core import DVV_MECHANISM
from repro.store import (GeoPlane, KVCluster, OpScheduler, SimNetwork,
                         Unavailable)
from repro.store.version import HLC_STEP, HybridClock, hlc_decode, hlc_encode

pytestmark = pytest.mark.geo

DCS = {"east": ("e0", "e1", "e2"), "west": ("w0", "w1", "w2")}
NODES = [n for ns in DCS.values() for n in ns]


def geo_cluster(seed=0, packed=True, shards=1, net=None, **kw):
    net = net or SimNetwork(seed=seed)
    return KVCluster(NODES, DVV_MECHANISM, packed=packed, network=net,
                     seed=seed, shards=shards, datacenters=DCS, **kw)


# ---------------------------------------------------------------------------
# Flat-default byte identity (the single-DC regression probe).
# ---------------------------------------------------------------------------

def _traced_run(tag_dcs):
    """A fixed workload on a plain (non-geo) cluster, recording every
    successful send's latency; optionally DC-tag the nodes WITHOUT
    configuring latency classes — tags alone must change nothing."""
    net = SimNetwork(seed=99)
    if tag_dcs:
        for i, n in enumerate(("a", "b", "c")):
            net.set_datacenter(n, f"dc{i % 2}")
    c = KVCluster(("a", "b", "c"), DVV_MECHANISM, network=net, seed=99)
    trace = []
    orig = SimNetwork.send

    def send(self, src, dst, payload):
        before = len(self.queue)
        ok = orig(self, src, dst, payload)
        if ok:
            trace.append((src, dst, self.now, self.queue[-1].deliver_at))
            assert len(self.queue) == before + 1
        return ok

    SimNetwork.send = send
    try:
        ctx = None
        for t in range(12):
            node = ("a", "b", "c")[t % 3]
            c.put("k", f"v{t}", context=ctx, via=node, coordinator=node)
            if t % 3 == 0:
                c.deliver_replication()
            ctx = c.get("k", via=node).context
        c.deliver_replication()
    finally:
        SimNetwork.send = orig
    return c, trace


def test_flat_default_trace_is_pregeo_arithmetic():
    """Every successful send consumes exactly one RNG draw and prices
    latency as ``base + draw * jitter`` — replayable with a fresh
    ``random.Random(seed)``, i.e. the untagged fabric is byte-identical
    to the pre-geo one (same stream, same arithmetic, same draw count)."""
    c, trace = _traced_run(tag_dcs=False)
    replay = random.Random(99)
    for (src, dst, now, deliver_at) in trace:
        expect = now + (c.network.base_latency
                        + replay.random() * c.network.jitter)
        assert deliver_at == expect, (src, dst, deliver_at, expect)
    assert len(trace) > 10


def test_dc_tags_alone_change_nothing():
    """DC tags without ``set_latency_classes`` keep the flat default —
    identical trace to the untagged run (geo pricing is strictly opt-in).
    Only the WAN byte meters notice the tags."""
    c0, t0 = _traced_run(tag_dcs=False)
    c1, t1 = _traced_run(tag_dcs=True)
    assert t0 == t1
    assert c0.network.bytes_sent == c1.network.bytes_sent
    assert c0.network.wan_messages == 0
    assert c1.network.wan_messages > 0          # tags meter, never reprice
    for n in c0.nodes:
        assert c0.nodes[n].versions("k") == c1.nodes[n].versions("k")


def test_single_dc_walls_and_tokens_are_pregeo():
    """Non-geo clusters mint walls equal to the raw shared clock (the HLC
    physical branch always wins) and emit tokens without the HLC flag —
    the exact pre-geo wire bytes."""
    c = KVCluster(("a", "b"), DVV_MECHANISM, seed=1)
    for t in range(6):
        c.put("k", t, via="a")
    walls = sorted(v.wall for v in c.nodes["a"].versions("k"))
    assert walls == [float(t) for t in range(1, 7)]
    r = c.get("k", via="a")
    assert r.context.hlc == 0.0
    tok = r.context.to_bytes()
    assert tok[4] == 0                          # flag byte: no residue, no hlc
    assert len(tok) == 7 + (2 + 1 + 8)          # header + one entry, no tail


# ---------------------------------------------------------------------------
# Latency classes and per-link overrides.
# ---------------------------------------------------------------------------

def test_latency_tiers_override_beats_class_beats_flat():
    net = SimNetwork(seed=3, base_latency=1.0, jitter=0.0)
    for n in ("a", "b", "x"):
        net.set_datacenter(n, "d1" if n != "x" else "d2")
    assert net._link_params("a", "b") == (1.0, 0.0)          # flat (no class)
    net.set_latency_classes(lan=(2.0, 0.0), wan=(40.0, 5.0))
    assert net._link_params("a", "b") == (2.0, 0.0)          # LAN class
    assert net._link_params("a", "x") == (40.0, 5.0)         # WAN class
    assert net._link_params("a", "untagged") == (1.0, 0.0)   # flat fallback
    net.set_link_latency("a", "x", 7.0, 0.25)
    assert net._link_params("a", "x") == (7.0, 0.25)         # override wins
    assert net._link_params("x", "a") == (40.0, 5.0)         # directed
    net.clear_link_latency("a", "x")
    assert net._link_params("a", "x") == (40.0, 5.0)
    # the send path actually prices through the resolved tier
    net.set_link_latency("a", "x", 7.0, 0.0)
    assert net.send("a", "x", "payload")
    assert net.queue[-1].deliver_at == net.now + 7.0
    assert net.is_wan("a", "x") and not net.is_wan("a", "b")
    assert net.wan_messages == 1


# ---------------------------------------------------------------------------
# Hybrid logical clocks.
# ---------------------------------------------------------------------------

def test_hlc_encode_decode_roundtrip():
    for l, cnt in [(0, 0), (1, 0), (5, 3), (2**30, 2**19), (12345, 1)]:
        assert hlc_decode(hlc_encode(l, cnt)) == (l, cnt)
    assert hlc_encode(5, 0) == 5.0                # pure physical is exact
    assert hlc_encode(5, 1) == 5.0 + HLC_STEP


def test_hlc_mint_monotone_under_backwards_clock():
    h = HybridClock()
    prev = 0.0
    for pt in [10, 11, 12, 5, 5, 5, 13, 2, 2, 14]:
        w = h.mint(pt)
        assert w > prev, (pt, w, prev)
        assert w >= pt                            # never behind physical
        prev = w


def test_cluster_mints_monotone_walls_despite_clock_regression():
    """The regression the HLC exists for: pre-geo ``cluster.put`` trusted
    ``clock_time`` raw, so a backwards step would mint duplicate/reversed
    walls and break LWW resolution.  Now the coordinator's HybridClock
    absorbs the anomaly — strictly increasing walls, physical part never
    behind the clock — on geo and plain clusters alike."""
    for make in (lambda: geo_cluster(seed=2),
                 lambda: KVCluster(("a", "b"), DVV_MECHANISM, seed=2)):
        c = make()
        node = next(iter(c.nodes))
        seen = []
        for t in range(8):
            c.put("k", f"v{t}", via=node, coordinator=node)
            if t == 3:
                c.clock_time -= 5.0               # inject the anomaly
            seen.append(max(v.wall for v in c.nodes[node].versions("k")))
        assert seen == sorted(seen)
        assert len(set(seen)) == len(seen), seen  # strictly increasing


def test_causal_read_write_orders_walls_across_dcs():
    """read-at-west → put-at-west must mint above everything the read saw,
    even when west's own clock view lags: the token's HLC watermark is
    folded in at the coordinator."""
    g = geo_cluster(seed=4)
    g.put("k1", "v1", via="e0")
    g.deliver_replication()
    g.geo.wan_round()
    r = g.snapshot_get("k1", via="w0")
    assert r.context.hlc > 0.0
    wall1 = max(v.wall for v in g.nodes["w0"].versions("k1"))
    g.put("k2", "v2", r.context, via="w0")
    wall2 = max(v.wall for v in g.nodes["w0"].versions("k2"))
    assert wall2 > wall1
    assert wall2 > r.context.hlc


# ---------------------------------------------------------------------------
# Geo topology: construction, placement, membership.
# ---------------------------------------------------------------------------

def test_geo_constructor_validation():
    with pytest.raises(ValueError, match="at least two"):
        KVCluster(("a", "b"), DVV_MECHANISM,
                  datacenters={"only": ("a", "b")})
    with pytest.raises(ValueError, match="equal-sized"):
        KVCluster(("a", "b", "c"), DVV_MECHANISM,
                  datacenters={"d1": ("a", "b"), "d2": ("c",)})
    with pytest.raises(ValueError, match="two datacenters"):
        KVCluster(("a", "b"), DVV_MECHANISM,
                  datacenters={"d1": ("a",), "d2": ("a",)})
    with pytest.raises(ValueError, match="cover exactly"):
        KVCluster(("a", "b", "c"), DVV_MECHANISM,
                  datacenters={"d1": ("a",), "d2": ("b",)})


def test_geo_placement_mirrors_key_space():
    """Every DC owns an identical copy of the key space: each key's
    replica set holds its full local replica count in every DC, and
    mirror rows pair one node per DC."""
    g = geo_cluster(seed=0, shards=4)
    assert isinstance(g.geo, GeoPlane)
    assert g.replication == 3                     # defaults to DC size
    for key in (f"key{i}" for i in range(40)):
        reps = g.replicas_for(key)
        per_dc = {dc: sum(1 for r in reps if g.geo.dc_of[r] == dc)
                  for dc in DCS}
        assert per_dc == {"east": 3, "west": 3}, (key, reps)
    for n in NODES:
        row = g.geo.mirrors(n)
        assert len(row) == len(DCS) and n in row
        assert {g.geo.dc_of[m] for m in row} == set(DCS)


def test_geo_membership_is_frozen():
    g = geo_cluster()
    with pytest.raises(ValueError, match="geo"):
        g.add_node("late")
    with pytest.raises(ValueError, match="geo"):
        g.remove_node("e0")


def test_geo_gossip_stays_lan_scoped():
    g = geo_cluster()
    for node in NODES:
        dc = g.geo.dc_of[node]
        for step in range(6):
            for peer in g.gossip_peers(node, 2, step):
                assert g.geo.dc_of[peer] == dc, (node, peer)


# ---------------------------------------------------------------------------
# Causal snapshot reads.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("shards", [1, 4])
def test_snapshot_zero_wan_and_causal_under_cut(packed, shards):
    g = geo_cluster(seed=7, packed=packed, shards=shards)
    net = g.network
    net.set_latency_classes(lan=(1.0, 0.5), wan=(30.0, 10.0))
    net.partition(set(DCS["east"]), set(DCS["west"]))
    g.put("k1", "v1", via="e0")
    r = g.get("k1", via="e0")
    g.put("k2", "v2-after-k1", r.context, via="e1")
    g.deliver_replication()
    # west sees nothing yet — but serves, locally, with zero WAN traffic
    wan0 = net.wan_messages
    s = g.snapshot_get_many(["k1", "k2"], via="w0")
    assert s["k1"].values == () and s["k2"].values == ()
    net.heal()
    g.deliver_replication()
    g.geo.wan_round()
    s = g.snapshot_get_many(["k1", "k2"], via="w0")
    assert s["k2"].values == ("v2-after-k1",)
    assert s["k1"].values == ("v1",)              # causal: dep visible too
    assert net.wan_messages == wan0, "snapshot path sent WAN messages"


@pytest.mark.parametrize("packed", [True, False])
def test_snapshot_serves_displaced_versions_from_shadows(packed):
    """A frontier held below a dominator's wall must still see the
    displaced predecessor — the stable-shadow retention path — and the
    shadow is pruned once the obligation clears."""
    g = geo_cluster(seed=3, packed=packed)
    g.put("k", "v1", via="e0")
    g.deliver_replication()
    g.geo.wan_round()
    assert g.snapshot_get("k", via="w0").values == ("v1",)
    g.geo.note_send_failed("e0", "w1", 1.5)       # synthetic obligation
    r = g.get("k", via="e0")
    g.put("k", "v2", r.context, via="e0")
    g.deliver_replication()
    g.geo.wan_round()                             # west fully displaced v1
    for w in DCS["west"]:
        assert g.nodes[w].versions("k") == g.nodes["e0"].versions("k")
    assert g.snapshot_get("k", via="w0").values == ("v1",)
    g.delta_antientropy("e0", "w1")               # discharge the obligation
    assert g.snapshot_get("k", via="w0").values == ("v2",)
    assert not any(g.geo.shadow.get(w, {}).get("k") for w in DCS["west"])


def test_snapshot_requires_local_replicas_only():
    """A WAN cut never blocks snapshots; a down local replica does (the
    frontier only promises SOME local member holds each version)."""
    g = geo_cluster(seed=5)
    net = g.network
    net.partition(set(DCS["east"]), set(DCS["west"]))
    assert g.probe_snapshot(["k"], via="w0") is None
    g.snapshot_get("k", via="w0")                 # serves (empty) fine
    net.fail_node("w2")
    assert g.probe_snapshot(["k"], via="w0") is not None
    with pytest.raises(Unavailable, match="snapshot"):
        g.snapshot_get("k", via="w0")
    net.recover_node("w2")
    assert g.probe_snapshot(["k"], via="w0") is None


def test_frontier_monotone_and_lag_closes():
    g = geo_cluster(seed=11)
    net = g.network
    net.partition(set(DCS["east"]), set(DCS["west"]))
    fs = [g.geo.stable_frontier("west")]
    for t in range(10):
        g.put(f"k{t % 3}", f"v{t}", via="e0")
        fs.append(g.geo.stable_frontier("west"))
    assert fs == sorted(fs)                       # monotone under cut
    assert g.geo.frontier_lag("west") > 0.0       # backlog holds it down
    net.heal()
    g.deliver_replication()
    g.geo.wan_round()
    assert g.geo.frontier_lag("west") == 0.0      # ships → lag closes
    assert g.geo.stable_frontier("west") >= fs[-1]


def test_wan_shipper_runs_on_sim_time():
    """The continuous loop: advancing simulated time alone ships committed
    writes cross-DC and converges snapshot reads, with backlogs discharged
    by complete ticks (no hand-cranked wan_round)."""
    g = geo_cluster(seed=13, wan_period=10.0)
    net = g.network
    g.put("k", "v1", via="e0")
    assert g.geo.wan_backlog.get(("east", "west"))
    net.advance(200.0)
    assert g.snapshot_get("k", via="w0").values == ("v1",)
    assert not g.geo.wan_backlog.get(("east", "west"))
    assert g.geo.shipper.ticks > 0 and g.geo.wan_rounds > 0
    # idle links back off: later ticks come slower than the base period
    t0 = g.geo.shipper.ticks
    net.advance(200.0)
    assert g.geo.shipper.ticks - t0 < 200.0 / 10.0


@pytest.mark.parametrize("packed", [True, False])
def test_snapshot_packed_object_conformance(packed):
    """Randomized mixed workload: snapshot results agree across backends
    at every probe point (same walls, values, tokens)."""
    del packed  # both built below; param keeps ids stable under -k filters
    rng = random.Random(21)
    ops = []
    for t in range(30):
        p = rng.random()
        if p < 0.5:
            ops.append(("put", rng.randrange(4), rng.randrange(6)))
        elif p < 0.7:
            ops.append(("snap", rng.randrange(4), rng.randrange(6)))
        elif p < 0.8:
            ops.append(("cut",))
        elif p < 0.9:
            ops.append(("heal",))
        else:
            ops.append(("ship",))

    def run(packed_flag):
        g = geo_cluster(seed=21, packed=packed_flag)
        out = []
        for op in ops:
            if op[0] == "put":
                _, ki, ni = op
                try:
                    g.put(f"k{ki}", f"v{len(out)}", via=NODES[ni])
                except Unavailable:
                    pass
            elif op[0] == "snap":
                _, ki, ni = op
                try:
                    r = g.snapshot_get(f"k{ki}", via=NODES[ni])
                    out.append((r.values, r.context))
                except Unavailable:
                    out.append(None)
            elif op[0] == "cut":
                g.network.partition(set(DCS["east"]), set(DCS["west"]))
            elif op[0] == "heal":
                g.network.heal()
            else:
                g.deliver_replication()
                g.geo.wan_round()
        return out

    assert run(True) == run(False)


def test_scheduled_snapshots_match_direct_and_share_one_plane_call():
    g = geo_cluster(seed=6)
    g.put("a", "v1", via="e0")
    g.put("b", "v2", via="e1")
    g.deliver_replication()
    g.geo.wan_round()
    sched = OpScheduler(g, via="w0", max_batch=16, max_delay=2.0)
    s1 = sched.session("s1")
    s2 = sched.session("s2")
    direct = g.snapshot_get_many(["a", "b"], via="w0")
    planes0 = g.plane_reads
    op1 = s1.submit_snapshot_get(["a"])
    op2 = s2.submit_snapshot_get(["b", "a"])
    op3 = s1.submit_snapshot_get(["b"])
    sched.flush()
    assert op1.result() == {"a": direct["a"]}
    assert op2.result() == {"b": direct["b"], "a": direct["a"]}
    assert op3.result() == {"b": direct["b"]}
    assert sched.stats()["snapshot_calls"] == 1
    assert g.plane_reads == planes0 + 1           # one shared invocation


def test_scheduled_flush_snapshot_precedes_same_flush_puts():
    """Within one flush, snapshot results are those of the pre-flush
    frontier: a put on the same key in the same batch is not yet stable
    (its replication/WAN obligations hold the frontier), so the snapshot
    must not observe it — deterministic order: snapshots run first."""
    g = geo_cluster(seed=8)
    g.put("k", "old", via="w0")
    g.deliver_replication()
    g.geo.wan_round()
    sched = OpScheduler(g, via="w0", max_batch=64, max_delay=2.0)
    s = sched.session("s")
    snap = s.submit_snapshot_get(["k"])
    put = s.submit_put({"k": ("new", None)})
    sched.flush()
    assert put.error is None
    assert snap.result()["k"].values == ("old",)
