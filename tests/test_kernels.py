"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp ref oracles
(interpret=True executes the kernel bodies on CPU)."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DVV
from repro.core import batched as B
from repro.kernels.dvv_ops import (
    dvv_concurrent, dvv_dominates, dvv_leq, dvv_sync_mask,
)
from repro.kernels.dvv_ops.ref import concurrent_ref, leq_ref, sync_mask_ref
from repro.kernels.flash_attention import flash_attention, gqa_flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


# ---------------------------------------------------------------------------
# dvv_ops
# ---------------------------------------------------------------------------

def _rand_clock(rng, universe):
    comps = []
    for r in universe:
        if rng.random() < 0.6:
            m = rng.randint(0, 6)
            if m > 0:
                comps.append([r, m, 0])
    if comps and rng.random() < 0.7:
        i = rng.randrange(len(comps))
        comps[i][2] = comps[i][1] + rng.randint(1, 3)
    return DVV(tuple(tuple(c) for c in comps if c[1] > 0 or c[2] > 0))


@pytest.mark.parametrize("n_replicas", [1, 3, 5, 9])
@pytest.mark.parametrize("n", [1, 17, 300])
def test_dvv_leq_kernel_sweep(n_replicas, n):
    rng = random.Random(n_replicas * 1000 + n)
    universe = [f"r{i}" for i in range(n_replicas)]
    xs = [_rand_clock(rng, universe) for _ in range(n)]
    ys = [_rand_clock(rng, universe) for _ in range(n)]
    vx, ix, nx = B.encode_batch(xs, universe)
    vy, iy, ny = B.encode_batch(ys, universe)
    args = [jnp.asarray(a) for a in (vx, ix, nx, vy, iy, ny)]
    got = np.asarray(dvv_leq(*args))
    ref = np.asarray(leq_ref(*args))
    pure = np.array([x.leq(y) for x, y in zip(xs, ys)])
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(got, pure)


def test_dvv_concurrent_and_dominates_consistency():
    rng = random.Random(0)
    universe = ["a", "b", "c"]
    xs = [_rand_clock(rng, universe) for _ in range(200)]
    ys = [_rand_clock(rng, universe) for _ in range(200)]
    vx, ix, nx = B.encode_batch(xs, universe)
    vy, iy, ny = B.encode_batch(ys, universe)
    args = [jnp.asarray(a) for a in (vx, ix, nx, vy, iy, ny)]
    conc = np.asarray(dvv_concurrent(*args))
    ref = np.asarray(concurrent_ref(*args))
    np.testing.assert_array_equal(conc, ref)
    dom = np.asarray(dvv_dominates(*args))
    pure_dom = np.array([x.dominates(y) for x, y in zip(xs, ys)])
    np.testing.assert_array_equal(dom, pure_dom)


@pytest.mark.parametrize("n_replicas", [1, 3, 9])
@pytest.mark.parametrize("n_keys,max_versions", [(1, 1), (19, 4), (150, 6)])
def test_dvv_sync_mask_fused_kernel_sweep(n_replicas, n_keys, max_versions):
    """The fused pairwise-dominance kernel equals the jnp sync_mask
    reference on randomized per-key clock sets (incl. invalid padding)."""
    rng = random.Random(n_replicas * 7919 + n_keys + max_versions)
    universe = [f"r{i}" for i in range(n_replicas)]
    vvs = np.zeros((n_keys, max_versions, n_replicas), np.int32)
    dids = np.full((n_keys, max_versions), B.NO_DOT, np.int32)
    dns = np.zeros((n_keys, max_versions), np.int32)
    valid = np.zeros((n_keys, max_versions), bool)
    for i in range(n_keys):
        for j in range(rng.randint(0, max_versions)):
            clock = _rand_clock(rng, universe)
            vvs[i, j], dids[i, j], dns[i, j] = B.encode(clock, universe)
            valid[i, j] = True
    args = [jnp.asarray(a) for a in (vvs, dids, dns, valid)]
    got = np.asarray(dvv_sync_mask(*args))
    ref = np.asarray(sync_mask_ref(*args))
    np.testing.assert_array_equal(got, ref)
    np_ref = B.sync_mask_np(vvs, dids, dns, valid)
    np.testing.assert_array_equal(got, np_ref)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 2, 128, 64), (2, 4, 256, 64), (1, 2, 256, 128),
])
@pytest.mark.parametrize("mode", ["causal", "window", "bidir", "softcap"])
def test_flash_attention_sweep(dtype, shape, mode):
    Bn, H, S, D = shape
    rng = np.random.default_rng(hash((Bn, H, S, D, mode)) % 2**31)
    q = jnp.asarray(rng.normal(size=shape), dtype)
    k = jnp.asarray(rng.normal(size=shape), dtype)
    v = jnp.asarray(rng.normal(size=shape), dtype)
    kw = dict(causal=True, window=0, softcap=0.0)
    if mode == "window":
        kw["window"] = S // 4
    elif mode == "bidir":
        kw["causal"] = False
    elif mode == "softcap":
        kw["softcap"] = 30.0
    out = flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    ref = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), **kw)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref)))
    assert err < tol, (mode, shape, dtype, err)


def test_flash_attention_gqa_wrapper():
    rng = np.random.default_rng(11)
    Bn, S, H, KV, D = 2, 128, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(Bn, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.float32)
    out = gqa_flash_attention(q, k, v, block_q=64, block_k=64)
    # reference: expand KV and run naive
    kx = jnp.repeat(k.transpose(0, 2, 1, 3), H // KV, axis=1)
    vx = jnp.repeat(v.transpose(0, 2, 1, 3), H // KV, axis=1)
    ref = mha_ref(q.transpose(0, 2, 1, 3), kx, vx, causal=True)
    err = float(jnp.max(jnp.abs(out.transpose(0, 2, 1, 3) - ref)))
    assert err < 1e-5


def test_flash_matches_model_chunked_attention():
    """Three-way agreement: pallas flash == model chunked == model naive."""
    from repro.models.attention import (
        AttnSpec, _attend_chunked, _attend_naive, _group_q,
    )
    rng = np.random.default_rng(5)
    Bn, S, H, KV, D = 2, 128, 4, 2, 64
    spec = AttnSpec(n_heads=H, n_kv_heads=KV, head_dim=D)
    q = jnp.asarray(rng.normal(size=(Bn, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bn, S, KV, D)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    naive = _attend_naive(_group_q(q, KV), k, v, pos, pos, spec)
    chunked = _attend_chunked(_group_q(q, KV), k, v, pos, pos, spec, 32)
    flash = gqa_flash_attention(q, k, v, block_q=64, block_k=64)
    flash = flash.reshape(naive.shape)
    assert float(jnp.max(jnp.abs(naive - chunked))) < 1e-5
    assert float(jnp.max(jnp.abs(naive - flash.reshape(naive.shape)))) < 1e-5


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("shape", [
    (1, 64, 2, 8, 16, 16), (2, 128, 3, 8, 16, 32), (1, 256, 4, 16, 32, 64),
])
def test_ssd_scan_sweep(dtype, shape):
    Bn, S, H, P, N, chunk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    xh = jnp.asarray(rng.normal(size=(Bn, S, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(Bn, S, H)), dtype)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), dtype)
    Bc = jnp.asarray(rng.normal(size=(Bn, S, N)), dtype)
    Cc = jnp.asarray(rng.normal(size=(Bn, S, N)), dtype)
    D = jnp.asarray(rng.normal(size=(H,)), dtype)
    y, hf = ssd_scan(xh, dt, A, Bc, Cc, D, chunk=chunk)
    y_ref, h_ref = ssd_ref(xh, dt, A, Bc, Cc, D, chunk)
    ry = float(jnp.max(jnp.abs(y - y_ref)) / (jnp.max(jnp.abs(y_ref)) + 1e-9))
    rh = float(jnp.max(jnp.abs(hf - h_ref)) / (jnp.max(jnp.abs(h_ref)) + 1e-9))
    assert ry < 1e-5 and rh < 1e-5, (shape, ry, rh)


def test_ssd_scan_bf16_tolerance():
    Bn, S, H, P, N, chunk = 1, 64, 2, 8, 16, 16
    rng = np.random.default_rng(1)
    xh = jnp.asarray(rng.normal(size=(Bn, S, H, P)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(Bn, S, H)), jnp.bfloat16)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(Bn, S, N)), jnp.bfloat16)
    Cc = jnp.asarray(rng.normal(size=(Bn, S, N)), jnp.bfloat16)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    y, _ = ssd_scan(xh, dt, A.astype(jnp.bfloat16), Bc, Cc,
                    D.astype(jnp.bfloat16), chunk=chunk)
    y_ref, _ = ssd_ref(xh.astype(jnp.float32), dt.astype(jnp.float32), A,
                       Bc.astype(jnp.float32), Cc.astype(jnp.float32), D,
                       chunk)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref))
                / (jnp.max(jnp.abs(y_ref)) + 1e-9))
    assert rel < 5e-2, rel


def test_model_forward_with_pallas_attention_matches():
    """use_pallas=True routes the model's attention through the flash
    kernel (interpret-mode on CPU) — logits must match the jnp path."""
    from dataclasses import replace

    import jax

    from repro.configs import get_config
    from repro.models import forward, init_params

    cfg = get_config("granite-8b").smoke()
    cfg = replace(cfg, attn_chunk=16)
    params = init_params(jax.random.key(0), cfg)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)),
        jnp.int32)
    ref, _ = forward(params, {"tokens": toks}, cfg)
    out, _ = forward(params, {"tokens": toks},
                     replace(cfg, use_pallas=True))
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 0.05, err
