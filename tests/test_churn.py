"""Randomized churn conformance: the store under a changing universe.

One schedule interpreter drives *everything the system can do at once* —
puts (with and without causal context), gets, partitions, heals, node
failures/recoveries, joins (with warm bootstrap) and departures — against
a cluster whose gossip runs continuously off simulated time
(``GossipDriver``).  After the schedule, the world is quiesced (heal,
recover, drain, gossip to convergence) and three properties must hold:

* **replica agreement** — every live replica holds the identical sibling
  set for every key (and ``cluster_converged`` says so);
* **backend agreement** — the packed int32 store and the object-clock
  store, driven by the same schedule, end observationally equal
  (version sets, metadata sizes, resolved register values);
* **seed determinism** — the same seed replays the identical message
  trace, byte for byte, timers included (churn must not introduce
  iteration-order or hash-order nondeterminism anywhere).

The hypothesis phase (``slow``+``churn`` markers — the ``make
test-churn`` lane is its dedicated home) fuzzes schedules; a few pinned
seeds run in tier-1 so the machinery never rots unexercised.
"""
import random
import shutil
import tempfile

import pytest

from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVCluster, MembershipController,
                         SimNetwork, Unavailable, cluster_converged)

pytestmark = pytest.mark.churn

KEYS = tuple(f"k{i}" for i in range(5))
BASE_NODES = ("n0", "n1", "n2")
MAX_NODES = 6


# ---------------------------------------------------------------------------
# The schedule interpreter (shared by both backends and the fuzzer).
# ---------------------------------------------------------------------------

def _run_schedule(seed, ops, packed, quiesce=True, shards=1,
                  membership=False, wal_dir=None):
    """Interpret one churn schedule.  All choices are resolved against
    *current* membership (indices mod the live node list), so the same op
    list is meaningful whatever the interleaving did to the cluster.

    ``membership=True`` attaches a ``MembershipController`` — the
    self-driving loop then evicts/re-admits nodes on its own (schedules
    exercising it use fault ops, never hand-called add/remove), and the
    conformance helpers verify the membership *trajectory* is identical
    across backends too.  The fault ops (``cut``/``heal_link``/``slow``/
    ``dup``/``reorder``/``flap``) drive the SimNetwork fault matrix.

    ``wal_dir`` turns on the durable segment logs (small snapshot/seal
    knobs so schedules cross many snapshot and seal boundaries) and
    enables the ``crash_restart`` op: discard a node's process state and
    rebuild it warm from disk mid-schedule (DESIGN.md §14)."""
    net = SimNetwork(seed=seed)
    wal_kwargs = {} if wal_dir is None else dict(
        wal_dir=wal_dir, wal_snapshot_every=6, wal_seal_bytes=2048)
    c = KVCluster(BASE_NODES, DVV_MECHANISM, packed=packed, network=net,
                  seed=seed, shards=shards, **wal_kwargs)
    driver = GossipDriver(c, period=6.0, seed=seed)
    controller = MembershipController(c, period=6.0, seed=seed) \
        if membership else None
    contexts = {}
    next_id = len(BASE_NODES)
    for t, op in enumerate(ops):
        kind = op[0]
        nodes = list(c.nodes)
        if kind == "put":
            _, ki, ni, use_ctx = op
            node = nodes[ni % len(nodes)]
            key = KEYS[ki % len(KEYS)]
            ctx = contexts.get((node, key)) if use_ctx else None
            try:
                c.put(key, f"v{t}", context=ctx, via=node, coordinator=node)
            except Unavailable:
                pass
        elif kind == "get":
            _, ki, ni = op
            node = nodes[ni % len(nodes)]
            key = KEYS[ki % len(KEYS)]
            try:
                contexts[(node, key)] = c.get(key, via=node).context
            except Unavailable:
                pass
        elif kind == "partition":
            _, p = op
            g1 = {n for i, n in enumerate(nodes) if (i + p) % 2}
            g2 = set(nodes) - g1
            if g1 and g2:
                net.partition(g1, g2)
        elif kind == "heal":
            net.heal()
        elif kind == "fail":
            _, ni = op
            node = nodes[ni % len(nodes)]
            if len(net.down) < len(nodes) - 1:   # keep one node alive
                net.fail_node(node)
        elif kind == "recover":
            _, ni = op
            net.recover_node(nodes[ni % len(nodes)])
        elif kind == "add":
            if len(c.nodes) < MAX_NODES:
                c.add_node(f"n{next_id}")
                next_id += 1
        elif kind == "remove":
            _, ni = op
            if len(c.nodes) > 2:
                c.remove_node(nodes[ni % len(nodes)])
        elif kind == "advance":
            _, dt = op
            driver.run_for(float(dt))
        elif kind == "deliver":
            c.deliver_replication()
        elif kind == "cut":                      # one-directional link cut
            _, i, j = op
            a, b = nodes[i % len(nodes)], nodes[j % len(nodes)]
            if a != b:
                net.cut_link(a, b)
        elif kind == "heal_link":
            _, i, j = op
            net.heal_link(nodes[i % len(nodes)], nodes[j % len(nodes)])
        elif kind == "slow":                     # slow-not-dead node
            _, ni, factor = op
            net.set_delay_factor(nodes[ni % len(nodes)], float(factor))
        elif kind == "dup":
            _, rate = op
            net.set_duplication(float(rate))
        elif kind == "reorder":
            _, rate = op
            net.set_reorder(float(rate), spread=25.0)
        elif kind == "flap":
            _, i, j = op
            a, b = nodes[i % len(nodes)], nodes[j % len(nodes)]
            if a != b and len(net._flaps) < 2:   # bound concurrent flaps
                net.flap_link(a, b, up_for=8.0, down_for=8.0)
        elif kind == "crash_restart":
            # process crash + immediate warm restart from the durable log
            # (the old replica object is discarded, so any state the log
            # failed to carry would be *observably* lost here)
            _, ni = op
            node = nodes[ni % len(nodes)]
            if wal_dir is not None and node in c.wal:
                c.restart_node(node)
        else:                                    # pragma: no cover
            raise AssertionError(op)
    if quiesce:
        net.stop_flaps()
        net.heal()
        for n in list(net.down):
            net.recover_node(n)
        for n in list(net.delay_factors):
            net.set_delay_factor(n, 1.0)
        net.set_duplication(0.0)
        net.set_reorder(0.0)
        c.deliver_replication()
        driver.run_for(60.0 * len(c.nodes))
        # slow-node stragglers may still be queued with due times past the
        # run_for horizon; a second unbounded drain flushes them
        c.deliver_replication()
        # belt and braces: bounded explicit rounds prove a fixpoint even
        # if the adaptive cadence backed off right before the deadline
        for _ in range(len(c.nodes) + 1):
            c.delta_antientropy_round()
        # queue-leak probe (satellite bugfix): nothing may remain queued
        # toward a node that is no longer a member — eviction must purge
        assert all(m.dst in c.nodes for m in net.queue), \
            [(m.src, m.dst) for m in net.queue if m.dst not in c.nodes]
        if controller is not None:
            # zero hand-called membership: after full heal + recovery the
            # loop must have re-admitted every evicted node by itself
            assert not controller.evicted_nodes(), controller.evicted_nodes()
    return c, driver


def _assert_replicas_agree(c, tag):
    assert cluster_converged(c), tag
    for k in KEYS:
        ref = None
        for n in c.nodes:
            vs = c.nodes[n].versions(k)
            if ref is None:
                ref = vs
            assert vs == ref, (tag, n, k)


def _assert_backends_agree(cp, co, tag):
    assert list(cp.nodes) == list(co.nodes), tag
    for k in KEYS:
        for n in cp.nodes:
            vp, vo = cp.nodes[n].versions(k), co.nodes[n].versions(k)
            assert vp == vo, (tag, n, k, vp, vo)
            assert cp.nodes[n].metadata_size(k) == \
                co.nodes[n].metadata_size(k), (tag, n, k)
        gp, go = cp.get(k), co.get(k)
        assert gp.values == go.values, (tag, k)
        assert gp.value == go.value, (tag, k)
        assert gp.context == go.context, (tag, k)


def _conformance(seed, ops, tag, shards=1, membership=False, wal=False):
    tmp = tempfile.mkdtemp(prefix="churnwal-") if wal else None
    try:
        cp, _ = _run_schedule(
            seed, ops, packed=True, shards=shards, membership=membership,
            wal_dir=tmp and f"{tmp}/packed")
        co, _ = _run_schedule(
            seed, ops, packed=False, shards=shards, membership=membership,
            wal_dir=tmp and f"{tmp}/object")
        _assert_replicas_agree(cp, ("packed", tag))
        _assert_replicas_agree(co, ("object", tag))
        _assert_backends_agree(cp, co, tag)
        if membership:
            # the self-driving loop's decisions are part of conformance:
            # same probes, same evictions, same re-admissions on both
            # backends
            mp, mo = cp.membership, co.membership
            assert (mp.probes, mp.evictions, mp.readmissions) == \
                (mo.probes, mo.evictions, mo.readmissions), tag
        if wal:
            # every packed store must come out of replay + catch-up with
            # coherent digest trees and bucket indexes
            for n in cp.nodes.values():
                for st in n.shard_stores:
                    assert st.check_digests(), tag
                    assert st.check_bucket_index(), tag
        return cp, co
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)


def _random_ops(seed, n_ops=40):
    rng = random.Random(seed)
    ops = []
    for _ in range(n_ops):
        p = rng.random()
        if p < 0.35:
            ops.append(("put", rng.randrange(8), rng.randrange(8),
                        rng.random() < 0.5))
        elif p < 0.50:
            ops.append(("get", rng.randrange(8), rng.randrange(8)))
        elif p < 0.58:
            ops.append(("partition", rng.randrange(1, 6)))
        elif p < 0.64:
            ops.append(("heal",))
        elif p < 0.70:
            ops.append(("fail", rng.randrange(8)))
        elif p < 0.76:
            ops.append(("recover", rng.randrange(8)))
        elif p < 0.81:
            ops.append(("add",))
        elif p < 0.86:
            ops.append(("remove", rng.randrange(8)))
        elif p < 0.96:
            ops.append(("advance", rng.randrange(1, 25)))
        else:
            ops.append(("deliver",))
    return ops


# ---------------------------------------------------------------------------
# Tier-1 pinned schedules (fast lane: the machinery never rots).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7, 23])
def test_churn_conformance_pinned(seed):
    _conformance(seed, _random_ops(seed), seed)


@pytest.mark.parametrize("seed", [0, 23])
def test_churn_conformance_pinned_sharded(seed):
    """The same schedules with the store split across 4 hash shards:
    placement, per-shard gossip, rebalance-on-join and handoff-on-depart
    must leave the sharded stores observationally identical to the
    single-dict object backend."""
    _conformance(seed, _random_ops(seed), ("sharded", seed), shards=4)


def _random_durable_ops(seed, n_ops=36):
    """Churn ops with warm restarts in the mix: crashes land between
    partitions, failures and membership changes, so replay + one-delta-pass
    recovery is exercised against every kind of concurrent divergence."""
    rng = random.Random(f"durable:{seed}")
    ops = []
    for _ in range(n_ops):
        p = rng.random()
        if p < 0.34:
            ops.append(("put", rng.randrange(8), rng.randrange(8),
                        rng.random() < 0.5))
        elif p < 0.46:
            ops.append(("get", rng.randrange(8), rng.randrange(8)))
        elif p < 0.54:
            ops.append(("crash_restart", rng.randrange(8)))
        elif p < 0.61:
            ops.append(("partition", rng.randrange(1, 6)))
        elif p < 0.66:
            ops.append(("heal",))
        elif p < 0.71:
            ops.append(("fail", rng.randrange(8)))
        elif p < 0.76:
            ops.append(("recover", rng.randrange(8)))
        elif p < 0.80:
            ops.append(("add",))
        elif p < 0.84:
            ops.append(("remove", rng.randrange(8)))
        elif p < 0.95:
            ops.append(("advance", rng.randrange(1, 25)))
        else:
            ops.append(("deliver",))
    return ops


@pytest.mark.durable
@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("seed", [2, 11])
def test_durable_churn_conformance_pinned(seed, shards):
    """Warm restarts mixed with partitions and membership churn: packed
    and object backends must stay observationally equal when every node
    logs durably and some get crash-restarted mid-schedule."""
    _conformance(seed, _random_durable_ops(seed), ("durable", seed, shards),
                 shards=shards, wal=True)


@pytest.mark.durable
def test_durable_restart_during_partition_schedule():
    """Hand-written worst case: a node restarts *while partitioned away*
    (its recovery delta pass reaches only its own side), then the heal
    must reconcile both the restart and the partition divergence."""
    ops = [
        ("put", 0, 0, False), ("put", 1, 1, False), ("advance", 10),
        ("partition", 1), ("put", 0, 0, True), ("put", 2, 2, False),
        ("crash_restart", 1),                # restart inside the partition
        ("put", 3, 1, False), ("advance", 15),
        ("heal",), ("crash_restart", 0),     # restart right after heal
        ("put", 4, 2, True), ("advance", 20),
        ("fail", 2), ("crash_restart", 2),   # restart a failed-dead node
        ("advance", 25), ("deliver",),
    ]
    _conformance(5, ops, "durable-partition-restart", wal=True)


def test_churn_heavy_membership_schedule():
    """A hand-written worst case: join during partition, write to the
    joiner, depart an original node while its writes are still in flight."""
    ops = [
        ("put", 0, 0, False), ("put", 1, 1, False), ("advance", 10),
        ("partition", 1), ("put", 0, 0, True), ("add",),
        ("put", 2, 3, False),                # write lands on the joiner
        ("advance", 15), ("heal",), ("add",),
        ("fail", 1), ("put", 3, 0, False), ("advance", 20),
        ("remove", 1),                       # depart one of the originals
        ("recover", 1), ("put", 4, 2, True), ("advance", 30),
    ]
    _conformance(3, ops, "heavy-membership")


def test_same_seed_identical_message_trace():
    """The seed-determinism probe: two runs of one seed produce the same
    message trace (src, dst, kind, size, send-time), the same timer count,
    and the same wire totals — churn introduces no hidden ordering."""
    from repro.store.network import payload_nbytes

    def run_with_trace():
        trace = []
        orig_send = SimNetwork.send

        def send(self, src, dst, payload):
            ok = orig_send(self, src, dst, payload)
            trace.append((round(self.now, 9), src, dst, payload[0],
                          payload_nbytes(payload), ok))
            return ok

        SimNetwork.send = send
        try:
            c, d = _run_schedule(17, _random_ops(17, 50), packed=True)
        finally:
            SimNetwork.send = orig_send
        return trace, c, d

    t1, c1, d1 = run_with_trace()
    t2, c2, d2 = run_with_trace()
    assert t1 == t2
    assert c1.network.timers_fired == c2.network.timers_fired
    assert c1.network.bytes_sent == c2.network.bytes_sent
    assert (d1.ticks, d1.rounds, d1.wire_bytes()) == \
        (d2.ticks, d2.rounds, d2.wire_bytes())
    for k in KEYS:
        for n in c1.nodes:
            assert c1.nodes[n].versions(k) == c2.nodes[n].versions(k)


# ---------------------------------------------------------------------------
# Geo schedules: whole-DC WAN cuts (+ the usual churn) against a
# two-datacenter cluster; snapshot reads probed mid-schedule.
# ---------------------------------------------------------------------------

GEO_DCS = {"east": ("e0", "e1", "e2"), "west": ("w0", "w1", "w2")}
GEO_NODES = tuple(n for ns in GEO_DCS.values() for n in ns)


def _run_geo_schedule(seed, ops, packed, quiesce=True, shards=1):
    """The churn interpreter's geo twin: fixed membership (mirror placement
    is static), WAN latency classes, a ``partition_dc`` action that cuts a
    whole datacenter off the WAN, and ``snapshot_get`` probes whose
    results are collected for cross-backend comparison."""
    net = SimNetwork(seed=seed)
    net.set_latency_classes(lan=(1.0, 0.5), wan=(30.0, 10.0))
    c = KVCluster(GEO_NODES, DVV_MECHANISM, packed=packed, network=net,
                  seed=seed, shards=shards, datacenters=GEO_DCS,
                  wan_period=12.0)
    driver = GossipDriver(c, period=6.0, seed=seed)
    contexts = {}
    snaps = []
    for t, op in enumerate(ops):
        kind = op[0]
        if kind == "put":
            _, ki, ni, use_ctx = op
            node = GEO_NODES[ni % len(GEO_NODES)]
            key = KEYS[ki % len(KEYS)]
            ctx = contexts.get((node, key)) if use_ctx else None
            try:
                c.put(key, f"v{t}", context=ctx, via=node, coordinator=node)
            except Unavailable:
                pass
        elif kind == "get":
            _, ki, ni = op
            node = GEO_NODES[ni % len(GEO_NODES)]
            key = KEYS[ki % len(KEYS)]
            try:
                contexts[(node, key)] = c.get(key, via=node).context
            except Unavailable:
                pass
        elif kind == "snapshot_get":
            _, ki, ni = op
            node = GEO_NODES[ni % len(GEO_NODES)]
            key = KEYS[ki % len(KEYS)]
            try:
                r = c.snapshot_get(key, via=node)
                snaps.append((t, key, node, r.values, r.context))
                contexts[(node, key)] = r.context
            except Unavailable:
                snaps.append((t, key, node, None, None))
        elif kind == "partition_dc":
            _, di = op
            dc = list(GEO_DCS)[di % len(GEO_DCS)]
            cut = set(GEO_DCS[dc])
            net.partition(cut, set(GEO_NODES) - cut)
        elif kind == "heal":
            net.heal()
        elif kind == "fail":
            _, ni = op
            node = GEO_NODES[ni % len(GEO_NODES)]
            if len(net.down) < len(GEO_NODES) - 1:
                net.fail_node(node)
        elif kind == "recover":
            _, ni = op
            net.recover_node(GEO_NODES[ni % len(GEO_NODES)])
        elif kind == "advance":
            _, dt = op
            driver.run_for(float(dt))
        elif kind == "deliver":
            c.deliver_replication()
        else:                                    # pragma: no cover
            raise AssertionError(op)
    if quiesce:
        net.heal()
        for n in list(net.down):
            net.recover_node(n)
        c.deliver_replication()
        driver.run_for(60.0 * len(c.nodes))
        for _ in range(len(c.nodes) + 1):
            c.geo.wan_round()
            c.delta_antientropy_round()
    return c, driver, snaps


def _assert_geo_frontier_converged(c, tag):
    """Post-heal: every DC's frontier covers every live wall, so snapshot
    reads equal quorum reads for every key at every proxy."""
    top = 0.0
    for k in KEYS:
        for n in c.nodes:
            for v in c.nodes[n].versions(k):
                top = max(top, v.wall)
    for dc in GEO_DCS:
        assert c.geo.stable_frontier(dc) >= top, (tag, dc, top)
        assert c.geo.frontier_lag(dc) == 0.0, (tag, dc)
    for k in KEYS:
        ref = c.get(k)
        for dc, members in GEO_DCS.items():
            s = c.snapshot_get(k, via=members[0])
            assert s.values == ref.values, (tag, dc, k)
            assert s.value == ref.value, (tag, dc, k)


def _geo_conformance(seed, ops, tag, shards=1):
    cp, _, sp = _run_geo_schedule(seed, ops, packed=True, shards=shards)
    co, _, so = _run_geo_schedule(seed, ops, packed=False, shards=shards)
    _assert_replicas_agree(cp, ("geo-packed", tag))
    _assert_replicas_agree(co, ("geo-object", tag))
    _assert_backends_agree(cp, co, ("geo", tag))
    assert sp == so, ("geo-snapshots", tag)       # mid-schedule snapshots
    _assert_geo_frontier_converged(cp, ("geo-packed", tag))
    _assert_geo_frontier_converged(co, ("geo-object", tag))


def _random_geo_ops(seed, n_ops=32):
    rng = random.Random(seed)
    ops = []
    for _ in range(n_ops):
        p = rng.random()
        if p < 0.30:
            ops.append(("put", rng.randrange(8), rng.randrange(8),
                        rng.random() < 0.5))
        elif p < 0.42:
            ops.append(("get", rng.randrange(8), rng.randrange(8)))
        elif p < 0.54:
            ops.append(("snapshot_get", rng.randrange(8), rng.randrange(8)))
        elif p < 0.62:
            ops.append(("partition_dc", rng.randrange(2)))
        elif p < 0.68:
            ops.append(("heal",))
        elif p < 0.73:
            ops.append(("fail", rng.randrange(8)))
        elif p < 0.80:
            ops.append(("recover", rng.randrange(8)))
        elif p < 0.95:
            ops.append(("advance", rng.randrange(1, 25)))
        else:
            ops.append(("deliver",))
    return ops


@pytest.mark.geo
@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("seed", [1, 19])
def test_geo_churn_conformance_pinned(seed, shards):
    _geo_conformance(seed, _random_geo_ops(seed), (seed, shards),
                     shards=shards)


@pytest.mark.geo
def test_geo_churn_dc_cut_heal_schedule():
    """Hand-written worst case: writes on both sides of a WAN cut, causal
    chains crossing the heal, snapshots probed throughout."""
    ops = [
        ("put", 0, 0, False), ("advance", 10), ("snapshot_get", 0, 4),
        ("partition_dc", 0),
        ("put", 0, 1, True), ("put", 1, 4, False),   # both sides write
        ("snapshot_get", 0, 4), ("snapshot_get", 1, 1),
        ("advance", 20), ("heal",), ("advance", 40),
        ("get", 0, 5), ("put", 2, 5, True),          # chain across the heal
        ("snapshot_get", 2, 0), ("advance", 30),
        ("fail", 3), ("snapshot_get", 1, 3), ("recover", 3),
        ("advance", 25), ("deliver",),
    ]
    _geo_conformance(29, ops, "geo-cut-heal")


# ---------------------------------------------------------------------------
# Hypothesis phase: ≥200 randomized schedules across BOTH backends
# (`make test-churn`; deselected from tier-1 via the slow marker).
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    _op = st.one_of(
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),               # twice: writes dominate
        st.tuples(st.just("get"), st.integers(0, 7), st.integers(0, 7)),
        st.tuples(st.just("partition"), st.integers(1, 5)),
        st.tuples(st.just("heal")),
        st.tuples(st.just("fail"), st.integers(0, 7)),
        st.tuples(st.just("recover"), st.integers(0, 7)),
        st.tuples(st.just("add")),
        st.tuples(st.just("remove"), st.integers(0, 7)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("deliver")),
    )

    # slow + churn only (no `property` marker): the churn lane is these
    # tests' dedicated home — carrying `property` too would run the same
    # 200 examples again in the nightly test-property lane.
    @pytest.mark.slow
    @settings(max_examples=200, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20),
           st.lists(_op, min_size=4, max_size=28),
           st.sampled_from([1, 4]))
    def test_churn_conformance_fuzzed(seed, ops, shards):
        _conformance(seed, ops, (seed, len(ops), shards), shards=shards)

    _geo_op = st.one_of(
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),               # twice: writes dominate
        st.tuples(st.just("get"), st.integers(0, 7), st.integers(0, 7)),
        st.tuples(st.just("snapshot_get"), st.integers(0, 7),
                  st.integers(0, 7)),
        st.tuples(st.just("partition_dc"), st.integers(0, 1)),
        st.tuples(st.just("heal")),
        st.tuples(st.just("fail"), st.integers(0, 7)),
        st.tuples(st.just("recover"), st.integers(0, 7)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("deliver")),
    )

    @pytest.mark.slow
    @pytest.mark.geo
    @settings(max_examples=100, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20),
           st.lists(_geo_op, min_size=4, max_size=24),
           st.sampled_from([1, 4]))
    def test_geo_churn_conformance_fuzzed(seed, ops, shards):
        _geo_conformance(seed, ops, (seed, len(ops), shards), shards=shards)

    _durable_op = st.one_of(
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),
        st.tuples(st.just("put"), st.integers(0, 7), st.integers(0, 7),
                  st.booleans()),               # twice: writes dominate
        st.tuples(st.just("get"), st.integers(0, 7), st.integers(0, 7)),
        st.tuples(st.just("crash_restart"), st.integers(0, 7)),
        st.tuples(st.just("crash_restart"), st.integers(0, 7)),
        st.tuples(st.just("partition"), st.integers(1, 5)),
        st.tuples(st.just("heal")),
        st.tuples(st.just("fail"), st.integers(0, 7)),
        st.tuples(st.just("recover"), st.integers(0, 7)),
        st.tuples(st.just("add")),
        st.tuples(st.just("remove"), st.integers(0, 7)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("deliver")),
    )

    @pytest.mark.slow
    @pytest.mark.durable
    @settings(max_examples=100, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20),
           st.lists(_durable_op, min_size=4, max_size=24),
           st.sampled_from([1, 4]))
    def test_durable_churn_conformance_fuzzed(seed, ops, shards):
        _conformance(seed, ops, ("durable", seed, len(ops), shards),
                     shards=shards, wal=True)

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20))
    def test_churn_determinism_fuzzed(seed):
        """Same seed ⇒ identical final state AND identical wire totals."""
        ops = _random_ops(seed, 30)
        c1, d1 = _run_schedule(seed, ops, packed=True)
        c2, d2 = _run_schedule(seed, ops, packed=True)
        assert c1.network.bytes_sent == c2.network.bytes_sent
        assert c1.network.timers_fired == c2.network.timers_fired
        assert (d1.ticks, d1.rounds, d1.wire_bytes()) == \
            (d2.ticks, d2.rounds, d2.wire_bytes())
        for k in KEYS:
            for n in c1.nodes:
                assert c1.nodes[n].versions(k) == c2.nodes[n].versions(k)
except ImportError:     # pinned schedules above still run
    pass
