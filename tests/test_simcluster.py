"""Integration: the full control-plane state machine around training —
failure detection → membership update → elastic rescale → checkpoint
restore → training continues."""
import pytest

from repro.data import PipelineConfig
from repro.models import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime.simcluster import SimCluster
from repro.runtime.train_loop import TrainerConfig


def tiny():
    return ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128, remat=False)


def make_cluster(tmp_path, n_workers=4, total=40):
    return SimCluster(
        n_workers=n_workers,
        model_cfg=tiny(),
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=total),
        pipe_cfg=PipelineConfig(vocab_size=128, seq_len=16, global_batch=4),
        trainer_cfg=TrainerConfig(total_steps=total, ckpt_every=5,
                                  log_every=10),
        blob_root=str(tmp_path),
        mesh_candidates=[((4,), ("data",)), ((2,), ("data",)),
                         ((1,), ("data",))],
    )


def test_steady_state_trains_to_completion(tmp_path):
    sim = make_cluster(tmp_path, total=20)
    for _ in range(25):
        out = sim.round()
        if out["step"] >= 20:
            break
    assert sim.trainer.step == 20
    assert sim.rescales == 0


def test_worker_death_triggers_rescale_and_training_continues(tmp_path):
    sim = make_cluster(tmp_path, total=40)
    for _ in range(5):
        sim.round()
    step_before = sim.trainer.step
    sim.kill("w3")
    sim.kill("w2")
    # run enough rounds for detection (dead_threshold=8 intervals) + rescale
    for _ in range(20):
        sim.round()
    assert sim.rescales >= 1
    assert sim.assignment.mesh_shape == (2,)
    assert sim.trainer.step > step_before          # training continued
    assert any("DETECT-DEAD" in e for e in sim.events)
    assert any("RESCALE" in e for e in sim.events)


def test_stalled_worker_detected_as_suspect_then_dead(tmp_path):
    sim = make_cluster(tmp_path, total=40)
    for _ in range(4):
        sim.round()
    sim.stall("w1")
    for _ in range(12):
        sim.round()
    assert "w1" not in sim.fd.alive(sim.now)
    view = sim.membership.view()
    assert "w1" not in view.alive()


def test_recovery_rejoins_and_scales_back_up(tmp_path):
    sim = make_cluster(tmp_path, total=60)
    for _ in range(3):
        sim.round()
    sim.kill("w3")
    for _ in range(15):
        sim.round()
    assert sim.assignment.mesh_shape == (2,)
    sim.recover("w3")
    for _ in range(3):
        sim.round()
    assert sim.assignment.mesh_shape == (4,)       # scaled back up
    # training still progresses after the second rescale
    s = sim.trainer.step
    sim.round()
    assert sim.trainer.step >= s
