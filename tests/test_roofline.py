"""Roofline methodology tests: the facts the analysis relies on."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.launch.roofline import (
    collective_bytes_by_kind, collective_bytes_detailed,
    correct_promoted_f32, cost_analysis_dict, model_flops,
)


def test_cost_analysis_counts_scan_body_once():
    """The documented XLA behaviour that motivates two-point extrapolation."""
    def body(x, w):
        return x @ w, None

    def f_scan(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((1, 128, 128), jnp.float32)
    scan10 = cost_analysis_dict(
        jax.jit(f_scan).lower(x, ws).compile())["flops"]
    scan1 = cost_analysis_dict(
        jax.jit(f_scan).lower(x, w1).compile())["flops"]
    # body counted once regardless of trip count (± loop-counter flops)
    assert abs(scan10 - scan1) < 0.01 * scan1, (scan10, scan1)


def test_collective_parser_on_synthetic_hlo():
    hlo = textwrap.dedent("""
      %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
      %fusion = f32[8,8]{1,0} fusion(%z), kind=kLoop
      %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(%a, %b)
    """)
    by_kind = collective_bytes_by_kind(hlo)
    assert by_kind["all-gather"] == 16 * 1024 * 2
    assert by_kind["all-reduce"] == 256 * 4
    assert by_kind["reduce-scatter"] == 2 * 32 * 4
    assert "fusion" not in by_kind

    detailed = collective_bytes_detailed(hlo)
    assert detailed["all-gather"] == {"bf16": 16 * 1024 * 2}
    corrected = correct_promoted_f32(detailed)
    assert corrected["all-reduce"] == 256 * 2   # f32 halved
    assert corrected["all-gather"] == 16 * 1024 * 2  # bf16 untouched


def test_model_flops_moe_counts_active_only():
    from repro.configs import SHAPES, get_config
    cfg = get_config("qwen3-moe-30b-a3b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    dense_equiv = 6 * cfg.param_count() * SHAPES["train_4k"].global_batch \
        * SHAPES["train_4k"].seq_len
    assert mf < 0.2 * dense_equiv   # ~3.3B active of 30.5B total


DRYRUN_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from dataclasses import replace
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_cell, extrapolated_costs
mesh = make_mesh((2, 4), ("data", "model"))
cfg = replace(get_config("granite-8b").smoke(), remat=True)
shape = replace(SHAPES["train_4k"], seq_len=64, global_batch=4)
_, compiled, _ = lower_cell(cfg, shape, mesh)
assert compiled.memory_analysis().temp_size_in_bytes > 0
costs = extrapolated_costs(cfg, shape, mesh)
assert costs["flops"] > 0 and costs["bytes"] > 0
# linearity check: a 2x-deeper model must cost ~2x the per-group part
deep = replace(cfg, n_layers=2 * cfg.n_layers)
costs2 = extrapolated_costs(deep, shape, mesh)
ratio = costs2["flops"] / costs["flops"]
assert 1.5 < ratio < 2.5, ratio
print("DRYRUN_SMOKE_OK")
"""


def test_dryrun_machinery_on_small_mesh():
    """Lower+compile+extrapolate on a 2×4 mesh in a subprocess (the forced
    device count must not leak into this test process)."""
    proc = subprocess.run(
        [sys.executable, "-c", DRYRUN_SMOKE],
        capture_output=True, text=True, timeout=480,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(
            __import__("os").path.abspath(__file__))))
    assert "DRYRUN_SMOKE_OK" in proc.stdout, proc.stderr[-2000:]
