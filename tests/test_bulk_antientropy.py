"""Bulk (batched/Pallas) anti-entropy must equal object-level anti-entropy
on identical divergent states — property-tested over random store runs."""
import random

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

pytestmark = pytest.mark.property     # dedicated lane: `make test-property`

from repro.core import DVV_MECHANISM
from repro.store import KVCluster, SimNetwork, Unavailable
from repro.store.bulk import bulk_receive_antientropy, bulk_sync

NODES = ("a", "b", "c")
KEYS = tuple(f"k{i}" for i in range(5))


def _diverged_cluster(seed: int, ops: int = 40):
    """Drive a cluster into a divergent state (no replication delivery)."""
    rng = random.Random(seed)
    c = KVCluster(NODES, DVV_MECHANISM, network=SimNetwork(seed=seed))
    contexts = {}
    for i in range(ops):
        key = rng.choice(KEYS)
        node = rng.choice(NODES)
        if rng.random() < 0.3:
            try:
                contexts[(node, key)] = c.get(key, via=node).context
            except Unavailable:
                pass
        else:
            ctx = contexts.get((node, key), frozenset()) \
                if rng.random() < 0.6 else frozenset()
            c.put(key, f"v{i}", context=ctx, via=node, coordinator=node)
    c.network.queue.clear()   # drop replication: maximum divergence
    return c


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(min_value=0, max_value=10_000),
       st.booleans())
def test_bulk_equals_object_level(seed, use_kernel):
    c1 = _diverged_cluster(seed)
    c2 = _diverged_cluster(seed)   # identical twin
    src, dst = "a", "b"
    payload1 = c1.nodes[src].antientropy_payload()
    payload2 = c2.nodes[src].antientropy_payload()
    assert payload1 == payload2

    # object-level path
    c1.nodes[dst].receive_antientropy(payload1)
    # bulk batched path
    bulk_receive_antientropy(c2.nodes[dst], payload2, use_kernel=use_kernel)

    for k in KEYS:
        assert c1.nodes[dst].versions(k) == c2.nodes[dst].versions(k), (
            seed, k, use_kernel)


def test_bulk_sync_empty_and_disjoint():
    assert bulk_sync({}, {}) == {}
    c = _diverged_cluster(1)
    only_local = {k: c.nodes["a"].versions(k) for k in KEYS[:2]}
    out = bulk_sync(only_local, {})
    assert out == {k: v for k, v in only_local.items()}


def test_bulk_kernel_path_smoke():
    c = _diverged_cluster(7)
    payload = c.nodes["a"].antientropy_payload()
    changed = bulk_receive_antientropy(c.nodes["c"], payload, use_kernel=True)
    assert changed >= 0
    # convergence: applying the same payload again changes nothing
    changed2 = bulk_receive_antientropy(c.nodes["c"], payload,
                                        use_kernel=True)
    assert changed2 == 0
