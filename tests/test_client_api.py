"""Client API: opaque CausalContext tokens, KVClient sessions, batching.

Covers the PR's acceptance surface:

* token round-trips — encode→bytes→decode→PUT equals object-context PUT on
  randomized schedules, on both the packed and object backends;
* the §5.4 compaction claim — ``to_bytes()`` is O(R), independent of the
  sibling count;
* zero object-clock decodes on packed GET (monkeypatched codec);
* deterministic ``GetResult.value`` resolution by (wall_time, clock, value);
* ``KVClient`` sessions: counters, ``get_many``/``put_many`` conformance
  with looped single-key operations, quorum/Unavailable error paths;
* gossip scheduling: seeded round-robin ``fanout=`` rounds converge and are
  deterministic; the per-round ``max_ranges`` budget defaults on;
* the bucket→slot index: payload slicing stays exact through kills,
  compaction and digest-tree growth.
"""
import random

import numpy as np
import pytest

from repro.core import ALL_MECHANISMS, DVV_MECHANISM
from repro.store import (
    CausalContext, KVClient, KVCluster, SimNetwork, Unavailable,
)
from repro.store.packed import PackedVersionStore

KEYS = tuple(f"k{i}" for i in range(6))
NODES = ("a", "b", "c", "d")


def _cluster(seed=0, packed=None, mech="dvv", nodes=NODES, **kw):
    return KVCluster(nodes, ALL_MECHANISMS[mech],
                     network=SimNetwork(seed=seed), packed=packed, **kw)


# ---------------------------------------------------------------------------
# Token round-trips (randomized schedules, both backends).
# ---------------------------------------------------------------------------

def _drive_tokens(seed: int, packed: bool, roundtrip: bool,
                  ops: int = 100) -> KVCluster:
    """Randomized PUT/GET/partition schedule; ``roundtrip=True`` sends every
    context through bytes (encode→decode) before the PUT."""
    rng = random.Random(seed)
    c = _cluster(seed=seed, packed=packed)
    contexts = {}
    for i in range(ops):
        key, node = rng.choice(KEYS), rng.choice(NODES)
        p = rng.random()
        if p < 0.3:
            try:
                ctx = c.get(key, via=node).context
                assert isinstance(ctx, CausalContext)
                contexts[(node, key)] = ctx
            except Unavailable:
                pass
        elif p < 0.75:
            ctx = contexts.get((node, key)) if rng.random() < 0.7 else None
            if roundtrip and ctx is not None:
                ctx = CausalContext.from_bytes(ctx.to_bytes())
            try:
                c.put(key, f"v{i}", context=ctx, via=node, coordinator=node)
            except Unavailable:
                pass
        elif p < 0.85:
            c.deliver_replication()
        elif p < 0.95:
            halves = set(rng.sample(NODES, 2))
            c.network.partition(halves, set(NODES) - halves)
        else:
            c.network.heal()
    c.network.heal()
    c.deliver_replication()
    c.antientropy_round()
    return c


@pytest.mark.property
@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("seed", [0, 7, 42])
def test_token_bytes_roundtrip_equals_object_context(seed, packed):
    direct = _drive_tokens(seed, packed, roundtrip=False)
    viabytes = _drive_tokens(seed, packed, roundtrip=True)
    for n in NODES:
        for k in KEYS:
            assert direct.nodes[n].versions(k) == \
                viabytes.nodes[n].versions(k), (seed, packed, n, k)
    # and packed equals object under byte-roundtripped tokens
    other = _drive_tokens(seed, not packed, roundtrip=True)
    for n in NODES:
        for k in KEYS:
            assert other.nodes[n].versions(k) == \
                viabytes.nodes[n].versions(k), (seed, packed, n, k)
            ra = other.get(k, via=n)
            rb = viabytes.get(k, via=n)
            assert ra.values == rb.values
            assert ra.value == rb.value        # deterministic resolution
            assert ra.context.entries == rb.context.entries


def test_token_is_o_of_replicas_not_siblings():
    """§5.4: five concurrent siblings through one coordinator still compact
    to a ceiling over the replica universe — byte size doesn't grow with
    the sibling count."""
    c = _cluster(nodes=("a", "b"))
    c.put("k", "v0", coordinator="b")
    one_sibling = c.get("k", via="b").context.to_bytes()
    for i in range(1, 5):
        c.put("k", f"v{i}", coordinator="b")   # blind writes: all concurrent
    got = c.get("k", via="b")
    assert got.siblings == 5
    assert len(got.context.entries) <= 2                   # ≤ R entries
    assert len(got.context.to_bytes()) == len(one_sibling)  # O(R), not O(sib)


def test_token_clock_set_view_and_legacy_shim():
    """Tokens iterate as clock sets (ceiling DVV); raw frozenset contexts
    still work through the deprecation shim and produce identical state."""
    c1 = _cluster(seed=3, nodes=("a", "b"))
    c2 = _cluster(seed=3, nodes=("a", "b"))
    for c in (c1, c2):
        c.put("k", "v", coordinator="b")
        c.put("k", "w", coordinator="b")
    tok = c1.get("k", via="b").context
    clocks = frozenset(tok)                    # legacy clock-set view
    assert len(clocks) == 1                    # one compacted ceiling clock
    c1.put("k", "merged", context=tok, coordinator="b")
    with pytest.deprecated_call():
        c2.put("k", "merged", context=clocks, coordinator="b")
    assert c1.nodes["b"].versions("k") == c2.nodes["b"].versions("k")
    assert c1.get("k", via="b").values == ("merged",)


def test_token_residue_non_dvv_mechanisms():
    """Non-DVV clocks ride in the residue and round-trip through bytes."""
    c = _cluster(seed=1, mech="oracle", nodes=("a", "b"))
    c.put("k", "v", coordinator="b")
    c.put("k", "w", coordinator="b")
    tok = c.get("k", via="b").context
    assert tok.residue and not tok.entries
    tok2 = CausalContext.from_bytes(tok.to_bytes())
    assert tok2 == tok
    c.put("k", "merged", context=tok2, coordinator="b")
    assert c.get("k", via="b").values == ("merged",)


def test_coerce_rejects_garbage():
    with pytest.raises(TypeError):
        CausalContext.coerce(42)
    with pytest.raises(ValueError):
        CausalContext.from_bytes(b"not-a-token")


def test_from_bytes_rejects_truncated_corrupt_and_empty():
    """Satellite edge cases: a malformed token must fail with a clean
    ``ValueError`` — never an IndexError/struct.error, never a context
    carrying a *prefix* of the encoded entries."""
    tok = CausalContext(entries=(("node-a", 7), ("node-b", 3),
                                 ("node-c", 12)))
    wire = tok.to_bytes()
    assert CausalContext.from_bytes(wire) == tok
    # empty and sub-magic inputs
    for data in (b"", b"D", b"DCX", b"XXX1"):
        with pytest.raises(ValueError):
            CausalContext.from_bytes(data)
    # truncation at EVERY byte boundary fails cleanly — no partial decode
    for cut in range(len(wire)):
        with pytest.raises(ValueError):
            CausalContext.from_bytes(wire[:cut])
    # trailing garbage is corruption, not silently ignored
    with pytest.raises(ValueError):
        CausalContext.from_bytes(wire + b"\x00")
    # corrupt residue flag / unpicklable residue blob
    with pytest.raises(ValueError):
        CausalContext.from_bytes(wire[:4] + b"\x07" + wire[5:])
    residueless_header = wire[:4] + b"\x01" + wire[5:]
    with pytest.raises(ValueError):
        CausalContext.from_bytes(residueless_header + b"\x80garbage")
    # an entry id that is not UTF-8
    bad = bytearray(wire)
    bad[9:11] = b"\xff\xfe"                   # inside "node-a"
    with pytest.raises(ValueError):
        CausalContext.from_bytes(bytes(bad))
    # trailing garbage after a residue blob (pickle STOPs early) is
    # corruption too, and residue truncation fails cleanly
    res_tok = CausalContext(entries=(("node-a", 1),), residue=("stamp",))
    res_wire = res_tok.to_bytes()
    assert CausalContext.from_bytes(res_wire) == res_tok
    with pytest.raises(ValueError):
        CausalContext.from_bytes(res_wire + b"\x00")
    with pytest.raises(ValueError):
        CausalContext.from_bytes(res_wire[:-1])


def test_from_bytes_rejects_pickle_gadgets(tmp_path):
    """Tokens travel through untrusted clients: a crafted residue blob
    whose pickle would execute a callable must be *rejected*, not run."""
    import os
    import pickle
    import struct

    from repro.store.context import _MAGIC

    marker = tmp_path / "pwned"

    class Evil:
        def __reduce__(self):
            return (os.system, (f"touch {marker}",))

    evil_wire = _MAGIC + struct.pack("<BH", 1, 0) + pickle.dumps((Evil(),))
    with pytest.raises(ValueError):
        CausalContext.from_bytes(evil_wire)
    assert not marker.exists()               # the gadget never executed
    # protocol-4 dotted STACK_GLOBAL through a repro module's own imports
    # (repro.ckpt.shards does `import os`) must be rejected too — a
    # namespace-prefix allowance would resolve `os.system` through it
    def short_unicode(s):
        b = s.encode()
        return b"\x8c" + bytes([len(b)]) + b

    dotted = (b"\x80\x04"                                 # PROTO 4
              + short_unicode("repro.ckpt.shards")
              + short_unicode("os.system")
              + b"\x93"                                   # STACK_GLOBAL
              + short_unicode(f"touch {marker}")
              + b"\x85R.")                                # TUPLE1 REDUCE STOP
    with pytest.raises(ValueError):
        CausalContext.from_bytes(_MAGIC + struct.pack("<BH", 1, 0) + dotted)
    assert not marker.exists()


# ---------------------------------------------------------------------------
# Acceptance: packed GET performs zero object-clock decodes.
# ---------------------------------------------------------------------------

def test_packed_get_zero_object_decodes(monkeypatch):
    import repro.core.batched as batched

    c = _cluster(seed=5)
    for i in range(12):
        c.put(KEYS[i % 3], f"v{i}", via=NODES[i % 4],
              coordinator=NODES[i % 4])
    c.deliver_replication()
    calls = {"decode": 0, "encode": 0}
    real_dec, real_enc = batched.decode, batched.encode

    def count_dec(*a, **kw):
        calls["decode"] += 1
        return real_dec(*a, **kw)

    def count_enc(*a, **kw):
        calls["encode"] += 1
        return real_enc(*a, **kw)

    monkeypatch.setattr(batched, "decode", count_dec)
    monkeypatch.setattr(batched, "encode", count_enc)
    for k in KEYS[:3]:
        got = c.get(k, via="a", quorum=3)
        assert got.values
        assert got.value is not None
        assert got.context.entries
    assert calls == {"decode": 0, "encode": 0}


def test_packed_store_context_of_matches_clock_ceiling():
    c = _cluster(seed=6, nodes=("a", "b"))
    c.put("k", "v", coordinator="a")
    c.put("k", "w", coordinator="b")
    c.antientropy_round()
    store = c.nodes["a"].backend.packed
    tok = store.context_of("k")
    want = CausalContext.from_clocks(
        v.clock for v in c.nodes["a"].versions("k"))
    assert tok.entries == want.entries
    assert store.context_of("absent-key").is_empty


# ---------------------------------------------------------------------------
# Deterministic GetResult.value resolution.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False])
def test_value_resolution_latest_wall_time_wins(packed):
    c = _cluster(seed=2, packed=packed, nodes=("a", "b"))
    c.network.partition({"a"}, {"b"})
    c.put("k", "older", coordinator="a", via="a")     # wall 1.0
    c.put("k", "newer", coordinator="b", via="b")     # wall 2.0
    c.network.heal()
    c.antientropy_round()
    got = c.get("k", via="a")
    assert set(got.values) == {"newer", "older"}      # both siblings kept
    assert got.siblings == 2
    assert got.value == "newer"                       # resolved by wall time
    assert len(got.resolution) == 2


@pytest.mark.property
def test_value_resolution_agrees_across_backends():
    for seed in (0, 11, 42):
        cp = _drive_tokens(seed, packed=True, roundtrip=False)
        co = _drive_tokens(seed, packed=False, roundtrip=False)
        for n in NODES:
            for k in KEYS:
                rp, ro = cp.get(k, via=n), co.get(k, via=n)
                assert rp.value == ro.value, (seed, n, k)
                assert rp.resolution == ro.resolution, (seed, n, k)


# ---------------------------------------------------------------------------
# KVClient sessions: batching conformance + error paths.
# ---------------------------------------------------------------------------

def test_kvclient_session_counter_and_roundtrip():
    c = _cluster(seed=4)
    client = KVClient(c, "alice", via="a")
    client.put("cart", "apple")
    assert client.counter == 1
    got = client.get("cart")
    client.put("cart", "apple+banana", context=got.context)
    assert client.counter == 2
    assert client.get("cart").values == ("apple+banana",)


@pytest.mark.parametrize("packed", [True, False])
def test_put_many_equals_looped_puts(packed):
    """The batched path is observationally equal to K single puts — same
    coordinators, same wall-times, same minted clocks, same replica state."""
    keys = [f"key{i}" for i in range(40)]
    looped = _cluster(seed=9, packed=packed)
    batched_ = _cluster(seed=9, packed=packed)
    cl_l = KVClient(looped, "c1", via="a")
    cl_b = KVClient(batched_, "c1", via="a")
    acks_l = {k: cl_l.put(k, f"v-{k}") for k in keys}
    acks_b = cl_b.put_many({k: (f"v-{k}", None) for k in keys})
    for k in keys:
        assert acks_l[k].clock == acks_b[k].clock, k
        assert acks_l[k].coordinator == acks_b[k].coordinator, k
        assert set(acks_l[k].replicated_to) == set(acks_b[k].replicated_to)
    looped.deliver_replication()
    batched_.deliver_replication()
    for n in NODES:
        for k in keys:
            assert looped.nodes[n].versions(k) == \
                batched_.nodes[n].versions(k), (n, k)
    # second round WITH contexts: read-modify-write via get_many/put_many
    ctxs_l = {k: cl_l.get(k, quorum=3) for k in keys}
    ctxs_b = cl_b.get_many(keys, quorum=3)
    for k in keys:
        cl_l.put(k, f"w-{k}", context=ctxs_l[k].context)
    cl_b.put_many({k: (f"w-{k}", ctxs_b[k].context) for k in keys})
    looped.deliver_replication()
    batched_.deliver_replication()
    for n in NODES:
        for k in keys:
            assert looped.nodes[n].versions(k) == \
                batched_.nodes[n].versions(k), (n, k)
            assert looped.get(k, via=n).values == (f"w-{k}",)


def test_put_many_duplicate_keys_rejected():
    c = _cluster(seed=1)
    store = c.nodes["a"].backend.packed
    with pytest.raises(ValueError):
        store.update_keys([("k", (), "v1", 1.0), ("k", (), "v2", 2.0)], "a")


def test_kvclient_unavailable_paths():
    net = SimNetwork(seed=12)
    c = KVCluster(NODES, DVV_MECHANISM, network=net)
    client = KVClient(c, "c2", via="a")
    # down proxy
    net.fail_node("a")
    with pytest.raises(Unavailable):
        client.get("k")
    with pytest.raises(Unavailable):
        client.put_many({"k": ("v", None)})
    net.recover_node("a")
    # read quorum unreachable
    net.partition({"a"}, set(NODES) - {"a"})
    with pytest.raises(Unavailable):
        client.get("k", quorum=4)
    # write quorum unreachable: durable at coordinator, then raises
    with pytest.raises(Unavailable):
        client.put_many({f"key{i}": (f"v{i}", None) for i in range(5)},
                        quorum=4)
    assert any(c.nodes["a"].versions(f"key{i}") for i in range(5))
    net.heal()


def test_put_many_admission_is_atomic():
    """If ANY key of a batch has no reachable coordinator, nothing at all
    is written (single-replica keys during a partition)."""
    c2 = KVCluster(("x", "y", "z"), DVV_MECHANISM, replication=1,
                   network=SimNetwork(seed=3))
    cl2 = KVClient(c2, "c3", via="x")
    keys = [f"p{i}" for i in range(12)]
    owners = {k: c2.replicas_for(k)[0] for k in keys}
    assert {"x"} < set(owners.values())   # some keys owned by x, some not
    c2.network.partition({"x"}, {"y", "z"})
    with pytest.raises(Unavailable):
        cl2.put_many({k: (f"v-{k}", None) for k in keys})
    for k in keys:                        # even x-owned keys: not written
        assert not c2.nodes[owners[k]].versions(k), k


# ---------------------------------------------------------------------------
# Gossip scheduling: seeded round-robin fanout + per-round budgets.
# ---------------------------------------------------------------------------

def _diverged(seed=21, nodes=tuple(f"n{i}" for i in range(6))):
    rng = random.Random(seed)
    c = KVCluster(nodes, DVV_MECHANISM, network=SimNetwork(seed=seed))
    for i in range(60):
        n = rng.choice(nodes)
        c.put(rng.choice(KEYS), f"v{i}", via=n, coordinator=n)
    c.network.queue.clear()      # drop replication: gossip must do the work
    return c


def test_fanout_rounds_converge_and_cycle_all_peers():
    c = _diverged()
    n = len(c.nodes)
    pushes = []
    for _ in range(3 * n):       # round-robin cycles every peer within n-1
        stats = c.delta_antientropy_round(fanout=1)
        assert len(stats) == n   # one push per node per round
        pushes.append(len(stats))
        if all(s.buckets_divergent == 0 for s in stats):
            break
    ref = c.nodes["n0"]
    for other in c.nodes.values():
        for k in KEYS:
            assert other.versions(k) == ref.versions(k), (other.node_id, k)


def test_fanout_schedule_is_deterministic():
    a, b = _diverged(seed=33), _diverged(seed=33)
    for _ in range(4):
        sa = a.delta_antientropy_round(fanout=2)
        sb = b.delta_antientropy_round(fanout=2)
        assert sa == sb
    for k in KEYS:
        assert a.nodes["n1"].versions(k) == b.nodes["n1"].versions(k)


def test_fanout_defaults_max_ranges_budget():
    c = _diverged(seed=5)
    c.delta_range_budget = 2
    stats = c.delta_antientropy_round(fanout=1)
    assert all(s.buckets_sent <= 2 for s in stats)
    # explicit max_ranges still wins
    stats = c.delta_antientropy_round(fanout=1, max_ranges=1)
    assert all(s.buckets_sent <= 1 for s in stats)
    # no fanout ⇒ all-pairs, uncapped (legacy behaviour)
    stats = c.delta_antientropy_round()
    assert len(stats) == len(c.nodes) * (len(c.nodes) - 1)


# ---------------------------------------------------------------------------
# Bucket→slot index: payload slicing stays exact through mutation.
# ---------------------------------------------------------------------------

def test_bucket_index_tracks_kills_compaction_and_growth():
    rng = np.random.default_rng(0)
    s = PackedVersionStore(n_buckets=256)
    for r in ("r0", "r1", "r2"):
        s.intern_replica(r)
    # enough keys to trigger digest-tree growth (and index rebuild)
    for i in range(1500):
        col = int(rng.integers(0, 3))
        vv = np.zeros(s.n_replicas, np.int32)
        vv[col] = int(rng.integers(0, 4))
        s.sync_key(f"key{i}", vv[None, :], np.asarray([col], np.int32),
                   np.asarray([vv[col] + 1], np.int32), [f"v{i}"])
    assert s.n_buckets > 256
    assert s.check_bucket_index()
    # overwrite a scattered subset (kills + inserts), then force compaction
    for i in range(0, 1500, 7):
        vv = np.full(s.n_replicas, 9, np.int32)
        s.sync_key(f"key{i}", vv[None, :], np.asarray([1], np.int32),
                   np.asarray([10], np.int32), [f"w{i}"])
    s.compact(force=True)
    assert s.check_bucket_index()
    # sliced payloads from the index equal explicit key selection
    from repro.store.packed import key_bucket
    from repro.store.replica import _as_object_payload
    buckets = sorted({int(key_bucket(k, s.n_buckets)) for k in s.keys[:40]})
    by_range = s.payload(key_ranges=buckets)
    want = [k for k in s.keys
            if key_bucket(k, s.n_buckets) in set(buckets) and s.key_slots(k)]
    assert _as_object_payload(by_range) == \
        _as_object_payload(s.payload(sorted(want)))
    # empty ranges produce an empty payload
    empty = [b for b in range(s.n_buckets) if not s._bucket_slots.get(b)]
    assert len(s.payload(key_ranges=empty[:5])) == 0


# ---------------------------------------------------------------------------
# Hypothesis fuzz (property lane; see pytest.ini markers).
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @pytest.mark.property
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=100_000), st.booleans())
    def test_token_roundtrip_fuzzed(seed, packed):
        direct = _drive_tokens(seed, packed, roundtrip=False)
        viabytes = _drive_tokens(seed, packed, roundtrip=True)
        for n in NODES:
            for k in KEYS:
                assert direct.nodes[n].versions(k) == \
                    viabytes.nodes[n].versions(k), (seed, packed, n, k)
except ImportError:     # deterministic seeds above still run
    pass
