"""Coalescing serving plane (store/serving.py) conformance + mechanics.

The load-bearing property is **semantic transparency**: a flush that
coalesces many sessions' ops into shared plane calls must produce, for
every op, the byte-identical result the op would have gotten executing
alone in submission order — same ``GetResult`` tuples (values, contexts,
resolution walls), same ``PutAck``s, same raised ``Unavailable``s — and
must leave every replica in the identical per-key version state.  The
conformance harness here drives same-seed twin clusters (one scheduled,
one sequential) through randomized multi-session schedules and asserts
exactly that, on both store backends.

Mechanics get their own tests: flush triggers at the ``max_batch`` /
``max_delay`` boundaries, same-key conflict sequencing into distinct put
phases, read-your-writes inside one flush, per-op admission isolation
under node failures, the session token-codec memo, and the plane-call
accounting the serving benchmark's ≥5× claim rests on.

The hypothesis phase (``slow``+``serving`` markers — the ``make
test-serving`` lane) reuses the churn suite's schedule machinery
(op vocabulary, fuzzer, convergence asserts) with an ``OpScheduler``
splicing the client ops, checking packed-vs-object backend agreement
while flush timers interleave with gossip, partitions and membership
churn on the shared simulated clock.
"""
import random

import pytest

from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVCluster, OpScheduler, SimNetwork,
                         Unavailable)

import test_churn as churn

pytestmark = pytest.mark.serving

NODES = ("n0", "n1", "n2", "n3", "n4")
KEYS = tuple(f"k{i}" for i in range(8))


def _mk_cluster(seed, packed, nodes=NODES, replication=3):
    net = SimNetwork(seed=seed)
    return KVCluster(nodes, DVV_MECHANISM, packed=packed, network=net,
                     seed=seed, replication=replication,
                     read_quorum=2, write_quorum=2)


# ---------------------------------------------------------------------------
# Coalesced == sequential conformance.
# ---------------------------------------------------------------------------
#
# A schedule is a list of rounds; a round is a list of (session, kind,
# keys) triples.  Contexts follow the paper's client workflow: a session
# carries the (byte-encoded) token from its latest GET of a key into its
# next PUT of that key.  Both runners snapshot the token map at round
# start — in the scheduled run a put submitted this round can only carry
# a token from an *earlier* flush, so the sequential run must use the
# same discipline for the workloads to be identical.

def _schedule(seed, rounds=8, sessions=4):
    rng = random.Random(seed)
    out = []
    for _ in range(rounds):
        batch = []
        for s in range(sessions):
            if rng.random() < 0.85:
                kind = "put" if rng.random() < 0.5 else "get"
                ks = rng.sample(KEYS, 1 + (rng.random() < 0.3))
                batch.append((s, kind, tuple(ks)))
        out.append(batch)
    return out


def _put_items(s, r, j, ks, snap):
    return {k: (f"v{s}.{r}.{j}", snap.get((s, k))) for k in ks}


def _record_gets(client, ctxs, s, ks, res):
    for k in ks:
        ctxs[(s, k)] = client.encode_context(res[k].context)


def _run_sequential(cluster, sched, n_sessions):
    clients = {s: _mk_client(cluster, s) for s in range(n_sessions)}
    results, ctxs = [], {}
    for r, batch in enumerate(sched):
        snap = dict(ctxs)
        for j, (s, kind, ks) in enumerate(batch):
            cl = clients[s]
            try:
                if kind == "get":
                    res = cl.get_many(list(ks))
                    _record_gets(cl, ctxs, s, ks, res)
                else:
                    res = cl.put_many(_put_items(s, r, j, ks, snap))
            except Unavailable as e:
                res = ("unavailable", str(e))
            results.append(res)
        cluster.deliver_replication()
    return results


def _mk_client(cluster, s):
    from repro.store import KVClient
    return KVClient(cluster, f"s{s}", via="n0", read_quorum=2,
                    write_quorum=2, read_repair=True)


def _run_coalesced(cluster, sched, n_sessions, *, max_batch=64,
                   max_delay=2.0, by_timer=False):
    sch = OpScheduler(cluster, via="n0", max_batch=max_batch,
                      max_delay=max_delay)
    clients = {s: sch.session(f"s{s}", read_quorum=2, write_quorum=2,
                              read_repair=True)
               for s in range(n_sessions)}
    results, ctxs = [], {}
    for r, batch in enumerate(sched):
        snap = dict(ctxs)
        pend = []
        for j, (s, kind, ks) in enumerate(batch):
            cl = clients[s]
            if kind == "get":
                pend.append((s, kind, ks, cl.submit_get(list(ks))))
            else:
                pend.append((s, kind, ks,
                             cl.submit_put(_put_items(s, r, j, ks, snap))))
        if by_timer:
            cluster.network.advance(max_delay + 0.001)
        else:
            sch.flush()
        for s, kind, ks, op in pend:
            assert op.done, "flush must complete every queued op"
            try:
                res = op.result()
            except Unavailable as e:
                res = ("unavailable", str(e))
            results.append(res)
            if kind == "get" and not isinstance(res, tuple):
                _record_gets(clients[s], ctxs, s, ks, res)
        cluster.deliver_replication()
    return results, sch


def _assert_state_identical(ca, cb, tag):
    assert ca.clock_time == cb.clock_time, tag
    for k in KEYS:
        for n in ca.nodes:
            assert ca.nodes[n].versions(k) == cb.nodes[n].versions(k), \
                (tag, n, k)


@pytest.mark.parametrize("packed", [True, False],
                         ids=["packed", "object"])
@pytest.mark.parametrize("seed", [0, 7, 19])
def test_coalesced_equals_sequential(seed, packed):
    """Randomized multi-session schedules: per-op results byte-identical
    to solo execution, final replica state identical, both backends."""
    sched = _schedule(seed)
    cs = _mk_cluster(seed, packed)
    seq = _run_sequential(cs, sched, 4)
    cc = _mk_cluster(seed, packed)
    coal, sch = _run_coalesced(cc, sched, 4)
    assert coal == seq
    _assert_state_identical(cc, cs, ("state", seed, packed))
    assert sch.ops_submitted == sum(len(b) for b in sched)
    assert sch.pending == 0


@pytest.mark.parametrize("seed", [3, 11])
def test_coalesced_equals_sequential_size_flushes(seed):
    """max_batch=4 forces size-triggered flushes mid-round — different
    flush composition, same per-op results."""
    sched = _schedule(seed, rounds=6, sessions=6)
    cs = _mk_cluster(seed, True)
    seq = _run_sequential(cs, sched, 6)
    cc = _mk_cluster(seed, True)
    coal, sch = _run_coalesced(cc, sched, 6, max_batch=4)
    assert coal == seq
    _assert_state_identical(cc, cs, ("state", seed))
    assert sch.flush_triggers.get("size", 0) > 0


def test_coalesced_equals_sequential_timer_flushes():
    """Timer-triggered flushes (the steady-state trigger) preserve the
    same per-op results as manual flushing and solo execution."""
    sched = _schedule(23)
    cs = _mk_cluster(23, True)
    seq = _run_sequential(cs, sched, 4)
    cc = _mk_cluster(23, True)
    coal, sch = _run_coalesced(cc, sched, 4, by_timer=True)
    assert coal == seq
    _assert_state_identical(cc, cs, "timer-state")
    assert sch.flush_triggers.get("timer", 0) > 0
    assert sch.flush_triggers.get("manual", 0) == 0


# ---------------------------------------------------------------------------
# Flush-trigger boundaries.
# ---------------------------------------------------------------------------

def test_flush_on_exact_max_batch():
    """The max_batch'th submit flushes synchronously and cancels the
    pending delay timer."""
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0", max_batch=3, max_delay=5.0)
    ops = [sch.submit_get([f"k{i}"]) for i in range(3)]
    assert all(op.done for op in ops)
    assert sch.flush_triggers == {"size": 1}
    assert sch._timer is None
    assert c.network.next_timer_due() is None   # timer truly cancelled
    assert sch.pending == 0


def test_timer_flush_with_queue_of_one():
    """A single queued op flushes exactly max_delay ticks after submit."""
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0", max_batch=64, max_delay=4.0)
    op = sch.submit_get(["k0"])
    c.network.advance(3.999)
    assert not op.done
    c.network.advance(0.002)
    assert op.done
    assert op.latency == pytest.approx(4.0, abs=0.01)
    assert sch.flush_triggers == {"timer": 1}


def test_empty_flush_is_a_noop():
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0")
    assert sch.flush() == 0
    assert sch.flushes == 0
    assert sch.stats()["plane_calls"] == 0


def test_submit_during_flush_lands_in_next_batch():
    """Ops submitted from completion callbacks defer to the next flush;
    if they re-trip max_batch the outer drain loop runs them before
    returning."""
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0", max_batch=2, max_delay=5.0)
    follow = []

    def chain(op):
        follow.extend(sch.submit_get(["k5"]) for _ in range(2))

    first = sch.submit_get(["k0"])
    first.on_done(chain)
    sch.submit_get(["k1"])          # trips max_batch → flush → chain()
    assert first.done
    assert all(op.done for op in follow)   # drained by the outer loop
    assert sch.flushes == 2


# ---------------------------------------------------------------------------
# Ordering semantics inside one flush.
# ---------------------------------------------------------------------------

def test_same_key_conflicts_sequence_into_put_phases():
    """Two same-context puts to one key in one flush land in distinct
    put phases and match sequential execution exactly: concurrent-writer
    siblings (DVV keeps both — neither context covers the other's dot),
    walls assigned in submission order."""
    cs = _mk_cluster(0, True)
    ca, cb = _mk_client(cs, 0), _mk_client(cs, 1)
    ca.put_many({"kx": ("v0", None)})
    ctx = cs.get("kx", via="n0", quorum=2).context
    ca.put_many({"kx": ("va", ctx)})
    cb.put_many({"kx": ("vb", ctx)})
    want = cs.get("kx", via="n0", quorum=2)

    cc = _mk_cluster(0, True)
    sch = OpScheduler(cc, via="n0", max_batch=64)
    a, b = sch.session("s0"), sch.session("s1")
    a.submit_put({"kx": ("v0", None)})
    sch.flush()
    ctx2 = cc.get("kx", via="n0", quorum=2).context
    assert ctx2 == ctx
    pa = a.submit_put({"kx": ("va", ctx2)})
    pb = b.submit_put({"kx": ("vb", ctx2)})
    sch.flush()
    assert pa.done and pb.done
    got = cc.get("kx", via="n0", quorum=2)
    assert got == want
    assert got.siblings == 2            # concurrent writers both survive
    assert got.value == "vb"            # later wall wins resolution
    assert sch.phases_run >= 3          # seed + two conflict phases


def test_read_your_writes_within_one_flush():
    """put(k) then get(k) submitted into the same flush: the get phase
    plans after the put phase, so the session reads its own write."""
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0", max_batch=64)
    s = sch.session("s0")
    pw = s.submit_put({"k0": ("mine", None)})
    rd = s.submit_get(["k0"])
    sch.flush()
    assert pw.done and rd.done
    assert "mine" in rd.result()["k0"].values


def test_gets_float_past_puts_on_other_keys():
    """A get on an untouched key joins the first get phase even when puts
    on other keys were queued before it — fewer phases, same results."""
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0", max_batch=64)
    s = sch.session("s0")
    s.submit_get(["k0"])
    s.submit_put({"k1": ("v", None)})
    s.submit_get(["k2"])            # floats into the k0 get phase
    s.submit_put({"k3": ("w", None)})   # joins the k1 put phase
    sch.flush()
    assert sch.phases_run == 2
    assert sch.get_calls == 1 and sch.put_calls == 1


def test_put_submission_order_is_global():
    """Puts never reorder across sessions: walls are assigned in
    submission order, so the resolved register matches sequential
    last-writer-wins for concurrent siblings."""
    cs = _mk_cluster(5, True)
    sa = _mk_client(cs, 0)
    sb = _mk_client(cs, 1)
    sa.put_many({"kz": ("first", None)})
    sb.put_many({"kz": ("second", None)})
    want = cs.get("kz", via="n0", quorum=2)

    cc = _mk_cluster(5, True)
    sch = OpScheduler(cc, via="n0")
    sch.session("s0").submit_put({"kz": ("first", None)})
    sch.session("s1").submit_put({"kz": ("second", None)})
    sch.flush()
    got = cc.get("kz", via="n0", quorum=2)
    assert got == want
    assert got.value == "second"


# ---------------------------------------------------------------------------
# Per-op admission isolation under failures.
# ---------------------------------------------------------------------------

def _partitioned_keys(c):
    """One key whose read quorum survives the down node and one whose
    doesn't (probed, so the choice tracks the ring placement)."""
    ok = bad = None
    for i in range(64):
        k = f"p{i}"
        if c.probe_read(k, via="n0", quorum=2):
            ok = ok or k
        else:
            bad = bad or k
        if ok and bad:
            return ok, bad
    raise AssertionError("no suitable keys found")


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "object"])
def test_per_op_failure_isolation(packed):
    """With a replica down, only the ops whose solo call would raise
    ``Unavailable`` fail; flush-mates on healthy keys succeed with the
    sequential-identical results."""
    cs = _mk_cluster(1, packed, nodes=("n0", "n1", "n2", "n3"),
                     replication=2)
    cc = _mk_cluster(1, packed, nodes=("n0", "n1", "n2", "n3"),
                     replication=2)
    for c in (cs, cc):
        c.put("seed", "x", via="n0")   # identical warm-up
        c.deliver_replication()
        c.network.fail_node("n3")
    ok_key, bad_key = _partitioned_keys(cs)
    assert _partitioned_keys(cc) == (ok_key, bad_key)

    # sequential reference
    seq = []
    cli = _mk_client(cs, 0)
    for kind, key in [("get", ok_key), ("get", bad_key),
                      ("put", ok_key), ("put", bad_key)]:
        try:
            if kind == "get":
                seq.append(cli.get_many([key]))
            else:
                seq.append(cli.put_many({key: (f"w.{key}", None)}))
        except Unavailable:
            seq.append("unavailable")

    sch = OpScheduler(cc, via="n0")
    s = sch.session("s0", read_quorum=2, write_quorum=2, read_repair=True)
    ops = [s.submit_get([ok_key]), s.submit_get([bad_key]),
           s.submit_put({ok_key: (f"w.{ok_key}", None)}),
           s.submit_put({bad_key: (f"w.{bad_key}", None)})]
    sch.flush()
    coal = []
    for op in ops:
        try:
            coal.append(op.result())
        except Unavailable:
            coal.append("unavailable")
    assert coal == seq
    assert coal[0] != "unavailable" and coal[1] == "unavailable"


def test_quorum_miss_put_still_writes_durably():
    """A put predicted to miss its write quorum runs solo and reports
    ``Unavailable`` — but the write is durable at the coordinator and
    visible after the node recovers (the single-call contract)."""
    c = _mk_cluster(2, True, nodes=("n0", "n1", "n2", "n3"), replication=2)
    c.network.fail_node("n3")
    _, bad_key = _partitioned_keys(c)
    sch = OpScheduler(c, via="n0")
    op = sch.session("s0", write_quorum=2).submit_put(
        {bad_key: ("survives", None)})
    sch.flush()
    with pytest.raises(Unavailable):
        op.result()
    c.network.recover_node("n3")
    c.deliver_replication()
    assert "survives" in c.get(bad_key, via="n0", quorum=2).values


def test_proxy_down_fails_whole_flush():
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0")
    op = sch.submit_get(["k0"])
    c.network.fail_node("n0")
    sch.flush()
    with pytest.raises(Unavailable):
        op.result()


# ---------------------------------------------------------------------------
# Token-codec memo (KVClient).
# ---------------------------------------------------------------------------

def test_codec_memo_round_trip_and_invalidation():
    c = _mk_cluster(0, True)
    cli = _mk_client(c, 0)
    cli.put_many({"k0": ("v", None)})
    ctx = cli.get_many(["k0"])["k0"].context
    tok = cli.encode_context(ctx)
    assert cli.encode_context(ctx) == tok          # encode memo hit
    assert cli.decode_context(tok) is ctx          # primed decode hit
    assert cli.codec_hits == 2
    before = cli.codec_misses
    cli.put_many({"k0": ("w", tok)})               # put invalidates
    assert cli.codec_info()["cached"] == 0
    cli.decode_context(tok)
    assert cli.codec_misses == before + 1          # cold again after put

    # decode-direction priming: from_bytes result is re-encoded for free
    tok2 = cli.encode_context(cli.decode_context(tok))
    assert tok2 == tok


def test_codec_memo_on_scheduled_path():
    """submit_put thaws byte tokens through the memo and invalidates at
    submission, exactly like the synchronous path."""
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0")
    cli = sch.session("s0", read_repair=True)
    op = cli.submit_put({"k0": ("v", None)})
    sch.flush()
    op.result()
    g = cli.submit_get(["k0"])
    sch.flush()
    tok = cli.encode_context(g.result()["k0"].context)
    misses = cli.codec_misses
    p = cli.submit_put({"k0": ("w", tok)})         # thaw = memo hit
    assert cli.codec_hits >= 1
    assert cli.codec_info()["cached"] == 0         # invalidated at submit
    sch.flush()
    p.result()
    assert cli.codec_misses == misses


# ---------------------------------------------------------------------------
# Plane-invocation accounting (the ≥5x claim's substrate).
# ---------------------------------------------------------------------------

def test_plane_invocation_ratio_on_disjoint_keys():
    """32 sessions × (get+put) on distinct keys: sequential pays one
    plane invocation per op; one flush pays 1 get sweep + ≤|nodes|
    coordinator groups — ≥5x fewer."""
    cs = _mk_cluster(9, True)
    cli = _mk_client(cs, 0)
    for i in range(32):
        cli.get_many([f"d{i}"])
        cli.put_many({f"d{i}": ("v", None)})
    seq_planes = cs.plane_invocations
    assert seq_planes == 64

    cc = _mk_cluster(9, True)
    sch = OpScheduler(cc, via="n0", max_batch=128)
    sessions = [sch.session(f"s{i}", read_repair=True) for i in range(32)]
    gets = [s.submit_get([f"d{i}"]) for i, s in enumerate(sessions)]
    sch.flush()
    for i, s in enumerate(sessions):
        s.submit_put({f"d{i}": ("v", None)})
    sch.flush()
    assert all(op.done for op in gets)
    coal_planes = cc.plane_invocations
    assert coal_planes * 5 <= seq_planes
    assert sch.stats()["plane_calls"] <= coal_planes


def test_scheduler_stats_shape():
    c = _mk_cluster(0, True)
    sch = OpScheduler(c, via="n0", max_batch=4)
    for i in range(5):
        sch.submit_get([f"k{i % 3}"])
    sch.flush()
    st = sch.stats()
    assert st["ops_submitted"] == 5
    assert st["ops_ok"] == 5 and st["ops_failed"] == 0
    assert st["flushes"] == 2 and st["pending"] == 0
    assert st["largest_flush"] == 4
    assert st["plane_calls"] == st["get_calls"] + st["put_calls"]


# ---------------------------------------------------------------------------
# Closed-loop engine smoke (full sweeps live in benchmarks/serving_bench).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["coalesced", "direct"])
def test_engine_smoke(mode):
    from repro.store import ClosedLoopEngine
    c = _mk_cluster(4, True)
    eng = ClosedLoopEngine(c, sessions=10_000, keys=200, zipf_s=0.9,
                           concurrency=32, think_time=6.0, rmw_time=1.0,
                           mode=mode, via="n0", seed=4, max_batch=32,
                           max_delay=2.0)
    out = eng.run(80)
    assert out["steps"] == 80
    assert out["ops"] == 160 and out["ops_failed"] == 0
    assert out["plane_invocations"] > 0
    assert out["codec"]["hits"] > 0
    if mode == "coalesced":
        assert out["scheduler"]["pending"] == 0
        assert out["p99_latency_ticks"] <= 2.0 + 1e-9
    else:
        assert out["p99_latency_ticks"] == 0.0


def test_engine_coalescing_uses_fewer_planes():
    """Same seed, same workload: coalesced mode needs ≥3x fewer plane
    invocations even at smoke scale (the full-scale bench shows ≥5x)."""
    from repro.store import ClosedLoopEngine
    planes = {}
    for mode in ("direct", "coalesced"):
        c = _mk_cluster(6, True)
        eng = ClosedLoopEngine(c, sessions=10_000, keys=500, zipf_s=0.9,
                               concurrency=128, think_time=8.0,
                               rmw_time=1.0, mode=mode, via="n0", seed=6,
                               max_batch=128, max_delay=2.0)
        out = eng.run(200)
        assert out["ops_failed"] == 0
        planes[mode] = out["plane_invocations"]
    assert planes["coalesced"] * 3 <= planes["direct"]


# ---------------------------------------------------------------------------
# Churn-machinery phase: the scheduler under membership/fault churn.
# ---------------------------------------------------------------------------
#
# Reuses the churn suite's op vocabulary, fuzzer and convergence asserts,
# splicing an OpScheduler between the client ops and the cluster: gets
# record contexts via completion callbacks, puts carry whatever token the
# (node, key) slot holds at submission.  Conformance here is
# packed-vs-object backend agreement with flush timers riding the same
# simulated clock as gossip, partitions and joins (coalesced-vs-
# sequential equality under churn is ill-posed: admission probes sample
# topology at flush time, not submit time).

def _run_schedule_scheduled(seed, ops, packed, shards=1):
    net = SimNetwork(seed=seed)
    c = KVCluster(churn.BASE_NODES, DVV_MECHANISM, packed=packed,
                  network=net, seed=seed, shards=shards)
    driver = GossipDriver(c, period=6.0, seed=seed)
    sch = OpScheduler(c, via="n0", max_batch=8, max_delay=3.0)
    contexts = {}
    next_id = len(churn.BASE_NODES)

    def record(node, key):
        def cb(op):
            if op.error is None:
                contexts[(node, key)] = op.result()[key].context
        return cb

    for t, op in enumerate(ops):
        kind = op[0]
        nodes = list(c.nodes)
        if kind == "put":
            _, ki, ni, use_ctx = op
            node = nodes[ni % len(nodes)]
            key = churn.KEYS[ki % len(churn.KEYS)]
            ctx = contexts.get((node, key)) if use_ctx else None
            sch.submit_put({key: (f"v{t}", ctx)}, client_id=f"c{ni % 4}")
        elif kind == "get":
            _, ki, ni = op
            node = nodes[ni % len(nodes)]
            key = churn.KEYS[ki % len(churn.KEYS)]
            sch.submit_get([key]).on_done(record(node, key))
        elif kind == "partition":
            _, p = op
            g1 = {n for i, n in enumerate(nodes) if (i + p) % 2}
            g2 = set(nodes) - g1
            if g1 and g2:
                net.partition(g1, g2)
        elif kind == "heal":
            net.heal()
        elif kind == "fail":
            _, ni = op
            node = nodes[ni % len(nodes)]
            if len(net.down) < len(nodes) - 1:
                net.fail_node(node)
        elif kind == "recover":
            _, ni = op
            net.recover_node(nodes[ni % len(nodes)])
        elif kind == "add":
            if len(c.nodes) < churn.MAX_NODES:
                c.add_node(f"n{next_id}")
                next_id += 1
        elif kind == "remove":
            _, ni = op
            node = nodes[ni % len(nodes)]
            # never remove the scheduler's proxy (a removed via is a
            # config error, not a fault the serving plane models)
            if len(c.nodes) > 2 and node != "n0":
                c.remove_node(node)
        elif kind == "advance":
            _, dt = op
            driver.run_for(float(dt))   # flush timers fire inside
        elif kind == "deliver":
            c.deliver_replication()
        else:                            # pragma: no cover
            raise AssertionError(op)
    sch.flush()                          # drain stragglers
    net.heal()
    for n in list(net.down):
        net.recover_node(n)
    c.deliver_replication()
    driver.run_for(60.0 * len(c.nodes))
    for _ in range(len(c.nodes) + 1):
        c.delta_antientropy_round()
    return c


def _scheduled_conformance(seed, ops, tag, shards=1):
    cp = _run_schedule_scheduled(seed, ops, packed=True, shards=shards)
    co = _run_schedule_scheduled(seed, ops, packed=False, shards=shards)
    churn._assert_replicas_agree(cp, ("packed", tag))
    churn._assert_replicas_agree(co, ("object", tag))
    churn._assert_backends_agree(cp, co, tag)


@pytest.mark.parametrize("seed", [0, 13])
def test_scheduled_churn_conformance_pinned(seed):
    _scheduled_conformance(seed, churn._random_ops(seed, 35), seed)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    # slow + serving only: the test-serving lane is this phase's home
    # (mirrors the churn suite's marker discipline).
    @pytest.mark.slow
    @settings(max_examples=75, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20),
           st.lists(churn._op, min_size=4, max_size=24),
           st.sampled_from([1, 4]))
    def test_scheduled_churn_conformance_fuzzed(seed, ops, shards):
        _scheduled_conformance(seed, list(ops), (seed, len(ops), shards),
                               shards=shards)

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=1 << 20),
           st.sampled_from([2, 4, 64]))
    def test_coalesced_conformance_fuzzed(seed, max_batch):
        """Fuzzed coalesced-vs-sequential equality on healthy clusters,
        across flush-composition extremes (size-dominated to one-shot)."""
        sched = _schedule(seed, rounds=6, sessions=5)
        cs = _mk_cluster(seed, True)
        seq = _run_sequential(cs, sched, 5)
        cc = _mk_cluster(seed, True)
        coal, _ = _run_coalesced(cc, sched, 5, max_batch=max_batch)
        assert coal == seq
        _assert_state_identical(cc, cs, (seed, max_batch))
except ImportError:     # pinned phases above still run
    pass
