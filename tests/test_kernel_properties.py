"""Property tests for the §4 kernel conditions on DVV update/sync."""
from __future__ import annotations

from typing import Dict, FrozenSet, Tuple

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

pytestmark = pytest.mark.property     # dedicated lane: `make test-property`

from repro.core import (
    DVV, DVV_MECHANISM, downset, sync_conditions_hold,
    update_conditions_hold_histories,
)
from repro.core.dvv import sync as dvv_sync, update as dvv_update
from repro.store import KVCluster, SimNetwork, Unavailable

NODES = ("a", "b", "c")
KEY = "k"


@st.composite
def schedules(draw):
    """(op, node, use_context) sequences over a single key."""
    n = draw(st.integers(min_value=1, max_value=20))
    ops = []
    for _ in range(n):
        kind = draw(st.sampled_from(["put", "get", "deliver", "ae"]))
        node = draw(st.sampled_from(NODES))
        other = draw(st.sampled_from(NODES))
        use_ctx = draw(st.booleans())
        ops.append((kind, node, other, use_ctx))
    return ops


@settings(max_examples=150, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(schedules())
def test_update_conditions_hold_on_every_put(ops):
    """At every PUT, u = update(S, S_C, C) satisfies the paper's 3 conditions,
    verified in causal-history space via the semantic function C[[.]]."""
    cluster = KVCluster(NODES, DVV_MECHANISM, network=SimNetwork(seed=3))
    contexts: Dict[str, FrozenSet] = {}
    counter = 0
    for (kind, node, other, use_ctx) in ops:
        if kind == "put":
            counter += 1
            ctx = contexts.get(node, frozenset()) if use_ctx else frozenset()
            coord = cluster.nodes[node]
            S_r = coord.clocks(KEY)
            # all clocks currently stored anywhere (the global condition)
            all_clocks = set()
            for nd in cluster.nodes.values():
                all_clocks |= nd.clocks(KEY)
            u = dvv_update(frozenset(ctx), S_r, node)
            ok = update_conditions_hold_histories(
                frozenset(c.to_history() for c in ctx),
                frozenset(c.to_history() for c in all_clocks),
                u.to_history(),
            )
            assert ok, (ctx, S_r, u)
            # commit through the real protocol so state evolves identically
            cluster.put(KEY, f"v{counter}", context=ctx, via=node,
                        coordinator=node)
        elif kind == "get":
            try:
                contexts[node] = cluster.get(KEY, via=node).context
            except Unavailable:
                pass
        elif kind == "deliver":
            cluster.deliver_replication()
        elif kind == "ae" and node != other:
            try:
                cluster.antientropy(node, other)
            except Unavailable:
                pass
        # the downset invariant must hold at every replica after every step
        for nd in cluster.nodes.values():
            assert downset(nd.clocks(KEY))


# -- sync conditions on arbitrary (even non-store) DVV antichains ------------

@st.composite
def dvv_clock(draw):
    comps = []
    for r in ("a", "b", "c"):
        if draw(st.booleans()):
            m = draw(st.integers(min_value=0, max_value=4))
            dotted = draw(st.booleans())
            if dotted:
                n = m + draw(st.integers(min_value=1, max_value=3))
                comps.append((r, m, n))
            elif m > 0:
                comps.append((r, m, 0))
    return DVV(tuple(comps))


@settings(max_examples=200, deadline=None)
@given(st.frozensets(dvv_clock(), max_size=4),
       st.frozensets(dvv_clock(), max_size=4))
def test_sync_conditions_on_arbitrary_clock_sets(S1, S2):
    """§4's sync conditions hold for *any* clock sets once reduced to
    antichains (the store only ever holds antichains)."""
    from repro.core import antichain
    S1, S2 = antichain(S1), antichain(S2)
    S = dvv_sync(S1, S2)
    assert sync_conditions_hold(S1, S2, S)


@settings(max_examples=200, deadline=None)
@given(dvv_clock(), dvv_clock())
def test_dvv_order_equals_history_inclusion(x, y):
    """§5.2: the component-wise order computes exactly history inclusion."""
    assert x.leq(y) == x.to_history().leq(y.to_history())
    assert x.concurrent(y) == x.to_history().concurrent(y.to_history())


def test_equivalent_nonidentical_representations():
    """DVV representations are not canonical: (a,2,3) ≡ (a,3) — same
    history, mutually ≤.  The order must treat them as equal, never as a
    strict domination (this was a hypothesis-found counterexample against
    a too-strict reading of the §4 antichain condition)."""
    dotted = DVV.from_dict({"a": (2, 3)})
    plain = DVV.from_dict({"a": (3,)})
    assert dotted.to_history() == plain.to_history()
    assert dotted.leq(plain) and plain.leq(dotted)
    assert not dotted.concurrent(plain)
    # sync over the pair keeps them (equivalence-class duplicates), and the
    # conditions still hold under the equivalence-aware reading
    S = dvv_sync(frozenset({dotted}), frozenset({plain}))
    assert sync_conditions_hold(frozenset({dotted}), frozenset({plain}), S)
