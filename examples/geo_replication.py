"""Two datacenters, one key space: the geo tier end to end.

A six-node cluster spans ``east`` and ``west``.  Writes commit against a
local-DC quorum (no WAN round trip on the write path), a ``WanShipper``
carries committed versions across the ocean asynchronously on digest-
diffed delta rounds, every version is stamped with a hybrid logical
clock, and each DC maintains a Global Stable Frontier — the wall below
which everything is locally visible.  ``snapshot_get`` serves causally
consistent (possibly stale) reads from local replicas only: zero WAN
messages, even while the other DC is partitioned away.

Run:  PYTHONPATH=src python examples/geo_replication.py
"""
import random

from repro.core import DVV_MECHANISM
from repro.store import GossipDriver, KVClient, KVCluster, SimNetwork

DCS = {"east": ("e0", "e1", "e2"), "west": ("w0", "w1", "w2")}
EAST, WEST = set(DCS["east"]), set(DCS["west"])


def status(c, label):
    g = c.geo
    fr = {dc: f"{g.stable_frontier(dc):.1f}" for dc in g.dc_names}
    print(f"  [{label}] t={c.network.now:8.1f}  frontiers={fr}  "
          f"wan_msgs={c.network.wan_messages}  ship={g.ship_bytes:,}B")


def main():
    net = SimNetwork(seed=7)
    net.set_latency_classes(lan=(1.0, 0.5), wan=(40.0, 10.0))
    cluster = KVCluster(tuple(n for ns in DCS.values() for n in ns),
                        DVV_MECHANISM, network=net, seed=7,
                        datacenters=DCS, wan_period=25.0)
    driver = GossipDriver(cluster, period=10.0, seed=7)
    client = KVClient(cluster, "geo-client")

    print("== writes commit on local quorums; the shipper carries them ==")
    rng = random.Random(0)
    for i in range(24):
        home = "east" if i % 3 else "west"
        node = rng.choice(DCS[home])
        ack = client.put(f"user/{i % 6}", f"rev{i}", via=node)
        driver.run_for(4.0)
        if i == 0:
            wall = cluster.nodes[ack.coordinator].max_wall
            print(f"  first put: wall={wall:.1f} coordinator={ack.coordinator}"
                  f" replicated_to={sorted(ack.replicated_to)} "
                  f"({home} only)")
    driver.run_for(200.0)
    status(cluster, "steady")

    print("\n== snapshot reads: causal, local-DC only, zero WAN traffic ==")
    wan_before = net.wan_messages
    snap = client.snapshot_get("user/0", via="w0")
    print(f"  west snapshot user/0 = {snap.value!r} "
          f"(frontier={cluster.geo.stable_frontier('west'):.1f}, "
          f"wan msgs used: {net.wan_messages - wan_before})")

    print("\n== the ocean cable is cut: snapshots keep serving ==")
    net.partition(EAST, WEST)
    for i in range(6):
        client.put(f"user/{i}", f"cutrev{i}", via="e0")
        driver.run_for(5.0)
    lag = cluster.geo.frontier_lag("west")
    snap = client.snapshot_get("user/0", via="w1")
    print(f"  west still answers: user/0 = {snap.value!r} "
          f"(stale by {lag:.0f} ticks — east's cut-era writes are pending)")
    many = client.snapshot_get_many([f"user/{i}" for i in range(6)], via="w2")
    print(f"  snapshot_get_many: {len(many)} keys from local replicas")

    print("\n== heal: delta rounds drain the backlog, frontiers catch up ==")
    net.heal()
    driver.run_for(300.0)
    while cluster.geo.frontier_lag("west") > 0.0:
        cluster.geo.wan_round()
        cluster.delta_antientropy_round()
    status(cluster, "healed")
    east_read = client.get("user/0", via="e1")
    west_snap = client.snapshot_get("user/0", via="w1")
    print(f"  east quorum read == west snapshot: "
          f"{east_read.value!r} == {west_snap.value!r} "
          f"-> {east_read.value == west_snap.value}")

    print("\n== HLC: walls order causally-related writes across DCs ==")
    r = client.get("user/5", via="e2")
    a1 = client.put("user/5", "seen-in-east", r.context, via="e2")
    w1 = max(v.wall for v in cluster.nodes[a1.coordinator].versions("user/5"))
    cluster.geo.wan_round()
    r = client.get("user/5", via="w0")
    a2 = client.put("user/5", "then-west", r.context, via="w0")
    w2 = max(v.wall for v in cluster.nodes[a2.coordinator].versions("user/5"))
    print(f"  east wall {w1:.6f} < west wall {w2:.6f} -> {w1 < w2}")


if __name__ == "__main__":
    main()
