"""Durability walkthrough: write, crash mid-write, come back warm.

A cluster built with ``wal_dir`` logs every replica's post-state changes
to per-shard segment logs (DESIGN.md §14).  This demo crashes node "b"
*mid-byte* with a ``CrashFS`` budget — the torn record is truncated on
reopen — lets the survivors keep writing, then warm-restarts b from its
log: snapshot + tail replay, plus one digest-diffed pull+push delta pass
per peer, and the cluster is digest-equal again.  Compare the resync
bytes against what a cold full-payload bootstrap would have shipped.

Run:  PYTHONPATH=src python examples/durable_restart.py
"""
import shutil
import tempfile

from repro.core import DVV_MECHANISM
from repro.store import (CrashFS, CrashPoint, KVCluster, LocalFS,
                         cluster_converged)


def main():
    tmp = tempfile.mkdtemp(prefix="dvv-wal-")
    fs = CrashFS(None)                       # recording mode for now
    cluster = KVCluster(("a", "b", "c"), DVV_MECHANISM, shards=4, seed=11,
                        replication=3, write_quorum=2, wal_dir=tmp,
                        wal_snapshot_every=8, wal_seal_bytes=2048,
                        wal_fs={"b": fs})

    print("== phase 1: a working set, logged as it lands ==")
    for i in range(12):
        via = ("a", "b", "c")[i % 3]
        cluster.put(f"item/{i % 5}", f"rev{i}", via=via, coordinator=via)
        cluster.deliver_replication()
    print(f"  b's log: {cluster.wal['b'].log_bytes():,}B across "
          f"{len(cluster.wal['b']._logs)} shard streams")

    print("\n== phase 2: power cut mid-append on b ==")
    fs.budget = fs.written + 37              # dies 37 bytes from now
    try:
        for i in range(12, 24):
            cluster.put(f"item/{i % 5}", f"crash{i}", via="b",
                        coordinator="b")
            cluster.deliver_replication()
    except CrashPoint as e:
        print(f"  b crashed: {e}")
    cluster.network.fail_node("b")
    cluster.wal["b"].detach()

    print("\n== phase 3: the survivors move on without b ==")
    for i in range(6):
        cluster.put(f"item/{i % 5}", f"while-b-down{i}", via="a",
                    coordinator="a")
        cluster.deliver_replication()

    print("\n== phase 4: warm restart from the log ==")
    cluster.network.recover_node("b")
    cluster.wal["b"].set_fs(LocalFS())       # new process, same bytes
    stats = cluster.restart_node("b")
    cluster.deliver_replication()
    replay = cluster.last_replay
    warm = sum(s.payload_bytes + s.digest_bytes for s in stats)
    print(f"  replayed {replay.records} records "
          f"(snapshot {replay.snapshot_bytes:,}B + tail "
          f"{replay.tail_bytes:,}B, torn {replay.torn_bytes}B truncated)")
    print(f"  resync wire: {warm:,}B over {len(stats)} delta rounds")
    for st in cluster.nodes["b"].shard_stores:
        st.check_digests()                   # replay kept the trees exact
    print(f"  converged={cluster_converged(cluster)}")

    print("\n== the cold comparison: what a full bootstrap ships ==")
    cold = cluster.bootstrap_node("b")
    print(f"  bootstrap_node after the fact: "
          f"{sum(s.payload_bytes + s.digest_bytes for s in cold):,}B "
          f"(mostly digests now — but an *empty* returnee pays the "
          f"whole payload; see BENCH_durable.json)")

    shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
