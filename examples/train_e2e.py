"""End-to-end training driver with failure injection.

Trains a ~20M-parameter granite-family model for a few hundred steps on
CPU, checkpointing through the DVV-replicated control plane; at one third
of the run the process "crashes" and training resumes from the replicated
manifest — final state is bitwise identical to an uninterrupted run (the
assertion at the bottom).

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--d-model 256]
"""
import argparse
import tempfile

from repro.ckpt import CheckpointManager
from repro.core import DVV_MECHANISM
from repro.data import PipelineConfig
from repro.models import LayerSpec, ModelConfig
from repro.optim import AdamWConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.store import KVCluster, SimNetwork


def model_cfg(d_model: int) -> ModelConfig:
    # granite-8b family, laptop-scale: ~20M params at d_model=256
    return ModelConfig(
        name="granite-mini", family="dense", n_layers=4, d_model=d_model,
        n_heads=8, n_kv_heads=2, head_dim=d_model // 8, d_ff=4 * d_model,
        vocab_size=8192, pattern=(LayerSpec("attn", "mlp"),),
        tie_embeddings=True, remat=False)


def make_trainer(cfg, store, blob, node, steps):
    return Trainer(
        cfg,
        AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=steps),
        PipelineConfig(vocab_size=cfg.vocab_size, seq_len=128,
                       global_batch=8, seed=7),
        TrainerConfig(total_steps=steps, ckpt_every=max(steps // 6, 10),
                      log_every=max(steps // 15, 5)),
        CheckpointManager(store, blob, run_id="e2e", node_id=node))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    args = ap.parse_args()

    cfg = model_cfg(args.d_model)
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")

    # reference run (uninterrupted)
    store_ref = KVCluster(("s1", "s2", "s3"), DVV_MECHANISM,
                          network=SimNetwork(seed=0))
    ref = make_trainer(cfg, store_ref, tempfile.mkdtemp(), "s1", args.steps)
    ref.init_fresh()
    print("reference run...")
    ref.run()
    for row in ref.metrics_log[:3] + ref.metrics_log[-3:]:
        print("  ", row)

    # faulty run: crash at 1/3, resume on a different control-plane node
    store = KVCluster(("s1", "s2", "s3"), DVV_MECHANISM,
                      network=SimNetwork(seed=0))
    blob = tempfile.mkdtemp()
    t1 = make_trainer(cfg, store, blob, "s1", args.steps)
    t1.init_fresh()
    crash_at = args.steps // 3
    print(f"\nfaulty run: will crash at step {crash_at}...")
    try:
        t1.run(crash_at=crash_at)
    except RuntimeError as e:
        print(f"  {e}")
    store.antientropy_round()   # control plane converges

    t2 = make_trainer(cfg, store, blob, "s2", args.steps)
    assert t2.try_restore(), "no manifest found after crash!"
    print(f"  resumed at step {t2.step} on node s2")
    t2.run()

    fp_ref, fp_resumed = ref.state_fingerprint(), t2.state_fingerprint()
    print(f"\nreference   final loss {ref.metrics_log[-1]['loss']:.4f}  "
          f"fingerprint {fp_ref}")
    print(f"crash+resume final loss {t2.metrics_log[-1]['loss']:.4f}  "
          f"fingerprint {fp_resumed}")
    assert fp_ref == fp_resumed, "resume was not bitwise identical!"
    print("\nPASS: crash/resume is bitwise identical to the uninterrupted run")


if __name__ == "__main__":
    main()
