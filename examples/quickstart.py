"""Quickstart: the three layers of the framework in 60 lines.

  1. DVV clocks (the paper's contribution) on a replicated KV store;
  2. a model from the zoo doing a forward/train step;
  3. a DVV-checkpointed training step you can kill and resume.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import DVV_MECHANISM
from repro.store import KVClient, KVCluster, SimNetwork

# --- 1. the paper: concurrent writes through ONE coordinator survive -------
store = KVCluster(("a", "b"), DVV_MECHANISM, network=SimNetwork(seed=0))
c1 = KVClient(store, "c1", via="b")
c2 = KVClient(store, "c2", via="b")
c1.put("config", "v-from-client1")
c2.put("config", "v-from-client2")
got = c1.get("config")
print(f"siblings after same-coordinator concurrent puts: {got.values}")
assert set(got.values) == {"v-from-client1", "v-from-client2"}

# the client resolves with the opaque causal token — the resolution
# supersedes both siblings (see examples/shopping_cart.py for the full
# session walkthrough: token bytes, batched put_many, ...)
c1.put("config", "merged", context=got.context)
print(f"after context write: {c1.get('config').values}")

# --- 2. a model from the zoo -------------------------------------------------
from repro.configs import get_config
from repro.models import forward, init_params

cfg = get_config("gemma-2b").smoke()          # reduced config, CPU-friendly
params = init_params(jax.random.key(0), cfg)
batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
         "labels": jnp.zeros((2, 16), jnp.int32)}
logits, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
print(f"{cfg.name}: logits {logits.shape}, finite={bool(jnp.isfinite(logits).all())}")

# --- 3. checkpointed training with crash recovery ---------------------------
import tempfile

from repro.ckpt import CheckpointManager
from repro.data import PipelineConfig
from repro.optim import AdamWConfig
from repro.runtime.train_loop import Trainer, TrainerConfig

blob = tempfile.mkdtemp()
ckpt = CheckpointManager(store, blob, run_id="quickstart", node_id="a")
trainer = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
                  PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4),
                  TrainerConfig(total_steps=20, ckpt_every=5, log_every=5),
                  ckpt)
trainer.init_fresh()
try:
    trainer.run(crash_at=12)                  # dies after step 12
except RuntimeError as e:
    print(f"crash injected: {e}")

store.deliver_replication()      # control plane converges to node "b"
resumed = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
                  PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4),
                  TrainerConfig(total_steps=20, ckpt_every=5, log_every=5),
                  CheckpointManager(store, blob, run_id="quickstart",
                                    node_id="b"))
assert resumed.try_restore()
print(f"resumed from step {resumed.step} (checkpointed via the DVV store)")
stats = resumed.run()
print(f"finished: {stats}")
