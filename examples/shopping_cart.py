"""The Dynamo shopping cart, end to end with the KVClient session API.

The canonical workload behind the paper (and Dynamo §4.4): a cart must
*never lose an added item*, even when two devices write through different
replicas during a partition.  This walkthrough shows the full client
contract:

  1. sessions   — ``KVClient`` owns the client id/counter and the proxy;
  2. tokens     — GET returns an *opaque* ``CausalContext``; the client
                  carries it (even across processes, as bytes) and hands it
                  back on PUT — it never inspects it;
  3. siblings   — concurrent carts survive as siblings; the app merges them
                  (set union) and writes the merge with the combined token;
  4. batching   — the checkout pipeline writes order/receipt/inventory keys
                  in one ``put_many`` (one vectorized coordinator update).

Run:  PYTHONPATH=src python examples/shopping_cart.py
"""
import json

from repro.core import DVV_MECHANISM
from repro.store import CausalContext, KVClient, KVCluster, SimNetwork

store = KVCluster(("r1", "r2", "r3"), DVV_MECHANISM,
                  network=SimNetwork(seed=7))


def cart_encode(items):
    return json.dumps(sorted(items))


def cart_decode(res):
    """Merge sibling carts: set union — the Dynamo resolution rule."""
    merged = set()
    for blob in res.values:
        merged |= set(json.loads(blob))
    return merged


# --- 1. one shopper, two devices ------------------------------------------
phone = KVClient(store, "alice-phone", via="r1")
laptop = KVClient(store, "alice-laptop", via="r3")

phone.put("cart/alice", cart_encode({"book"}))
store.deliver_replication()

# the laptop reads the cart and gets an opaque causal token with it
res = laptop.get("cart/alice", quorum=2)
print(f"laptop sees {cart_decode(res)} with token {res.context!r}")

# tokens are wire-encodable: O(R) bytes, independent of sibling count —
# a real client ships this blob to the browser and back
blob = res.context.to_bytes()
token = CausalContext.from_bytes(blob)
print(f"token travels as {len(blob)} bytes")

# --- 2. a partition splits the devices ------------------------------------
store.network.partition({"r1"}, {"r2", "r3"})
phone.put("cart/alice", cart_encode({"book", "pen"}),
          context=token, coordinator="r1")            # phone adds a pen
laptop.put("cart/alice", cart_encode({"book", "mug"}),
           context=token, coordinator="r3")           # laptop adds a mug
store.network.heal()
store.antientropy_round()

# both writes survive as siblings — nothing was lost (the paper's point;
# an LWW store would have silently dropped one device's item)
res = phone.get("cart/alice", quorum=3)
print(f"after heal: {res.siblings} sibling carts -> merged "
      f"{cart_decode(res)}")
assert cart_decode(res) == {"book", "pen", "mug"}

# the app-level merge becomes a new write that *supersedes* both siblings
# because it carries the combined token
phone.put("cart/alice", cart_encode(cart_decode(res)), context=res.context)
store.deliver_replication()
res = laptop.get("cart/alice", quorum=3)
assert res.siblings == 1
print(f"resolved everywhere: {cart_decode(res)}")

# --- 3. checkout: batched multi-key writes --------------------------------
# Checkout touches many keys; put_many groups them by coordinator and runs
# each group as one vectorized store update + one replication payload per
# destination replica.
cart = laptop.get("cart/alice")
order_keys = {
    "order/1042": (cart_encode(cart_decode(cart)), None),
    "receipt/1042": ("paid:3_items", None),
    "inventory/book": ("decrement", None),
    "inventory/pen": ("decrement", None),
    "inventory/mug": ("decrement", None),
    # clearing the cart is causally AFTER what we just read: pass the token
    "cart/alice": (cart_encode(set()), cart.context),
}
acks = laptop.put_many(order_keys)
store.deliver_replication()
print(f"checkout wrote {len(acks)} keys via "
      f"{sorted({a.coordinator for a in acks.values()})}")

# the multi-key fetch takes the batched read plane: keys grouped by their
# read-quorum set, ONE stacked quorum-merge sweep per group (instead of a
# per-key merge), per-key results sliced out — and with repair=True any
# replica the merge finds stale is healed by one consolidated push
# (Dynamo-style read-repair: hot keys converge at read latency)
batch = laptop.get_many(list(order_keys), quorum=2, repair=True)
store.deliver_replication()                 # flush the repair pushes
assert batch["cart/alice"].values == (cart_encode(set()),)
assert batch["order/1042"].siblings == 1
print(f"cart is empty, order persisted: {batch['order/1042'].values[0]}")

# deterministic conflict resolution, documented: GetResult.value picks the
# sibling maximal in (wall_time, clock, value) — stable across replicas
print(f"resolved register view of the receipt: "
      f"{batch['receipt/1042'].value}")
