"""Paper walkthrough: the Figure 7 run, step by step, under DVV — and the
same run under the baselines, showing exactly what each one gets wrong.

Run:  PYTHONPATH=src python examples/kvstore_demo.py
"""
from repro.core import ALL_MECHANISMS
from repro.store import KVCluster, SimNetwork


def run(mech_name: str, verbose: bool = False):
    c = KVCluster(("a", "b"), ALL_MECHANISMS[mech_name],
                  network=SimNetwork(seed=0))

    def show(step):
        if verbose:
            sa = c.nodes["a"].versions("k")
            sb = c.nodes["b"].versions("k")
            print(f"  {step}")
            print(f"    Ra: {sorted(map(repr, sa))}")
            print(f"    Rb: {sorted(map(repr, sb))}")

    c.put("k", "v", coordinator="b", client_id="C1", client_counter=1,
          wall_time=1.0)
    show("C1 PUT v @ Rb (empty context)")
    c.put("k", "w", coordinator="b", client_id="C2", client_counter=1,
          wall_time=2.0)
    show("C2 PUT w @ Rb (empty context)  <- concurrent, same coordinator")
    c.put("k", "x", coordinator="a", client_id="C3", client_counter=1,
          wall_time=3.0)
    show("C3 PUT x @ Ra (empty context)")
    ctx = c.get("k", via="a").context
    c.put("k", "y", context=ctx, coordinator="a", client_id="C3",
          client_counter=2, wall_time=4.0)
    show("C3 PUT y @ Ra (context = x)    <- session overwrite")
    c.antientropy("b", "a")
    show("anti-entropy Rb -> Ra")
    ctx_b = c.get("k", via="b").context
    c.put("k", "z", context=ctx_b, coordinator="a", client_id="C2",
          client_counter=2, wall_time=5.0)
    show("C2 PUT z @ Ra (context = {v,w} from Rb)")

    final = c.get("k", via="a")
    return final.values


print("=== Figure 7 run under each mechanism ===\n")
print("expected final state at Ra: {y, z} (z subsumes v,w; y ∥ z)\n")
for mech in ("dvv", "oracle", "vv_server", "vv_client", "lamport",
             "wallclock_lww"):
    values = run(mech, verbose=(mech == "dvv"))
    verdict = "CORRECT" if set(values) == {"y", "z"} else "WRONG (lost update)"
    print(f"{mech:18s} -> {values}   {verdict}")

print("""
Why the baselines fail (paper §3):
  vv_server      : w's clock {(b,2)} falsely dominates v's {(b,1)}; later
                   z's {(a,3),(b,2)} falsely dominates y's {(a,2)}.
  lamport / LWW  : total order — every concurrent write but the "last" is
                   silently discarded.
DVV's clock for z is {(a,0,3),(b,2)}: the (b,2) component carries the
causal context (v,w), the dot (a,0,3) is the new event — so z replaces
v and w but stays concurrent with y.  Exactly the paper's Figure 7.""")
