"""Replicated serving with DVV-tracked session state.

A small decoder serves batched generation requests.  Each session's cursor
(position, last token) lives in the replicated DVV store so ANY serving
node can continue a session — including after the node holding it dies
mid-generation.  Concurrent continuations of one session (split-brain
during a partition) surface as siblings and are resolved deterministically
instead of silently double-generating — the paper's same-coordinator
concurrency case, at the serving layer.

Run:  PYTHONPATH=src python examples/serve_replicated.py
"""
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import DVV_MECHANISM
from repro.models import decode_step, init_cache, init_params
from repro.store import KVCluster, SimNetwork


def main():
    cfg = get_config("gemma-2b").smoke()
    params = init_params(jax.random.key(0), cfg)
    B, MAXLEN = 4, 32
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))

    store = KVCluster(("srv1", "srv2"), DVV_MECHANISM,
                      network=SimNetwork(seed=0))

    def save_cursor(session, pos, toks, node):
        res = store.get(f"session/{session}", via=node)
        store.put(f"session/{session}",
                  json.dumps({"pos": pos, "toks": toks}),
                  context=res.context, via=node, client_id=node)

    def load_cursor(session, node):
        res = store.get(f"session/{session}", via=node)
        if not res.values:
            return None
        cursors = [json.loads(v) for v in res.values]
        if len(cursors) > 1:
            print(f"  [{session}] {len(cursors)} concurrent cursors detected "
                  f"-> resolving to max-pos (deterministic)")
        chosen = max(cursors, key=lambda c: (c["pos"], json.dumps(c)))
        store.put(f"session/{session}", json.dumps(chosen),
                  context=res.context, via=node, client_id=node)
        return chosen

    # --- serve a batch of 4 sessions on srv1 --------------------------------
    cache = init_cache(cfg, B, MAXLEN)
    toks = jnp.zeros((B,), jnp.int32)
    history = [[] for _ in range(B)]
    print("srv1: decoding steps 0..9 for 4 sessions")
    for pos in range(10):
        logits, cache = step(params, cache, toks, jnp.asarray(pos, jnp.int32))
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(B):
            history[i].append(int(toks[i]))
    for i in range(B):
        save_cursor(f"s{i}", 10, history[i], "srv1")
    store.antientropy_round()

    # --- srv1 dies; srv2 picks the sessions up ------------------------------
    print("srv1 dies; srv2 restores sessions from the DVV store")
    store.network.fail_node("srv1")
    cursors = [load_cursor(f"s{i}", "srv2") for i in range(B)]
    assert all(c is not None and c["pos"] == 10 for c in cursors)
    # rebuild the KV cache by replaying the session tokens (prefill would be
    # the production path; replay keeps the example short)
    cache2 = init_cache(cfg, B, MAXLEN)
    replay = jnp.zeros((B,), jnp.int32)
    for pos in range(10):
        _, cache2 = step(params, cache2, replay, jnp.asarray(pos, jnp.int32))
        replay = jnp.asarray([c["toks"][pos] for c in cursors], jnp.int32)
    print("srv2: continuing steps 10..14")
    toks2 = replay
    for pos in range(10, 15):
        logits, cache2 = step(params, cache2, toks2,
                              jnp.asarray(pos, jnp.int32))
        toks2 = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(B):
            cursors[i]["toks"].append(int(toks2[i]))
    for i in range(B):
        save_cursor(f"s{i}", 15, cursors[i]["toks"], "srv2")
    print("sessions completed on srv2:",
          [c["toks"][-3:] for c in cursors])

    # --- split-brain: both nodes continue the SAME session ------------------
    print("\nsplit-brain drill: srv1 recovers, partition, both continue s0")
    store.network.recover_node("srv1")
    store.antientropy_round()
    store.network.partition({"srv1"}, {"srv2"})
    for node, pos in (("srv1", 16), ("srv2", 17)):
        res = store.get("session/s0", via=node)
        cur = json.loads(sorted(res.values)[0])
        cur["pos"] = pos
        store.put("session/s0", json.dumps(cur), context=res.context,
                  via=node, client_id=node)
    store.network.heal()
    store.antientropy_round()
    final = load_cursor("s0", "srv1")
    print(f"after heal, resolved cursor pos={final['pos']} "
          f"(both continuations were visible as siblings, none lost)")


if __name__ == "__main__":
    main()
