"""A cluster that runs itself: continuous gossip under live churn.

Everything previous examples did by hand — delivering replication,
cranking anti-entropy rounds — happens here as a side effect of simulated
time passing: a ``GossipDriver`` owns per-node timers on the SimNetwork
heap, adapts each node's cadence and range budget to the divergence it
observes, and follows the membership as nodes join (bootstrapping warm),
fail, recover and depart.

Run:  PYTHONPATH=src python examples/gossip_churn.py
"""
import random

from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVClient, KVCluster, SimNetwork,
                         cluster_converged)


def status(c, d, label):
    ivs = ", ".join(f"{n}:{iv:.0f}s" for n, iv in sorted(d.intervals().items()))
    print(f"  [{label}] t={c.network.now:7.1f}  converged={cluster_converged(c)}"
          f"  wire={d.wire_bytes():,}B  intervals {{{ivs}}}")


def main():
    net = SimNetwork(seed=42)
    cluster = KVCluster(("a", "b", "c"), DVV_MECHANISM, network=net, seed=42)
    driver = GossipDriver(cluster, period=10.0, seed=42)
    client = KVClient(cluster, "cart-client")

    print("== write a working set; gossip converges it unattended ==")
    rng = random.Random(0)
    for i in range(30):
        node = rng.choice(list(cluster.nodes))
        client.put(f"item/{i % 8}", f"rev{i}", via=node)
        driver.run_for(2.0)
    driver.run_for(120.0)
    status(cluster, driver, "steady")

    print("\n== idle cluster: cadences back off to a digest heartbeat ==")
    driver.run_for(400.0)
    status(cluster, driver, "idle")

    print("\n== a node joins and bootstraps warm (ranked digest catch-up) ==")
    stats = cluster.add_node("d")
    print(f"  bootstrap: {len(stats)} pulls, "
          f"{sum(s.payload_slots for s in stats)} versions, "
          f"{sum(s.payload_bytes for s in stats):,}B payload")
    print(f"  d now stores {cluster.nodes['d'].total_keys()} keys")
    driver.run_for(60.0)
    status(cluster, driver, "joined")

    print("\n== node b dies mid-traffic; the survivors keep converging ==")
    net.fail_node("b")
    for i in range(10):
        client.put(f"item/{i % 8}", f"outage-rev{i}", via="a")
        driver.run_for(3.0)
    driver.run_for(60.0)
    status(cluster, driver, "b down")

    print("\n== b recovers: the topology wake-up snaps cadences back ==")
    net.recover_node("b")
    driver.run_for(60.0)
    status(cluster, driver, "healed")
    got = client.get("item/0", via="b")
    print(f"  read-your-recovery at b: item/0 = {got.value!r} "
          f"({got.siblings} sibling)")

    print("\n== node a is decommissioned; the cluster shrinks cleanly ==")
    cluster.remove_node("a")
    client.via = "c"
    client.put("item/0", "final-rev", via="c")
    driver.run_for(120.0)
    status(cluster, driver, "removed")
    print(f"  members: {sorted(cluster.nodes)}  "
          f"driver: {driver.ticks} ticks, {driver.rounds} rounds")


if __name__ == "__main__":
    main()
