"""Sharded stores on the vnode ring: cache-sized shards, K/N rebalance.

``KVCluster(..., shards=S)`` splits every replica's packed store into S
shard-local stores (DESIGN.md §10).  Placement is one blake2b-8 hash +
one table index (the vnode consistent-hash ring is consulted only on
membership change); gossip runs one plane per shard, opening each with a
32-byte root probe so converged shards cost two int compares; and a
join/leave moves only the shards whose ring walk changed — the joiner's
~K/N share, never the whole store.

Run:  PYTHONPATH=src python examples/sharded_cluster.py
"""
from repro.core import DVV_MECHANISM
from repro.store import (GossipDriver, KVCluster, SimNetwork,
                         cluster_converged)


def shard_histogram(node):
    sizes = [len(st.keys) for st in node.shard_stores]
    return f"{len(sizes)} shards, {min(sizes)}–{max(sizes)} keys each"


def main():
    net = SimNetwork(seed=7)
    cluster = KVCluster([f"n{i}" for i in range(4)], DVV_MECHANISM,
                        replication=2, network=net, seed=7,
                        shards=16)                      # <- the new knob
    driver = GossipDriver(cluster, period=8.0, seed=7)

    print("== 2,000 keys spread over 16 shard-local stores ==")
    for i in range(2000):
        cluster.put(f"user/{i}", f"profile-{i}")
    cluster.deliver_replication()
    driver.run_for(200.0)
    print(f"  converged={cluster_converged(cluster)}  "
          f"n0 holds {shard_histogram(cluster.nodes['n0'])}")

    print("\n== join: warm bootstrap pulls ONLY the joiner's shards ==")
    stats = cluster.add_node("n4")
    moved = sum(s.payload_bytes for s in stats)
    owned = len(cluster._owned["n4"])
    print(f"  n4 owns {owned}/16 shards; pulled {moved:,}B "
          f"({sum(s.changed for s in stats)} keys) — its K/N share")
    driver.run_for(200.0)
    print(f"  converged={cluster_converged(cluster)}")

    print("\n== planned departure: handoff covers only moved shards ==")
    cluster.remove_node("n2")
    driver.run_for(200.0)
    print(f"  converged={cluster_converged(cluster)}  "
          f"reads still serve: user/42 -> "
          f"{cluster.get('user/42').values[0]!r}")


if __name__ == "__main__":
    main()
