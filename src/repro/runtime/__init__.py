from .train_loop import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig"]
