"""The training runtime: jitted step + DVV-checkpointed state machine.

One ``Trainer`` is one logical training job.  Every ``ckpt_every`` steps it
persists (params, opt moments, data cursor, RNG fold) through the
CheckpointManager — whose manifests live in the replicated DVV store — so
a crash at ANY point resumes bitwise-identically, including after
divergent manifests from a partitioned control plane (the manager
reconciles deterministically).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import CheckpointManager
from ..data import PipelineConfig, SyntheticTokens
from ..models import ModelConfig, init_params, loss_fn
from ..optim import AdamWConfig, adamw_update, init_opt_state


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    mesh_shape: Tuple[int, ...] = (1,)


def _flatten_state(params, opt_state) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "p/" + "/".join(str(getattr(k, "key", k)) for k in path)
        out[key] = np.asarray(leaf)
    for path, leaf in jax.tree_util.tree_flatten_with_path(opt_state)[0]:
        key = "o/" + "/".join(str(getattr(k, "key", k)) for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_state(arrays: Dict[str, np.ndarray], params_like,
                     opt_like) -> Tuple[Any, Any]:
    def rebuild(prefix, like):
        flat = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat[0]:
            key = prefix + "/".join(str(getattr(k, "key", k)) for k in path)
            arr = arrays[key]
            leaves.append(jnp.asarray(arr, leaf.dtype).reshape(leaf.shape))
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    return rebuild("p/", params_like), rebuild("o/", opt_like)


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: AdamWConfig,
                 pipe_cfg: PipelineConfig, trainer_cfg: TrainerConfig,
                 ckpt: CheckpointManager):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.trainer_cfg = trainer_cfg
        self.ckpt = ckpt
        self.pipeline = SyntheticTokens(pipe_cfg)
        self.step = 0
        self.params = None
        self.opt_state = None
        self.metrics_log: List[Dict] = []

        cfg = model_cfg

        @jax.jit
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
            params, opt_state, om = adamw_update(
                params, grads, opt_state, opt_cfg)
            return params, opt_state, {"loss": loss, **metrics, **om}

        self._train_step = train_step

    # -- lifecycle ------------------------------------------------------------
    def init_fresh(self) -> None:
        rng = jax.random.key(self.trainer_cfg.seed)
        self.params = init_params(rng, self.model_cfg)
        self.opt_state = init_opt_state(self.params, self.opt_cfg)
        self.step = 0
        self.pipeline.restore(0)

    def try_restore(self) -> bool:
        """Restore from the latest manifest; returns True if one existed."""
        if self.params is None:
            self.init_fresh()           # build templates for unflatten
        res = self.ckpt.restore()
        if res is None:
            return False
        self.params, self.opt_state = _unflatten_state(
            res.arrays, self.params, self.opt_state)
        self.step = res.manifest.step
        self.pipeline.restore(res.manifest.data_cursor)
        return True

    def save(self) -> None:
        arrays = _flatten_state(self.params, self.opt_state)
        self.ckpt.save(
            self.step, arrays, data_cursor=self.pipeline.state(),
            rng_seed=self.trainer_cfg.seed, rng_fold=self.step,
            mesh_shape=self.trainer_cfg.mesh_shape)

    # -- run -----------------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            crash_at: Optional[int] = None) -> Dict:
        """Train ``steps`` (default: to total_steps).  ``crash_at`` raises
        mid-run AFTER that step — the fault-injection hook used by tests
        and the e2e example."""
        target = min(self.trainer_cfg.total_steps,
                     self.step + (steps or self.trainer_cfg.total_steps))
        t0 = time.time()
        while self.step < target:
            batch_np = self.pipeline.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % self.trainer_cfg.log_every == 0 or \
                    self.step == target:
                row = {"step": self.step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"])}
                self.metrics_log.append(row)
            if self.step % self.trainer_cfg.ckpt_every == 0:
                self.save()
            if crash_at is not None and self.step >= crash_at:
                raise RuntimeError(f"injected crash at step {self.step}")
        return {"steps": self.step, "wall_s": time.time() - t0,
                "final_loss": self.metrics_log[-1]["loss"]
                if self.metrics_log else None}

    def state_fingerprint(self) -> str:
        """Hash of all params — for bitwise resume assertions."""
        import hashlib
        h = hashlib.sha256()
        for leaf in jax.tree.leaves(self.params):
            h.update(np.asarray(leaf).tobytes())
        h.update(str(self.pipeline.state()).encode())
        return h.hexdigest()[:16]
