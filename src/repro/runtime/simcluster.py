"""Simulated multi-worker training cluster — where ALL the control-plane
pieces meet: DVV store, membership, heartbeats/failure detection, elastic
mesh replanning, and checkpoint-based recovery.

One process simulates N logical workers in lockstep rounds.  Each round:
workers heartbeat, the failure detector classifies them, the elastic
controller replans the mesh if membership changed, and the *leader*
(lowest-id live worker) advances training and checkpoints.  Failure events
(kill / stall / partition) are injected by the driver or tests.

The data plane executes once per round on the real device — the point of
the simulation is the control-plane state machine, which is exactly the
substrate the paper provides.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..ckpt import CheckpointManager
from ..cluster import (
    Assignment, ElasticController, FailureDetector, MembershipService,
    NodeStatus,
)
from ..core import DVV_MECHANISM
from ..data import PipelineConfig
from ..optim import AdamWConfig
from ..store import KVCluster, SimNetwork
from .train_loop import Trainer, TrainerConfig


@dataclass
class SimWorker:
    worker_id: str
    alive: bool = True
    stalled: bool = False


class SimCluster:
    def __init__(self, *, n_workers: int, model_cfg, opt_cfg: AdamWConfig,
                 pipe_cfg: PipelineConfig, trainer_cfg: TrainerConfig,
                 blob_root: str, store_nodes: Tuple[str, ...] = ("s1", "s2", "s3"),
                 mesh_candidates=None, seed: int = 0):
        self.store = KVCluster(store_nodes, DVV_MECHANISM,
                               network=SimNetwork(seed=seed))
        self.workers = {f"w{i}": SimWorker(f"w{i}") for i in range(n_workers)}
        self.membership = MembershipService(self.store, store_nodes[0])
        for w in self.workers:
            self.membership.join(w)
        self.fd = FailureDetector(heartbeat_interval=1.0)
        self.elastic = ElasticController(mesh_candidates or [
            ((n_workers,), ("data",)),
            ((max(n_workers // 2, 1),), ("data",)),
            ((1,), ("data",)),
        ])
        self.assignment: Optional[Assignment] = self.elastic.plan(
            self.membership.view())
        self.trainer = Trainer(
            model_cfg, opt_cfg, pipe_cfg, trainer_cfg,
            CheckpointManager(self.store, blob_root, "simrun",
                              store_nodes[0]))
        self.trainer.init_fresh()
        self.now = 0.0
        self.events: List[str] = []
        self.rescales = 0

    # -- fault injection -------------------------------------------------------
    def kill(self, worker_id: str) -> None:
        self.workers[worker_id].alive = False
        self.events.append(f"t={self.now:.0f} KILL {worker_id}")

    def stall(self, worker_id: str) -> None:
        self.workers[worker_id].stalled = True
        self.events.append(f"t={self.now:.0f} STALL {worker_id}")

    def recover(self, worker_id: str) -> None:
        w = self.workers[worker_id]
        w.alive, w.stalled = True, False
        self.membership.join(worker_id)
        self.events.append(f"t={self.now:.0f} RECOVER {worker_id}")

    # -- one control-plane round -------------------------------------------------
    def round(self, train_steps: int = 1) -> Dict:
        self.now += 1.0
        for w in self.workers.values():
            if w.alive and not w.stalled:
                self.fd.record(w.worker_id, self.now)
        # the leader marks detected-dead workers in the membership store
        for dead in self.fd.dead(self.now):
            view = self.membership.view()
            if dead in view.alive():
                self.membership.mark_dead(dead)
                self.events.append(f"t={self.now:.0f} DETECT-DEAD {dead}")
        view = self.membership.view()
        new_assign, changed = self.elastic.replan_on_failure(
            view, self.assignment)
        if changed and new_assign is not None:
            # rescale: restore-from-checkpoint then continue on the new mesh
            self.rescales += 1
            self.events.append(
                f"t={self.now:.0f} RESCALE {self.assignment and self.assignment.mesh_shape} "
                f"-> {new_assign.mesh_shape}")
            self.assignment = new_assign
            restored = self.trainer.try_restore()
            self.events.append(
                f"t={self.now:.0f} RESTORE step={self.trainer.step} "
                f"(found={restored})")
        # the data plane advances (leader-driven; single real device)
        if self.assignment is not None and \
                self.trainer.step < self.trainer.trainer_cfg.total_steps:
            self.trainer.run(steps=train_steps)
        self.store.deliver_replication()
        return {"step": self.trainer.step,
                "live": len(self.fd.alive(self.now)),
                "mesh": self.assignment.mesh_shape
                if self.assignment else None}
