"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as <name>/<name>.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jit'd public wrapper, interpret-mode fallback off-TPU) and
<name>/ref.py (pure-jnp oracle used by the sweep tests):

  * dvv_ops         — batched dotted-version-vector dominance (the paper's
                      clock algebra, vectorized for anti-entropy sweeps)
  * flash_attention — blockwise online-softmax attention (causal, sliding
                      window, softcap, GQA)
  * ssd_scan        — Mamba-2 SSD chunked scan (sequential chunk
                      recurrence + intra-chunk quadratic form)
"""
from . import dvv_ops, flash_attention, ssd_scan

__all__ = ["dvv_ops", "flash_attention", "ssd_scan"]
