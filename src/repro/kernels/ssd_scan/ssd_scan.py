"""Pallas TPU kernel: Mamba-2 SSD chunked scan (forward).

Grid: (batch, heads, chunks) with the chunk axis innermost/sequential; the
running inter-chunk state h [P, N] lives in VMEM scratch.  Per step the
kernel computes the intra-chunk quadratic form (two [c,c]·[c,P]-class
matmuls on the MXU) plus the state in/out projections, then advances h.

Block sizes: chunk c=128..256, P=64, N=128 → per-step VMEM:
x [c,P] + B/C [c,N] + decay [c,c] + h [P,N] + y [c,P] ≈ 0.5 MB fp32 — tiny;
the MXU dims (c×N, c×c, c×P) are all multiples of 64/128.

TPU adaptation (DESIGN.md §3): the CUDA SSD kernel fuses conv1d + proj;
here those stay in XLA (they fuse well) and the kernel owns exactly the
part XLA does badly — the sequential chunk recurrence with the quadratic
intra-chunk term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                h_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)        # [c, P]
    dt = dt_ref[...].astype(jnp.float32)      # [c, 1]
    A = a_ref[0, 0]                           # scalar (per head)
    Bm = b_ref[...].astype(jnp.float32)       # [c, N]
    Cm = c_ref[...].astype(jnp.float32)       # [c, N]
    D = d_ref[0, 0]

    a = dt * A                                # [c,1] per-step log decay
    acs = jnp.cumsum(a, axis=0)               # [c,1]

    # intra-chunk: scores[t,s] = (C_t·B_s) exp(acs_t - acs_s) dt_s, s<=t
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c,c]
    diff = acs - acs.T                        # [c,c] (t row, s col)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = cb * decay * dt.T                # [c,c]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [c,P]

    # inter-chunk: y += (C_t exp(acs_t)) · h_prev^T   (h [P,N])
    h = h_ref[...]
    y += jax.lax.dot_general(Cm * jnp.exp(acs), h,
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)

    y_ref[...] = (y + x * D).astype(y_ref.dtype)

    # state update: h_new = exp(sum a) h + sum_s exp(acs_end - acs_s) dt_s x_s B_s^T
    tail = jnp.exp(acs[-1:] - acs) * dt       # [c,1]
    hx = jax.lax.dot_general(x * tail, Bm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P,N]
    h_ref[...] = jnp.exp(acs[-1, 0]) * h + hx

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        hout_ref[...] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(xh, dt, A, Bc, Cc, D, *, chunk: int = 128,
                    interpret: bool = True):
    """xh [B,S,H,P]; dt [B,S,H] (softplus-ed); A [H] (<0); Bc/Cc [B,S,N];
    D [H].  Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    # layout: per (batch, head) streams
    x_l = xh.transpose(0, 2, 1, 3)            # [B,H,S,P]
    dt_l = dt.transpose(0, 2, 1)[..., None]   # [B,H,S,1]
    a_l = jnp.broadcast_to(A[None, :, None, None], (B, H, 1, 1))
    d_l = jnp.broadcast_to(D[None, :, None, None], (B, H, 1, 1))
    b_l = jnp.broadcast_to(Bc[:, None], (B, H, S, N))
    c_l = jnp.broadcast_to(Cc[:, None], (B, H, S, N))

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((None, None, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, 1, 1), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((None, None, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, 1, 1), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), xh.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x_l, dt_l, a_l, b_l, c_l, d_l)
    return y.transpose(0, 2, 1, 3), h_final
