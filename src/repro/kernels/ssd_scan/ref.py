"""Pure-jnp oracle for the SSD kernel: re-export of the model's chunked
implementation (itself validated against the naive recurrence in tests)."""
from __future__ import annotations

from ...models.ssm import ssd_chunked


def ssd_ref(xh, dt, A, Bc, Cc, D, chunk):
    """xh [B,S,H,P], dt [B,S,H] (softplus-ed), A [H] (<0), Bc/Cc [B,S,N],
    D [H] -> (y [B,S,H,P], h_final [B,H,P,N])."""
    return ssd_chunked(xh, dt, A, Bc, Cc, D, chunk)
