"""Jitted wrapper selecting compiled-vs-interpret and chunk size."""
from __future__ import annotations

import jax

from .ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd_scan(xh, dt, A, Bc, Cc, D, *, chunk: int = 128):
    """Drop-in for models.ssm.ssd_chunked (forward)."""
    return ssd_scan_pallas(xh, dt, A, Bc, Cc, D, chunk=chunk,
                           interpret=_interpret())
