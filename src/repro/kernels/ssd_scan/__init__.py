from .ops import ssd_scan
from .ssd_scan import ssd_scan_pallas

__all__ = ["ssd_scan", "ssd_scan_pallas"]
