"""Pure-jnp oracle for the flash-attention kernel: the naive S²
materialization with identical masking/softcap semantics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def mha_ref(q, k, v, *, causal: bool = True, window: int = 0,
            softcap: float = 0.0, scale: float | None = None) -> jnp.ndarray:
    """q [B,H,Sq,D]; k,v [B,H,Sk,D] (KV already expanded to H). -> [B,H,Sq,D]."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = scale or D ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)
    kp = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window:
        ok &= kp[None, :] > qp[:, None] - window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)
