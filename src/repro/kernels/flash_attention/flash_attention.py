"""Pallas TPU flash attention (forward): blockwise online softmax.

Grid: (batch·heads, q_blocks, kv_blocks) — the kv axis is the innermost
(sequential) grid dim; running max / denominator / accumulator live in VMEM
scratch across kv steps and the output block is written on the last step.

BlockSpecs tile q/out to [Bq, D] and k/v to [Bk, D] in VMEM: with
Bq = Bk = 512 and D ≤ 256 the working set is ≤ 0.75 MB + scratch ≈ 1 MB,
comfortably inside the ~16 MB VMEM budget, and matmul dims (512×D×512) are
MXU-aligned (multiples of 128).

Supports: causal masking, sliding window, attention-logit softcap.
Causality-induced dead blocks are skipped with ``pl.when`` guards (the
block still iterates but does no FLOPs — the index map cannot prune a 3-D
grid without a scan DSL).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, n_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # causal pruning: a block is dead iff its earliest k exceeds the latest q
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window:
        live = live & (k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _step():
        q = q_ref[...].astype(jnp.float32)            # [Bq, D]
        k = k_ref[...].astype(jnp.float32)            # [Bk, D]
        v = v_ref[...]                                # [Bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [Bq, Bk]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones_like(s, dtype=bool)
        if causal:
            ok &= kp <= qp
        if window:
            ok &= kp > qp - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                           # [Bq, 1]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                        # dead rows -> exp(NEG)≈0
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [Bq, D]
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale",
                     "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = True) -> jnp.ndarray:
    """q [B,H,Sq,D]; k,v [B,H,Sk,D] (KV pre-expanded). -> [B,H,Sq,D]."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = scale or D ** -0.5
    nq = Sq // block_q
    nk = Sk // block_k

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
