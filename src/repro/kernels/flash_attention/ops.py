"""Jitted GQA-aware wrapper: maps the model's attention call onto the
flash kernel (expanding KV heads lazily per q-head group)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gqa_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, scale: float | None = None,
                        block_q: int = 512, block_k: int = 512):
    """q [B,S,H,Dh]; k,v [B,S,KV,Dh] -> [B,S,H,Dh].

    KV heads are expanded to query heads *per kernel call*; on TPU the
    expansion is a broadcast in HBM->VMEM streaming, not a materialized 8×
    copy (XLA fuses the repeat into the block loads).
    """
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qt = q.transpose(0, 2, 1, 3)                     # [B,H,S,D]
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    out = flash_attention(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k,
        interpret=_interpret())
    return out.transpose(0, 2, 1, 3)
