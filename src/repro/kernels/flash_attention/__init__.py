from .flash_attention import flash_attention
from .ops import gqa_flash_attention

__all__ = ["flash_attention", "gqa_flash_attention"]
