"""Pallas TPU kernel: batched dotted-version-vector dominance.

Anti-entropy between replica nodes compares the clock sets of every
transferred key (paper §4.1); at production scale that is millions of
``leq`` evaluations per round.  The array encoding (core/batched.py) turns
one comparison into a handful of int32 vector ops over the replica
universe — ideal VPU work.  This kernel tiles the key dimension into VMEM
blocks; the replica dim is padded to the 128-wide lane axis.

Design notes (TPU adaptation, DESIGN.md §3):
  * the per-clock dot lookup ``vy[ix]`` is a dynamic gather in the jnp
    reference; here it is a masked lane-sum (`where(lane==ix, vy, 0)`),
    which maps to VPU selects + a lane reduction instead of a gather;
  * all scalars ride as [N, 1] columns so every op stays 2-D (sublane ×
    lane), the layout the TPU vector unit wants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NO_DOT = -1
LANES = 128
DEFAULT_BLOCK = 512


def _leq_kernel(vx_ref, ix_ref, nx_ref, vy_ref, iy_ref, ny_ref, out_ref):
    vx = vx_ref[...]                       # [BN, R]
    vy = vy_ref[...]
    ix = ix_ref[...]                       # [BN, 1]
    nx = nx_ref[...]
    iy = iy_ref[...]
    ny = ny_ref[...]

    BN, R = vx.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (BN, R), 1)

    # range coverage: 1..vx[r] ⊆ 1..vy[r] ∪ {ny at iy}
    dot_extends = (lane == iy) & (vx == ny) & (vx == vy + 1)
    range_ok = jnp.all((vx <= vy) | dot_extends, axis=1, keepdims=True)

    # dot coverage: nx ≤ vy[ix]  ∨  (ix == iy ∧ nx == ny)
    vy_at_ix = jnp.sum(jnp.where(lane == ix, vy, 0), axis=1, keepdims=True)
    dot_ok = (nx <= vy_at_ix) | ((iy == ix) & (nx == ny))
    has_dot = ix != NO_DOT
    ok = range_ok & jnp.where(has_dot, dot_ok, True)
    out_ref[...] = ok.astype(jnp.int8)


def _sync_mask_kernel(vv_ref, id_ref, n_ref, valid_ref, out_ref):
    """Fused pairwise dominance + survival for one block of keys.

    vv_ref    : int32[K, BN, Rp]  — K version slots per key, keys on sublanes
    id/n/valid: int32[K, BN, 1]
    out_ref   : int8 [K, BN, 1]   — survival mask

    The K axis is a *static* Python loop (K = max versions per key, small);
    every op inside is a 2-D [BN, Rp] VPU op.  Dominance of x by y is the
    same masked-lane-sum formulation as ``_leq_kernel``; survival folds the
    K×K sweep into one kernel so bulk anti-entropy is a single launch.
    """
    K, BN, Rp = vv_ref.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (BN, Rp), 1)

    def leq(vx, ix, nx, vy, iy, ny):
        dot_extends = (lane == iy) & (vx == ny) & (vx == vy + 1)
        range_ok = jnp.all((vx <= vy) | dot_extends, axis=1, keepdims=True)
        vy_at_ix = jnp.sum(jnp.where(lane == ix, vy, 0), axis=1,
                           keepdims=True)
        dot_ok = (nx <= vy_at_ix) | ((iy == ix) & (nx == ny))
        return range_ok & jnp.where(ix != NO_DOT, dot_ok, True)

    for xk in range(K):
        vx, ix, nx = vv_ref[xk], id_ref[xk], n_ref[xk]
        x_valid = valid_ref[xk] != 0
        dominated = jnp.zeros((BN, 1), dtype=jnp.bool_)
        for yk in range(K):
            if yk == xk:
                continue
            vy, iy, ny = vv_ref[yk], id_ref[yk], n_ref[yk]
            y_valid = valid_ref[yk] != 0
            le = leq(vx, ix, nx, vy, iy, ny)
            ge = leq(vy, iy, ny, vx, ix, nx)
            kill = le & ~ge                       # strictly dominated
            if yk < xk:
                kill = kill | (le & ge)           # duplicate: keep earliest
            dominated = dominated | (kill & y_valid)
        out_ref[xk] = (x_valid & ~dominated).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dvv_sync_mask_pallas(vvs, dot_ids, dot_ns, valid, *,
                         block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Which clocks of each key's combined set survive sync — one launch.

    vvs: int32[N, K, R]; dot_ids/dot_ns: int32[N, K]; valid: bool[N, K].
    Returns bool[N, K].  Semantics identical to ``core.batched.sync_mask``.

    Layout: keys ride the sublane axis (N blocked), the replica universe is
    padded to the 128-lane axis, and the K version slots become the leading
    (static-loop) axis so every in-kernel op is a 2-D tile.
    """
    N, K, R = vvs.shape
    if N == 0 or K == 0:
        return jnp.zeros((N, K), bool)
    block = min(block, max(8, N))
    Rp = max(LANES, ((R + LANES - 1) // LANES) * LANES)
    Np = ((N + block - 1) // block) * block

    vvs_t = jnp.pad(vvs, ((0, Np - N), (0, 0), (0, Rp - R))
                    ).transpose(1, 0, 2)                       # [K, Np, Rp]

    def col(a, fill=0):
        return jnp.pad(a, ((0, Np - N), (0, 0)),
                       constant_values=fill).T[..., None]      # [K, Np, 1]

    args = (vvs_t, col(dot_ids, NO_DOT), col(dot_ns),
            col(valid.astype(jnp.int32)))
    grid = (Np // block,)
    out = pl.pallas_call(
        _sync_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block, Rp), lambda i: (0, i, 0)),
            pl.BlockSpec((K, block, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((K, block, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((K, block, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((K, block, 1), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, Np, 1), jnp.int8),
        interpret=interpret,
    )(*args)
    return out[:, :N, 0].T.astype(bool)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dvv_leq_pallas(vx, ix, nx, vy, iy, ny, *, block: int = DEFAULT_BLOCK,
                   interpret: bool = True):
    """history(x_k) ⊆ history(y_k) for k in [N].

    vx, vy: int32[N, R]; ix/nx/iy/ny: int32[N].  Returns bool[N].
    """
    N, R = vx.shape
    Rp = max(LANES, ((R + LANES - 1) // LANES) * LANES)
    Np = ((N + block - 1) // block) * block

    def pad2(a, fill=0):
        return jnp.pad(a, ((0, Np - N), (0, Rp - R)), constant_values=fill)

    def pad1(a, fill=0):
        return jnp.pad(a, (0, Np - N), constant_values=fill)[:, None]

    args = (pad2(vx), pad1(ix, NO_DOT), pad1(nx), pad2(vy),
            pad1(iy, NO_DOT), pad1(ny))
    grid = (Np // block,)
    out = pl.pallas_call(
        _leq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, Rp), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, Rp), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 1), jnp.int8),
        interpret=interpret,
    )(*args)
    return out[:N, 0].astype(bool)
