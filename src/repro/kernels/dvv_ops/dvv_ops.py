"""Pallas TPU kernel: batched dotted-version-vector dominance.

Anti-entropy between replica nodes compares the clock sets of every
transferred key (paper §4.1); at production scale that is millions of
``leq`` evaluations per round.  The array encoding (core/batched.py) turns
one comparison into a handful of int32 vector ops over the replica
universe — ideal VPU work.  This kernel tiles the key dimension into VMEM
blocks; the replica dim is padded to the 128-wide lane axis.

Design notes (TPU adaptation, DESIGN.md §3):
  * the per-clock dot lookup ``vy[ix]`` is a dynamic gather in the jnp
    reference; here it is a masked lane-sum (`where(lane==ix, vy, 0)`),
    which maps to VPU selects + a lane reduction instead of a gather;
  * all scalars ride as [N, 1] columns so every op stays 2-D (sublane ×
    lane), the layout the TPU vector unit wants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NO_DOT = -1
LANES = 128
DEFAULT_BLOCK = 512


def _leq_kernel(vx_ref, ix_ref, nx_ref, vy_ref, iy_ref, ny_ref, out_ref):
    vx = vx_ref[...]                       # [BN, R]
    vy = vy_ref[...]
    ix = ix_ref[...]                       # [BN, 1]
    nx = nx_ref[...]
    iy = iy_ref[...]
    ny = ny_ref[...]

    BN, R = vx.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (BN, R), 1)

    # range coverage: 1..vx[r] ⊆ 1..vy[r] ∪ {ny at iy}
    dot_extends = (lane == iy) & (vx == ny) & (vx == vy + 1)
    range_ok = jnp.all((vx <= vy) | dot_extends, axis=1, keepdims=True)

    # dot coverage: nx ≤ vy[ix]  ∨  (ix == iy ∧ nx == ny)
    vy_at_ix = jnp.sum(jnp.where(lane == ix, vy, 0), axis=1, keepdims=True)
    dot_ok = (nx <= vy_at_ix) | ((iy == ix) & (nx == ny))
    has_dot = ix != NO_DOT
    ok = range_ok & jnp.where(has_dot, dot_ok, True)
    out_ref[...] = ok.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dvv_leq_pallas(vx, ix, nx, vy, iy, ny, *, block: int = DEFAULT_BLOCK,
                   interpret: bool = True):
    """history(x_k) ⊆ history(y_k) for k in [N].

    vx, vy: int32[N, R]; ix/nx/iy/ny: int32[N].  Returns bool[N].
    """
    N, R = vx.shape
    Rp = max(LANES, ((R + LANES - 1) // LANES) * LANES)
    Np = ((N + block - 1) // block) * block

    def pad2(a, fill=0):
        return jnp.pad(a, ((0, Np - N), (0, Rp - R)), constant_values=fill)

    def pad1(a, fill=0):
        return jnp.pad(a, (0, Np - N), constant_values=fill)[:, None]

    args = (pad2(vx), pad1(ix, NO_DOT), pad1(nx), pad2(vy),
            pad1(iy, NO_DOT), pad1(ny))
    grid = (Np // block,)
    out = pl.pallas_call(
        _leq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, Rp), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, Rp), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 1), jnp.int8),
        interpret=interpret,
    )(*args)
    return out[:N, 0].astype(bool)
