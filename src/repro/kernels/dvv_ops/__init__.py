from .ops import (
    antientropy_obsolete, dvv_concurrent, dvv_dominates, dvv_leq,
    dvv_read_sweep, dvv_read_sweep_bucketed, dvv_sync_mask,
    dvv_sync_mask_bucketed,
)

__all__ = ["dvv_leq", "dvv_dominates", "dvv_concurrent",
           "antientropy_obsolete", "dvv_sync_mask", "dvv_sync_mask_bucketed",
           "dvv_read_sweep", "dvv_read_sweep_bucketed"]
