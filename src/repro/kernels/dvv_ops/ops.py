"""Jitted public wrappers over the DVV Pallas kernel.

``interpret`` defaults to True off-TPU (the kernel body executes in Python
on CPU for correctness); on TPU backends the compiled kernel runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.batched import BucketedSyncMask
from .dvv_ops import dvv_leq_pallas, dvv_sync_mask_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def dvv_leq(vx, ix, nx, vy, iy, ny):
    """Batched history-inclusion: bool[N]."""
    return dvv_leq_pallas(vx, ix, nx, vy, iy, ny, interpret=_interpret())


def dvv_sync_mask(vvs, dot_ids, dot_ns, valid):
    """Fused per-key survival sweep: bool[N, K] (see dvv_sync_mask_pallas).

    Drop-in for ``core.batched.sync_mask`` — this is the ``mask_fn`` the
    packed store's bulk anti-entropy hands its grouped clock tensor to.
    """
    return dvv_sync_mask_pallas(jnp.asarray(vvs), jnp.asarray(dot_ids),
                                jnp.asarray(dot_ns), jnp.asarray(valid),
                                interpret=_interpret())


#: Shape-bucketed front end over the fused kernel: pads [N, K, R] to the
#: power-of-two bucket (core.batched.bucket_shape) so every delta round —
#: whatever its size — reuses one of a handful of warm compilations instead
#: of re-tracing ``pallas_call`` at a fresh shape.  Pad rows are invalid and
#: provably inert (tests/test_delta_sync.py).  ``jit=False``: the pallas
#: wrapper is already jitted; bucketing is what makes its cache hit.
dvv_sync_mask_bucketed = BucketedSyncMask(dvv_sync_mask, jit=False)


def dvv_dominates(vx, ix, nx, vy, iy, ny):
    """x dominates y ⟺ y ≤ x."""
    return dvv_leq(vy, iy, ny, vx, ix, nx)


def dvv_concurrent(vx, ix, nx, vy, iy, ny):
    a = dvv_leq(vx, ix, nx, vy, iy, ny)
    b = dvv_leq(vy, iy, ny, vx, ix, nx)
    return ~a & ~b


def antientropy_obsolete(vx, ix, nx, vy, iy, ny):
    """Anti-entropy sweep primitive: for each key k, is the local version
    x_k *strictly dominated* by the incoming y_k (and hence discardable)?
    Strict: x ≤ y ∧ ¬(y ≤ x)."""
    le = dvv_leq(vx, ix, nx, vy, iy, ny)
    ge = dvv_leq(vy, iy, ny, vx, ix, nx)
    return le & ~ge
