"""Jitted public wrappers over the DVV Pallas kernel.

``interpret`` defaults to True off-TPU (the kernel body executes in Python
on CPU for correctness); on TPU backends the compiled kernel runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from ...core.batched import BucketedSyncMask, bucket_shape, merge_context, \
    pad_sync_args
from .dvv_ops import dvv_leq_pallas, dvv_sync_mask_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def dvv_leq(vx, ix, nx, vy, iy, ny):
    """Batched history-inclusion: bool[N]."""
    return dvv_leq_pallas(vx, ix, nx, vy, iy, ny, interpret=_interpret())


def dvv_sync_mask(vvs, dot_ids, dot_ns, valid):
    """Fused per-key survival sweep: bool[N, K] (see dvv_sync_mask_pallas).

    Drop-in for ``core.batched.sync_mask`` — this is the ``mask_fn`` the
    packed store's bulk anti-entropy hands its grouped clock tensor to.
    """
    return dvv_sync_mask_pallas(jnp.asarray(vvs), jnp.asarray(dot_ids),
                                jnp.asarray(dot_ns), jnp.asarray(valid),
                                interpret=_interpret())


#: Shape-bucketed front end over the fused kernel: pads [N, K, R] to the
#: power-of-two bucket (core.batched.bucket_shape) so every delta round —
#: whatever its size — reuses one of a handful of warm compilations instead
#: of re-tracing ``pallas_call`` at a fresh shape.  Pad rows are invalid and
#: provably inert (tests/test_delta_sync.py).  ``jit=False``: the pallas
#: wrapper is already jitted; bucketing is what makes its cache hit.
dvv_sync_mask_bucketed = BucketedSyncMask(dvv_sync_mask, jit=False)


def dvv_read_sweep(vvs, dot_ids, dot_ns, valid):
    """Fused quorum-read sweep: survival + per-key §5.4 ceiling, one pass.

    The read plane's device-side primitive: the fused Pallas survival
    kernel produces the mask, and the ceiling ⌈S⌉ of each key's *surviving*
    rows falls out of the same resident tensor via ``merge_context`` (a
    masked column max with the dots folded in) — no second gather of the
    clock rows.  Returns ``(mask bool[N, K], ceil int32[N, R])``; semantics
    equal ``core.batched.sync_mask_np`` + ``grouped_ceiling_np`` over the
    surviving rows (conformance-tested in tests/test_read_path.py).
    Production reads enter through ``dvv_read_sweep_bucketed`` below.
    """
    vvs = jnp.asarray(vvs)
    dot_ids = jnp.asarray(dot_ids)
    dot_ns = jnp.asarray(dot_ns)
    mask = dvv_sync_mask_pallas(vvs, dot_ids, dot_ns, jnp.asarray(valid),
                                interpret=_interpret())
    return mask, merge_context(vvs, dot_ids, dot_ns, mask)


class BucketedReadSweep:
    """Shape-bucketed front end over ``dvv_read_sweep`` — the §6.4 cache
    trick applied to the read plane.  Quorum merges arrive as arbitrary
    small ``[N, K, R]`` tensors; padding to the power-of-two bucket keeps
    the pallas survival kernel's compilation cache warm across all of
    them.  Pad rows are invalid (inert for both mask and ceiling — an
    invalid row contributes nothing to ``merge_context``) and pad replica
    columns come back as zero ceilings, sliced off on return.  This is
    the ``sweep_fn`` that ``KVCluster.get_many(use_kernel=True)`` hands
    ``quorum_merge_many``."""

    def __init__(self):
        self._seen: set = set()
        self.hits = 0
        self.misses = 0

    def __call__(self, vvs, dot_ids, dot_ns, valid):
        vvs = np.asarray(vvs)
        dot_ids = np.asarray(dot_ids)
        dot_ns = np.asarray(dot_ns)
        valid = np.asarray(valid)
        N, K, R = vvs.shape
        if N == 0 or K == 0:
            return np.zeros((N, K), bool), np.zeros((N, R), np.int64)
        key = bucket_shape(N, K, R)
        if key in self._seen:
            self.hits += 1
        else:
            self.misses += 1
            self._seen.add(key)
        args = pad_sync_args(vvs, dot_ids, dot_ns, valid, key)
        mask, ceil = dvv_read_sweep(*args)
        return (np.asarray(mask)[:N, :K],
                np.asarray(ceil)[:N, :R].astype(np.int64))

    def cache_info(self) -> dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": round(self.hits / total, 4) if total else 0.0,
                "buckets": sorted(self._seen)}

    def reset_stats(self) -> None:
        """Zero the counters without cooling the bucket set — per-window
        cross-flush hit-rate accounting (mirrors ``BucketedSyncMask``)."""
        self.hits = 0
        self.misses = 0


#: Module-level instance (one shared bucket cache, like
#: ``dvv_sync_mask_bucketed``).
dvv_read_sweep_bucketed = BucketedReadSweep()


def dvv_dominates(vx, ix, nx, vy, iy, ny):
    """x dominates y ⟺ y ≤ x."""
    return dvv_leq(vy, iy, ny, vx, ix, nx)


def dvv_concurrent(vx, ix, nx, vy, iy, ny):
    a = dvv_leq(vx, ix, nx, vy, iy, ny)
    b = dvv_leq(vy, iy, ny, vx, ix, nx)
    return ~a & ~b


def antientropy_obsolete(vx, ix, nx, vy, iy, ny):
    """Anti-entropy sweep primitive: for each key k, is the local version
    x_k *strictly dominated* by the incoming y_k (and hence discardable)?
    Strict: x ≤ y ∧ ¬(y ≤ x)."""
    le = dvv_leq(vx, ix, nx, vy, iy, ny)
    ge = dvv_leq(vy, iy, ny, vx, ix, nx)
    return le & ~ge
