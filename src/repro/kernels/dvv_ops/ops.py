"""Jitted public wrappers over the DVV Pallas kernel.

``interpret`` defaults to True off-TPU (the kernel body executes in Python
on CPU for correctness); on TPU backends the compiled kernel runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .dvv_ops import dvv_leq_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def dvv_leq(vx, ix, nx, vy, iy, ny):
    """Batched history-inclusion: bool[N]."""
    return dvv_leq_pallas(vx, ix, nx, vy, iy, ny, interpret=_interpret())


def dvv_dominates(vx, ix, nx, vy, iy, ny):
    """x dominates y ⟺ y ≤ x."""
    return dvv_leq(vy, iy, ny, vx, ix, nx)


def dvv_concurrent(vx, ix, nx, vy, iy, ny):
    a = dvv_leq(vx, ix, nx, vy, iy, ny)
    b = dvv_leq(vy, iy, ny, vx, ix, nx)
    return ~a & ~b


def antientropy_obsolete(vx, ix, nx, vy, iy, ny):
    """Anti-entropy sweep primitive: for each key k, is the local version
    x_k *strictly dominated* by the incoming y_k (and hence discardable)?
    Strict: x ≤ y ∧ ¬(y ≤ x)."""
    le = dvv_leq(vx, ix, nx, vy, iy, ny)
    ge = dvv_leq(vy, iy, ny, vx, ix, nx)
    return le & ~ge
