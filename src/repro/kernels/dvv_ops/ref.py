"""Pure-jnp oracle for the batched DVV kernels.

Thin re-exports of ``repro.core.batched`` — the reference semantics the
Pallas kernel is validated against (which is itself fuzz-checked against
the pure-Python ``repro.core.dvv`` clocks).
"""
from __future__ import annotations

import jax.numpy as jnp

from ...core.batched import NO_DOT, dominates, leq, sync_mask


def leq_ref(vx, ix, nx, vy, iy, ny):
    return leq(vx, ix, nx, vy, iy, ny)


def dominates_ref(vx, ix, nx, vy, iy, ny):
    return dominates(vx, ix, nx, vy, iy, ny)


def concurrent_ref(vx, ix, nx, vy, iy, ny):
    a = leq(vx, ix, nx, vy, iy, ny)
    b = leq(vy, iy, ny, vx, ix, nx)
    return ~a & ~b


def sync_mask_ref(vvs, dot_ids, dot_ns, valid):
    return sync_mask(vvs, dot_ids, dot_ns, valid)
