"""Crash-safe file writes: write temp → fsync → atomic rename.

The one torn-write discipline shared by everything that persists control
state: checkpoint shard blobs (``ckpt/shards.py``), WAL manifests and
packed-SoA snapshots (``store/wal.py``).  ``os.replace`` is atomic on
POSIX, so a reader either sees the complete previous file or the complete
new one — never a prefix.  The durability chain is: file bytes are fsynced
before the rename (no rename-to-garbage), and the containing directory is
fsynced after it (the rename itself survives a power cut), best-effort on
platforms where directories cannot be opened.
"""
from __future__ import annotations

import os
import tempfile


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Replace ``path`` with ``data`` atomically (all-or-nothing on crash)."""
    directory = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".tmp-", suffix=".part")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    fsync_dir(directory)


def fsync_dir(directory: str) -> None:
    """Flush a directory entry (the rename) to stable storage, best effort."""
    try:
        dfd = os.open(directory, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(dfd)
    except OSError:
        pass
    finally:
        os.close(dfd)


__all__ = ["atomic_write_bytes", "fsync_dir"]
