"""Distributed checkpointing with DVV-tracked manifests."""
from .manager import CheckpointManager, RestoreResult
from .manifest import Manifest, ShardRecord, resolve_manifest_siblings
from .shards import load_array, load_tree, save_array, save_tree

__all__ = [
    "CheckpointManager", "RestoreResult",
    "Manifest", "ShardRecord", "resolve_manifest_siblings",
    "save_array", "load_array", "save_tree", "load_tree",
]
