"""Bulk shard I/O — array bytes on (simulated) blob storage.

Arrays are saved per logical path; on real hardware each host writes only
its addressable shards (the manifest records the global layout so restore
can re-shard onto a different mesh).  Checksums let restores detect torn or
corrupted writes — a manifest referencing a bad shard is rejected and the
manager falls back to the parent lineage.
"""
from __future__ import annotations

import io
import os
from typing import Dict, Tuple

import numpy as np

from .atomic import atomic_write_bytes
from .manifest import ShardRecord, content_checksum


def _blob_name(run_id: str, step: int, path: str, writer: str) -> str:
    # Writer-namespaced: concurrent coordinators finalizing the same step
    # (post-partition) must not clobber each other's bytes — the DVV
    # manifest layer decides which lineage wins, and its shards must still
    # exist intact.
    safe = path.replace("/", "__")
    return f"{run_id}-step{step:08d}-{writer}-{safe}.npy"


def save_array(root: str, run_id: str, step: int, path: str,
               value: np.ndarray, writer: str = "w") -> ShardRecord:
    os.makedirs(root, exist_ok=True)
    fname = _blob_name(run_id, step, path, writer)
    full = os.path.join(root, fname)
    value = np.asarray(value)
    # Serialize in memory, then temp → fsync → rename: a crash mid-save
    # leaves either no blob or the complete blob, never a torn .npy that a
    # later manifest could reference.
    buf = io.BytesIO()
    np.save(buf, value)
    atomic_write_bytes(full, buf.getvalue())
    checksum = content_checksum(value.tobytes())
    return ShardRecord(path=path, file=fname, shape=tuple(value.shape),
                       dtype=str(value.dtype), checksum=checksum)


def load_array(root: str, record: ShardRecord, *,
               verify: bool = True) -> np.ndarray:
    full = os.path.join(root, record.file)
    value = np.load(full)
    if tuple(value.shape) != tuple(record.shape) or str(value.dtype) != record.dtype:
        raise IOError(f"shard {record.file}: shape/dtype mismatch vs manifest")
    if verify:
        checksum = content_checksum(value.tobytes())
        if checksum != record.checksum:
            raise IOError(f"shard {record.file}: checksum mismatch (torn write?)")
    return value


def save_tree(root: str, run_id: str, step: int,
              tree: Dict[str, np.ndarray],
              writer: str = "w") -> Tuple[ShardRecord, ...]:
    return tuple(save_array(root, run_id, step, path, v, writer)
                 for path, v in sorted(tree.items()))


def load_tree(root: str, records: Tuple[ShardRecord, ...],
              *, verify: bool = True) -> Dict[str, np.ndarray]:
    return {r.path: load_array(root, r, verify=verify) for r in records}
