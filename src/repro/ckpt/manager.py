"""Checkpoint manager: save/restore/reconcile through the DVV store.

save():    write shards to blob storage, then PUT the manifest with the
           causal context of the last manifest read — the new checkpoint
           *dominates* its parent, so replicas discard the old one on sync.
restore(): GET the manifest; if concurrent lineages surface as siblings
           (post-partition), resolve deterministically, write the
           resolution back (so it dominates both branches), and load shards.

The manager also keeps a bounded number of shard generations (keep_n) and
never deletes shards referenced by any *visible* manifest sibling — GC of a
losing lineage happens only after the resolution write dominates it.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, FrozenSet, Optional, TYPE_CHECKING, Tuple

import numpy as np

# Submodule imports (not the repro.store package) so the store's durable
# log can depend on ckpt helpers without an import cycle.
from ..store.network import Unavailable
from .manifest import Manifest, resolve_manifest_siblings
from .shards import load_tree, save_tree

if TYPE_CHECKING:
    from ..store.cluster import KVCluster


def _manifest_key(run_id: str) -> str:
    return f"ckpt/{run_id}/manifest"


@dataclass
class RestoreResult:
    manifest: Manifest
    arrays: Dict[str, np.ndarray]
    had_conflict: bool


class CheckpointManager:
    def __init__(self, store: KVCluster, blob_root: str, run_id: str,
                 node_id: str, keep_n: int = 2):
        self.store = store
        self.blob_root = blob_root
        self.run_id = run_id
        self.node_id = node_id
        self.keep_n = keep_n
        self._last_context: FrozenSet = frozenset()
        self._parent_checksum = ""

    # -- save ------------------------------------------------------------------
    def save(self, step: int, arrays: Dict[str, np.ndarray], *,
             data_cursor: int, rng_seed: int, rng_fold: int,
             mesh_shape: Tuple[int, ...], via: Optional[str] = None) -> Manifest:
        via = via or self.node_id
        records = save_tree(self.blob_root, self.run_id, step, arrays,
                            writer=self.node_id)
        manifest = Manifest(
            run_id=self.run_id, step=step, shards=records,
            data_cursor=data_cursor, rng_seed=rng_seed, rng_fold=rng_fold,
            mesh_shape=mesh_shape, writer=self.node_id,
            parent_checksum=self._parent_checksum)
        self.store.put(_manifest_key(self.run_id), manifest.serialize(),
                       context=self._last_context, via=via,
                       client_id=self.node_id)
        # our own write becomes the causal context for the next save
        res = self.store.get(_manifest_key(self.run_id), via=via)
        self._last_context = res.context
        self._parent_checksum = manifest.checksum()
        self._gc(keep_step=step)
        return manifest

    # -- restore -----------------------------------------------------------------
    def restore(self, *, via: Optional[str] = None,
                verify: bool = True) -> Optional[RestoreResult]:
        via = via or self.node_id
        try:
            res = self.store.get(_manifest_key(self.run_id), via=via)
        except Unavailable:
            return None
        if not res.values:
            return None
        # Dedupe by content: two nodes concurrently writing back the *same*
        # resolution produces concurrent clocks over identical manifests —
        # an artifact of the merge protocol, not a divergence.
        manifests = tuple(
            Manifest.deserialize(v) for v in sorted(set(res.values)))
        had_conflict = len(manifests) > 1
        chosen = resolve_manifest_siblings(manifests)
        if len(res.values) > 1:
            # write the resolution back with full context: it dominates both
            # lineages, so every replica converges on one checkpoint.
            self.store.put(_manifest_key(self.run_id), chosen.serialize(),
                           context=res.context, via=via,
                           client_id=self.node_id)
            res = self.store.get(_manifest_key(self.run_id), via=via)
        self._last_context = res.context
        self._parent_checksum = chosen.checksum()
        arrays = load_tree(self.blob_root, chosen.shards, verify=verify)
        return RestoreResult(manifest=chosen, arrays=arrays,
                             had_conflict=had_conflict)

    # -- GC ------------------------------------------------------------------------
    def _gc(self, keep_step: int) -> None:
        """Drop shard generations older than the keep_n newest present on
        disk, never touching files referenced by any visible manifest
        sibling.

        Conservative by construction: during a partition this node cannot
        see the other side's manifests, so visibility-based GC would delete
        blobs a divergent lineage still needs (observed in
        tests/test_fault_tolerance.py).  Retaining the newest keep_n
        *on-disk generations* bounds the race to operators setting keep_n
        below the maximum expected partition duration in checkpoints."""
        try:
            res = self.store.get(_manifest_key(self.run_id), via=self.node_id)
            referenced = set()
            for v in res.values:
                referenced |= {s.file
                               for s in Manifest.deserialize(v).shards}
        except Unavailable:
            referenced = set()
        if not os.path.isdir(self.blob_root):
            return
        prefix = f"{self.run_id}-step"

        def blob_step(fname: str):
            try:
                return int(fname[len(prefix):len(prefix) + 8])
            except ValueError:
                return None

        on_disk = [f for f in os.listdir(self.blob_root)
                   if f.startswith(prefix) and blob_step(f) is not None]
        generations = sorted({blob_step(f) for f in on_disk})
        keep_steps = set(generations[-self.keep_n:]) | {keep_step}
        for fname in on_disk:
            if fname in referenced or blob_step(fname) in keep_steps:
                continue
            try:
                os.unlink(os.path.join(self.blob_root, fname))
            except OSError:
                continue
