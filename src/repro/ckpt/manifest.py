"""Checkpoint manifests — the control-plane record of a checkpoint.

A manifest is what makes a pile of array shards a *checkpoint*: the step,
the shard table (logical path → file, shape, dtype), the data-pipeline
cursor and RNG state.  Manifests are small and live in the replicated DVV
store; shards are bulk bytes on (simulated) blob storage.

The failure mode this guards against: after a network partition, two
coordinators can both finalize "step-N" manifests built from different
worker subsets.  Under LWW one lineage silently vanishes (and its shards
leak / the restore mixes lineages).  Under DVV both manifests surface as
siblings at read time and ``resolve_manifest_siblings`` picks a winner
deterministically — every node restores the *same* lineage.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


def content_checksum(data: bytes) -> str:
    """The integrity stamp shared by shard blobs and WAL artifacts.

    16 hex chars of sha256 — short enough to live inline in manifests,
    long enough that a torn or corrupted blob cannot collide in practice.
    """
    return hashlib.sha256(data).hexdigest()[:16]


@dataclass(frozen=True)
class ShardRecord:
    path: str           # logical parameter path, e.g. "layers/attn/wq"
    file: str           # blob name
    shape: Tuple[int, ...]
    dtype: str
    checksum: str       # content hash — restores verify integrity


@dataclass(frozen=True)
class Manifest:
    run_id: str
    step: int
    shards: Tuple[ShardRecord, ...]
    data_cursor: int            # tokens consumed — pipeline resume point
    rng_seed: int
    rng_fold: int               # step-folded key state
    mesh_shape: Tuple[int, ...]
    writer: str                 # which coordinator finalized it
    parent_checksum: str = ""   # lineage link to previous manifest

    def serialize(self) -> str:
        d = {
            "run_id": self.run_id, "step": self.step,
            "shards": [vars(s) for s in self.shards],
            "data_cursor": self.data_cursor,
            "rng_seed": self.rng_seed, "rng_fold": self.rng_fold,
            "mesh_shape": list(self.mesh_shape), "writer": self.writer,
            "parent_checksum": self.parent_checksum,
        }
        return json.dumps(d, sort_keys=True)

    @staticmethod
    def deserialize(s: str) -> "Manifest":
        d = json.loads(s)
        shards = tuple(
            ShardRecord(path=r["path"], file=r["file"],
                        shape=tuple(r["shape"]), dtype=r["dtype"],
                        checksum=r["checksum"])
            for r in d["shards"])
        return Manifest(
            run_id=d["run_id"], step=d["step"], shards=shards,
            data_cursor=d["data_cursor"], rng_seed=d["rng_seed"],
            rng_fold=d["rng_fold"], mesh_shape=tuple(d["mesh_shape"]),
            writer=d["writer"], parent_checksum=d["parent_checksum"])

    def checksum(self) -> str:
        return content_checksum(self.serialize().encode())


def resolve_manifest_siblings(manifests: Tuple[Manifest, ...]) -> Manifest:
    """Deterministic reconciliation of concurrent checkpoint lineages.

    Policy: highest step wins (most progress); ties broken by the lineage
    whose content hash is lexicographically smallest — arbitrary but
    *identical on every node*, which is the property that matters.
    """
    return sorted(manifests, key=lambda m: (-m.step, m.checksum()))[0]
