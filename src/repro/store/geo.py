"""Geo-replication plane: DC topology, HLC frontiers, causal snapshots.

This is the store's second consistency level (DESIGN.md §12).  The quorum
plane (PR 5/7) is intra-datacenter: reads and writes assemble quorums
wherever replicas live, which across a WAN means paying cross-DC round
trips.  The geo plane splits the cluster into *datacenters* of equal size
and serves a different contract per direction:

* **Writes** commit against the coordinator's *local* DC only (the write
  quorum is scoped to same-DC replicas), then ship cross-DC asynchronously
  — one digest-diffed delta round per WAN link per shipping tick, between
  *mirror* nodes (slot i of DC A pairs with slot i of DC B; placement rows
  are mirror-expanded, so mirrors own identical key sets and the PR-2
  delta machinery applies unchanged, per shard when ``shards > 1``).
* **Snapshot reads** (``KVCluster.snapshot_get*``) are served entirely
  from the local DC with zero WAN messages: they return every version
  whose wall falls at or below the DC's **Global Stable Frontier** — the
  Okapi/GentleRain stabilization point, made skew-robust by minting
  ``Version.wall`` from per-node hybrid logical clocks
  (``version.HybridClock``).  Results are causally consistent: walls of
  causally ordered writes are ordered (coordinators fold the read
  watermark ``CausalContext.hlc`` and their own wall-column high-water
  mark into the HLC before minting), so no version is returned whose
  causal predecessor is still invisible.

The frontier for DC *d* folds, in one pass:

1. the min over **all** nodes' HLC readings (heartbeat-advanced to the
   shared physical clock) — nothing below it can still be minted;
2. the min wall across in-flight ``("store", ...)`` messages addressed to
   members of *d* (intra-DC replication still queued, plus cross-DC
   read-repair pushes);
3. the min over the **WAN backlog** into *d*: walls committed in another
   DC and not yet covered by a completed shipping tick on that link;
4. the min over the **drop backlog**: walls whose local replication send
   failed outright (partition), cleared when a delta round covers the
   failed edge.

Each node feeds (1) via max-reduces over its packed wall column
(``PackedVersionStore.max_wall`` is the incrementally-folded column max),
and the result is clamped monotone.  The invariant the fold maintains is
deliberately one-sided: every version with wall ≤ frontier is held by *at
least one* local member (the coordinator's mirror receives it on the
first completed tick), which is why snapshot reads merge across **all**
local replicas of a key — and why they require all of them reachable.

Version stores are not multiversioned, so a version still *visible* at
the frontier can be displaced from the live set by an unstable dominator
(wall > frontier).  The plane keeps a bounded per-(node, key) **stable
shadow**: backends invoke ``shadow_hook(key, before_set)`` whenever a
non-empty live set changes, and displaced sets are retained until every
member is dominated by a live version at or below the frontier
(GentleRain's retention rule), then pruned.  Both backends drive the same
hook from their single mutation choke points, so snapshot results stay
packed==object conformant by construction.
"""
from __future__ import annotations

from typing import Any, Dict, FrozenSet, List, Mapping, Optional, \
    Sequence, Tuple

from .version import HLC_EPS, Version, sync_versions

#: Shadow sets retained per (node, key) before an append forces a prune
#: against the last computed frontier (reads prune with a fresh one).
SHADOW_DEPTH = 8


def _inner_payload(message_payload: Any) -> Any:
    """Unwrap a ``("store", payload)`` message body (the only message kind
    the fabric carries)."""
    if isinstance(message_payload, tuple) and len(message_payload) == 2:
        return message_payload[1]
    return message_payload


def _payload_wall_bounds(payload: Any) -> Tuple[Optional[float],
                                                Optional[float]]:
    """(min, max) wall carried by a replication payload — ``None`` when it
    carries no versions.  Packed payloads answer from their wall column;
    object payloads scan their version sets."""
    wall = getattr(payload, "wall", None)
    if wall is not None:
        if len(wall) == 0:
            return None, None
        return float(wall.min()), float(wall.max())
    if isinstance(payload, Mapping):
        walls = [v.wall for vs in payload.values() for v in vs]
        if not walls:
            return None, None
        return min(walls), max(walls)
    return None, None


class GeoPlane:
    """Datacenter bookkeeping bolted onto one ``KVCluster``.

    Owns the DC maps (node → DC, mirror rows), the WAN/drop backlogs the
    frontier folds, the per-(node, key) stable shadows, and the
    ``WanShipper`` that runs the per-link delta shipping loop on the
    SimNetwork timer heap.  Constructed by ``KVCluster(datacenters=...)``
    — not user-instantiated.
    """

    def __init__(self, cluster, datacenters: Mapping[str, Sequence[str]],
                 *, wan_period: float = 25.0, autostart: bool = True):
        if len(datacenters) < 2:
            raise ValueError("geo mode needs at least two datacenters")
        self.cluster = cluster
        self.dcs: Dict[str, Tuple[str, ...]] = {
            dc: tuple(nodes) for dc, nodes in datacenters.items()}
        self.dc_names: Tuple[str, ...] = tuple(self.dcs)
        sizes = {len(v) for v in self.dcs.values()}
        if len(sizes) != 1 or 0 in sizes:
            raise ValueError(
                "datacenters must be equal-sized and non-empty (mirror "
                f"placement), got sizes {sorted(len(v) for v in self.dcs.values())}")
        self.dc_size = len(next(iter(self.dcs.values())))
        self.dc_of: Dict[str, str] = {}
        self._mirrors: Dict[str, Tuple[str, ...]] = {}
        for dc, nodes in self.dcs.items():
            for i, n in enumerate(nodes):
                if n in self.dc_of:
                    raise ValueError(f"node {n!r} appears in two datacenters")
                self.dc_of[n] = dc
        if set(self.dc_of) != set(cluster.nodes):
            raise ValueError("datacenters must cover exactly the cluster's "
                             "node set")
        for i in range(self.dc_size):
            row = tuple(self.dcs[dc][i] for dc in self.dc_names)
            for n in row:
                self._mirrors[n] = row
        # the ring is built over the first DC's nodes; placement rows are
        # mirror-expanded so every DC owns an identical copy of key space
        self.canonical_nodes: Tuple[str, ...] = self.dcs[self.dc_names[0]]

        net = cluster.network
        for n, dc in self.dc_of.items():
            net.set_datacenter(n, dc)

        # frontier inputs (module docstring, terms 3 and 4)
        self.wan_backlog: Dict[Tuple[str, str], List[float]] = {}
        self.drop_backlog: Dict[Tuple[str, str], List[float]] = {}
        self._frontier_cache: Dict[str, float] = {}

        # stable shadows: node → key → [displaced version sets]
        self.shadow: Dict[str, Dict[str, List[FrozenSet[Version]]]] = {}
        for n, node in cluster.nodes.items():
            node.backend.shadow_hook = \
                (lambda key, before, _n=n: self._note_displaced(
                    _n, key, before))

        # shipping accounting (the geo benchmark's WAN wire meter)
        self.wan_ticks = 0
        self.wan_rounds = 0
        self.ship_digest_bytes = 0
        self.ship_payload_bytes = 0
        self.ship_payload_slots = 0

        from .gossip import WanShipper
        self.shipper = WanShipper(self, period=wan_period,
                                  autostart=autostart)

    # -- topology ----------------------------------------------------------

    def mirrors(self, node: str) -> Tuple[str, ...]:
        """``node``'s mirror row: the same ring slot in every DC (itself
        included), ordered by DC declaration order."""
        return self._mirrors[node]

    def links(self) -> List[Tuple[str, str]]:
        """All directed WAN links, in DC declaration order."""
        return [(a, b) for a in self.dc_names for b in self.dc_names
                if a != b]

    def members(self, dc: str) -> Tuple[str, ...]:
        return self.dcs[dc]

    # -- commit-path bookkeeping (called by KVCluster) ---------------------

    def on_commit(self, src_dc: str, walls: Sequence[float]) -> None:
        """Writes committed in ``src_dc``: their walls join the WAN backlog
        of every other DC until a shipping tick on that link completes."""
        for dc in self.dc_names:
            if dc != src_dc:
                self.wan_backlog.setdefault((src_dc, dc), []).extend(walls)

    def note_send_failed(self, src: str, dst: str, wall: float) -> None:
        """A local replication send failed outright (partition/down peer):
        the wall stays a frontier obligation for ``dst``'s DC until a
        delta round covers the ``src → dst`` edge."""
        self.drop_backlog.setdefault((src, dst), []).append(wall)

    def note_delta_round(self, src: str, dst: str) -> None:
        """A completed anti-entropy round ``src → dst``: everything ``src``
        held is now at ``dst``, so drop-backlog entries for that edge are
        discharged, and ``dst``'s HLC observes its new column max."""
        self.drop_backlog.pop((src, dst), None)
        self.cluster.hlc[dst].observe(self.cluster.nodes[dst].max_wall)

    def note_receive(self, dst: str, message_payload: Any) -> None:
        """A replication message arrived at ``dst``: its HLC observes the
        payload's max wall (keeps frontier term 1 fresh without waiting
        for the next mint at ``dst``)."""
        _, top = _payload_wall_bounds(_inner_payload(message_payload))
        if top is not None:
            self.cluster.hlc[dst].observe(top)

    # -- WAN shipping ------------------------------------------------------

    def wan_tick(self, src_dc: str, dst_dc: str, *,
                 max_ranges=None, use_kernel: bool = False
                 ) -> Tuple[list, bool]:
        """One shipping tick on the ``src_dc → dst_dc`` link: a digest-
        diffed delta round per mirror slot pair (mirrors own identical key
        sets, so slot-pair rounds cover the whole key space — per shard,
        via the ordinary sharded delta machinery).  Returns ``(stats,
        complete)``; only a *complete* tick (every slot pair reachable and
        synced) discharges the link's WAN backlog — the coordinator of
        every backlogged write synced its mirror, so each shipped version
        now has at least one holder in ``dst_dc``, which is all the
        frontier invariant needs (snapshot reads merge all local members).
        """
        c = self.cluster
        pending = self.wan_backlog.get((src_dc, dst_dc))
        stats = []
        complete = True
        self.wan_ticks += 1
        for a, b in zip(self.dcs[src_dc], self.dcs[dst_dc]):
            if not c.network.reachable(a, b):
                complete = False
                continue
            st = c.delta_antientropy(a, b, max_ranges=max_ranges,
                                     use_kernel=use_kernel)
            stats.append(st)
            self.wan_rounds += 1
            self.ship_digest_bytes += st.digest_bytes
            self.ship_payload_bytes += st.payload_bytes
            self.ship_payload_slots += st.payload_slots
        if complete and pending:
            del pending[:]
        return stats, complete

    def wan_round(self, **kw) -> list:
        """One tick on every WAN link (the hand-cranked/quiesce form of
        what ``WanShipper`` runs continuously)."""
        out = []
        for a, b in self.links():
            out.extend(self.wan_tick(a, b, **kw)[0])
        return out

    @property
    def ship_bytes(self) -> int:
        return self.ship_digest_bytes + self.ship_payload_bytes

    # -- the Global Stable Frontier ----------------------------------------

    def stable_frontier(self, dc: str) -> float:
        """The DC's stabilization point: every version with wall ≤ frontier
        is visible to a snapshot read in ``dc`` (held by at least one local
        replica of its key, with its causal predecessors likewise visible).
        One fold over the four obligation sources in the module docstring,
        clamped monotone."""
        c = self.cluster
        pt = int(c.clock_time)
        for h in c.hlc.values():
            h.observe_physical(pt)
        f = min(h.read() for h in c.hlc.values())
        members = set(self.dcs[dc])
        for m in c.network.queue:
            if m.dst in members:
                low, _ = _payload_wall_bounds(_inner_payload(m.payload))
                if low is not None:
                    f = min(f, low - HLC_EPS)
        for (_, d), walls in self.wan_backlog.items():
            if d == dc and walls:
                f = min(f, min(walls) - HLC_EPS)
        for (_, d), walls in self.drop_backlog.items():
            if d in members and walls:
                f = min(f, min(walls) - HLC_EPS)
        f = max(f, self._frontier_cache.get(dc, 0.0))
        self._frontier_cache[dc] = f
        return f

    def frontier_lag(self, dc: str) -> float:
        """Staleness: how far (in clock ticks) the DC's frontier trails
        the shared physical clock."""
        return max(0.0, self.cluster.clock_time - self.stable_frontier(dc))

    # -- stable shadows ----------------------------------------------------

    def _note_displaced(self, node: str, key: str,
                        before: FrozenSet[Version]) -> None:
        lst = self.shadow.setdefault(node, {}).setdefault(key, [])
        lst.append(before)
        if len(lst) > SHADOW_DEPTH:
            # bound growth against the last frontier this plane computed
            # (0.0 before any snapshot read: keep everything — safe, and
            # reads prune with a fresh frontier anyway)
            self.prune_shadow(
                node, key,
                self._frontier_cache.get(self.dc_of[node], 0.0))

    def prune_shadow(self, node: str, key: str, frontier: float) -> None:
        """Drop shadow sets whose every member is (equal to or) dominated
        by a live version at or below ``frontier`` — any present or future
        snapshot read will see the dominator, so the set contributes
        nothing (frontiers are monotone)."""
        by_key = self.shadow.get(node)
        lst = by_key.get(key) if by_key else None
        if not lst:
            return
        live = self.cluster.nodes[node].versions(key)
        by_key[key] = [s for s in lst
                       if not self._stabilized(s, live, frontier)]

    @staticmethod
    def _stabilized(shadow_set: FrozenSet[Version],
                    live: FrozenSet[Version], frontier: float) -> bool:
        for v in shadow_set:
            if not any(w.wall <= frontier
                       and (w.clock == v.clock or v.clock.lt(w.clock))
                       for w in live):
                return False
        return True

    # -- snapshot reads ----------------------------------------------------

    def snapshot_members(self, dc: str, key: str) -> List[str]:
        """The local-DC replicas of ``key`` (mirror rows make this exactly
        ``replication`` nodes)."""
        return [r for r in self.cluster.replicas_for(key)
                if self.dc_of[r] == dc]

    def snapshot_versions(self, dc: str, key: str, frontier: float,
                          members: Optional[Sequence[str]] = None
                          ) -> FrozenSet[Version]:
        """The key's causally consistent snapshot at ``frontier``: pool the
        live sets and stable shadows of every local member, keep versions
        at or below the frontier, reduce to the maximal antichain.  Zero
        network traffic — everything read is DC-local."""
        c = self.cluster
        if members is None:
            members = self.snapshot_members(dc, key)
        pool = set()
        for m in members:
            self.prune_shadow(m, key, frontier)
            pool |= c.nodes[m].versions(key)
            by_key = self.shadow.get(m)
            if by_key:
                for s in by_key.get(key, ()):
                    pool |= s
        visible = frozenset(v for v in pool if v.wall <= frontier)
        return sync_versions(
            visible, frozenset(),
            total_order=not c.mechanism.tracks_concurrency)

    # -- admission ---------------------------------------------------------

    def check_snapshot(self, proxy: str, key: str) -> Optional[str]:
        """Why a snapshot read for ``key`` via ``proxy`` would fail right
        now, or ``None`` if it is admissible.  The frontier only promises
        *some* local member holds each stable version, so the read needs
        every local replica of the key reachable from the proxy — WAN
        cuts never trip this (the whole point), intra-DC faults do."""
        if proxy in self.cluster.network.down:
            return f"proxy {proxy} is down"
        dc = self.dc_of[proxy]
        for r in self.snapshot_members(dc, key):
            if not self.cluster.network.reachable(proxy, r):
                return (f"local replica {r} unreachable from {proxy} "
                        f"(snapshot reads merge all {dc!r} members)")
        return None

    def __repr__(self) -> str:      # pragma: no cover
        return (f"<GeoPlane dcs={list(self.dc_names)} size={self.dc_size} "
                f"ticks={self.wan_ticks} ship={self.ship_bytes}B>")


__all__ = ["GeoPlane", "SHADOW_DEPTH"]
