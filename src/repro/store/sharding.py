"""Consistent-hash ring with virtual nodes + fixed key-space shards.

Placement used to be an md5 full-sort over every node *per key*, memoised
in an unbounded per-key dict (``KVCluster._ring_cache``) that grew with the
key universe and was invalidated wholesale on every membership change.
This module replaces it with the classic two-level scheme:

* **Shards** — the key space is cut into a fixed power-of-two number of
  shards by the top bits of a stable 64-bit key hash (blake2b-8, the same
  hash family the digest trees use; top bits so shard choice stays
  independent of the digest-bucket low bits).  A shard is the unit of
  placement, of per-shard packed stores, of gossip planes and of
  rebalance transfer.
* **Ring** — nodes project ``vnodes`` virtual tokens each onto the 64-bit
  hash circle (``blake2b-8("node#v")``).  A shard's replica set is found
  by one ``bisect`` over the sorted token array from the shard's range
  start, walking clockwise until ``replication`` *distinct* nodes are
  collected — O(log V) per lookup, V = nodes x vnodes.

The cluster keeps one O(shards) placement table rebuilt on membership
change (shards x O(log V)); per-key placement is then one hash + one
index.  Memory is bounded by the shard count, never by the key universe,
and a join/leave moves only the shards whose ring walk actually changed —
~1/N of them in expectation, the consistent-hashing guarantee.
"""
from __future__ import annotations

import hashlib
from bisect import bisect_right
from typing import Dict, Iterable, List, Sequence, Tuple

#: Placement granularity when sharded stores are off (``shards=1``): keys
#: still place through the ring, at this many fixed hash-range slices, so
#: the placement table stays O(1)-bounded instead of O(keys).
DEFAULT_PLACEMENT_SLICES = 128

#: Virtual tokens per node.  More vnodes = smoother load split and finer
#: rebalance granularity, at O(log V) lookup cost that grows only in the log.
DEFAULT_VNODES = 64

_HASH_BITS = 64


def key_hash64(s: str) -> int:
    """Stable (process-independent) 64-bit hash — blake2b-8, the single
    hash every placement decision derives from."""
    return int.from_bytes(
        hashlib.blake2b(s.encode(), digest_size=8).digest(), "little")


def _check_shards(shards: int) -> int:
    if shards < 1 or shards & (shards - 1):
        raise ValueError(f"shards must be a power of two >= 1, got {shards}")
    return _HASH_BITS - (shards.bit_length() - 1)


def shard_of_hash(h: int, shards: int) -> int:
    """Shard of a 64-bit key hash: the top log2(shards) bits."""
    return h >> _check_shards(shards)


def shard_of_key(key: str, shards: int) -> int:
    if shards == 1:
        return 0
    return key_hash64(key) >> _check_shards(shards)


def shard_point(shard: int, shards: int) -> int:
    """The ring point a shard is placed at: the start of its hash range.
    Every key hashing into the shard shares this placement, which is what
    makes ownership (and therefore rebalance) exact at shard granularity."""
    return shard << _check_shards(shards)


class HashRing:
    """Sorted-token consistent-hash ring with virtual nodes.

    Deterministic: tokens are pure functions of node ids, ties (64-bit
    collisions) break on the node id, and membership is kept as a sorted
    structure — two rings built from the same node set are identical
    whatever the insertion order was.
    """

    def __init__(self, nodes: Iterable[str] = (), *,
                 vnodes: int = DEFAULT_VNODES):
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        self._members: Dict[str, None] = {}
        self._tokens: List[int] = []
        self._owners: List[str] = []
        for n in nodes:
            self._members[n] = None
        self._rebuild()

    # -- membership --------------------------------------------------------

    def __len__(self) -> int:
        return len(self._members)

    def __contains__(self, node: str) -> bool:
        return node in self._members

    @property
    def nodes(self) -> Tuple[str, ...]:
        return tuple(self._members)

    @property
    def n_tokens(self) -> int:
        return len(self._tokens)

    def add(self, node: str) -> None:
        if node in self._members:
            raise ValueError(f"node {node!r} already on ring")
        self._members[node] = None
        self._rebuild()

    def remove(self, node: str) -> None:
        if node not in self._members:
            raise KeyError(f"node {node!r} not on ring")
        del self._members[node]
        self._rebuild()

    def _rebuild(self) -> None:
        pairs = sorted(
            (key_hash64(f"{n}#vn{v}"), n)
            for n in sorted(self._members) for v in range(self.vnodes))
        self._tokens = [t for t, _ in pairs]
        self._owners = [n for _, n in pairs]

    # -- lookup ------------------------------------------------------------

    def replicas_for_hash(self, h: int, n: int) -> Tuple[str, ...]:
        """The first ``n`` distinct nodes clockwise from ``h``: one bisect
        (O(log V)) plus a short walk.  ``n`` past the member count returns
        every member in walk order."""
        V = len(self._tokens)
        if V == 0 or n < 1:
            return ()
        want = min(n, len(self._members))
        start = bisect_right(self._tokens, h) % V
        out: List[str] = []
        seen = set()
        for i in range(V):
            owner = self._owners[(start + i) % V]
            if owner not in seen:
                seen.add(owner)
                out.append(owner)
                if len(out) == want:
                    break
        return tuple(out)

    def replicas_for_key(self, key: str, n: int) -> Tuple[str, ...]:
        """Direct per-key lookup (no table): hash + bisect, O(log V)."""
        return self.replicas_for_hash(key_hash64(key), n)

    def placement_table(self, shards: int, n: int
                        ) -> List[Tuple[str, ...]]:
        """Replica sets for every shard — the bounded O(shards) table the
        cluster serves per-key placement from."""
        return [self.replicas_for_hash(shard_point(s, shards), n)
                for s in range(shards)]

    def __repr__(self) -> str:
        return (f"<HashRing nodes={len(self._members)} "
                f"vnodes={self.vnodes} tokens={len(self._tokens)}>")


def owned_shards(table: Sequence[Tuple[str, ...]], node: str
                 ) -> frozenset:
    """Shards whose replica set includes ``node`` under ``table``."""
    return frozenset(s for s, reps in enumerate(table) if node in reps)


def moved_shards(before: Sequence[Tuple[str, ...]],
                 after: Sequence[Tuple[str, ...]]) -> List[int]:
    """Shards whose replica set changed between two placement tables —
    the exact rebalance set on a membership change."""
    return [s for s, (a, b) in enumerate(zip(before, after)) if a != b]


__all__ = [
    "DEFAULT_PLACEMENT_SLICES", "DEFAULT_VNODES", "HashRing",
    "key_hash64", "moved_shards", "owned_shards",
    "shard_of_hash", "shard_of_key", "shard_point",
]
