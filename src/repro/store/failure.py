"""Self-driving membership: accrual failure detection wired into the loop.

The seed's ``cluster/failure_detector.py`` was a training-sim helper that
nothing in the store called — membership changes were hand-invoked, so the
paper's "bounded by the degree of replication" claim only held while an
operator watched the cluster.  This module promotes the detector to a
first-class store citizen and closes the SWIM-style loop:

* ``FailureDetector`` — per-node accrual suspicion.  A node's suspicion is
  its silence measured in *expected heartbeat intervals*; the expected
  interval adapts to the observed gap history (median of clamped gaps, so
  one long partition cannot inflate it — the Okapi/GentleRain+ lesson that
  robustness claims only hold once anomalies are injected deliberately).
  Members are registered the moment they join, so a node that joins and
  immediately goes silent is visible to the detector from its first
  missing beat.
* ``MembershipController`` — the control loop.  Per-node *probe* timers on
  the ``SimNetwork`` heap (fixed cadence, seeded jitter) record a beat
  whenever the node's gossip/acks can reach at least one live member;
  crossing ``dead_threshold`` triggers ``KVCluster.remove_node`` with
  handoff automatically (purging the fabric queue of messages addressed to
  the corpse), and an evicted node that becomes reachable again is
  re-admitted through the warm digest-diffed bootstrap.  No hand-called
  membership anywhere.

Suspicion also feeds the data plane: ``KVCluster`` deprioritizes suspect
replicas when assembling quorums and picking coordinators, and
``GossipDriver`` skips suspects in its regular rounds while aiming one
dedicated probe round per tick at the most-suspect reachable member —
suspicion *raises* a node's anti-entropy priority (it gets focused
attention) while backing regular gossip off it (a flapping peer stops
snapping every cadence in the cluster).

Determinism contract: probe fire times are pure functions of
``(seed, node)``, and a beat depends only on fabric reachability and
current membership — never on payload contents, adapted gossip cadences
or backend representation.  Eviction/re-admission times are therefore
byte-identical between the packed and object backends, which is what lets
the churn/fault conformance suites assert ``packed == object`` *including
the membership trajectory*.  See DESIGN.md §13.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class FailureDetector:
    """Accrual-style failure detection over heartbeats.

    Suspicion is the normalized time since the last beat; crossing
    ``suspect_threshold`` marks the node suspect, ``dead_threshold`` lets
    the control loop declare it dead.  ``heartbeat_interval`` is the
    prior for the expected gap until a history exists.
    """

    heartbeat_interval: float = 1.0
    suspect_threshold: float = 3.0   # intervals without a beat -> suspect
    dead_threshold: float = 8.0      # intervals without a beat -> dead
    last_beat: Dict[str, float] = field(default_factory=dict)
    history: Dict[str, List[float]] = field(default_factory=dict)

    def record(self, node: str, now: float) -> None:
        prev = self.last_beat.get(node)
        if prev is not None:
            self.history.setdefault(node, []).append(now - prev)
            # keep a bounded window for the adaptive interval estimate
            if len(self.history[node]) > 64:
                self.history[node] = self.history[node][-64:]
        self.last_beat[node] = now

    def register(self, node: str, now: float) -> None:
        """Start tracking a member that has produced no beat yet (a fresh
        join): suspicion is measured from registration.  Without this, a
        node that joins and immediately goes silent never enters
        ``last_beat`` and is invisible to ``suspects()``/``dead()``
        forever.  A no-op for already-tracked nodes."""
        if node not in self.last_beat:
            self.last_beat[node] = now

    def forget(self, node: str) -> None:
        """Drop all state for a departed node (mirrors
        ``SimNetwork.forget``).  Without it, ``last_beat``/``history``
        leak forever and a removed-then-readded node inherits stale gap
        history from its previous life."""
        self.last_beat.pop(node, None)
        self.history.pop(node, None)

    def known(self) -> List[str]:
        return list(self.last_beat)

    def _expected_interval(self, node: str) -> float:
        """Median of the observed gaps, each clamped at
        ``suspect_threshold`` intervals.  A raw mean lets one long
        partition gap inflate the estimate and suppress suspicion for
        many intervals after the heal; the clamped median forgets an
        outage as soon as regular beats resume."""
        hist = self.history.get(node)
        if not hist:
            return self.heartbeat_interval
        cap = self.suspect_threshold * self.heartbeat_interval
        gaps = sorted(min(g, cap) for g in hist)
        n = len(gaps)
        mid = n // 2
        med = gaps[mid] if n % 2 else 0.5 * (gaps[mid - 1] + gaps[mid])
        return max(med, 1e-9)

    def suspicion(self, node: str, now: float) -> float:
        """0 = just heard from it; grows linearly in missed intervals."""
        if node not in self.last_beat:
            return float("inf")
        return (now - self.last_beat[node]) / self._expected_interval(node)

    def suspects(self, now: float) -> List[str]:
        return [n for n in self.last_beat
                if self.suspect_threshold <= self.suspicion(n, now)
                < self.dead_threshold]

    def dead(self, now: float) -> List[str]:
        return [n for n in self.last_beat
                if self.suspicion(n, now) >= self.dead_threshold]

    def alive(self, now: float) -> List[str]:
        return [n for n in self.last_beat
                if self.suspicion(n, now) < self.suspect_threshold]


@dataclass
class _ProbeState:
    """Per-node probe scheduling state (all simulated-time units)."""

    rng: random.Random
    timer: Optional[int] = None


class MembershipController:
    """Closes the membership loop over a ``KVCluster`` (DESIGN.md §13).

    Construction registers the controller on the cluster
    (``cluster.membership``) and arms one probe timer per member on the
    shared ``SimNetwork`` heap.  Each fire records a beat iff the node's
    outbound traffic can currently reach at least one live member, then
    sweeps: members past ``dead_threshold`` are evicted via
    ``remove_node(handoff=...)`` (the fabric queue toward them is purged,
    their detector state forgotten, and — when the eviction hit a node
    the fault injector had crashed — the crash outlives the eviction so a
    later recovery is still required before re-admission); evicted nodes
    that became reachable again are re-admitted via ``add_node`` and the
    PR-4 warm digest-diffed bootstrap.  Topology changes trigger an
    immediate sweep, so a heal re-admits at event speed rather than probe
    cadence.
    """

    def __init__(self, cluster, *, period: float = 10.0,
                 jitter: float = 0.25, suspect_threshold: float = 3.0,
                 dead_threshold: float = 8.0, min_members: int = 2,
                 handoff: bool = True, readmit: bool = True,
                 bootstrap_ranges: Optional[int] = None,
                 seed: Optional[int] = None, autostart: bool = True):
        if period <= 0:
            raise ValueError("period must be positive")
        if not 0 <= jitter < 1:
            raise ValueError("jitter must be in [0, 1)")
        if not 0 < suspect_threshold < dead_threshold:
            raise ValueError("need 0 < suspect_threshold < dead_threshold")
        if getattr(cluster, "geo", None) is not None:
            raise ValueError("self-driving membership is not supported on "
                             "a geo cluster (mirror placement is static)")
        self.cluster = cluster
        self.network = cluster.network
        self.period = float(period)
        self.jitter = jitter
        self.detector = FailureDetector(
            heartbeat_interval=self.period,
            suspect_threshold=suspect_threshold,
            dead_threshold=dead_threshold)
        self.min_members = max(min_members, 1)
        self.handoff = handoff
        self.readmit = readmit
        self.bootstrap_ranges = bootstrap_ranges
        self.seed = cluster.seed if seed is None else seed
        self._state: Dict[str, _ProbeState] = {}
        self._evicted: Dict[str, float] = {}     # node -> eviction time
        self._running = False
        self._sweeping = False
        self.probes = 0
        self.evictions = 0
        self.readmissions = 0
        cluster.membership = self
        if autostart:
            self.start()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        self._running = True
        net = self.network
        if self._on_topology not in net.topology_listeners:
            net.topology_listeners.append(self._on_topology)
        self._adopt()
        for node, st in list(self._state.items()):
            if node in self.cluster.nodes and st.timer is None:
                self._arm(node)

    def stop(self) -> None:
        self._running = False
        net = self.network
        if self._on_topology in net.topology_listeners:
            net.topology_listeners.remove(self._on_topology)
        for st in self._state.values():
            if st.timer is not None:
                net.cancel(st.timer)
                st.timer = None

    # -- probing -----------------------------------------------------------

    def _adopt(self) -> None:
        """Track any member the controller has not seen: register it with
        the detector (suspicion measured from registration) and arm its
        probe timer.  Prune state of departed nodes and drop the eviction
        record of anything hand-re-added behind our back."""
        for node in [n for n in self._state
                     if n not in self.cluster.nodes]:
            st = self._state.pop(node)
            if st.timer is not None:
                self.network.cancel(st.timer)
        for node in self.cluster.nodes:
            self._evicted.pop(node, None)
            if node not in self._state:
                self._state[node] = _ProbeState(
                    rng=random.Random(f"{self.seed}:fd:{node}"))
                self.detector.register(node, self.network.now)
                self._arm(node)

    def _arm(self, node: str) -> None:
        if not self._running:
            return
        st = self._state[node]
        delay = self.period * (
            1.0 + self.jitter * (2.0 * st.rng.random() - 1.0))
        st.timer = self.network.schedule(delay, lambda: self._probe(node))

    def _heard(self, node: str) -> bool:
        """Would the node's outbound gossip/acks reach anyone right now?
        Pure fabric arithmetic (down set, partitions, directed link cuts)
        over current membership — deliberately independent of payloads
        and adapted gossip cadences, so membership decisions are
        byte-identical across storage backends."""
        if node in self.network.down:
            return False
        return any(self.network.reachable(node, m)
                   for m in self.cluster.nodes if m != node)

    def _probe(self, node: str) -> None:
        st = self._state.get(node)
        if st is None:
            return
        st.timer = None
        if node not in self.cluster.nodes:       # departed: disarm for good
            del self._state[node]
            return
        self._adopt()
        self.probes += 1
        now = self.network.now
        if self._heard(node):
            self.detector.record(node, now)
        self.sweep(now)
        if node in self._state:                  # not evicted by the sweep
            self._arm(node)

    def _on_topology(self) -> None:
        """Topology changed (partition/heal/cut/flap/fail/recover/join/
        depart): adopt joiners, and sweep immediately — a heal may have
        made an evicted node reachable (re-admit now, not a probe period
        later) or left a dead one finally safe to evict with handoff."""
        if not self._running:
            return
        self._adopt()
        self.sweep(self.network.now)

    # -- the membership decisions ------------------------------------------

    def sweep(self, now: float) -> None:
        """Evict members past the dead threshold, re-admit evicted nodes
        that are reachable again.  Re-entrancy guarded: evictions and
        re-admissions themselves fire topology events."""
        if self._sweeping:
            return
        self._sweeping = True
        try:
            for node in sorted(self.detector.dead(now)):
                if node in self.cluster.nodes and \
                        len(self.cluster.nodes) > self.min_members:
                    self._evict(node, now)
            if self.readmit:
                for node in sorted(self._evicted):
                    if node not in self.network.down and \
                            any(self.network.reachable(node, m)
                                for m in self.cluster.nodes):
                        self._readmit(node)
        finally:
            self._sweeping = False

    def _evict(self, node: str, now: float) -> None:
        was_down = node in self.network.down
        # remove_node rehashes placement, runs the final handoff push to
        # every *reachable* survivor (a genuinely dead node hands off
        # nothing; a falsely-suspected live one saves its sole-copy
        # writes), and purges the fabric queue of messages addressed to
        # the departed id — the leak that otherwise grows every
        # ``deliver()`` scan forever.
        self.cluster.remove_node(node, handoff=self.handoff)
        if was_down:
            # the eviction is a membership decision; the *crash* is the
            # fault injector's state and must outlive it (forget() clears
            # the down flag for planned departures)
            self.network.down.add(node)
        self.detector.forget(node)
        st = self._state.pop(node, None)
        if st is not None and st.timer is not None:
            self.network.cancel(st.timer)
        self._evicted[node] = now
        self.evictions += 1

    def _readmit(self, node: str) -> None:
        del self._evicted[node]
        if node in getattr(self.cluster, "wal", {}):
            # Durable-log recovery (DESIGN.md §14): the evicted node left a
            # segment log behind, so it rejoins *warm* — replay snapshot +
            # tail from disk, then one digest-diffed delta round for what
            # it missed — instead of paying the O(store) bootstrap.
            self.cluster.restart_node(node)
        else:
            # warm re-entry: placement rehash + ranked digest-diffed
            # bootstrap pulls (only the shards it owns, on a sharded
            # cluster)
            self.cluster.add_node(node, bootstrap=True,
                                  bootstrap_ranges=self.bootstrap_ranges)
        self.readmissions += 1

    # -- suspicion surface (the data-plane hooks) --------------------------

    def suspicion(self, node: str, now: Optional[float] = None) -> float:
        if now is None:
            now = self.network.now
        return self.detector.suspicion(node, now)

    def is_suspect(self, node: str, now: Optional[float] = None) -> bool:
        """True iff a *tracked* node's suspicion crossed the suspect
        threshold.  Unknown nodes (joiners the controller has not adopted
        yet) are not suspect — they simply have no evidence either way."""
        if node not in self.detector.last_beat:
            return False
        return self.suspicion(node, now) >= self.detector.suspect_threshold

    def suspect_nodes(self, now: Optional[float] = None) -> List[str]:
        """Current members at or past the suspect threshold (including
        dead-but-not-yet-evicted), in membership order."""
        return [n for n in self.cluster.nodes if self.is_suspect(n, now)]

    def evicted_nodes(self) -> List[str]:
        return sorted(self._evicted)

    def __repr__(self) -> str:      # pragma: no cover
        return (f"<MembershipController nodes={len(self._state)} "
                f"probes={self.probes} evictions={self.evictions} "
                f"readmissions={self.readmissions}>")


__all__ = ["FailureDetector", "MembershipController"]
