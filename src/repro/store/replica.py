"""A replica node: per-key version sets + the paper's node-local operations."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Iterable, Optional

from ..core.kernel import Mechanism
from .version import Version, clocks_of, sync_versions


@dataclass
class ReplicaNode:
    node_id: str
    mechanism: Mechanism
    store: Dict[str, FrozenSet[Version]] = field(default_factory=dict)

    def versions(self, key: str) -> FrozenSet[Version]:
        return self.store.get(key, frozenset())

    def clocks(self, key: str) -> FrozenSet[Any]:
        return clocks_of(self.versions(key))

    # -- §4.1 node-local steps -------------------------------------------------
    def apply_sync(self, key: str, incoming: FrozenSet[Version]) -> FrozenSet[Version]:
        """S_i' = sync(S_i, incoming); store and return it."""
        merged = sync_versions(
            self.versions(key), incoming,
            total_order=not self.mechanism.tracks_concurrency)
        self.store[key] = merged
        return merged

    def coordinate_update(self, key: str, value: Any,
                          context: FrozenSet[Any], *,
                          client_id: str = "?", client_counter: int = 0,
                          wall_time: float = 0.0) -> Version:
        """u = update(S, S_C, C) followed by S_C' = sync(S_C, {u})."""
        u_clock = self.mechanism.update(
            context, self.clocks(key), self.node_id,
            client_id, client_counter, wall_time)
        version = Version(u_clock, value)
        self.apply_sync(key, frozenset({version}))
        return version

    # -- anti-entropy ------------------------------------------------------------
    def antientropy_payload(self, keys: Optional[Iterable[str]] = None
                            ) -> Dict[str, FrozenSet[Version]]:
        if keys is None:
            keys = list(self.store.keys())
        return {k: self.versions(k) for k in keys}

    def receive_antientropy(self, payload: Dict[str, FrozenSet[Version]]) -> None:
        for k, versions in payload.items():
            self.apply_sync(k, versions)

    # -- introspection -------------------------------------------------------------
    def metadata_size(self, key: str) -> int:
        """Total integers stored in clocks for ``key`` (paper's space metric)."""
        return sum(v.clock.size() for v in self.versions(key))

    def total_keys(self) -> int:
        return len(self.store)
