"""A replica node: per-key version sets + the paper's node-local operations.

Two storage backends implement the same node-local surface:

* ``PackedBackend`` — the default for the DVV mechanism.  Clocks live as
  packed int32 arrays (``store.packed.PackedVersionStore``); object ``DVV``s
  appear only at the client API edge (GET contexts, PUT acks) and in
  control-plane replication messages.  Anti-entropy payloads are
  ``PackedPayload`` arrays end to end.
* ``ObjectBackend`` — Python clock objects in a dict, used by every other
  mechanism (version vectors, LWW, the causal-history oracle) and — forced
  via ``packed=False`` — as the conformance reference the packed store is
  tested observationally equal to.
"""
from __future__ import annotations

from typing import Any, Dict, FrozenSet, Iterable, List, Optional, Sequence, \
    Tuple, Union

from ..core import batched as B
from ..core.kernel import Mechanism
from .context import CausalContext
from .packed import PackedPayload, PackedVersionStore
from .version import Version, clocks_of, sync_versions

Payload = Union[Dict[str, FrozenSet[Version]], PackedPayload]

#: One batched write: (key, context token, value, wall_time).
UpdateBatch = Sequence[Tuple[str, CausalContext, Any, float]]


class ObjectBackend:
    """Per-key frozensets of (clock, value) objects — the generic backend."""

    def __init__(self, mechanism: Mechanism, node_id: str):
        self.mechanism = mechanism
        self.node_id = node_id
        self.store: Dict[str, FrozenSet[Version]] = {}

    def versions(self, key: str) -> FrozenSet[Version]:
        return self.store.get(key, frozenset())

    def apply_sync(self, key: str, incoming: FrozenSet[Version]
                   ) -> FrozenSet[Version]:
        merged = sync_versions(
            self.versions(key), incoming,
            total_order=not self.mechanism.tracks_concurrency)
        self.store[key] = merged
        return merged

    def coordinate_update(self, key: str, value: Any,
                          context: CausalContext, *,
                          client_id: str, client_counter: int,
                          wall_time: float) -> Version:
        u_clock = self.mechanism.update(
            context.to_clock_set(), clocks_of(self.versions(key)),
            self.node_id, client_id, client_counter, wall_time)
        version = Version(u_clock, value, wall=wall_time)
        self.apply_sync(key, frozenset({version}))
        return version

    def antientropy_payload(self, keys: Optional[Iterable[str]] = None
                            ) -> Dict[str, FrozenSet[Version]]:
        if keys is None:
            keys = list(self.store.keys())
        return {k: self.versions(k) for k in keys}

    def receive_antientropy(self, payload: Payload) -> int:
        changed = 0
        for k, versions in _as_object_payload(payload).items():
            before = self.versions(k)
            if self.apply_sync(k, versions) != before:
                changed += 1
        return changed

    def metadata_size(self, key: str) -> int:
        return sum(v.clock.size() for v in self.versions(key))

    def total_keys(self) -> int:
        return len(self.store)


class PackedBackend:
    """Packed int32 clocks as the resident representation (DVV only)."""

    def __init__(self, mechanism: Mechanism, node_id: str):
        if mechanism.name != "dvv":
            # The packed backend *implements* the DVV §5.3 update/sync in
            # arrays; running it under another mechanism would silently
            # swap that mechanism's semantics for DVV's.
            raise ValueError(
                f"packed backend implements DVV semantics; mechanism "
                f"{mechanism.name!r} must use the object backend")
        self.mechanism = mechanism
        self.node_id = node_id
        self.packed = PackedVersionStore()
        self.packed.intern_replica(node_id)

    def versions(self, key: str) -> FrozenSet[Version]:
        return self.packed.versions(key)           # edge decode, one key

    def apply_sync(self, key: str, incoming: FrozenSet[Version]
                   ) -> FrozenSet[Version]:
        """Object versions arrive from control-plane replication messages;
        encode at the boundary, then merge in arrays."""
        self.packed.sync_key_objects(key, incoming)
        return self.versions(key)

    def coordinate_update(self, key: str, value: Any,
                          context: CausalContext, *,
                          client_id: str, client_counter: int,
                          wall_time: float) -> Version:
        # Token-native: the ceiling entries go straight to int32 columns —
        # no clock object is built from the context.
        ctx_vv = self.packed.ceiling_of_entries(context.ceiling_items())
        vv, r_ix, dot_n = self.packed.update_key(
            key, ctx_vv, self.node_id, value, wall=wall_time)
        # Decode only the freshly minted clock for the PutAck (edge decode).
        clock = B.decode(vv[: self.packed.n_replicas], r_ix, dot_n,
                         self.packed.replica_ids)
        return Version(clock, value, wall=wall_time)

    def coordinate_updates(self, batch: UpdateBatch, *,
                           mask_fn=None) -> List[Version]:
        """Batched §5.3 updates over distinct keys: one grouped encode →
        one vectorized update → one scatter (``PackedVersionStore.
        update_keys``), instead of K independent ``sync_key`` walks."""
        items = [(key, ctx.ceiling_items(), value, wall)
                 for (key, ctx, value, wall) in batch]
        vv, r_ix, dot_n = self.packed.update_keys(
            items, self.node_id, mask_fn=mask_fn)
        R = self.packed.n_replicas
        return [
            Version(B.decode(vv[i, :R], r_ix, int(dot_n[i]),
                             self.packed.replica_ids),
                    batch[i][2], wall=batch[i][3])
            for i in range(len(batch))]

    def antientropy_payload(self, keys: Optional[Iterable[str]] = None
                            ) -> PackedPayload:
        return self.packed.payload(keys)           # arrays out, zero decode

    def receive_antientropy(self, payload: Payload, *,
                            mask_fn=None) -> int:
        if isinstance(payload, PackedPayload):     # arrays in, zero encode
            return self.packed.apply_payload(payload, mask_fn=mask_fn)
        changed = 0
        for k, versions in payload.items():
            before = self.versions(k)
            if self.apply_sync(k, versions) != before:
                changed += 1
        return changed

    def metadata_size(self, key: str) -> int:
        return self.packed.metadata_size(key)

    def total_keys(self) -> int:
        return len(self.packed.keys)


def _as_object_payload(payload: Payload) -> Dict[str, FrozenSet[Version]]:
    """Decode a packed payload for an object-backend receiver (mixed-backend
    interop; not a hot path)."""
    if not isinstance(payload, PackedPayload):
        return payload
    out: Dict[str, set] = {k: set() for k in payload.keys}
    R = len(payload.replica_ids)
    for i in range(len(payload)):
        clock = B.decode(payload.vv[i, :R], int(payload.dot_id[i]),
                         int(payload.dot_n[i]), payload.replica_ids)
        out[payload.keys[int(payload.key_ix[i])]].add(
            Version(clock, payload.values[i], wall=float(payload.wall[i])))
    return {k: frozenset(v) for k, v in out.items()}


class ReplicaNode:
    """Facade over a storage backend; the paper's §4.1 node-local steps."""

    def __init__(self, node_id: str, mechanism: Mechanism,
                 packed: Optional[bool] = None):
        self.node_id = node_id
        self.mechanism = mechanism
        if packed is None:
            packed = mechanism.name == "dvv"
        self.backend = (PackedBackend if packed else ObjectBackend)(
            mechanism, node_id)

    @property
    def is_packed(self) -> bool:
        return isinstance(self.backend, PackedBackend)

    def versions(self, key: str) -> FrozenSet[Version]:
        return self.backend.versions(key)

    def clocks(self, key: str) -> FrozenSet[Any]:
        return clocks_of(self.versions(key))

    # -- §4.1 node-local steps ------------------------------------------------
    def apply_sync(self, key: str, incoming: FrozenSet[Version]
                   ) -> FrozenSet[Version]:
        """S_i' = sync(S_i, incoming); store and return it."""
        return self.backend.apply_sync(key, incoming)

    def coordinate_update(self, key: str, value: Any,
                          context: Any = None, *,
                          client_id: str = "?", client_counter: int = 0,
                          wall_time: float = 0.0) -> Version:
        """u = update(S, S_C, C) followed by S_C' = sync(S_C, {u}).

        ``context`` may be a ``CausalContext`` token, its bytes encoding,
        or (deprecated) a raw clock set."""
        return self.backend.coordinate_update(
            key, value, CausalContext.coerce(context), client_id=client_id,
            client_counter=client_counter, wall_time=wall_time)

    def coordinate_updates(self, batch: UpdateBatch, *,
                           client_id: str = "?", client_counter: int = 0,
                           mask_fn=None) -> List[Version]:
        """Batched multi-key coordination.  The packed backend takes the
        one-scatter vectorized path; the object backend (the conformance
        reference, and any non-DVV mechanism) degrades to a loop."""
        if isinstance(self.backend, PackedBackend):
            return self.backend.coordinate_updates(batch, mask_fn=mask_fn)
        return [
            self.backend.coordinate_update(
                key, value, ctx, client_id=client_id,
                client_counter=client_counter, wall_time=wall)
            for (key, ctx, value, wall) in batch]

    # -- anti-entropy ------------------------------------------------------------
    def antientropy_payload(self, keys: Optional[Iterable[str]] = None
                            ) -> Payload:
        return self.backend.antientropy_payload(keys)

    def receive_antientropy(self, payload: Payload) -> int:
        return self.backend.receive_antientropy(payload)

    # -- introspection -------------------------------------------------------------
    def metadata_size(self, key: str) -> int:
        """Total integers stored in clocks for ``key`` (paper's space metric)."""
        return self.backend.metadata_size(key)

    def total_keys(self) -> int:
        return self.backend.total_keys()
