"""A replica node: per-key version sets + the paper's node-local operations.

Two storage backends implement the same node-local surface:

* ``PackedBackend`` — the default for the DVV mechanism.  Clocks live as
  packed int32 arrays (``store.packed.PackedVersionStore``); object ``DVV``s
  appear only at the client API edge (GET contexts, PUT acks) and in
  control-plane replication messages.  Anti-entropy payloads are
  ``PackedPayload`` arrays end to end.
* ``ObjectBackend`` — Python clock objects in a dict, used by every other
  mechanism (version vectors, LWW, the causal-history oracle) and — forced
  via ``packed=False`` — as the conformance reference the packed store is
  tested observationally equal to.
"""
from __future__ import annotations

from typing import Any, Dict, FrozenSet, Iterable, List, Optional, Sequence, \
    Tuple, Union

from ..core import batched as B
from ..core.kernel import Mechanism
from .context import CausalContext
from .packed import DIGEST_BUCKETS, PackedPayload, PackedVersionStore, \
    concat_payloads, split_payload
from .sharding import shard_of_key
from .version import Version, clocks_of, sync_versions

Payload = Union[Dict[str, FrozenSet[Version]], PackedPayload]

#: One batched write: (key, context token, value, wall_time).
UpdateBatch = Sequence[Tuple[str, CausalContext, Any, float]]


class ObjectBackend:
    """Per-key frozensets of (clock, value) objects — the generic backend."""

    def __init__(self, mechanism: Mechanism, node_id: str):
        self.mechanism = mechanism
        self.node_id = node_id
        self.store: Dict[str, FrozenSet[Version]] = {}
        # geo tier (DESIGN.md §12): same displacement hook + wall high-water
        # surface as PackedVersionStore, so the snapshot plane's shadow
        # retention is backend-agnostic (packed==object conformance).
        self.max_wall = 0.0
        self.shadow_hook = None
        # durability tier (DESIGN.md §14): ``wal_hook(key, merged)`` fires
        # with the committed post-state whenever a key's set changes — the
        # object-backend mirror of ``PackedVersionStore.wal_hook``.
        self.wal_hook = None

    def versions(self, key: str) -> FrozenSet[Version]:
        return self.store.get(key, frozenset())

    def _store_merged(self, key: str, before: FrozenSet[Version],
                      merged: FrozenSet[Version]) -> None:
        self.store[key] = merged
        if merged:
            top = max(v.wall for v in merged)
            if top > self.max_wall:
                self.max_wall = top
        if self.shadow_hook is not None and before and merged != before:
            self.shadow_hook(key, before)
        if self.wal_hook is not None and merged != before:
            self.wal_hook(key, merged)

    def apply_sync(self, key: str, incoming: FrozenSet[Version]
                   ) -> FrozenSet[Version]:
        before = self.versions(key)
        merged = sync_versions(
            before, incoming,
            total_order=not self.mechanism.tracks_concurrency)
        self._store_merged(key, before, merged)
        return merged

    def replace_key(self, key: str, versions: FrozenSet[Version]) -> None:
        """Overwrite one key's version set with an already-merged result
        (the bulk delta-round write-back) through the same shadow/wall
        bookkeeping as ``apply_sync``."""
        self._store_merged(key, self.versions(key), versions)

    def coordinate_update(self, key: str, value: Any,
                          context: CausalContext, *,
                          client_id: str, client_counter: int,
                          wall_time: float) -> Version:
        u_clock = self.mechanism.update(
            context.to_clock_set(), clocks_of(self.versions(key)),
            self.node_id, client_id, client_counter, wall_time)
        version = Version(u_clock, value, wall=wall_time)
        self.apply_sync(key, frozenset({version}))
        return version

    def antientropy_payload(self, keys: Optional[Iterable[str]] = None
                            ) -> Dict[str, FrozenSet[Version]]:
        if keys is None:
            keys = list(self.store.keys())
        return {k: self.versions(k) for k in keys}

    def receive_antientropy(self, payload: Payload) -> int:
        changed = 0
        for k, versions in _as_object_payload(payload).items():
            before = self.versions(k)
            if self.apply_sync(k, versions) != before:
                changed += 1
        return changed

    def metadata_size(self, key: str) -> int:
        return sum(v.clock.size() for v in self.versions(key))

    def total_keys(self) -> int:
        return len(self.store)


class PackedBackend:
    """Packed int32 clocks as the resident representation (DVV only).

    With ``shards > 1`` the key space is cut by the stable 64-bit key hash
    (``sharding.shard_of_key``) into that many independent
    ``PackedVersionStore``s, each with its own (proportionally narrower)
    digest tree — stores stay cache-sized, and compaction, digest rebuilds
    and delta rounds are per-shard.  Every entry point routes by key
    shard; cross-shard batches are grouped so each shard still runs its
    one vectorized pass.  ``shards == 1`` is byte-identical to the
    unsharded store.
    """

    def __init__(self, mechanism: Mechanism, node_id: str, *,
                 shards: int = 1):
        if mechanism.name != "dvv":
            # The packed backend *implements* the DVV §5.3 update/sync in
            # arrays; running it under another mechanism would silently
            # swap that mechanism's semantics for DVV's.
            raise ValueError(
                f"packed backend implements DVV semantics; mechanism "
                f"{mechanism.name!r} must use the object backend")
        if shards < 1 or shards & (shards - 1):
            raise ValueError(
                f"shards must be a power of two >= 1, got {shards}")
        self.mechanism = mechanism
        self.node_id = node_id
        self.shards = shards
        # Split the digest budget across shards so a sharded node's total
        # leaf count starts where the unsharded one did (each store still
        # widens itself with size).
        buckets = max(DIGEST_BUCKETS // shards, 16)
        self.stores: List[PackedVersionStore] = [
            PackedVersionStore(n_buckets=buckets) for _ in range(shards)]
        for st in self.stores:
            st.intern_replica(node_id)

    @property
    def packed(self) -> PackedVersionStore:
        """The single store of an unsharded backend (shard 0 otherwise) —
        the pre-sharding attribute most introspection reaches for."""
        return self.stores[0]

    def store_for(self, key: str) -> PackedVersionStore:
        return self.stores[shard_of_key(key, self.shards)]

    def versions(self, key: str) -> FrozenSet[Version]:
        return self.store_for(key).versions(key)   # edge decode, one key

    def apply_sync(self, key: str, incoming: FrozenSet[Version]
                   ) -> FrozenSet[Version]:
        """Object versions arrive from control-plane replication messages;
        encode at the boundary, then merge in arrays."""
        self.store_for(key).sync_key_objects(key, incoming)
        return self.versions(key)

    def coordinate_update(self, key: str, value: Any,
                          context: CausalContext, *,
                          client_id: str, client_counter: int,
                          wall_time: float) -> Version:
        # Token-native: the ceiling entries go straight to int32 columns —
        # no clock object is built from the context.
        store = self.store_for(key)
        ctx_vv = store.ceiling_of_entries(context.ceiling_items())
        vv, r_ix, dot_n = store.update_key(
            key, ctx_vv, self.node_id, value, wall=wall_time)
        # Decode only the freshly minted clock for the PutAck (edge decode).
        clock = B.decode(vv[: store.n_replicas], r_ix, dot_n,
                         store.replica_ids)
        return Version(clock, value, wall=wall_time)

    def coordinate_updates(self, batch: UpdateBatch, *,
                           mask_fn=None) -> List[Version]:
        """Batched §5.3 updates over distinct keys: one grouped encode →
        one vectorized update → one scatter (``PackedVersionStore.
        update_keys``) *per shard touched*, instead of K independent
        ``sync_key`` walks.  Results come back in batch order."""
        groups: Dict[int, List[int]] = {}
        for i, (key, _, _, _) in enumerate(batch):
            groups.setdefault(shard_of_key(key, self.shards), []).append(i)
        out: List[Optional[Version]] = [None] * len(batch)
        for s, idxs in groups.items():
            store = self.stores[s]
            items = [(batch[i][0], batch[i][1].ceiling_items(),
                      batch[i][2], batch[i][3]) for i in idxs]
            vv, r_ix, dot_n = store.update_keys(
                items, self.node_id, mask_fn=mask_fn)
            R = store.n_replicas
            for j, i in enumerate(idxs):
                out[i] = Version(
                    B.decode(vv[j, :R], r_ix, int(dot_n[j]),
                             store.replica_ids),
                    batch[i][2], wall=batch[i][3])
        return out                                 # type: ignore[return-value]

    def antientropy_payload(self, keys: Optional[Iterable[str]] = None
                            ) -> PackedPayload:
        if self.shards == 1:
            return self.stores[0].payload(keys)    # arrays out, zero decode
        if keys is None:
            return concat_payloads([st.payload() for st in self.stores])
        by_shard: Dict[int, List[str]] = {}
        for k in keys:
            by_shard.setdefault(shard_of_key(k, self.shards), []).append(k)
        return concat_payloads([self.stores[s].payload(ks)
                                for s, ks in by_shard.items()])

    def receive_antientropy(self, payload: Payload, *,
                            mask_fn=None) -> int:
        if isinstance(payload, PackedPayload):     # arrays in, zero encode
            if self.shards == 1:
                return self.stores[0].apply_payload(payload, mask_fn=mask_fn)
            return sum(
                self.stores[s].apply_payload(part, mask_fn=mask_fn)
                for s, part in split_payload(payload, self.shards).items())
        changed = 0
        for k, versions in payload.items():
            before = self.versions(k)
            if self.apply_sync(k, versions) != before:
                changed += 1
        return changed

    def metadata_size(self, key: str) -> int:
        return self.store_for(key).metadata_size(key)

    def total_keys(self) -> int:
        return sum(len(st.keys) for st in self.stores)

    @property
    def max_wall(self) -> float:
        """Max over the per-shard wall-column high-water marks (each an
        O(1) fold maintained by the stores)."""
        return max(st.max_wall for st in self.stores)

    @property
    def shadow_hook(self):
        return self.stores[0].shadow_hook

    @shadow_hook.setter
    def shadow_hook(self, fn) -> None:
        for st in self.stores:
            st.shadow_hook = fn


def _as_object_payload(payload: Payload) -> Dict[str, FrozenSet[Version]]:
    """Decode a packed payload for an object-backend receiver (mixed-backend
    interop; not a hot path)."""
    if not isinstance(payload, PackedPayload):
        return payload
    out: Dict[str, set] = {k: set() for k in payload.keys}
    R = len(payload.replica_ids)
    for i in range(len(payload)):
        clock = B.decode(payload.vv[i, :R], int(payload.dot_id[i]),
                         int(payload.dot_n[i]), payload.replica_ids)
        out[payload.keys[int(payload.key_ix[i])]].add(
            Version(clock, payload.values[i], wall=float(payload.wall[i])))
    return {k: frozenset(v) for k, v in out.items()}


class ReplicaNode:
    """Facade over a storage backend; the paper's §4.1 node-local steps.

    ``shards`` partitions the key space (``sharding.shard_of_key``) into
    that many per-shard packed stores.  The object backend keeps one dict
    — sharding is a *physical* layout choice and must be observationally
    invisible, which is exactly what the packed==object conformance suite
    checks — but the node still records the logical shard count so
    protocol layers (bootstrap, handoff) can filter by shard on either
    backend.
    """

    def __init__(self, node_id: str, mechanism: Mechanism,
                 packed: Optional[bool] = None, *, shards: int = 1):
        self.node_id = node_id
        self.mechanism = mechanism
        self.shards = shards
        if packed is None:
            packed = mechanism.name == "dvv"
        self.backend = (
            PackedBackend(mechanism, node_id, shards=shards) if packed
            else ObjectBackend(mechanism, node_id))

    @property
    def is_packed(self) -> bool:
        return isinstance(self.backend, PackedBackend)

    # -- shard routing -----------------------------------------------------
    def shard_of(self, key: str) -> int:
        return shard_of_key(key, self.shards)

    def store_for(self, key: str) -> PackedVersionStore:
        """The packed store holding ``key`` (packed backends only)."""
        return self.backend.store_for(key)      # type: ignore[union-attr]

    @property
    def shard_stores(self) -> List[PackedVersionStore]:
        """All per-shard packed stores (packed backends only)."""
        return self.backend.stores              # type: ignore[union-attr]

    def versions(self, key: str) -> FrozenSet[Version]:
        return self.backend.versions(key)

    def clocks(self, key: str) -> FrozenSet[Any]:
        return clocks_of(self.versions(key))

    # -- §4.1 node-local steps ------------------------------------------------
    def apply_sync(self, key: str, incoming: FrozenSet[Version]
                   ) -> FrozenSet[Version]:
        """S_i' = sync(S_i, incoming); store and return it."""
        return self.backend.apply_sync(key, incoming)

    def coordinate_update(self, key: str, value: Any,
                          context: Any = None, *,
                          client_id: str = "?", client_counter: int = 0,
                          wall_time: float = 0.0) -> Version:
        """u = update(S, S_C, C) followed by S_C' = sync(S_C, {u}).

        ``context`` may be a ``CausalContext`` token, its bytes encoding,
        or (deprecated) a raw clock set."""
        return self.backend.coordinate_update(
            key, value, CausalContext.coerce(context), client_id=client_id,
            client_counter=client_counter, wall_time=wall_time)

    def coordinate_updates(self, batch: UpdateBatch, *,
                           client_id: str = "?", client_counter: int = 0,
                           mask_fn=None) -> List[Version]:
        """Batched multi-key coordination.  The packed backend takes the
        one-scatter vectorized path; the object backend (the conformance
        reference, and any non-DVV mechanism) degrades to a loop."""
        if isinstance(self.backend, PackedBackend):
            return self.backend.coordinate_updates(batch, mask_fn=mask_fn)
        return [
            self.backend.coordinate_update(
                key, value, ctx, client_id=client_id,
                client_counter=client_counter, wall_time=wall)
            for (key, ctx, value, wall) in batch]

    # -- anti-entropy ------------------------------------------------------------
    def antientropy_payload(self, keys: Optional[Iterable[str]] = None
                            ) -> Payload:
        return self.backend.antientropy_payload(keys)

    def receive_antientropy(self, payload: Payload, *,
                            mask_fn=None) -> int:
        if isinstance(self.backend, PackedBackend):
            return self.backend.receive_antientropy(payload, mask_fn=mask_fn)
        return self.backend.receive_antientropy(payload)

    # -- introspection -------------------------------------------------------------
    def metadata_size(self, key: str) -> int:
        """Total integers stored in clocks for ``key`` (paper's space metric)."""
        return self.backend.metadata_size(key)

    def total_keys(self) -> int:
        return self.backend.total_keys()

    @property
    def max_wall(self) -> float:
        """High-water mark of the node's wall column (geo frontier input)."""
        return self.backend.max_wall
