"""Deterministic simulated transport: partitions, node failures, async delivery.

The container is a single process, so "the network" is a seeded discrete
queue.  Two properties matter for reproducing the paper (and for the
fault-tolerance story of the framework):

* **Reachability** — partitions and down nodes make quorum operations fail
  or proceed degraded, which is how replica divergence arises.
* **Asynchronous replication** — coordinator→replica store messages are
  *queued*, and drivers/tests decide when (whether) they are delivered.
  Interleaving control is what exposes the causality bugs of the §3
  baselines.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Set, Tuple


class Unavailable(Exception):
    """Raised when a quorum cannot be assembled (CAP: we choose AP, but a
    *strict* quorum request against a partitioned minority still fails)."""


def payload_nbytes(obj: Any) -> int:
    """Wire-size estimate of a message payload.

    Objects that know their encoding (``PackedPayload``, digest snapshots,
    ``CausalContext`` via ``to_bytes``) report it; containers recurse;
    everything else is priced at its repr — the sim-transport's
    serialization stand-in.  Keeps ``SimNetwork.bytes_sent`` honest now
    that replication messages carry encoded array payloads.
    """
    nbytes = getattr(obj, "nbytes", None)
    if callable(nbytes):
        return int(nbytes())
    to_bytes = getattr(obj, "to_bytes", None)
    if callable(to_bytes) and not isinstance(obj, int):
        try:
            return len(to_bytes())
        except TypeError:       # int.to_bytes-style signatures
            pass
    if isinstance(obj, (bytes, bytearray, str)):
        return len(obj)
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, (tuple, list, set, frozenset)):
        return sum(payload_nbytes(x) for x in obj)
    if isinstance(obj, dict):
        return sum(payload_nbytes(k) + payload_nbytes(v)
                   for k, v in obj.items())
    return len(repr(obj).encode())


@dataclass
class Message:
    src: str
    dst: str
    payload: Any
    deliver_at: float


class SimNetwork:
    """Seeded, deterministic message fabric between named nodes."""

    def __init__(self, seed: int = 0, base_latency: float = 1.0,
                 jitter: float = 0.5, drop_rate: float = 0.0):
        self.rng = random.Random(seed)
        self.now = 0.0
        self.base_latency = base_latency
        self.jitter = jitter
        self.drop_rate = drop_rate
        self.queue: List[Message] = []
        self.partition_groups: Optional[List[Set[str]]] = None
        self.down: Set[str] = set()
        self.delivered = 0
        self.dropped = 0
        self.bytes_sent = 0

    # -- topology control ----------------------------------------------------
    def partition(self, *groups: Set[str]) -> None:
        """Split the cluster into isolated groups (None heals)."""
        self.partition_groups = [set(g) for g in groups]

    def heal(self) -> None:
        self.partition_groups = None

    def fail_node(self, node: str) -> None:
        self.down.add(node)

    def recover_node(self, node: str) -> None:
        self.down.discard(node)

    def reachable(self, a: str, b: str) -> bool:
        if a in self.down or b in self.down:
            return False
        if a == b:
            return True
        if self.partition_groups is None:
            return True
        for g in self.partition_groups:
            if a in g and b in g:
                return True
        return False

    # -- messaging -------------------------------------------------------------
    def send(self, src: str, dst: str, payload: Any) -> bool:
        """Queue a message; returns False if it is dropped immediately."""
        if not self.reachable(src, dst):
            self.dropped += 1
            return False
        if self.drop_rate and self.rng.random() < self.drop_rate:
            self.dropped += 1
            return False
        latency = self.base_latency + self.rng.random() * self.jitter
        self.queue.append(Message(src, dst, payload, self.now + latency))
        self.bytes_sent += payload_nbytes(payload)
        return True

    def deliver(self, handler: Callable[[Message], None],
                until: Optional[float] = None,
                max_messages: Optional[int] = None) -> int:
        """Deliver queued messages in timestamp order (stable, deterministic).

        Messages to currently-unreachable destinations stay queued (they
        will flow once the partition heals — this models TCP retry /
        hinted handoff).
        """
        count = 0
        while True:
            ready = [m for m in self.queue
                     if (until is None or m.deliver_at <= until)
                     and self.reachable(m.src, m.dst)]
            if not ready or (max_messages is not None and count >= max_messages):
                break
            ready.sort(key=lambda m: (m.deliver_at, m.src, m.dst))
            msg = ready[0]
            self.queue.remove(msg)
            self.now = max(self.now, msg.deliver_at)
            handler(msg)
            count += 1
            self.delivered += 1
        return count

    def pending(self) -> int:
        return len(self.queue)

    def advance(self, dt: float) -> None:
        self.now += dt
