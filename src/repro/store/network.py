"""Deterministic simulated transport: partitions, node failures, async delivery.

The container is a single process, so "the network" is a seeded discrete
queue.  Two properties matter for reproducing the paper (and for the
fault-tolerance story of the framework):

* **Reachability** — partitions and down nodes make quorum operations fail
  or proceed degraded, which is how replica divergence arises.
* **Asynchronous replication** — coordinator→replica store messages are
  *queued*, and drivers/tests decide when (whether) they are delivered.
  Interleaving control is what exposes the causality bugs of the §3
  baselines.

Beyond symmetric partitions and crashed nodes, the fabric carries a
*fault-injection matrix* (DESIGN.md §13): directed link cuts
(``cut_link`` — A can talk to B while B cannot answer), slow-not-dead
nodes (``set_delay_factor`` — per-node latency multipliers applied
*after* the main RNG draw, so the no-fault trace is byte-identical),
seeded message duplication and reordering (``set_duplication`` /
``set_reorder`` — drawn from a dedicated ``fault_rng`` stream so
enabling them never perturbs base latency draws), and flapping links
(``flap_link`` — timer-chained up/down toggles).  These are exactly the
conditions under which accrual failure detection earns its keep, and
the conformance suite asserts packed==object under every mode.

The fabric also carries *timers* (``schedule``/``cancel``): callbacks keyed
to simulated time, fired in deterministic ``(fire_at, seq)`` order by
``advance``.  They are what lets the gossip driver (store/gossip.py) run
anti-entropy continuously off SimNetwork time instead of being hand-cranked
— simulated-clock scheduling, GentleRain-style, rather than wall time.
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Set, Tuple


class Unavailable(Exception):
    """Raised when a quorum cannot be assembled (CAP: we choose AP, but a
    *strict* quorum request against a partitioned minority still fails)."""


def payload_nbytes(obj: Any) -> int:
    """Wire-size estimate of a message payload.

    Objects that know their encoding (``PackedPayload``, digest snapshots,
    ``CausalContext`` via ``to_bytes``) report it; containers recurse;
    everything else is priced at its repr — the sim-transport's
    serialization stand-in.  Keeps ``SimNetwork.bytes_sent`` honest now
    that replication messages carry encoded array payloads.
    """
    nbytes = getattr(obj, "nbytes", None)
    if callable(nbytes):
        return int(nbytes())
    to_bytes = getattr(obj, "to_bytes", None)
    if callable(to_bytes) and not isinstance(obj, int):
        try:
            return len(to_bytes())
        except TypeError:       # int.to_bytes-style signatures
            pass
    if isinstance(obj, (bytes, bytearray, str)):
        return len(obj)
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, (tuple, list, set, frozenset)):
        return sum(payload_nbytes(x) for x in obj)
    if isinstance(obj, dict):
        return sum(payload_nbytes(k) + payload_nbytes(v)
                   for k, v in obj.items())
    return len(repr(obj).encode())


@dataclass
class Message:
    src: str
    dst: str
    payload: Any
    deliver_at: float


class SimNetwork:
    """Seeded, deterministic message fabric between named nodes."""

    def __init__(self, seed: int = 0, base_latency: float = 1.0,
                 jitter: float = 0.5, drop_rate: float = 0.0):
        self.rng = random.Random(seed)
        self.now = 0.0
        self.base_latency = base_latency
        self.jitter = jitter
        self.drop_rate = drop_rate
        self.queue: List[Message] = []
        self.partition_groups: Optional[List[Set[str]]] = None
        self.down: Set[str] = set()
        self.delivered = 0
        self.dropped = 0
        self.bytes_sent = 0
        # fault-injection matrix (DESIGN.md §13).  All state defaults off;
        # the dup/reorder draws come from a dedicated RNG stream so that
        # enabling a fault mode never shifts the main ``rng`` latency
        # sequence (trace determinism for everything else is preserved).
        self.link_cuts: Set[Tuple[str, str]] = set()      # directed (src, dst)
        self.delay_factors: Dict[str, float] = {}         # node -> multiplier
        self.dup_rate = 0.0
        self.reorder_rate = 0.0
        self.reorder_spread = 0.0
        self.fault_rng = random.Random(f"{seed}:faults")
        self.duplicated = 0
        self.reordered = 0
        self._flaps: Dict[int, Tuple[str, str]] = {}      # flap id -> link
        self._flap_seq = 0
        # datacenter topology (geo tier).  All three maps default empty, in
        # which case ``_link_params`` returns the flat (base_latency, jitter)
        # pair and ``send`` is byte-identical to the untagged fabric — same
        # arithmetic, same single RNG draw per successful send.
        self.datacenters: Dict[str, str] = {}
        self._lan_latency: Optional[Tuple[float, float]] = None
        self._wan_latency: Optional[Tuple[float, float]] = None
        self._link_overrides: Dict[Tuple[str, str], Tuple[float, float]] = {}
        self.wan_messages = 0
        self.wan_bytes = 0
        # timers: (fire_at, seq, callback) min-heap; cancellation is lazy
        # (cancelled ids are skipped when popped) so cancel is O(1)
        self._timers: List[Tuple[float, int, Callable[[], None]]] = []
        self._timer_seq = 0
        self._cancelled: Set[int] = set()
        self.timers_fired = 0
        # synchronous observers of reachability changes (partition/heal/
        # fail/recover/forget) — how the gossip driver snaps backed-off
        # cadences the moment the topology shifts, the way a real
        # membership layer reacts to connection events
        self.topology_listeners: List[Callable[[], None]] = []

    # -- topology control ----------------------------------------------------
    def _topology_changed(self) -> None:
        for listener in list(self.topology_listeners):
            listener()

    def partition(self, *groups: Set[str]) -> None:
        """Split the cluster into isolated groups (None heals)."""
        self.partition_groups = [set(g) for g in groups]
        self._topology_changed()

    def heal(self) -> None:
        """Full heal: clears partitions *and* directed link cuts (active
        flaps will re-cut their link on the next down phase; use
        ``stop_flaps`` first for a durable heal)."""
        self.partition_groups = None
        self.link_cuts.clear()
        self._topology_changed()

    def cut_link(self, src: str, dst: str) -> None:
        """Cut one *directed* link: ``src`` can no longer reach ``dst``
        while ``dst -> src`` stays up — the asymmetric failure mode a
        symmetric ``partition`` cannot express (a node whose outbound
        NIC died still hears everyone)."""
        self.link_cuts.add((src, dst))
        self._topology_changed()

    def heal_link(self, src: str, dst: str) -> None:
        if (src, dst) in self.link_cuts:
            self.link_cuts.discard((src, dst))
            self._topology_changed()

    def flap_link(self, a: str, b: str, *, up_for: float, down_for: float,
                  start_down: bool = True) -> int:
        """Start a flapping link: ``a <-> b`` (both directions) toggles
        down for ``down_for`` then up for ``up_for`` simulated seconds on
        the timer heap, forever, until ``stop_flap``.  Returns a flap id.
        Flapping is the adversarial input for membership: every toggle
        fires topology listeners, so naive cadence-snapping gossip pays
        full price per flap while suspicion-driven backoff does not."""
        if up_for <= 0 or down_for <= 0:
            raise ValueError("flap phases must be positive")
        self._flap_seq += 1
        fid = self._flap_seq
        self._flaps[fid] = (a, b)

        def phase(down: bool) -> None:
            if fid not in self._flaps:      # stopped: orphan timer, no-op
                return
            if down:
                self.link_cuts.add((a, b))
                self.link_cuts.add((b, a))
            else:
                self.link_cuts.discard((a, b))
                self.link_cuts.discard((b, a))
            self._topology_changed()
            self.schedule(down_for if down else up_for,
                          lambda: phase(not down))

        phase(start_down)
        return fid

    def stop_flap(self, flap_id: int) -> None:
        """Stop one flap and heal its link (the orphaned phase timer
        becomes a no-op)."""
        link = self._flaps.pop(flap_id, None)
        if link is not None:
            a, b = link
            self.link_cuts.discard((a, b))
            self.link_cuts.discard((b, a))
            self._topology_changed()

    def stop_flaps(self) -> None:
        for fid in list(self._flaps):
            self.stop_flap(fid)

    def set_delay_factor(self, node: str, factor: float) -> None:
        """Make ``node`` slow-not-dead: every message it sends or receives
        takes ``factor``× the drawn latency.  Applied *after* the main RNG
        draw, so a factor of 1.0 (the default) leaves traces
        byte-identical.  Slow nodes stay reachable — they strain quorum
        tails and failure detection without tripping ``reachable``."""
        if factor < 0:
            raise ValueError("delay factor must be non-negative")
        if factor == 1.0:
            self.delay_factors.pop(node, None)
        else:
            self.delay_factors[node] = float(factor)

    def set_duplication(self, rate: float) -> None:
        """Duplicate each queued send with probability ``rate`` (a second
        copy with its own fault-stream latency).  Duplicates are real
        traffic: they count toward ``bytes_sent`` (and WAN accounting),
        and the store must absorb them — DVV sync is a join, so
        re-applying a payload is a no-op (idempotence tested in the fault
        suite)."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError("duplication rate must be in [0, 1]")
        self.dup_rate = float(rate)

    def set_reorder(self, rate: float, spread: float = 25.0) -> None:
        """With probability ``rate``, add up to ``spread`` extra seconds of
        fault-stream latency to a send — enough to overtake later sends
        and invert delivery order (delivery remains timestamp-sorted; the
        *timestamps* are scrambled)."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError("reorder rate must be in [0, 1]")
        if spread < 0:
            raise ValueError("reorder spread must be non-negative")
        self.reorder_rate = float(rate)
        self.reorder_spread = float(spread)

    def fail_node(self, node: str) -> None:
        self.down.add(node)
        self._topology_changed()

    def recover_node(self, node: str) -> None:
        self.down.discard(node)
        self._topology_changed()

    def forget(self, node: str) -> int:
        """Remove a *departed* node from the fabric: purge queued messages
        addressed TO it (no destination exists — they would retry forever)
        and drop it from the down set.  Messages it already *sent* stay
        queued — their destinations are alive, and dropping them would
        destroy acknowledged writes in flight — so the node also stays in
        any partition group as a ghost entry: stripping it would make
        those kept sends unreachable (``reachable`` finds the absent src
        in no group) until a heal.  Ghost entries are harmless for live
        pairs and vanish with the next ``partition``/``heal``.
        Returns the number of purged messages."""
        before = len(self.queue)
        self.queue = [m for m in self.queue if m.dst != node]
        self.down.discard(node)
        self._topology_changed()
        return before - len(self.queue)

    # -- datacenter topology (geo tier) --------------------------------------
    def set_datacenter(self, node: str, dc: str) -> None:
        """Tag ``node`` as living in datacenter ``dc``."""
        self.datacenters[node] = dc

    def dc_of(self, node: str) -> Optional[str]:
        return self.datacenters.get(node)

    def set_latency_classes(self, lan: Tuple[float, float],
                            wan: Tuple[float, float]) -> None:
        """Give intra-DC and cross-DC links distinct ``(base, jitter)``
        latency classes.  Links whose endpoints lack DC tags keep the flat
        default; per-link overrides beat both classes."""
        self._lan_latency = (float(lan[0]), float(lan[1]))
        self._wan_latency = (float(wan[0]), float(wan[1]))

    def set_link_latency(self, src: str, dst: str, base: float,
                         jitter: float) -> None:
        """Override one *directed* link's latency parameters (the most
        specific tier: override > DC class > flat default)."""
        self._link_overrides[(src, dst)] = (float(base), float(jitter))

    def clear_link_latency(self, src: str, dst: str) -> None:
        self._link_overrides.pop((src, dst), None)

    def _link_params(self, src: str, dst: str) -> Tuple[float, float]:
        """Resolve ``(base, jitter)`` for one directed link.  With no
        overrides, classes, or DC tags this returns the constructor pair —
        ``send`` then computes the exact expression the flat fabric always
        used, preserving byte-identical traces for untagged clusters."""
        ov = self._link_overrides.get((src, dst))
        if ov is not None:
            return ov
        if self._lan_latency is not None or self._wan_latency is not None:
            sdc = self.datacenters.get(src)
            ddc = self.datacenters.get(dst)
            if sdc is not None and ddc is not None:
                if sdc == ddc:
                    if self._lan_latency is not None:
                        return self._lan_latency
                elif self._wan_latency is not None:
                    return self._wan_latency
        return self.base_latency, self.jitter

    def is_wan(self, src: str, dst: str) -> bool:
        """True iff both endpoints are DC-tagged and the tags differ."""
        sdc = self.datacenters.get(src)
        ddc = self.datacenters.get(dst)
        return sdc is not None and ddc is not None and sdc != ddc

    def reachable(self, a: str, b: str) -> bool:
        """Can ``a`` currently get a message *to* ``b``?  Directional:
        a cut ``(a, b)`` link blocks this way while ``(b, a)`` may flow."""
        if a in self.down or b in self.down:
            return False
        if a == b:
            return True
        if (a, b) in self.link_cuts:
            return False
        if self.partition_groups is None:
            return True
        for g in self.partition_groups:
            if a in g and b in g:
                return True
        return False

    # -- messaging -------------------------------------------------------------
    def send(self, src: str, dst: str, payload: Any) -> bool:
        """Queue a message; returns False if it is dropped immediately."""
        if not self.reachable(src, dst):
            self.dropped += 1
            return False
        if self.drop_rate and self.rng.random() < self.drop_rate:
            self.dropped += 1
            return False
        base, jit = self._link_params(src, dst)
        latency = base + self.rng.random() * jit
        # fault matrix: delay factors scale the drawn latency (slow-not-
        # dead nodes); reorder adds fault-stream latency so this send can
        # be overtaken by later ones.  Both are applied after the main RNG
        # draw — with faults off, the arithmetic and the RNG stream are
        # exactly the pre-fault fabric's.
        if self.delay_factors:
            latency *= (self.delay_factors.get(src, 1.0)
                        * self.delay_factors.get(dst, 1.0))
        if self.reorder_rate and self.fault_rng.random() < self.reorder_rate:
            latency += self.fault_rng.random() * self.reorder_spread
            self.reordered += 1
        self.queue.append(Message(src, dst, payload, self.now + latency))
        nbytes = payload_nbytes(payload)
        self.bytes_sent += nbytes
        wan = self.is_wan(src, dst)
        if wan:
            self.wan_messages += 1
            self.wan_bytes += nbytes
        if self.dup_rate and self.fault_rng.random() < self.dup_rate:
            dup_latency = base + self.fault_rng.random() * jit
            if self.delay_factors:
                dup_latency *= (self.delay_factors.get(src, 1.0)
                                * self.delay_factors.get(dst, 1.0))
            self.queue.append(
                Message(src, dst, payload, self.now + dup_latency))
            self.duplicated += 1
            self.bytes_sent += nbytes       # duplicates cost real wire
            if wan:
                self.wan_messages += 1
                self.wan_bytes += nbytes
        return True

    def deliver(self, handler: Callable[[Message], None],
                until: Optional[float] = None,
                max_messages: Optional[int] = None) -> int:
        """Deliver queued messages in timestamp order (stable, deterministic).

        Messages to currently-unreachable destinations stay queued (they
        will flow once the partition heals — this models TCP retry /
        hinted handoff).
        """
        count = 0
        while True:
            ready = [m for m in self.queue
                     if (until is None or m.deliver_at <= until)
                     and self.reachable(m.src, m.dst)]
            if not ready or (max_messages is not None and count >= max_messages):
                break
            ready.sort(key=lambda m: (m.deliver_at, m.src, m.dst))
            msg = ready[0]
            self.queue.remove(msg)
            self.now = max(self.now, msg.deliver_at)
            handler(msg)
            count += 1
            self.delivered += 1
        return count

    def pending(self) -> int:
        return len(self.queue)

    def queued_for(self, node: str) -> int:
        """Messages queued toward ``node`` — the churn suite's leak probe:
        after a control-loop eviction this must be zero (``forget`` purges
        sends to a destination that no longer exists)."""
        return sum(1 for m in self.queue if m.dst == node)

    # -- timers (simulated-clock scheduling) -----------------------------------
    def schedule(self, delay: float, callback: Callable[[], None]) -> int:
        """Arm ``callback`` to fire ``delay`` simulated seconds from now.
        Returns a timer id for ``cancel``.  Callbacks run inside ``advance``
        and may schedule further timers (the re-arming gossip pattern)."""
        self._timer_seq += 1
        heapq.heappush(self._timers,
                       (self.now + max(0.0, delay), self._timer_seq, callback))
        return self._timer_seq

    def schedule_at(self, fire_at: float, callback: Callable[[], None]) -> int:
        """Arm ``callback`` at *absolute* simulated time ``fire_at`` (past
        times fire on the next ``advance``).  The op-scheduler flush hook:
        deadlines are points on the shared clock, not relative delays."""
        return self.schedule(fire_at - self.now, callback)

    def cancel(self, timer_id: int) -> None:
        self._cancelled.add(timer_id)

    def next_timer_due(self) -> Optional[float]:
        """Earliest live timer deadline, or ``None`` — how an event loop
        steps straight to the next interesting instant instead of polling
        fixed increments.  Lazily prunes cancelled heap heads."""
        while self._timers and self._timers[0][1] in self._cancelled:
            _, seq, _ = heapq.heappop(self._timers)
            self._cancelled.discard(seq)
        return self._timers[0][0] if self._timers else None

    def timers_pending(self) -> int:
        return sum(1 for (_, seq, _) in self._timers
                   if seq not in self._cancelled)

    def advance(self, dt: float) -> None:
        """Move simulated time forward, firing due timers in deterministic
        ``(fire_at, seq)`` order.  ``now`` tracks each timer as it fires, so
        a callback observing ``now`` sees its own fire time."""
        target = self.now + dt
        while self._timers and self._timers[0][0] <= target:
            fire_at, seq, callback = heapq.heappop(self._timers)
            if seq in self._cancelled:
                self._cancelled.discard(seq)
                continue
            self.now = max(self.now, fire_at)
            self.timers_fired += 1
            callback()
        self.now = target

    def run_until(self, t: float) -> None:
        """Advance to absolute simulated time ``t`` (no-op if in the past)."""
        if t > self.now:
            self.advance(t - self.now)
