"""The replicated key-value store (paper §4.1): proxy → coordinator → quorum.

GET:  proxy fans out to a read quorum of the key's replica nodes, merges the
      replies (on the packed backend: one array sweep, zero object-clock
      decodes) and returns (values, opaque ``CausalContext`` token).
PUT:  forwarded to a coordinator that is a replica node for the key; the
      coordinator mints the clock with ``update`` from the token's §5.4
      ceiling, syncs locally, then replicates the resulting version set
      asynchronously (via SimNetwork) to the remaining replicas; a write
      quorum is awaited synchronously.  ``put_many`` batches same-
      coordinator writes through one vectorized store update and one
      replication payload per destination.

Failures, partitions and delayed replication all flow through ``SimNetwork``
so tests and the training runtime can inject them deterministically.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, Iterable, List, Mapping, Optional, \
    Sequence, Tuple

import numpy as np

from ..core.kernel import Mechanism
from .bulk import DeltaSyncStats, RangeBudget, \
    delta_antientropy as _delta_antientropy
from .context import CausalContext
from .network import SimNetwork, Unavailable
from .packed import MergedRead, NO_DOT, PackedPayload, quorum_merge_key, \
    quorum_merge_many, remap_rows
from .replica import ReplicaNode
from .sharding import DEFAULT_PLACEMENT_SLICES, DEFAULT_VNODES, HashRing, \
    key_hash64, moved_shards, owned_shards, shard_of_key
from .version import HybridClock, Version, clocks_of, sync_versions
from .wal import DurableLog, LocalFS, ReplayStats

#: Default per-push range budget when gossip fanout sampling is active
#: (`delta_antientropy_round(fanout=...)`); caps a single round's payload
#: so steady-state gossip cost is bounded per tick.
DELTA_RANGE_BUDGET = 64


@dataclass(frozen=True)
class GetResult:
    values: Tuple[Any, ...]
    context: CausalContext            # opaque causal token (paper §5.4)
    siblings: int                     # number of concurrent versions returned
    # Per-value resolution keys (wall_time, clock, value), aligned with
    # ``values`` — the documented total order behind ``value``.
    resolution: Tuple[Tuple[float, str, str], ...] = ()

    @property
    def value(self) -> Any:
        """Deterministic resolved register: the sibling that is maximal in
        the (wall_time, clock, value) total order — latest coordinator
        wall-time wins; clock repr, then value repr, break exact ties.
        Purely a client-side convenience: no causal information is lost
        (all siblings stay in ``values``/``context``)."""
        if not self.values:
            return None
        if len(self.resolution) == len(self.values):
            best = max(range(len(self.values)),
                       key=self.resolution.__getitem__)
            return self.values[best]
        return self.values[-1]


@dataclass(frozen=True)
class PutAck:
    clock: Any
    coordinator: str
    replicated_to: Tuple[str, ...]


def _merged_result(values: Sequence[Any], walls: Sequence[float],
                   ckeys: Sequence[str],
                   entries: Tuple[Tuple[str, int], ...],
                   hlc: float = 0.0) -> GetResult:
    """``GetResult`` from merged packed survivor rows.  Each value's repr
    is computed once and shared by the sort key and the resolution tuple
    (it used to be computed twice per sibling on the hot read path).
    ``hlc`` is the geo tier's read watermark carried on the token (0.0 —
    the non-geo case — keeps the token byte-identical)."""
    reprs = [repr(v) for v in values]
    order = sorted(range(len(values)),
                   key=lambda i: (reprs[i], walls[i], ckeys[i]))
    return GetResult(
        values=tuple(values[i] for i in order),
        context=CausalContext(entries=entries, hlc=hlc),
        siblings=len(values),
        resolution=tuple((walls[i], ckeys[i], reprs[i]) for i in order))


def _object_result(acc: FrozenSet[Version], hlc: float = 0.0) -> GetResult:
    """``GetResult`` from an object-backend merged version set (same
    repr-once discipline and ``hlc`` watermark as the packed twin)."""
    keyed = [(v, repr(v.clock), repr(v.value)) for v in acc]
    keyed.sort(key=lambda t: (t[2], t[0].wall, t[1]))
    ctx = CausalContext.from_clocks(clocks_of(acc))
    if hlc:
        ctx = CausalContext(entries=ctx.entries, residue=ctx.residue,
                            hlc=hlc)
    return GetResult(
        values=tuple(t[0].value for t in keyed),
        context=ctx,
        siblings=len(acc),
        resolution=tuple((t[0].wall, t[1], t[2]) for t in keyed))


def _repair_payload(items: Sequence[Tuple[str, MergedRead]]) -> PackedPayload:
    """One consolidated read-repair push for one destination: the merged
    surviving rows of every key the member is stale on, re-encoded as a
    single ``PackedPayload`` — the same wire shape ``antientropy_payload``
    slices produce, so receivers apply it through the ordinary
    ``("store", payload)`` path and ``SimNetwork.bytes_sent`` prices it
    like any other anti-entropy transfer."""
    ids: List[str] = []
    index: Dict[str, int] = {}
    for _, m in items:
        for rid in m.replica_ids:
            if rid not in index:
                index[rid] = len(ids)
                ids.append(rid)
    Ru = len(ids)
    M = sum(len(m.values) for _, m in items)
    vv = np.zeros((M, Ru), np.int32)
    did = np.full(M, NO_DOT, np.int32)
    dn = np.zeros(M, np.int32)
    wall = np.zeros(M, np.float64)
    kix = np.zeros(M, np.int32)
    values: List[Any] = []
    off = 0
    for out_ix, (_, m) in enumerate(items):
        n = len(m.values)
        cols = np.asarray([index[r] for r in m.replica_ids], np.int64)
        vv[off: off + n], did[off: off + n] = \
            remap_rows(m.vv, m.dot_id, cols, Ru)
        dn[off: off + n] = m.dot_n
        wall[off: off + n] = m.walls
        kix[off: off + n] = out_ix
        values.extend(m.values)
        off += n
    return PackedPayload(
        replica_ids=tuple(ids), keys=tuple(k for k, _ in items),
        vv=vv, dot_id=did, dot_n=dn, key_ix=kix,
        values=tuple(values), wall=wall)


class KVCluster:
    """A set of replica nodes + the client-facing get/put protocol."""

    def __init__(self, node_ids: Sequence[str], mechanism: Mechanism, *,
                 replication: Optional[int] = None,
                 read_quorum: int = 1, write_quorum: int = 1,
                 network: Optional[SimNetwork] = None, seed: int = 0,
                 packed: Optional[bool] = None,
                 delta_range_budget: int = DELTA_RANGE_BUDGET,
                 shards: int = 1, vnodes: int = DEFAULT_VNODES,
                 datacenters: Optional[Mapping[str, Sequence[str]]] = None,
                 wan_period: float = 25.0,
                 wal_dir: Optional[str] = None,
                 wal_snapshot_every: int = 64,
                 wal_seal_bytes: int = 1 << 15,
                 wal_fs: Optional[Mapping[str, LocalFS]] = None):
        if not node_ids:
            raise ValueError("need at least one node")
        if shards < 1 or shards & (shards - 1):
            raise ValueError(
                f"shards must be a power of two >= 1, got {shards}")
        self.mechanism = mechanism
        # packed=None: array-resident clocks for DVV, objects otherwise
        # (ReplicaNode decides); packed=False forces the object backend —
        # the conformance reference for the packed store.  Remembered so
        # nodes added later (``add_node``) get the same backend.
        self._packed = packed
        self.shards = shards
        # Placement granularity: with sharded stores, placement shard ==
        # store shard (rebalance is then exact at shard granularity); with
        # shards=1 keys still place through the ring at a fixed number of
        # hash-range slices, keeping the table O(1)-bounded either way.
        self._slices = shards if shards > 1 else DEFAULT_PLACEMENT_SLICES
        # hot-path constant: slice of a key = top bits of its 64-bit hash
        self._slice_shift = 64 - (self._slices.bit_length() - 1)
        self.nodes: Dict[str, ReplicaNode] = {
            n: ReplicaNode(n, mechanism, packed=packed, shards=shards)
            for n in node_ids}
        self.read_quorum = read_quorum
        self.write_quorum = write_quorum
        self.network = network or SimNetwork(seed=seed)
        self.clock_time = 0.0
        self.delta_range_budget = delta_range_budget
        self.seed = seed
        # Per-node hybrid logical clocks mint every ``Version.wall`` (the
        # geo tier's skew robustness; in a non-anomalous run the minted
        # values equal the raw shared clock, so single-DC behaviour is
        # unchanged down to the byte).
        self.hlc: Dict[str, HybridClock] = {n: HybridClock()
                                            for n in node_ids}
        # Geo tier (DESIGN.md §12): ``datacenters`` maps DC name → its
        # equal-sized node list.  The ring is then built over the FIRST
        # DC's nodes and placement rows are mirror-expanded, writes scope
        # their quorums to the coordinator's DC and ship cross-DC
        # asynchronously, and the snapshot read plane comes alive.
        self.geo = None
        if datacenters is not None:
            from .geo import GeoPlane
            self.geo = GeoPlane(self, datacenters, wan_period=wan_period)
        # replication counts nodes per DC in geo mode (mirror rows multiply
        # it by the DC count), defaulting to a full local DC.
        self.replication = replication or (
            len(node_ids) if self.geo is None else self.geo.dc_size)
        ring_ids = node_ids if self.geo is None \
            else self.geo.canonical_nodes
        self._ring = HashRing(ring_ids, vnodes=vnodes)
        self._rebuild_placement()
        # Seeded round-robin gossip schedule (delta_antientropy_round /
        # gossip_tick): each node's start offset is a pure function of
        # (seed, node id) — membership changes never reshuffle the schedule
        # of surviving nodes, so churn cannot break seed determinism.
        self._gossip_step = 0
        self._node_gossip_step: Dict[str, int] = {}
        self._gossip_base_cache: Dict[str, int] = {}
        # Plane-invocation meters: each fixed-cost entry into a read or
        # write plane (grouping, union-universe gather, jit-cache lookup,
        # per-destination payload assembly) counts once, however many keys
        # ride it.  The coalescing scheduler's whole thesis is driving
        # this number per-op toward zero; the serving benchmark reads it.
        self.plane_reads = 0
        self.plane_writes = 0
        # Self-driving membership (DESIGN.md §13): a MembershipController
        # registers itself here at construction.  When present, its
        # suspicion levels deprioritize suspect replicas in quorum
        # assembly/coordinator choice and steer the gossip driver; when
        # None (the default) every path below is byte-identical to the
        # hand-managed cluster.
        self.membership = None
        # Durability tier (DESIGN.md §14): with ``wal_dir`` set, every node
        # appends post-state records to per-shard segment logs under
        # ``wal_dir/<node>/shard-NN/`` and can come back warm via
        # ``restart_node``.  ``wal_dir=None`` (the default) leaves every
        # hook unset — byte-identical to the in-memory cluster.
        # ``incarnation`` counts process lifetimes per node id (bumped on
        # join and on every restart) so listeners like the gossip driver
        # can tell a restarted process from a surviving one.
        self.wal_dir = wal_dir
        self.wal: Dict[str, DurableLog] = {}
        self._wal_cfg = dict(snapshot_every=wal_snapshot_every,
                             seal_bytes=wal_seal_bytes)
        self._wal_fs = wal_fs or {}
        #: ReplayStats of the most recent ``restart_node`` (bench surface).
        self.last_replay: Optional[ReplayStats] = None
        self._epoch = 0
        self.incarnation: Dict[str, int] = {n: 1 for n in node_ids}
        if wal_dir is not None:
            if self.geo is not None:
                raise ValueError("durable logs are not supported on a geo "
                                 "cluster (membership there is static)")
            for n in node_ids:
                self._wal_attach(n)
            self._bump_epoch()

    # -- durability (DESIGN.md §14) -------------------------------------------
    def _wal_attach(self, node_id: str, *, reset: bool = False) -> None:
        log = self.wal.get(node_id)
        if log is None:
            log = self.wal[node_id] = DurableLog(
                self.wal_dir, node_id, fs=self._wal_fs.get(node_id),
                **self._wal_cfg)
        if reset:
            log.reset()
        log.attach(self.nodes[node_id])

    def _bump_epoch(self) -> None:
        """Stamp a new membership epoch into every attached node's log."""
        self._epoch += 1
        members = tuple(sorted(self.nodes))
        for node_id, log in self.wal.items():
            if log.node is not None:
                log.log_epoch(self._epoch, members)

    def restart_node(self, node_id: str, *,
                     use_kernel: bool = False) -> List[DeltaSyncStats]:
        """Warm restart from the durable log (the §14 recovery protocol).

        The crashed process's replica object is discarded and a fresh one
        is rebuilt from disk: reopen the shard manifests, truncate any
        torn tail (checksum-gated), replay snapshot + tail into packed
        columns / object sets (digest trees rebuild incrementally as the
        replay applies), then run exactly ONE digest-diffed delta pass per
        reachable peer — a pull (what the cluster wrote while this node
        was down) and a push (what this node coordinated or received but
        never finished replicating; the log keeps such writes alive even
        when the crash preempted their replication sends).  Both
        directions are O(divergence), not the O(store) ``bootstrap_node``
        path.  A node evicted by the MembershipController rejoins the
        ring here without a fresh-join bootstrap (warm readmit).
        """
        if self.geo is not None:
            raise ValueError("restart_node requires a non-geo cluster")
        log = self.wal.get(node_id)
        if log is None:
            raise ValueError(
                f"node {node_id!r} has no durable log (wal_dir unset)")
        if node_id in self.nodes:
            # In-place process bounce: same ring tokens and placement, new
            # replica object (the old process's memory is gone).
            self.nodes[node_id] = ReplicaNode(
                node_id, self.mechanism, packed=self._packed,
                shards=self.shards)
            self.hlc[node_id] = HybridClock()
            self.incarnation[node_id] = \
                self.incarnation.get(node_id, 0) + 1
        else:
            # Post-eviction readmit: rejoin ring + placement, no bootstrap.
            self._admit_node(node_id)
        self.last_replay = log.restore_into(self.nodes[node_id])
        if node_id in self.network.down:
            self.network.recover_node(node_id)
        else:
            self.network._topology_changed()
        self._bump_epoch()
        only = self._sync_shards(node_id)
        stats: List[DeltaSyncStats] = []
        for peer in list(self.nodes):
            if peer == node_id or \
                    not self.network.reachable(peer, node_id):
                continue
            # Sync only shards BOTH sides own: a peer outside shard s's
            # replica set holds nothing to pull, and pushing to it would
            # ship this node's whole shard into a store that doesn't own
            # it — O(store) wire for zero durability.
            pair = only
            if only is not None:
                peer_owned = self._owned.get(peer)
                if peer_owned is not None:
                    pair = only & peer_owned
                if not pair:
                    continue
            stats.append(self.delta_antientropy(
                peer, node_id, use_kernel=use_kernel, only_shards=pair))
            stats.append(self.delta_antientropy(
                node_id, peer, use_kernel=use_kernel, only_shards=pair))
        return stats

    # -- membership (dynamic: nodes join and leave at runtime) ----------------
    def _admit_node(self, node_id: str) -> None:
        """Shared join mechanics: replica + clock + ring + placement +
        topology event (no bootstrap, no durable-log reset)."""
        self.nodes[node_id] = ReplicaNode(node_id, self.mechanism,
                                          packed=self._packed,
                                          shards=self.shards)
        self.hlc[node_id] = HybridClock()
        self.incarnation[node_id] = self.incarnation.get(node_id, 0) + 1
        self._ring.add(node_id)
        self._rebuild_placement()
        # a join is a topology change too: listeners (the gossip driver)
        # adopt the newcomer immediately instead of on their next fire
        self.network._topology_changed()

    def add_node(self, node_id: str, *, bootstrap: bool = True,
                 bootstrap_ranges: Optional[int] = None,
                 use_kernel: bool = False) -> List[DeltaSyncStats]:
        """Join ``node_id`` to the cluster.

        The newcomer's vnode tokens land on the ring and the placement
        table is rebuilt — only the ~1/N of shards whose ring walk now
        meets a new token change replica sets — and, unless
        ``bootstrap=False``, the new node catches up *warm* via ranked
        digest-diffed pulls from every reachable peer (``bootstrap_node``;
        on a sharded cluster the pulls cover only the shards the newcomer
        now owns), so it serves reads with full causal state instead of
        empty version sets.  ``replication`` is a cluster parameter and
        does not change on join.
        """
        if self.geo is not None:
            raise ValueError("membership changes are not supported on a "
                             "geo cluster (mirror placement is static)")
        if node_id in self.nodes:
            raise ValueError(f"node {node_id!r} already in cluster")
        self._admit_node(node_id)
        if self.wal_dir is not None:
            # A *fresh* join wipes any log a previous incarnation of this
            # id left behind (its pre-departure state must not resurrect);
            # warm rejoins go through ``restart_node`` instead.
            self._wal_attach(node_id, reset=True)
            self._bump_epoch()
        if bootstrap:
            return self.bootstrap_node(node_id, max_ranges=bootstrap_ranges,
                                       use_kernel=use_kernel)
        return []

    def remove_node(self, node_id: str, *, handoff: bool = True,
                    handoff_ranges: Optional[int] = None
                    ) -> List[DeltaSyncStats]:
        """Depart ``node_id``: drop its replica, rehash placement, purge
        messages addressed to it from the fabric.

        A *planned* departure first hands the node's state off — one final
        delta push to every reachable survivor — so writes for which it
        held the only copy (e.g. quorum-1 writes acked during a partition)
        survive the decommission.  On a sharded cluster the handoff is
        placement-aware: only shards whose replica set changed travel, and
        each survivor receives just the moved shards it now owns — bytes
        moved scale with the departing node's ~K/N share, not the store.
        ``handoff=False`` models a crash-style removal; an unreachable/
        down node naturally hands off nothing.  Surviving nodes' gossip
        schedules are untouched (offsets are per-node functions of the
        seed), so removal never reshuffles peer sampling determinism."""
        if self.geo is not None:
            raise ValueError("membership changes are not supported on a "
                             "geo cluster (mirror placement is static)")
        if node_id not in self.nodes:
            raise KeyError(f"node {node_id!r} not in cluster")
        if len(self.nodes) == 1:
            raise ValueError("cannot remove the last node")
        stats: List[DeltaSyncStats] = []
        before = self._placement
        self._ring.remove(node_id)
        self._rebuild_placement()
        if handoff:
            moved = frozenset(moved_shards(before, self._placement)) \
                if self.shards > 1 else None
            for peer in list(self.nodes):
                if peer == node_id or \
                        not self.network.reachable(node_id, peer):
                    continue
                only: Optional[frozenset] = None
                if moved is not None:
                    only = moved & self._owned.get(peer, frozenset())
                    if not only:
                        continue
                stats.append(self.delta_antientropy(
                    node_id, peer, max_ranges=handoff_ranges,
                    only_shards=only))
        del self.nodes[node_id]
        self._owned.pop(node_id, None)
        self._node_gossip_step.pop(node_id, None)
        self.network.forget(node_id)
        if (log := self.wal.get(node_id)) is not None:
            # Keep the DurableLog object (and its files): a later
            # ``restart_node`` readmits warm from it; a later fresh
            # ``add_node`` wipes it.
            log.detach()
        if self.wal_dir is not None:
            self._bump_epoch()
        return stats

    def bootstrap_node(self, node_id: str, *,
                       max_ranges: Optional[int] = None,
                       use_kernel: bool = False,
                       max_passes: int = 64) -> List[DeltaSyncStats]:
        """Warm catch-up for a (typically fresh) node: repeated ranked
        digest-diffed delta pulls from every reachable peer, biggest ranges
        first (``payload(key_ranges=...)`` does the slicing), until a full
        pass over the peers changes nothing at the newcomer.  Progress is
        measured by ``changed`` (the newcomer's sets growing toward the
        union), which is finite — so the loop terminates even when peers
        stay mutually divergent among themselves.  ``max_ranges`` bounds
        one pull so a joining node can rate-limit its catch-up; uncapped,
        two passes suffice (the second proves quiescence).  On a sharded
        cluster the pulls are restricted to the shards ``node_id`` owns
        under the current placement — the rebalance plane moves the
        joiner's ~K/N share, not every peer's whole store."""
        only = self._sync_shards(node_id)
        stats: List[DeltaSyncStats] = []
        for _ in range(max_passes):
            progress = False
            for peer in list(self.nodes):
                if peer == node_id or \
                        not self.network.reachable(peer, node_id):
                    continue
                st = self.delta_antientropy(peer, node_id,
                                            use_kernel=use_kernel,
                                            max_ranges=max_ranges,
                                            only_shards=only)
                stats.append(st)
                if st.changed:
                    progress = True
            if not progress:
                break
        return stats

    # -- placement (consistent-hash ring) -------------------------------------
    def _rebuild_placement(self) -> None:
        """Recompute the O(slices) placement table from the ring — the only
        placement state there is (bounded by the slice count, never by the
        key universe; per-key lookup is then one hash + one index).  Geo
        mode expands each canonical (first-DC) row to its mirror rows:
        slot i of every DC owns slot i of the first DC's key ranges, so
        every DC holds a full copy and WAN delta rounds between mirror
        pairs are digest-comparable."""
        table = self._ring.placement_table(self._slices, self.replication)
        if self.geo is not None:
            table = [tuple(m for n in row for m in self.geo.mirrors(n))
                     for row in table]
        self._placement = table
        self._owned: Dict[str, frozenset] = (
            {n: owned_shards(self._placement, n) for n in self.nodes}
            if self.shards > 1 else {})

    def _sync_shards(self, node_id: str) -> Optional[frozenset]:
        """The shard filter for rebalance transfers involving ``node_id``:
        the shards it owns, or ``None`` (no filtering) when stores are
        unsharded or replication spans every node (everyone owns every
        shard, so filtering would be a no-op)."""
        if self.shards <= 1 or self.replication >= len(self.nodes):
            return None
        return self._owned.get(node_id)

    def replicas_for(self, key: str) -> Sequence[str]:
        """The key's replica set: one stable 64-bit hash (blake2b-8), one
        table index — O(1) per key, over a table the membership-change
        path rebuilds in O(slices · log V).  Returns the table's own
        (immutable) tuple — the hot path allocates nothing."""
        return self._placement[key_hash64(key) >> self._slice_shift]

    def _reachable_replicas(self, via: str, key: str) -> List[str]:
        reachable = [r for r in self.replicas_for(key)
                     if self.network.reachable(via, r)]
        # Local read preference: if the proxy is itself a replica, contact it
        # first (how Riak/Dynamo coordinators behave).  With a membership
        # controller attached, suspect replicas sort last — a quorum that
        # can be filled from non-suspect members never waits on a node the
        # failure detector already distrusts (the sort is stable, so the
        # non-suspect order is unchanged).
        mem = self.membership
        if mem is None:
            reachable.sort(key=lambda r: (r != via,))
        else:
            now = self.network.now
            reachable.sort(
                key=lambda r: (r != via, mem.is_suspect(r, now)))
        return reachable

    def _pick_coordinator(self, proxy: str, key: str,
                          coordinator: Optional[str] = None) -> str:
        """A reachable replica node to coordinate a PUT (paper step 2)."""
        if coordinator is not None:
            if not self.network.reachable(proxy, coordinator):
                raise Unavailable(f"coordinator {coordinator} unreachable")
            return coordinator
        candidates = [r for r in self.replicas_for(key)
                      if self.network.reachable(proxy, r)]
        if not candidates:
            raise Unavailable(f"no reachable coordinator for {key!r}")
        # Prefer coordinating at the proxy itself when it is a replica
        # (local coordination preserves read-your-writes via one node);
        # geo mode then prefers the proxy's own DC — commit latency stays
        # LAN-local, the geo tier's write-path promise.
        if self.geo is not None:
            pdc = self.geo.dc_of.get(proxy)
            candidates.sort(
                key=lambda r: (r != proxy, self.geo.dc_of[r] != pdc))
        elif self.membership is not None:
            # never coordinate a write at a suspect if a trusted replica
            # is available: a coordinator about to be evicted is the
            # sole-copy-write risk the controller exists to retire
            now = self.network.now
            candidates.sort(
                key=lambda r: (r != proxy,
                               self.membership.is_suspect(r, now)))
        else:
            candidates.sort(key=lambda r: (r != proxy,))
        return candidates[0]

    # -- admission probes (non-raising; the op-scheduler's per-op triage) -----
    def probe_read(self, key: str, *, via: str, quorum: int) -> bool:
        """Would a GET for ``key`` via ``via`` assemble its read quorum
        right now?  Pure reachability arithmetic — no store touched, no
        exception raised — so a scheduler can fail one op without
        poisoning its whole flush."""
        if via in self.network.down:
            return False
        return len(self._reachable_replicas(via, key)) >= quorum

    def probe_write(self, key: str, *, via: str) -> Tuple[Optional[str], int]:
        """``(coordinator, predicted_acks)`` for a PUT of ``key`` via
        ``via`` — coordinator ``None`` when none is reachable.  Predicted
        acks = coordinator + destinations currently reachable from it;
        exact when ``drop_rate == 0`` (the conformance regime), an upper
        bound otherwise."""
        if via in self.network.down:
            return None, 0
        try:
            coord = self._pick_coordinator(via, key)
        except Unavailable:
            return None, 0
        acks = 1 + sum(1 for r in self.replicas_for(key)
                       if r != coord and self.network.reachable(coord, r))
        return coord, acks

    @property
    def plane_invocations(self) -> int:
        return self.plane_reads + self.plane_writes

    # -- wall minting (hybrid logical clocks) ---------------------------------
    def _mint_wall(self, coordinator: str, ctx: CausalContext,
                   wall_time: Optional[float]) -> float:
        """Mint a write's wall at the coordinator's hybrid clock.

        In a non-anomalous run ``mint(clock_time)`` returns exactly
        ``clock_time`` (the shared clock strictly increases, so the
        physical branch always wins) — pre-geo behaviour to the byte; a
        stalled or backwards-stepping clock falls into the logical
        tiebreak and walls stay strictly increasing per coordinator.  Geo
        mode first folds in the token's read watermark and the
        coordinator's own wall-column max, making causal order imply wall
        order (what snapshot consistency rests on).  An explicit
        ``wall_time`` bypasses minting (a test hook; geo snapshot
        guarantees assume coordinator-minted walls)."""
        h = self.hlc[coordinator]
        if wall_time is not None:
            if self.geo is not None:
                h.observe(wall_time)
            return wall_time
        if self.geo is not None:
            if ctx.hlc:
                h.observe(ctx.hlc)
            h.observe(self.nodes[coordinator].max_wall)
        return h.mint(self.clock_time)

    def _read_watermark(self, walls: Iterable[float]) -> float:
        """HLC watermark a read stamps on its context token (geo only —
        non-geo tokens stay byte-identical to pre-geo ones): the max wall
        among returned versions, so a dependent write minted anywhere
        lands strictly above everything this read saw."""
        if self.geo is None:
            return 0.0
        return max((float(w) for w in walls), default=0.0)

    # -- client operations -------------------------------------------------------
    def _object_read(self, key: str, chosen: Sequence[ReplicaNode]
                     ) -> FrozenSet[Version]:
        """Object-backend quorum merge for one key (the generic path)."""
        acc: FrozenSet[Version] = frozenset()
        for node in chosen:
            acc = sync_versions(
                acc, node.versions(key),
                total_order=not self.mechanism.tracks_concurrency)
        return acc

    def get(self, key: str, *, via: Optional[str] = None,
            quorum: Optional[int] = None) -> GetResult:
        proxy = via or next(iter(self.nodes))
        if proxy in self.network.down:
            raise Unavailable(f"proxy {proxy} is down")
        quorum = quorum or self.read_quorum
        reachable = self._reachable_replicas(proxy, key)
        if len(reachable) < quorum:
            raise Unavailable(
                f"read quorum {quorum} unreachable for {key!r} via {proxy}")
        chosen = [self.nodes[r] for r in reachable[:max(quorum, 1)]]
        self.plane_reads += 1
        if all(n.is_packed for n in chosen):
            # Array-native read path: quorum merge + §5.4 ceiling token
            # straight from the int32 columns (the key's shard store) —
            # zero object-clock decodes.
            values, walls, ckeys, entries = quorum_merge_key(
                [n.store_for(key) for n in chosen], key)
            return _merged_result(values, walls, ckeys, entries,
                                  hlc=self._read_watermark(walls))
        acc = self._object_read(key, chosen)
        return _object_result(
            acc, hlc=self._read_watermark(v.wall for v in acc))

    def get_many(self, keys: Sequence[str], *, via: Optional[str] = None,
                 quorum: Optional[int] = None, repair: bool = False,
                 use_kernel: bool = False) -> Dict[str, GetResult]:
        """Multi-key GET through one proxy — the batched read plane.

        Admission mirrors ``put_many``: proxy reachability and the read
        quorum are resolved for *every* key up front, and ``Unavailable``
        is raised before any store is touched — a failing key never
        discards already-merged results.  Keys whose whole quorum is
        packed then run as grouped quorum merges (``quorum_merge_many``):
        one union-universe remap per quorum set, one stacked ``[N, K, R]``
        survival sweep (``use_kernel=True`` routes it through the fused
        §6.4 shape-bucketed read sweep, survival + ceilings in one
        device pass), one grouped §5.4 ceiling reduce.  Mixed/object
        quorums fall back to the per-key merge.

        ``repair=True`` closes the Dynamo read-repair loop: any quorum
        member whose live rows for a key differ from the merged survivors
        receives ONE consolidated ``("store", payload)`` push covering all
        of its stale keys (sent from the proxy, priced by
        ``SimNetwork.bytes_sent`` like any anti-entropy transfer; a stale
        *proxy* applies its payload locally instead of mailing itself),
        so hot keys converge on the read path instead of waiting for
        gossip.  A converged quorum generates zero repair traffic.
        """
        proxy = via or next(iter(self.nodes))
        if proxy in self.network.down:
            raise Unavailable(f"proxy {proxy} is down")
        quorum = quorum or self.read_quorum
        # -- admission: resolve every key's quorum before touching stores.
        # ONE atomic pass across all shards; keys sharing a placement slice
        # share one reachability resolution (same replica set, same fabric
        # state within the call).
        chosen: Dict[str, List[str]] = {}
        short: List[str] = []
        slice_reach: Dict[int, List[str]] = {}
        for key in keys:
            sl = shard_of_key(key, self._slices)
            reachable = slice_reach.get(sl)
            if reachable is None:
                reachable = slice_reach[sl] = \
                    self._reachable_replicas(proxy, key)
            if len(reachable) < quorum:
                short.append(key)
            else:
                chosen[key] = reachable[: max(quorum, 1)]
        if short:
            raise Unavailable(
                f"read quorum {quorum} unreachable for {len(short)}/"
                f"{len(chosen) + len(short)} keys via {proxy} "
                f"(e.g. {short[:3]})")
        results: Dict[str, GetResult] = {}
        packed_repairs: Dict[str, List[Tuple[str, MergedRead]]] = {}
        object_repairs: Dict[str, Dict[str, FrozenSet[Version]]] = {}
        packed_keys = [k for k, ids in chosen.items()
                       if all(self.nodes[r].is_packed for r in ids)]
        # one plane entry for the whole packed batch; each mixed/object
        # key below falls back to its own per-key merge (counted there)
        if packed_keys:
            self.plane_reads += 1
            sweep_fn = None
            if use_kernel:
                from ..kernels.dvv_ops import dvv_read_sweep_bucketed
                sweep_fn = dvv_read_sweep_bucketed
            # Stores are per-(node, shard): quorum_merge_many's grouping by
            # store-identity tuple therefore fans the sweep out per
            # (shard, quorum-group) — each group one stacked tensor.
            merged = quorum_merge_many(
                {k: [self.nodes[r].store_for(k) for r in chosen[k]]
                 for k in packed_keys},
                packed_keys, sweep_fn=sweep_fn, track_stale=repair)
            for k, m in merged.items():
                results[k] = _merged_result(m.values, m.walls, m.clock_keys,
                                            m.entries,
                                            hlc=self._read_watermark(m.walls))
                if repair:
                    for j in m.stale:
                        packed_repairs.setdefault(
                            chosen[k][j], []).append((k, m))
        for k, ids in chosen.items():
            if k in results:
                continue
            self.plane_reads += 1
            acc = self._object_read(k, [self.nodes[r] for r in ids])
            results[k] = _object_result(
                acc, hlc=self._read_watermark(v.wall for v in acc))
            if repair:
                for r in ids:
                    if self.nodes[r].versions(k) != acc:
                        object_repairs.setdefault(r, {})[k] = acc
        if repair:
            # A stale proxy repairs itself locally (it IS this process —
            # no self-addressed wire message, no phantom bytes_sent); every
            # other stale member gets its one consolidated push.
            for dst, items in packed_repairs.items():
                payload = _repair_payload(items)
                if dst == proxy:
                    self.nodes[dst].receive_antientropy(payload)
                else:
                    self.network.send(proxy, dst, ("store", payload))
            for dst, payload in object_repairs.items():
                if dst == proxy:
                    self.nodes[dst].receive_antientropy(payload)
                else:
                    self.network.send(proxy, dst, ("store", payload))
        return {k: results[k] for k in chosen}

    # -- causal snapshot reads (geo tier, DESIGN.md §12) --------------------
    def probe_snapshot(self, keys: Sequence[str],
                       *, via: Optional[str] = None) -> Optional[str]:
        """Admission probe for a snapshot batch: the failure reason a
        ``snapshot_get_many`` with these keys would raise, or ``None`` if
        it would be served.  The scheduler uses this to admit/defer
        snapshot ops without tripping exceptions."""
        if self.geo is None:
            return "snapshot reads require a geo cluster (datacenters=...)"
        proxy = via or next(iter(self.nodes))
        for key in keys:
            reason = self.geo.check_snapshot(proxy, key)
            if reason is not None:
                return reason
        return None

    def snapshot_get(self, key: str, *, via: Optional[str] = None
                     ) -> GetResult:
        """Causally consistent, possibly stale read served entirely from
        the proxy's datacenter — zero WAN round trips (single-key form of
        ``snapshot_get_many``)."""
        return self.snapshot_get_many([key], via=via)[key]

    def snapshot_get_many(self, keys: Sequence[str],
                          *, via: Optional[str] = None
                          ) -> Dict[str, GetResult]:
        """Batched causal snapshot read at the proxy's DC (DESIGN.md §12).

        The batch is served at ONE Global Stable Frontier F — the wall
        below which every version is provably held by at least one local
        member (the min-fold over member HLCs, queued replication
        messages, WAN-shipping backlogs and dropped-send backlogs).  Per
        key, the *union* of all local replicas' live versions and their
        retained stable shadows is filtered to wall ≤ F and sibling-merged
        — so two keys written causally (read k1 → put k2) can never appear
        inverted: the later write's wall is strictly larger, and any
        version ≤ F is guaranteed present locally.  No WAN message is sent
        or awaited; results may lag remote commits by the frontier lag.
        Admission is atomic (any key failing the local-coverage check
        raises before any merge), mirroring ``get_many``.
        """
        if self.geo is None:
            raise RuntimeError(
                "snapshot reads require a geo cluster (datacenters=...)")
        proxy = via or next(iter(self.nodes))
        failures = []
        for key in keys:
            reason = self.geo.check_snapshot(proxy, key)
            if reason is not None:
                failures.append((key, reason))
        if failures:
            raise Unavailable(
                f"snapshot unavailable for {len(failures)}/{len(keys)} "
                f"keys via {proxy} (e.g. {failures[:2]})")
        self.plane_reads += 1
        dc = self.geo.dc_of[proxy]
        frontier = self.geo.stable_frontier(dc)
        out: Dict[str, GetResult] = {}
        for key in keys:
            acc = self.geo.snapshot_versions(dc, key, frontier)
            out[key] = _object_result(
                acc, hlc=max((v.wall for v in acc), default=0.0))
        return out

    def put(self, key: str, value: Any, context: Any = None,
            *, via: Optional[str] = None, client_id: str = "?",
            client_counter: int = 0, wall_time: Optional[float] = None,
            coordinator: Optional[str] = None,
            quorum: Optional[int] = None) -> PutAck:
        proxy = via or next(iter(self.nodes))
        if proxy in self.network.down:
            raise Unavailable(f"proxy {proxy} is down")
        quorum = quorum or self.write_quorum
        self.clock_time += 1.0

        ctx = CausalContext.coerce(context)
        coordinator = self._pick_coordinator(proxy, key, coordinator)
        wall = self._mint_wall(coordinator, ctx, wall_time)
        self.plane_writes += 1
        node = self.nodes[coordinator]
        version = node.coordinate_update(
            key, value, ctx, client_id=client_id,
            client_counter=client_counter, wall_time=wall)

        # replicate S_C' to the other replicas (paper step 4): async
        # messages carrying the wire payload (packed: int32 arrays, no
        # object clocks on the control plane either).  Geo mode scopes this
        # synchronous fan-out (and the write quorum) to the coordinator's
        # own datacenter; mirrors in other DCs get the payload later via
        # the WAN shipper's digest-diffed delta rounds.
        geo = self.geo
        cdc = geo.dc_of[coordinator] if geo is not None else None
        payload = node.antientropy_payload([key])
        acked = [coordinator]
        for r in self.replicas_for(key):
            if r == coordinator:
                continue
            if geo is not None and geo.dc_of[r] != cdc:
                continue
            sent = self.network.send(coordinator, r, ("store", payload))
            if sent:
                acked.append(r)
            elif geo is not None:
                geo.note_send_failed(coordinator, r, wall)
        if geo is not None:
            geo.on_commit(cdc, (wall,))
        if len(acked) < quorum:
            # The write is still durable at the coordinator (always-writable
            # store) but the caller asked for more replicas than reachable.
            raise Unavailable(
                f"write quorum {quorum} > reachable replicas {len(acked)}")
        return PutAck(clock=version.clock, coordinator=coordinator,
                      replicated_to=tuple(acked))

    def put_many(self, items: Mapping[str, Tuple[Any, Any]], *,
                 via: Optional[str] = None, client_id: str = "?",
                 client_counter: int = 0, quorum: Optional[int] = None,
                 use_kernel: bool = False) -> Dict[str, PutAck]:
        """Batched multi-key PUT: ``{key: (value, context)}`` → per-key acks.

        Keys are grouped by coordinator; each same-coordinator group runs
        as ONE vectorized store update (one grouped encode → one
        ``sync_mask`` sweep → one scatter) and ONE replication payload per
        destination replica, instead of K independent ``sync_key`` walks
        and K·(R−1) messages.  Admission is atomic: if any key has no
        reachable coordinator, nothing is written.  Writes are always
        durable at their coordinators; if any key then misses its write
        quorum, ``Unavailable`` is raised after the batch is applied
        (mirroring the single-key contract).
        """
        proxy = via or next(iter(self.nodes))
        if proxy in self.network.down:
            raise Unavailable(f"proxy {proxy} is down")
        quorum = quorum or self.write_quorum

        groups: Dict[str, List[str]] = {}
        ctxs: Dict[str, CausalContext] = {}
        walls: Dict[str, float] = {}
        coord_of: Dict[str, str] = {}
        slice_coord: Dict[int, str] = {}
        for key, (value, context) in items.items():
            ctxs[key] = CausalContext.coerce(context)
            # one admission resolution per placement slice (atomic across
            # shards: any key without a reachable coordinator raises here,
            # before any store is touched)
            sl = shard_of_key(key, self._slices)
            coord = slice_coord.get(sl)
            if coord is None:
                coord = slice_coord[sl] = self._pick_coordinator(proxy, key)
            coord_of[key] = coord
            groups.setdefault(coord, []).append(key)
        minted: Dict[str, Version] = {}
        acked: Dict[str, List[str]] = {}
        mask_fn = None
        if use_kernel:
            from ..kernels.dvv_ops import dvv_sync_mask_bucketed
            mask_fn = dvv_sync_mask_bucketed
        geo = self.geo
        for key in items:
            self.clock_time += 1.0
            walls[key] = self._mint_wall(coord_of[key], ctxs[key], None)
        for coord, keys in groups.items():
            self.plane_writes += 1
            cdc = geo.dc_of[coord] if geo is not None else None
            node = self.nodes[coord]
            batch = [(k, ctxs[k], items[k][0], walls[k]) for k in keys]
            versions = node.coordinate_updates(
                batch, client_id=client_id, client_counter=client_counter,
                mask_fn=mask_fn)
            for k, v in zip(keys, versions):
                minted[k] = v
                acked[k] = [coord]
            # One replication payload per destination: all of this
            # coordinator's keys that destination replicates.  Geo mode
            # fans out local-DC only (mirrors ride the WAN shipper).
            dst_keys: Dict[str, List[str]] = {}
            for k in keys:
                for r in self.replicas_for(k):
                    if r == coord:
                        continue
                    if geo is not None and geo.dc_of[r] != cdc:
                        continue
                    dst_keys.setdefault(r, []).append(k)
            # Destinations replicating the same key set share one payload
            # object (receivers never mutate payloads; single-key put
            # already relies on this).
            payload_cache: Dict[Tuple[str, ...], Any] = {}
            for dst, ks in dst_keys.items():
                sig = tuple(ks)
                payload = payload_cache.get(sig)
                if payload is None:
                    payload = payload_cache[sig] = \
                        node.antientropy_payload(ks)
                if self.network.send(coord, dst, ("store", payload)):
                    for k in ks:
                        acked[k].append(dst)
                elif geo is not None:
                    for k in ks:
                        geo.note_send_failed(coord, dst, walls[k])
            if geo is not None:
                geo.on_commit(cdc, tuple(walls[k] for k in keys))
        failed = [k for k in items if len(acked[k]) < quorum]
        if failed:
            raise Unavailable(
                f"write quorum {quorum} unreachable for "
                f"{len(failed)}/{len(items)} keys (e.g. {failed[:3]})")
        return {k: PutAck(clock=minted[k].clock, coordinator=coord_of[k],
                          replicated_to=tuple(acked[k]))
                for k in items}

    # -- background machinery ------------------------------------------------------
    def deliver_replication(self, max_messages: Optional[int] = None,
                            until: Optional[float] = None) -> int:
        """Flush queued coordinator→replica store messages (``until`` limits
        delivery to messages due by that simulated time — the gossip
        driver's per-tick drain)."""
        def handler(msg):
            kind, payload = msg.payload
            assert kind == "store"
            self.nodes[msg.dst].receive_antientropy(payload)
            if self.geo is not None:
                self.geo.note_receive(msg.dst, msg.payload)
        return self.network.deliver(handler, until=until,
                                    max_messages=max_messages)

    def antientropy(self, src: str, dst: str,
                    keys: Optional[Sequence[str]] = None) -> None:
        """Replica `src` pushes state to `dst` (paper §4.1 Anti-entropy)."""
        if not self.network.reachable(src, dst):
            raise Unavailable(f"{src} -> {dst} unreachable")
        payload = self.nodes[src].antientropy_payload(keys)
        self.nodes[dst].receive_antientropy(payload)
        if self.geo is not None:
            self.geo.note_delta_round(src, dst)

    def antientropy_round(self) -> None:
        """One full push round between all reachable pairs."""
        ids = list(self.nodes)
        for a in ids:
            for b in ids:
                if a != b and self.network.reachable(a, b):
                    self.antientropy(a, b)

    def delta_antientropy(self, src: str, dst: str, *,
                          use_kernel: bool = False,
                          max_ranges: RangeBudget = None,
                          only_shards: Optional[Iterable[int]] = None
                          ) -> DeltaSyncStats:
        """Two-phase delta round (paper §4.1 anti-entropy, DESIGN.md §6):
        digest exchange, then only the divergent key ranges travel.  On a
        sharded cluster the round runs per shard (root-probe fast path for
        converged shards; ``max_ranges`` may map shard → budget);
        ``only_shards`` restricts it — the rebalance plane."""
        if not self.network.reachable(src, dst):
            raise Unavailable(f"{src} -> {dst} unreachable")
        stats = _delta_antientropy(self.nodes[src], self.nodes[dst],
                                   use_kernel=use_kernel,
                                   max_ranges=max_ranges,
                                   only_shards=only_shards)
        if self.geo is not None:
            self.geo.note_delta_round(src, dst)
        return stats

    def _gossip_base(self, node: str) -> int:
        """A node's gossip start offset: a pure function of (seed, node id),
        stable under membership churn — joins and leaves never reshuffle
        the rotation of surviving nodes."""
        base = self._gossip_base_cache.get(node)
        if base is None:
            base = self._gossip_base_cache[node] = random.Random(
                f"{self.seed}:{node}").randrange(1 << 30)
        return base

    def gossip_peers(self, node: str, k: int, step: int) -> List[str]:
        """The ``k`` peers ``node`` pushes to at rotation ``step``, sampled
        from *current* membership — departed nodes drop out of the rotation
        naturally (they are simply absent), reachability is checked by the
        caller.  Repeated steps cycle every node through all live peers.
        In geo mode gossip stays LAN-scoped — a node rotates only through
        its own datacenter; cross-DC convergence is the WAN shipper's job
        (digest-diffed delta rounds per link, not N² WAN chatter)."""
        if self.geo is not None and node in self.geo.dc_of:
            ids = list(self.geo.dcs[self.geo.dc_of[node]])
        else:
            ids = list(self.nodes)
        n = len(ids)
        if node not in self.nodes or n < 2:
            return []
        i = ids.index(node)
        peers = ids[i + 1:] + ids[:i]              # all others, rotated
        k = max(1, min(k, n - 1))
        off = (self._gossip_base(node) + step * k) % (n - 1)
        return [peers[(off + j) % (n - 1)] for j in range(k)]

    def gossip_tick(self, node: str, *, step: Optional[int] = None,
                    fanout: int = 1, max_ranges: RangeBudget = None,
                    use_kernel: bool = False,
                    exclude: FrozenSet[str] = frozenset()
                    ) -> List[Tuple[str, DeltaSyncStats]]:
        """One node's bounded gossip pushes — the unit the continuous
        ``GossipDriver`` fires per timer (its adaptation needs to know
        which peer each round hit, hence ``(peer, stats)`` pairs).
        ``step`` defaults to a per-node counter so hand-cranked ticks
        still cycle all peers; ``max_ranges`` defaults to
        ``delta_range_budget``.  Unreachable sampled peers are skipped
        (the tick is best-effort), as are peers in ``exclude`` — the
        driver's suspicion backoff: suspects leave the regular rotation
        (skipping never perturbs the seeded schedule itself) and get a
        dedicated probe round instead."""
        if node not in self.nodes:
            return []
        if step is None:
            step = self._node_gossip_step.get(node, 0)
            self._node_gossip_step[node] = step + 1
        if max_ranges is None:
            max_ranges = self.delta_range_budget
        out = []
        for b in self.gossip_peers(node, fanout, step):
            if b not in exclude and self.network.reachable(node, b):
                out.append((b, self.delta_antientropy(
                    node, b, use_kernel=use_kernel, max_ranges=max_ranges)))
        return out

    def delta_antientropy_round(self, *, use_kernel: bool = False,
                                max_ranges: Optional[int] = None,
                                fanout: Optional[int] = None
                                ) -> List[DeltaSyncStats]:
        """One seeded round-robin delta push round (gossip scheduling).

        Every node pushes to ``fanout`` peers chosen by a deterministic
        rotating schedule (seeded start offset + round counter), so
        repeated rounds cycle each node through *all* peers — probabilistic
        peer sampling without losing the coverage guarantee.  With
        ``fanout=None`` (default) each node pushes to every reachable peer,
        the all-pairs behaviour; with an explicit fanout, ``max_ranges``
        defaults to ``delta_range_budget`` so one gossip tick has bounded
        wire/compute cost.  Converged pairs cost one digest compare and
        move zero payload bytes either way.
        """
        ids = list(self.nodes)
        n = len(ids)
        if n < 2:
            return []
        k = n - 1 if fanout is None else max(1, min(fanout, n - 1))
        if fanout is not None and max_ranges is None:
            max_ranges = self.delta_range_budget
        step = self._gossip_step
        self._gossip_step += 1
        stats = []
        for a in ids:
            for b in self.gossip_peers(a, k, step):
                if self.network.reachable(a, b):
                    stats.append(self.delta_antientropy(
                        a, b, use_kernel=use_kernel, max_ranges=max_ranges))
        return stats

    # -- introspection ----------------------------------------------------------
    def siblings(self, key: str) -> Dict[str, int]:
        return {n: len(node.versions(key)) for n, node in self.nodes.items()}

    def metadata_size(self, key: str) -> Dict[str, int]:
        return {n: node.metadata_size(key) for n, node in self.nodes.items()}

    def all_values(self, key: str):
        out = set()
        for node in self.nodes.values():
            out |= {v.value for v in node.versions(key)}
        return frozenset(out)
