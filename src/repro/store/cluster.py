"""The replicated key-value store (paper §4.1): proxy → coordinator → quorum.

GET:  proxy fans out to a read quorum of the key's replica nodes, reduces the
      replies with ``sync`` and returns (values, opaque context).
PUT:  forwarded to a coordinator that is a replica node for the key; the
      coordinator mints the clock with ``update``, syncs locally, then
      replicates the resulting version set asynchronously (via SimNetwork)
      to the remaining replicas; a write quorum is awaited synchronously.

Failures, partitions and delayed replication all flow through ``SimNetwork``
so tests and the training runtime can inject them deterministically.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

from ..core.kernel import Mechanism
from .bulk import DeltaSyncStats, delta_antientropy as _delta_antientropy
from .network import SimNetwork, Unavailable
from .replica import ReplicaNode
from .version import Version, clocks_of, sync_versions, values_of


@dataclass(frozen=True)
class GetResult:
    values: Tuple[Any, ...]
    context: FrozenSet[Any]          # opaque clock set (paper §5.4)
    siblings: int                     # number of concurrent versions returned

    @property
    def value(self) -> Any:
        """Convenience for callers that expect a resolved register."""
        if not self.values:
            return None
        return self.values[-1]


@dataclass(frozen=True)
class PutAck:
    clock: Any
    coordinator: str
    replicated_to: Tuple[str, ...]


class KVCluster:
    """A set of replica nodes + the client-facing get/put protocol."""

    def __init__(self, node_ids: Sequence[str], mechanism: Mechanism, *,
                 replication: Optional[int] = None,
                 read_quorum: int = 1, write_quorum: int = 1,
                 network: Optional[SimNetwork] = None, seed: int = 0,
                 packed: Optional[bool] = None):
        if not node_ids:
            raise ValueError("need at least one node")
        self.mechanism = mechanism
        # packed=None: array-resident clocks for DVV, objects otherwise
        # (ReplicaNode decides); packed=False forces the object backend —
        # the conformance reference for the packed store.
        self.nodes: Dict[str, ReplicaNode] = {
            n: ReplicaNode(n, mechanism, packed=packed) for n in node_ids}
        self.replication = replication or len(node_ids)
        self.read_quorum = read_quorum
        self.write_quorum = write_quorum
        self.network = network or SimNetwork(seed=seed)
        self.clock_time = 0.0

    # -- placement (consistent-hash ring) -------------------------------------
    def replicas_for(self, key: str) -> List[str]:
        ring = sorted(
            self.nodes,
            key=lambda n: hashlib.md5(f"{n}:{key}".encode()).hexdigest())
        return ring[: self.replication]

    def _reachable_replicas(self, via: str, key: str) -> List[str]:
        reachable = [r for r in self.replicas_for(key)
                     if self.network.reachable(via, r)]
        # Local read preference: if the proxy is itself a replica, contact it
        # first (how Riak/Dynamo coordinators behave).
        reachable.sort(key=lambda r: (r != via,))
        return reachable

    # -- client operations -------------------------------------------------------
    def get(self, key: str, *, via: Optional[str] = None,
            quorum: Optional[int] = None) -> GetResult:
        proxy = via or next(iter(self.nodes))
        if proxy in self.network.down:
            raise Unavailable(f"proxy {proxy} is down")
        quorum = quorum or self.read_quorum
        reachable = self._reachable_replicas(proxy, key)
        if len(reachable) < quorum:
            raise Unavailable(
                f"read quorum {quorum} unreachable for {key!r} via {proxy}")
        acc: FrozenSet[Version] = frozenset()
        for r in reachable[:max(quorum, 1)]:
            acc = sync_versions(acc, self.nodes[r].versions(key),
                                total_order=not self.mechanism.tracks_concurrency)
        return GetResult(values=values_of(acc), context=clocks_of(acc),
                         siblings=len(acc))

    def put(self, key: str, value: Any, context: FrozenSet[Any] = frozenset(),
            *, via: Optional[str] = None, client_id: str = "?",
            client_counter: int = 0, wall_time: Optional[float] = None,
            coordinator: Optional[str] = None,
            quorum: Optional[int] = None) -> PutAck:
        proxy = via or next(iter(self.nodes))
        if proxy in self.network.down:
            raise Unavailable(f"proxy {proxy} is down")
        quorum = quorum or self.write_quorum
        self.clock_time += 1.0
        wall = self.clock_time if wall_time is None else wall_time

        replicas = self.replicas_for(key)
        # pick a coordinator that is a reachable replica node (paper step 2)
        if coordinator is None:
            candidates = [r for r in replicas if self.network.reachable(proxy, r)]
            if not candidates:
                raise Unavailable(f"no reachable coordinator for {key!r}")
            # Prefer coordinating at the proxy itself when it is a replica
            # (local coordination preserves read-your-writes via one node).
            candidates.sort(key=lambda r: (r != proxy,))
            coordinator = candidates[0]
        elif not self.network.reachable(proxy, coordinator):
            raise Unavailable(f"coordinator {coordinator} unreachable")

        node = self.nodes[coordinator]
        version = node.coordinate_update(
            key, value, context, client_id=client_id,
            client_counter=client_counter, wall_time=wall)
        s_c = node.versions(key)

        # replicate S_C' to the other replicas (paper step 4): async messages
        acked = [coordinator]
        for r in replicas:
            if r == coordinator:
                continue
            sent = self.network.send(coordinator, r, ("store", key, s_c))
            if sent:
                acked.append(r)
        if len(acked) < quorum:
            # The write is still durable at the coordinator (always-writable
            # store) but the caller asked for more replicas than reachable.
            raise Unavailable(
                f"write quorum {quorum} > reachable replicas {len(acked)}")
        return PutAck(clock=version.clock, coordinator=coordinator,
                      replicated_to=tuple(acked))

    # -- background machinery ------------------------------------------------------
    def deliver_replication(self, max_messages: Optional[int] = None) -> int:
        """Flush queued coordinator→replica store messages."""
        def handler(msg):
            kind, key, versions = msg.payload
            assert kind == "store"
            self.nodes[msg.dst].apply_sync(key, versions)
        return self.network.deliver(handler, max_messages=max_messages)

    def antientropy(self, src: str, dst: str,
                    keys: Optional[Sequence[str]] = None) -> None:
        """Replica `src` pushes state to `dst` (paper §4.1 Anti-entropy)."""
        if not self.network.reachable(src, dst):
            raise Unavailable(f"{src} -> {dst} unreachable")
        payload = self.nodes[src].antientropy_payload(keys)
        self.nodes[dst].receive_antientropy(payload)

    def antientropy_round(self) -> None:
        """One full push round between all reachable pairs."""
        ids = list(self.nodes)
        for a in ids:
            for b in ids:
                if a != b and self.network.reachable(a, b):
                    self.antientropy(a, b)

    def delta_antientropy(self, src: str, dst: str, *,
                          use_kernel: bool = False,
                          max_ranges: Optional[int] = None) -> DeltaSyncStats:
        """Two-phase delta round (paper §4.1 anti-entropy, DESIGN.md §6):
        digest exchange, then only the divergent key ranges travel."""
        if not self.network.reachable(src, dst):
            raise Unavailable(f"{src} -> {dst} unreachable")
        return _delta_antientropy(self.nodes[src], self.nodes[dst],
                                  use_kernel=use_kernel,
                                  max_ranges=max_ranges)

    def delta_antientropy_round(self, *, use_kernel: bool = False,
                                max_ranges: Optional[int] = None
                                ) -> List[DeltaSyncStats]:
        """One delta push round between all reachable pairs; converged pairs
        cost one digest compare and move zero payload bytes."""
        stats = []
        ids = list(self.nodes)
        for a in ids:
            for b in ids:
                if a != b and self.network.reachable(a, b):
                    stats.append(self.delta_antientropy(
                        a, b, use_kernel=use_kernel, max_ranges=max_ranges))
        return stats

    # -- introspection ----------------------------------------------------------
    def siblings(self, key: str) -> Dict[str, int]:
        return {n: len(node.versions(key)) for n, node in self.nodes.items()}

    def metadata_size(self, key: str) -> Dict[str, int]:
        return {n: node.metadata_size(key) for n, node in self.nodes.items()}

    def all_values(self, key: str) -> FrozenSet[Any]:
        out = set()
        for node in self.nodes.values():
            out |= {v.value for v in node.versions(key)}
        return frozenset(out)
