"""KVClient — the client session layer over ``KVCluster`` (paper §4.1).

The paper's client workflow is GET → (values, opaque context) → PUT with
that context.  ``KVClient`` packages the session state that workflow needs
— the client id, the monotone per-session counter (used by the §3
per-client version-vector baselines; DVV ignores it), and session defaults
(proxy node, quorums) — and adds the batched multi-key operations the
single-key API cannot express efficiently:

* ``get_many(keys)``     — one proxy round over many keys; packed quorums
  run as grouped one-sweep quorum merges (``quorum_merge_many``: one
  union-universe remap per quorum set, one stacked ``sync_mask`` sweep,
  one grouped §5.4 ceiling reduce), zero object-clock decodes.  With
  ``repair`` (per call, or ``read_repair=True`` as a session default)
  stale quorum members are healed by one consolidated read-repair push
  each — Dynamo-style convergence on the read path.
* ``put_many({k: (v, ctx)})`` — writes grouped by coordinator; each group
  executes as ONE vectorized store update (``PackedVersionStore.
  update_keys``: one grouped encode → one ``sync_mask`` sweep → one
  scatter) and ONE replication payload per destination replica, instead of
  K independent ``sync_key`` walks and K·(R−1) messages.

Contexts are opaque ``CausalContext`` tokens; ``KVClient`` never inspects
them, it only carries them — exactly the contract real Dynamo/Riak clients
have with their vector-clock blobs.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from .cluster import GetResult, KVCluster, PutAck


class KVClient:
    """A client session: owns the client counter and session defaults."""

    def __init__(self, cluster: KVCluster, client_id: str = "client", *,
                 via: Optional[str] = None,
                 read_quorum: Optional[int] = None,
                 write_quorum: Optional[int] = None,
                 use_kernel: bool = False,
                 read_repair: bool = False):
        self.cluster = cluster
        self.client_id = client_id
        self.via = via
        self.read_quorum = read_quorum
        self.write_quorum = write_quorum
        self.use_kernel = use_kernel
        self.read_repair = read_repair   # session default for get_many
        self.counter = 0                 # session-monotone update counter

    # -- single-key ---------------------------------------------------------

    def get(self, key: str, *, via: Optional[str] = None,
            quorum: Optional[int] = None) -> GetResult:
        return self.cluster.get(key, via=via or self.via,
                                quorum=quorum or self.read_quorum)

    def put(self, key: str, value: Any, context: Any = None, *,
            via: Optional[str] = None, quorum: Optional[int] = None,
            coordinator: Optional[str] = None) -> PutAck:
        """PUT with an opaque context token (or its ``bytes`` encoding).
        ``context=None`` starts a fresh causal thread (blind write)."""
        self.counter += 1
        return self.cluster.put(
            key, value, context, via=via or self.via,
            client_id=self.client_id, client_counter=self.counter,
            coordinator=coordinator, quorum=quorum or self.write_quorum)

    # -- batched ------------------------------------------------------------

    def get_many(self, keys: Sequence[str], *, via: Optional[str] = None,
                 quorum: Optional[int] = None,
                 repair: Optional[bool] = None) -> Dict[str, GetResult]:
        """Batched GET over the one-sweep read plane; ``repair`` overrides
        the session's ``read_repair`` default for this call."""
        return self.cluster.get_many(
            keys, via=via or self.via, quorum=quorum or self.read_quorum,
            repair=self.read_repair if repair is None else repair,
            use_kernel=self.use_kernel)

    def put_many(self, items: Mapping[str, Tuple[Any, Any]], *,
                 via: Optional[str] = None,
                 quorum: Optional[int] = None) -> Dict[str, PutAck]:
        """Batched PUT of ``{key: (value, context)}`` — distinct keys,
        coordinator-grouped vectorized execution (see module docstring)."""
        self.counter += len(items)
        return self.cluster.put_many(
            items, via=via or self.via, client_id=self.client_id,
            client_counter=self.counter,
            quorum=quorum or self.write_quorum,
            use_kernel=self.use_kernel)
