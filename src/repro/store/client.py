"""KVClient — the client session layer over ``KVCluster`` (paper §4.1).

The paper's client workflow is GET → (values, opaque context) → PUT with
that context.  ``KVClient`` packages the session state that workflow needs
— the client id, the monotone per-session counter (used by the §3
per-client version-vector baselines; DVV ignores it), and session defaults
(proxy node, quorums) — and adds the batched multi-key operations the
single-key API cannot express efficiently:

* ``get_many(keys)``     — one proxy round over many keys; packed quorums
  run as grouped one-sweep quorum merges (``quorum_merge_many``: one
  union-universe remap per quorum set, one stacked ``sync_mask`` sweep,
  one grouped §5.4 ceiling reduce), zero object-clock decodes.  With
  ``repair`` (per call, or ``read_repair=True`` as a session default)
  stale quorum members are healed by one consolidated read-repair push
  each — Dynamo-style convergence on the read path.
* ``put_many({k: (v, ctx)})`` — writes grouped by coordinator; each group
  executes as ONE vectorized store update (``PackedVersionStore.
  update_keys``: one grouped encode → one ``sync_mask`` sweep → one
  scatter) and ONE replication payload per destination replica, instead of
  K independent ``sync_key`` walks and K·(R−1) messages.

Contexts are opaque ``CausalContext`` tokens; ``KVClient`` never inspects
them, it only carries them — exactly the contract real Dynamo/Riak clients
have with their vector-clock blobs.  Because sessions shuttle the *same*
token bytes back and forth (GET → carry → PUT), the session memoizes the
``to_bytes``/``from_bytes`` round-trip: both directions are pure, so the
memo is always sound; it is cleared on any put through the session, which
bounds it to one causal round-trip's worth of tokens.

Two submission modes share all of this session state:

* **synchronous** — ``get``/``put``/``get_many``/``put_many`` call the
  cluster planes directly, one plane invocation per call.
* **scheduled** — with an ``OpScheduler`` attached (``scheduler=`` or
  ``attach_scheduler``), ``submit_get``/``submit_put`` enqueue the op and
  return a ``PendingOp`` future; many sessions' ops then ride ONE plane
  invocation per flush phase (store/serving.py), with per-session results
  split back out.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from .cluster import GetResult, KVCluster, PutAck
from .context import CausalContext

_BYTES = (bytes, bytearray, memoryview)


class KVClient:
    """A client session: owns the client counter and session defaults."""

    def __init__(self, cluster: KVCluster, client_id: str = "client", *,
                 via: Optional[str] = None,
                 read_quorum: Optional[int] = None,
                 write_quorum: Optional[int] = None,
                 use_kernel: bool = False,
                 read_repair: bool = False,
                 scheduler: Optional[Any] = None):
        self.cluster = cluster
        self.client_id = client_id
        self.via = via
        self.read_quorum = read_quorum
        self.write_quorum = write_quorum
        self.use_kernel = use_kernel
        self.read_repair = read_repair   # session default for get_many
        self.counter = 0                 # session-monotone update counter
        self.scheduler = scheduler       # OpScheduler for submit_* (or None)
        # token-codec memo (cleared on any put through this session)
        self._enc_cache: Dict[CausalContext, bytes] = {}
        self._dec_cache: Dict[bytes, CausalContext] = {}
        self.codec_hits = 0
        self.codec_misses = 0

    # -- token codec (memoized per causal round-trip) -----------------------

    def encode_context(self, context: CausalContext) -> bytes:
        """``context.to_bytes()`` through the session memo.  Encoding also
        primes the decode direction — the common GET→carry→PUT round-trip
        pays ``to_bytes`` once and ``from_bytes`` never."""
        data = self._enc_cache.get(context)
        if data is not None:
            self.codec_hits += 1
            return data
        self.codec_misses += 1
        data = context.to_bytes()
        self._enc_cache[context] = data
        self._dec_cache[data] = context
        return data

    def decode_context(self, data: Any) -> CausalContext:
        """``CausalContext.from_bytes`` through the session memo (only
        successful decodes are cached; malformed tokens still raise their
        clean ``ValueError`` every time)."""
        data = bytes(data)
        ctx = self._dec_cache.get(data)
        if ctx is not None:
            self.codec_hits += 1
            return ctx
        self.codec_misses += 1
        ctx = CausalContext.from_bytes(data)
        self._dec_cache[data] = ctx
        self._enc_cache[ctx] = data
        return ctx

    def codec_info(self) -> Dict[str, int]:
        return {"hits": self.codec_hits, "misses": self.codec_misses,
                "cached": len(self._dec_cache)}

    def _invalidate_codec(self) -> None:
        """Any put through the session starts a new causal round-trip:
        drop the memo (both directions are pure, so this is purely a
        bound on staleness-free memory, not a correctness need)."""
        self._enc_cache.clear()
        self._dec_cache.clear()

    def _thaw(self, context: Any) -> Any:
        """Route byte-encoded contexts through the decode memo; everything
        else passes through untouched (the cluster coerces)."""
        if isinstance(context, _BYTES):
            return self.decode_context(context)
        return context

    # -- single-key ---------------------------------------------------------

    def get(self, key: str, *, via: Optional[str] = None,
            quorum: Optional[int] = None) -> GetResult:
        return self.cluster.get(key, via=via or self.via,
                                quorum=quorum or self.read_quorum)

    def put(self, key: str, value: Any, context: Any = None, *,
            via: Optional[str] = None, quorum: Optional[int] = None,
            coordinator: Optional[str] = None) -> PutAck:
        """PUT with an opaque context token (or its ``bytes`` encoding).
        ``context=None`` starts a fresh causal thread (blind write)."""
        self.counter += 1
        context = self._thaw(context)
        self._invalidate_codec()
        return self.cluster.put(
            key, value, context, via=via or self.via,
            client_id=self.client_id, client_counter=self.counter,
            coordinator=coordinator, quorum=quorum or self.write_quorum)

    # -- causal snapshot reads (geo tier) ------------------------------------

    def snapshot_get(self, key: str, *, via: Optional[str] = None
                     ) -> GetResult:
        """Causally consistent, possibly stale read served from the proxy's
        datacenter with zero WAN round trips (geo clusters only).  The
        returned token carries the snapshot's HLC watermark, so a PUT with
        it mints a wall above everything the snapshot saw — session
        causality holds across the two read planes."""
        return self.cluster.snapshot_get(key, via=via or self.via)

    def snapshot_get_many(self, keys: Sequence[str],
                          *, via: Optional[str] = None
                          ) -> Dict[str, GetResult]:
        """Batched causal snapshot read — one Global Stable Frontier for
        the whole batch (see ``KVCluster.snapshot_get_many``)."""
        return self.cluster.snapshot_get_many(keys, via=via or self.via)

    # -- batched ------------------------------------------------------------

    def get_many(self, keys: Sequence[str], *, via: Optional[str] = None,
                 quorum: Optional[int] = None,
                 repair: Optional[bool] = None) -> Dict[str, GetResult]:
        """Batched GET over the one-sweep read plane; ``repair`` overrides
        the session's ``read_repair`` default for this call."""
        return self.cluster.get_many(
            keys, via=via or self.via, quorum=quorum or self.read_quorum,
            repair=self.read_repair if repair is None else repair,
            use_kernel=self.use_kernel)

    def put_many(self, items: Mapping[str, Tuple[Any, Any]], *,
                 via: Optional[str] = None,
                 quorum: Optional[int] = None) -> Dict[str, PutAck]:
        """Batched PUT of ``{key: (value, context)}`` — distinct keys,
        coordinator-grouped vectorized execution (see module docstring)."""
        self.counter += len(items)
        items = {k: (v, self._thaw(c)) for k, (v, c) in items.items()}
        self._invalidate_codec()
        return self.cluster.put_many(
            items, via=via or self.via, client_id=self.client_id,
            client_counter=self.counter,
            quorum=quorum or self.write_quorum,
            use_kernel=self.use_kernel)

    # -- scheduled (coalescing) submission ----------------------------------

    def attach_scheduler(self, scheduler: Any) -> "KVClient":
        """Bind this session to an ``OpScheduler`` (store/serving.py);
        returns ``self`` for chaining."""
        self.scheduler = scheduler
        return self

    def _require_scheduler(self) -> Any:
        if self.scheduler is None:
            raise RuntimeError(
                "session has no OpScheduler attached; pass scheduler= or "
                "call attach_scheduler() before submit_get/submit_put")
        return self.scheduler

    def submit_get(self, keys: Sequence[str], *,
                   quorum: Optional[int] = None,
                   repair: Optional[bool] = None):
        """Enqueue a GET on the session's scheduler → ``PendingOp`` whose
        result is the same ``{key: GetResult}`` dict ``get_many`` returns.
        The op executes at the next flush (size- or timer-triggered)."""
        return self._require_scheduler().submit_get(
            keys, quorum=quorum or self.read_quorum,
            repair=self.read_repair if repair is None else repair,
            client_id=self.client_id, session=self.client_id)

    def submit_snapshot_get(self, keys: Sequence[str]):
        """Enqueue a causal snapshot GET → ``PendingOp`` whose result is
        the same ``{key: GetResult}`` dict ``snapshot_get_many`` returns.
        All snapshot ops admitted into one flush share a single frontier
        resolution and one plane invocation."""
        return self._require_scheduler().submit_snapshot_get(
            keys, client_id=self.client_id, session=self.client_id)

    def submit_put(self, items: Mapping[str, Tuple[Any, Any]], *,
                   quorum: Optional[int] = None):
        """Enqueue a PUT batch → ``PendingOp`` whose result is the same
        ``{key: PutAck}`` dict ``put_many`` returns.  Counts against the
        session counter and invalidates the codec memo at *submission*
        (the put is part of this session's causal thread from that
        moment), exactly like the synchronous path."""
        sched = self._require_scheduler()
        self.counter += len(items)
        items = {k: (v, self._thaw(c)) for k, (v, c) in items.items()}
        self._invalidate_codec()
        return sched.submit_put(
            items, quorum=quorum or self.write_quorum,
            client_id=self.client_id, client_counter=self.counter,
            session=self.client_id)
