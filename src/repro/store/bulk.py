"""Bulk anti-entropy: the batched/Pallas DVV path for large key ranges.

With ``PackedVersionStore`` as the resident representation the steady-state
round is arrays end to end: the sender slices its slot arrays into a
``PackedPayload`` (zero decode), the receiver remaps replica columns with
one gather, groups rows per key with one stable sort, evaluates survival in
one ``sync_mask`` call — the jnp reference or the fused Pallas kernel
(``kernels.dvv_ops.dvv_sync_mask``, pairwise K×K dominance + survival in a
single ``pallas_call``) — and writes the surviving rows back.  No per-key
``DVV`` object is encoded or decoded anywhere on that path.

The object-level entry points (``bulk_sync`` on dicts of ``Version``s) are
kept for control-plane callers and for conformance testing against
``ReplicaNode``'s object backend; they pay the boundary codec once on the
way in and once on the way out.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Union

from .packed import PackedPayload, PackedVersionStore
from .replica import PackedBackend, ReplicaNode, _as_object_payload
from .version import Version


def _mask_fn(use_kernel: bool):
    if not use_kernel:
        return None                      # numpy/jnp reference inside packed
    from ..kernels.dvv_ops import dvv_sync_mask
    return dvv_sync_mask


def bulk_receive_antientropy(node: ReplicaNode,
                             payload: Union[PackedPayload,
                                            Dict[str, FrozenSet[Version]]],
                             use_kernel: bool = False) -> int:
    """Apply a bulk anti-entropy payload to ``node``; returns #keys changed.

    Packed node + packed payload: single-launch array path (optionally the
    fused Pallas kernel).  Object payloads are encoded at the boundary.
    Object-backend DVV nodes still take the batched sweep (the whole point
    of this entry point); only non-DVV mechanisms fall back to the per-key
    object walk, as their clocks have no array encoding.
    """
    backend = node.backend
    if isinstance(backend, PackedBackend):
        if isinstance(payload, PackedPayload):
            return backend.receive_antientropy(
                payload, mask_fn=_mask_fn(use_kernel))
        # object payload at the boundary: encode once into a staging store,
        # then take the array path
        staged = _stage_object_payload(payload)
        return backend.receive_antientropy(
            staged.payload(), mask_fn=_mask_fn(use_kernel))
    if node.mechanism.name == "dvv":
        payload_obj = _as_object_payload(payload)
        local = {k: node.versions(k) for k in payload_obj}
        new_sets = bulk_sync(local, payload_obj, use_kernel=use_kernel)
        changed = 0
        for k, versions in new_sets.items():
            if versions != node.versions(k):
                changed += 1
            backend.store[k] = versions
        return changed
    return backend.receive_antientropy(payload)


def _stage_object_payload(payload: Dict[str, FrozenSet[Version]]
                          ) -> PackedVersionStore:
    """Boundary codec: object versions → a throwaway packed store.

    Staging goes through ``sync_key`` so each key's set is reduced to its
    maximal antichain — arbitrary input dicts may contain internally
    dominated versions (protocol stores never do).
    """
    staged = PackedVersionStore()
    for k in sorted(payload):
        staged.sync_key_objects(k, payload[k])
    return staged


def bulk_sync(local: Dict[str, FrozenSet[Version]],
              incoming: Dict[str, FrozenSet[Version]],
              use_kernel: bool = False) -> Dict[str, FrozenSet[Version]]:
    """Object-level sync() per key, evaluated as one batched sweep.

    Returns the new version sets for every key in ``incoming`` ∪ ``local``.
    Both sides pay the boundary codec (this entry point exists for
    control-plane callers and conformance tests); resident stores use
    ``bulk_receive_antientropy`` with packed payloads instead.
    """
    if not local and not incoming:
        return {}
    staged = _stage_object_payload(local)
    staged.apply_payload(_stage_object_payload(incoming).payload(),
                         mask_fn=_mask_fn(use_kernel))
    return {k: staged.versions(k) for k in staged.keys}
