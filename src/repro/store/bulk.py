"""Bulk anti-entropy: the batched/Pallas DVV path for large key ranges.

With ``PackedVersionStore`` as the resident representation the steady-state
round is arrays end to end: the sender slices its slot arrays into a
``PackedPayload`` (zero decode), the receiver remaps replica columns with
one gather, groups rows per key with one stable sort, evaluates survival in
one ``sync_mask`` call — the jnp reference or the fused Pallas kernel
(``kernels.dvv_ops.dvv_sync_mask``, pairwise K×K dominance + survival in a
single ``pallas_call``) — and writes the surviving rows back.  No per-key
``DVV`` object is encoded or decoded anywhere on that path.

Steady state runs *delta* rounds (DESIGN.md §6): phase 1 exchanges digest
trees (``PackedVersionStore.sync_digest``), phase 2 ships only the
divergent key ranges via ``payload(key_ranges=...)``.  ``delta_antientropy``
below is that two-phase round between two nodes; the one-shot full round
stays available as the fallback (non-packed peers, digest-collision
recovery) and as the conformance reference the delta round is tested
byte-identical to.

The object-level entry points (``bulk_sync`` on dicts of ``Version``s) are
kept for control-plane callers and for conformance testing against
``ReplicaNode``'s object backend; they pay the boundary codec once on the
way in and once on the way out.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Mapping, Optional, \
    Tuple, Union

import numpy as np

from .packed import PackedPayload, PackedVersionStore, StoreDigest
from .replica import PackedBackend, ReplicaNode, _as_object_payload
from .sharding import shard_of_key
from .version import Version

#: A per-push range budget: one cap for every shard, or a per-shard map
#: (the gossip driver's independently-adapted hot-shard budgets).
RangeBudget = Union[None, int, Mapping[int, Optional[int]]]


def _mask_fn(use_kernel: bool):
    if not use_kernel:
        return None                      # numpy/jnp reference inside packed
    # Shape-bucketed front end: delta rounds come in arbitrary small shapes;
    # bucketing keeps the pallas_call cache warm across all of them.
    from ..kernels.dvv_ops import dvv_sync_mask_bucketed
    return dvv_sync_mask_bucketed


# ---------------------------------------------------------------------------
# Delta anti-entropy: digest exchange → ranked range request → sliced apply.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DeltaSyncStats:
    """What one delta round cost and did — the wire/compute accounting the
    divergence benchmark reports per row."""

    buckets_total: int        # digest-tree width
    buckets_divergent: int    # leaves whose digests differed
    buckets_sent: int         # after ranking / max_ranges truncation
    payload_slots: int        # versions shipped in phase 2
    payload_bytes: int        # phase-2 wire size
    digest_bytes: int         # phase-1 wire size (both directions)
    changed: int              # keys whose version set changed at the receiver
    fallback: bool = False    # True when the full-payload round ran instead
    shard: int = -1           # which shard this round covered (-1: unsharded
                              # or an aggregate over shards)
    # Sharded rounds: the per-shard constituent stats (aggregates sum the
    # numeric fields above).  Empty for unsharded/per-shard entries.
    per_shard: Tuple["DeltaSyncStats", ...] = field(default=())


def rank_ranges(src_store: PackedVersionStore, divergent: np.ndarray,
                width: int, *,
                max_ranges: Optional[int] = None) -> np.ndarray:
    """Rank divergent buckets (ids at ``width``) for shipping, biggest first.

    The ranking key is the sender's live-slot count per bucket (the best
    local proxy for how much catch-up a range carries); ties break on
    bucket id so rounds are deterministic.  ``max_ranges`` caps a round.
    A push can only fix ranges where the *sender* is ahead, so a capped
    one-directional push can re-ship a receiver-ahead range forever;
    capped rounds converge when run in both directions (as
    ``KVCluster.delta_antientropy_round`` does) — the reverse push drains
    a receiver-ahead range, after which it drops out of both diffs.
    """
    if len(divergent) == 0:
        return divergent
    counts = src_store.bucket_counts(width)
    order = np.argsort(-counts[divergent], kind="stable")
    ranked = divergent[order]
    if max_ranges is not None:
        ranked = ranked[:max_ranges]
    return ranked


def delta_plan(src_store: PackedVersionStore, dst_digest: StoreDigest, *,
               max_ranges: Optional[int] = None
               ) -> Tuple[np.ndarray, int, int]:
    """Phase-1 planning: diff the digest trees (at the common width), rank
    the divergent ranges.  Returns ``(ranked_buckets, width, n_divergent)``
    where ``n_divergent`` counts divergent buckets before any
    ``max_ranges`` truncation."""
    width = min(src_store.n_buckets, dst_digest.n_buckets)
    divergent = src_store.sync_digest().diff(dst_digest)
    ranked = rank_ranges(src_store, divergent, width, max_ranges=max_ranges)
    return ranked, width, len(divergent)


def _object_payload_nbytes(payload: Dict[str, FrozenSet[Version]]) -> int:
    """Wire-size estimate for an object payload, comparable to
    ``PackedPayload.nbytes``: keys + clock reprs + value reprs."""
    return sum(
        len(k.encode())
        + sum(len(repr(v.clock).encode()) + len(repr(v.value).encode())
              for v in vs)
        for k, vs in payload.items())


def _store_delta_round(src_store: PackedVersionStore,
                       dst_store: PackedVersionStore, *,
                       mask_fn=None, max_ranges: Optional[int] = None,
                       shard: int = -1) -> DeltaSyncStats:
    """The two-phase round between two packed stores (one shard's plane)."""
    dst_digest = dst_store.sync_digest()
    ranked, width, n_divergent = delta_plan(src_store, dst_digest,
                                            max_ranges=max_ranges)
    # Phase-1 wire: each side's tree travels folded to the common width,
    # plus one 8-byte value root per side (the content check below).
    digest_bytes = 2 * (dst_digest.fold(width).nbytes() + 8)
    if len(ranked) == 0:
        if src_store.value_root() != dst_store.value_root():
            # The §6.1 hashes cover clock+key only, so clock-equal/value-
            # different slots (only reachable through non-protocol
            # ``bulk_sync`` dicts) diff to zero divergent buckets.  The
            # value roots disagree exactly then: run the full-payload
            # round rather than silently reporting convergence.
            payload = src_store.payload()
            changed = dst_store.apply_payload(payload, mask_fn=mask_fn)
            return DeltaSyncStats(width, 0, 0, len(payload),
                                  payload.nbytes(), digest_bytes, changed,
                                  fallback=True, shard=shard)
        return DeltaSyncStats(width, 0, 0, 0, 0, digest_bytes, 0,
                              shard=shard)
    payload = src_store.payload(key_ranges=ranked, ranges_width=width)
    changed = dst_store.apply_payload(payload, mask_fn=mask_fn)
    return DeltaSyncStats(width, n_divergent, len(ranked),
                          len(payload), payload.nbytes(), digest_bytes,
                          changed, shard=shard)


def _shard_budget(max_ranges: RangeBudget, shard: int) -> Optional[int]:
    if isinstance(max_ranges, Mapping):
        return max_ranges.get(shard)
    return max_ranges


def _aggregate_stats(per: List[DeltaSyncStats],
                     probe_bytes: int = 0) -> DeltaSyncStats:
    """Sum per-shard rounds into one stats record.  ``per_shard`` keeps
    only the shards that actually ran a round (the budget-adaptation
    signal); converged shards contribute ``probe_bytes`` of root-probe
    wire and nothing else — no stats object each, so a converged sharded
    heartbeat stays O(shards) int compares."""
    return DeltaSyncStats(
        buckets_total=sum(p.buckets_total for p in per),
        buckets_divergent=sum(p.buckets_divergent for p in per),
        buckets_sent=sum(p.buckets_sent for p in per),
        payload_slots=sum(p.payload_slots for p in per),
        payload_bytes=sum(p.payload_bytes for p in per),
        digest_bytes=sum(p.digest_bytes for p in per) + probe_bytes,
        changed=sum(p.changed for p in per),
        fallback=any(p.fallback for p in per),
        per_shard=tuple(per))


def _node_keys(node: ReplicaNode) -> List[str]:
    b = node.backend
    if isinstance(b, PackedBackend):
        return [k for st in b.stores for k in st.keys]
    return list(b.store.keys())


def delta_antientropy(src: ReplicaNode, dst: ReplicaNode, *,
                      use_kernel: bool = False,
                      max_ranges: RangeBudget = None,
                      only_shards: Optional[Iterable[int]] = None
                      ) -> DeltaSyncStats:
    """One two-phase delta round: ``src`` pushes its divergent ranges to
    ``dst``.  Cost is proportional to divergence, not store size.

    Sharded nodes run one round *per shard* — each shard's round opens
    with a 16-byte root probe (8B digest root + 8B value root per
    direction) so converged shards cost 32 wire bytes total instead of a
    tree snapshot, and ``max_ranges`` may be a per-shard mapping so hot
    shards get independent budgets.  ``only_shards`` restricts the round
    to the given shards — the rebalance plane: bootstrap pulls only the
    shards a joiner owns, handoff pushes only shards whose ownership
    changed.  The returned stats aggregate the per-shard rounds
    (``per_shard`` holds the constituents).

    Falls back to the one-shot full-payload round when either side lacks a
    packed store (object backends have no digest tree); ``only_shards``
    then filters the payload's keys by shard so both backends move the
    same key set.
    """
    sb, db = src.backend, dst.backend
    if not (isinstance(sb, PackedBackend) and isinstance(db, PackedBackend)):
        keys = None
        if only_shards is not None:
            want = frozenset(only_shards)
            keys = [k for k in _node_keys(src)
                    if shard_of_key(k, src.shards) in want]
        payload = src.antientropy_payload(keys)
        if isinstance(payload, PackedPayload):
            slots, nbytes = len(payload), payload.nbytes()
        else:
            slots = sum(len(vs) for vs in payload.values())
            nbytes = _object_payload_nbytes(payload)
        changed = bulk_receive_antientropy(dst, payload,
                                           use_kernel=use_kernel)
        return DeltaSyncStats(0, 0, 0, slots, nbytes, 0, changed,
                              fallback=True)

    if sb.shards != db.shards:
        raise ValueError(
            f"shard counts differ: {sb.shards} (src) vs {db.shards} (dst)")
    mask_fn = _mask_fn(use_kernel)
    if sb.shards == 1:
        # Unsharded: the exact pre-sharding protocol (no root probe — the
        # tree diff's own root compare is the converged fast path).
        return _store_delta_round(sb.stores[0], db.stores[0],
                                  mask_fn=mask_fn,
                                  max_ranges=_shard_budget(max_ranges, 0))
    targets = range(sb.shards) if only_shards is None \
        else sorted(frozenset(only_shards))
    per: List[DeltaSyncStats] = []
    probe_bytes = 0
    src_stores, dst_stores = sb.stores, db.stores
    for s in targets:
        ss, ds = src_stores[s], dst_stores[s]
        if ss.digest_root() == ds.digest_root() \
                and ss.value_root() == ds.value_root():
            # phase-0 skip: 8B digest root + 8B value root each direction
            probe_bytes += 32
            continue
        per.append(_store_delta_round(
            ss, ds, mask_fn=mask_fn,
            max_ranges=_shard_budget(max_ranges, s), shard=s))
    return _aggregate_stats(per, probe_bytes)


def bulk_receive_antientropy(node: ReplicaNode,
                             payload: Union[PackedPayload,
                                            Dict[str, FrozenSet[Version]]],
                             use_kernel: bool = False) -> int:
    """Apply a bulk anti-entropy payload to ``node``; returns #keys changed.

    Packed node + packed payload: single-launch array path (optionally the
    fused Pallas kernel).  Object payloads are encoded at the boundary.
    Object-backend DVV nodes still take the batched sweep (the whole point
    of this entry point); only non-DVV mechanisms fall back to the per-key
    object walk, as their clocks have no array encoding.
    """
    backend = node.backend
    if isinstance(backend, PackedBackend):
        if isinstance(payload, PackedPayload):
            return backend.receive_antientropy(
                payload, mask_fn=_mask_fn(use_kernel))
        # object payload at the boundary: encode once into a staging store,
        # then take the array path
        staged = _stage_object_payload(payload)
        return backend.receive_antientropy(
            staged.payload(), mask_fn=_mask_fn(use_kernel))
    if node.mechanism.name == "dvv":
        payload_obj = _as_object_payload(payload)
        # Sparse deltas: only stage keys the node actually stores — a key
        # with no local slots has nothing to merge against, and staging its
        # empty set would pay one boundary encode per absent key.
        local = {}
        for k in payload_obj:
            versions = node.versions(k)
            if versions:
                local[k] = versions
        new_sets = bulk_sync(local, payload_obj, use_kernel=use_kernel)
        changed = 0
        for k, versions in new_sets.items():
            if versions != node.versions(k):
                changed += 1
            backend.replace_key(k, versions)
        return changed
    return backend.receive_antientropy(payload)


def _stage_object_payload(payload: Dict[str, FrozenSet[Version]]
                          ) -> PackedVersionStore:
    """Boundary codec: object versions → a throwaway packed store.

    Staging goes through ``sync_key`` so each key's set is reduced to its
    maximal antichain — arbitrary input dicts may contain internally
    dominated versions (protocol stores never do).
    """
    staged = PackedVersionStore(track_digests=False)   # scratch store: no
    for k in sorted(payload):                          # delta rounds, skip
        staged.sync_key_objects(k, payload[k])         # digest upkeep
    return staged


def bulk_sync(local: Dict[str, FrozenSet[Version]],
              incoming: Dict[str, FrozenSet[Version]],
              use_kernel: bool = False) -> Dict[str, FrozenSet[Version]]:
    """Object-level sync() per key, evaluated as one batched sweep.

    Returns the new version sets for every key in ``incoming`` ∪ ``local``.
    Both sides pay the boundary codec (this entry point exists for
    control-plane callers and conformance tests); resident stores use
    ``bulk_receive_antientropy`` with packed payloads instead.
    """
    if not local and not incoming:
        return {}
    staged = _stage_object_payload(local)
    staged.apply_payload(_stage_object_payload(incoming).payload(),
                         mask_fn=_mask_fn(use_kernel))
    return {k: staged.versions(k) for k in staged.keys}
