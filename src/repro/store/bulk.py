"""Bulk anti-entropy: the batched/Pallas DVV path for large key ranges.

Object-level anti-entropy (``ReplicaNode.receive_antientropy``) walks
Python clocks key by key — fine for control-plane traffic, hopeless for
millions of keys.  This module vectorizes the dominance sweep: both sides'
version sets are array-encoded (``core.batched``), a single
``sync_mask`` evaluation decides every version's survival, and only the
surviving versions are materialized back into Python objects.

The jnp reference path and the Pallas kernel (`kernels.dvv_ops`) share the
encoding; `use_kernel=True` routes the pairwise dominance through
``dvv_leq`` (interpret-mode on CPU).  Both are tested equal to the
object-level result (`tests/test_bulk_antientropy.py`).
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import batched as B
from ..core.dvv import DVV
from .replica import ReplicaNode
from .version import Version


def _universe(versions_by_key: Dict[str, List[Version]]) -> List[str]:
    ids = set()
    for versions in versions_by_key.values():
        for v in versions:
            ids |= v.clock.ids()
    return sorted(ids)


def bulk_sync(local: Dict[str, FrozenSet[Version]],
              incoming: Dict[str, FrozenSet[Version]],
              use_kernel: bool = False) -> Dict[str, FrozenSet[Version]]:
    """sync() per key, evaluated as one batched dominance sweep.

    Returns the new version sets for every key in ``incoming`` ∪ ``local``.
    """
    keys = sorted(set(local) | set(incoming))
    merged: Dict[str, List[Version]] = {
        k: sorted(set(local.get(k, frozenset()))
                  | set(incoming.get(k, frozenset())),
                  key=lambda v: (repr(v.clock), repr(v.value)))
        for k in keys
    }
    if not keys:
        return {}
    universe = _universe(merged)
    K = max(len(vs) for vs in merged.values())
    R = max(len(universe), 1)

    vvs = np.zeros((len(keys), K, R), np.int32)
    dids = np.full((len(keys), K), B.NO_DOT, np.int32)
    dns = np.zeros((len(keys), K), np.int32)
    valid = np.zeros((len(keys), K), bool)
    for i, k in enumerate(keys):
        for j, v in enumerate(merged[k]):
            vvs[i, j], dids[i, j], dns[i, j] = B.encode(v.clock, universe)
            valid[i, j] = True

    if use_kernel:
        from ..kernels.dvv_ops import dvv_leq

        # pairwise strict-domination via two kernel sweeps over flattened
        # (key, x, y) pairs
        N, Kk, _ = vvs.shape
        vx = np.repeat(vvs, Kk, axis=1).reshape(N * Kk * Kk, R)
        ix = np.repeat(dids, Kk, axis=1).reshape(-1)
        nx = np.repeat(dns, Kk, axis=1).reshape(-1)
        vy = np.tile(vvs, (1, Kk, 1)).reshape(N * Kk * Kk, R)
        iy = np.tile(dids, (1, Kk)).reshape(-1)
        ny = np.tile(dns, (1, Kk)).reshape(-1)
        le = np.asarray(dvv_leq(*map(jnp.asarray, (vx, ix, nx, vy, iy, ny))))
        ge = np.asarray(dvv_leq(*map(jnp.asarray, (vy, iy, ny, vx, ix, nx))))
        le = le.reshape(N, Kk, Kk)
        ge = ge.reshape(N, Kk, Kk)
        strictly_below = le & ~ge
        idx = np.arange(Kk)
        dup = (le & ge) & (idx[None, None, :] < idx[None, :, None])
        other_valid = valid[:, None, :]
        dominated = ((strictly_below | dup) & other_valid).any(axis=-1)
        mask = valid & ~dominated
    else:
        mask = np.asarray(B.sync_mask(
            jnp.asarray(vvs), jnp.asarray(dids), jnp.asarray(dns),
            jnp.asarray(valid)))

    out: Dict[str, FrozenSet[Version]] = {}
    for i, k in enumerate(keys):
        out[k] = frozenset(
            v for j, v in enumerate(merged[k]) if mask[i, j])
    return out


def bulk_receive_antientropy(node: ReplicaNode,
                             payload: Dict[str, FrozenSet[Version]],
                             use_kernel: bool = False) -> int:
    """Apply a bulk anti-entropy payload to ``node``; returns #keys updated.

    Only valid for DVV-mechanism nodes (the array encoding is DVV-specific).
    """
    local = {k: node.versions(k) for k in payload}
    new_sets = bulk_sync(local, payload, use_kernel=use_kernel)
    changed = 0
    for k, versions in new_sets.items():
        if versions != node.versions(k):
            changed += 1
        node.store[k] = versions
    return changed
