"""Versions: (clock, value) pairs — what replica nodes actually store.

``sync`` is lifted from clock sets (paper §4) to version sets: a version is
discarded iff its clock is strictly dominated.  Versions with equal clocks
are the same write (clocks are unique per update event) and are deduped.

Each version also records the coordinator wall-time of its PUT.  The wall
is *metadata*, not causality: it is excluded from equality/hashing (two
replicas holding the same write compare equal whatever bookkeeping they
carry) and never enters a clock comparison.  Its one job is the
deterministic register resolution of ``GetResult.value`` — concurrent
siblings are totally ordered by ``(wall, repr(clock), repr(value))``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, FrozenSet, Tuple


@dataclass(frozen=True)
class Version:
    clock: Any
    value: Any
    wall: float = field(default=0.0, compare=False)

    def __repr__(self) -> str:
        return f"<{self.value!r} @ {self.clock!r}>"


def resolution_key(v: Version) -> Tuple[float, str, str]:
    """The total order used to resolve concurrent siblings into a single
    register value: latest wall-time wins, clock repr then value repr break
    ties deterministically (documented in DESIGN.md §7)."""
    return (v.wall, repr(v.clock), repr(v.value))


def sync_versions(S1: FrozenSet[Version], S2: FrozenSet[Version],
                  total_order: bool = False) -> FrozenSet[Version]:
    """Paper §4 sync lifted to versions.

    ``total_order=True`` implements the LWW collapse: keep only the single
    maximal version (ties broken deterministically) — used by the wall-clock
    and Lamport baselines.
    """
    allv = S1 | S2
    if not allv:
        return frozenset()
    if total_order:
        best = None
        for v in sorted(allv, key=lambda v: repr(v.value)):
            if best is None or best.clock.lt(v.clock):
                best = v
        return frozenset({best})
    keep = set()
    for x in allv:
        dominated = any(
            x.clock.lt(y.clock) for y in allv if y is not x)
        duplicate = any(
            y.clock == x.clock and repr(y.value) < repr(x.value) for y in allv)
        if not dominated and not duplicate:
            keep.add(x)
    return frozenset(keep)


def clocks_of(S: FrozenSet[Version]) -> FrozenSet[Any]:
    return frozenset(v.clock for v in S)


def values_of(S: FrozenSet[Version]) -> Tuple[Any, ...]:
    return tuple(sorted((v.value for v in S), key=repr))
