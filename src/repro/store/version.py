"""Versions: (clock, value) pairs — what replica nodes actually store.

``sync`` is lifted from clock sets (paper §4) to version sets: a version is
discarded iff its clock is strictly dominated.  Versions with equal clocks
are the same write (clocks are unique per update event) and are deduped.

Each version also records the coordinator wall-time of its PUT.  The wall
is *metadata*, not causality: it is excluded from equality/hashing (two
replicas holding the same write compare equal whatever bookkeeping they
carry) and never enters a clock comparison.  Its one job is the
deterministic register resolution of ``GetResult.value`` — concurrent
siblings are totally ordered by ``(wall, repr(clock), repr(value))``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, FrozenSet, Tuple


@dataclass(frozen=True)
class Version:
    clock: Any
    value: Any
    wall: float = field(default=0.0, compare=False)

    def __repr__(self) -> str:
        return f"<{self.value!r} @ {self.clock!r}>"


# -- hybrid logical clocks (geo tier) ---------------------------------------
#
# The wall column doubles as a hybrid logical clock (GentleRain+-style):
# one float64 encodes (l, c) as l + c·2^-20, where l is the physical
# component (max physical time seen) and c the logical tiebreak counter.
# Exact in float64 for l < 2^31 and c < 2^20: the integer part needs 31
# bits, the fraction 20, both well inside the 52-bit mantissa.  Comparing
# encoded walls IS the HLC order (l first, c second), so the packed
# store's float64 wall column and every existing resolution path order
# HLC-minted versions correctly with zero schema change.

HLC_STEP = 2.0 ** -20           # one logical tick in encoded units
HLC_EPS = 2.0 ** -21            # < any tick: strict-inequality epsilon


def hlc_encode(l: int, c: int) -> float:
    return float(l) + c * HLC_STEP


def hlc_decode(wall: float) -> Tuple[int, int]:
    l = int(wall)
    return l, int(round((wall - l) / HLC_STEP))


class HybridClock:
    """Per-node HLC state: ``mint`` stamps a local event, ``observe``
    merges a remote wall (message receive), ``observe_physical`` folds in
    a bare physical reading (heartbeats).  Minted walls are strictly
    increasing even when the physical clock stalls or steps backwards —
    the logical counter absorbs the anomaly (GentleRain+ §3)."""

    __slots__ = ("l", "c")

    def __init__(self, l: int = 0, c: int = 0):
        self.l = l
        self.c = c

    def mint(self, physical: float) -> float:
        pt = int(physical)
        if pt > self.l:
            self.l, self.c = pt, 0
        else:
            self.c += 1
            if self.c >= 1 << 20:           # counter overflow: borrow a tick
                self.l += 1
                self.c = 0
        return hlc_encode(self.l, self.c)

    def observe(self, wall: float) -> None:
        l2, c2 = hlc_decode(wall)
        if l2 > self.l:
            self.l, self.c = l2, c2
        elif l2 == self.l and c2 > self.c:
            self.c = c2

    def observe_physical(self, physical: float) -> None:
        pt = int(physical)
        if pt > self.l:
            self.l, self.c = pt, 0

    def read(self) -> float:
        return hlc_encode(self.l, self.c)

    def __repr__(self) -> str:      # pragma: no cover
        return f"HybridClock(l={self.l}, c={self.c})"


def resolution_key(v: Version) -> Tuple[float, str, str]:
    """The total order used to resolve concurrent siblings into a single
    register value: latest wall-time wins, clock repr then value repr break
    ties deterministically (documented in DESIGN.md §7)."""
    return (v.wall, repr(v.clock), repr(v.value))


def sync_versions(S1: FrozenSet[Version], S2: FrozenSet[Version],
                  total_order: bool = False) -> FrozenSet[Version]:
    """Paper §4 sync lifted to versions.

    ``total_order=True`` implements the LWW collapse: keep only the single
    maximal version (ties broken deterministically) — used by the wall-clock
    and Lamport baselines.
    """
    allv = S1 | S2
    if not allv:
        return frozenset()
    if total_order:
        best = None
        for v in sorted(allv, key=lambda v: repr(v.value)):
            if best is None or best.clock.lt(v.clock):
                best = v
        return frozenset({best})
    keep = set()
    for x in allv:
        dominated = any(
            x.clock.lt(y.clock) for y in allv if y is not x)
        duplicate = any(
            y.clock == x.clock and repr(y.value) < repr(x.value) for y in allv)
        if not dominated and not duplicate:
            keep.add(x)
    return frozenset(keep)


def clocks_of(S: FrozenSet[Version]) -> FrozenSet[Any]:
    return frozenset(v.clock for v in S)


def values_of(S: FrozenSet[Version]) -> Tuple[Any, ...]:
    return tuple(sorted((v.value for v in S), key=repr))
