"""Array-resident version store: packed int32 clocks as the source of truth.

``ReplicaNode`` historically kept per-key version sets as Python ``DVV``
objects and re-encoded them to arrays on every bulk anti-entropy round — an
O(keys) object-walk tax on the hot path.  ``PackedVersionStore`` inverts
that: the structure-of-arrays encoding of ``core.batched`` *is* the resident
representation, and object clocks exist only at the client API edge
(GET contexts, PUT acks).  See DESIGN.md §3.4.

Layout (structure of arrays over "slots"; one slot = one stored version):

    vv      : int32[cap, R]  — per-replica contiguous ranges 1..m
    dot_id  : int32[cap]     — replica column of the single dot (−1 if none)
    dot_n   : int32[cap]     — the dot's counter (0 if none)
    key_ix  : int32[cap]     — interned key of the slot
    valid   : bool[cap]      — live/dead (dead slots are reclaimed by compact)
    values  : list[Any]      — the opaque payloads, aligned with slots

The replica universe is *dynamic*: replica ids are interned on first sight
and the ``vv`` matrix grows columns in place (zero-fill is exact — absent
ids have empty ranges).  Capacity grows by doubling; ``compact()`` drops
dead slots when they outnumber the live ones.

Anti-entropy ships ``PackedPayload`` — the same arrays plus the sender's
replica/key interning tables — so a full round is: one column remap
(vectorized gather), one grouped scatter, one ``sync_mask`` evaluation
(jnp or the fused Pallas kernel), one masked write-back.  No per-key DVV
object is created anywhere on that path.

Steady-state rounds are *delta* rounds (DESIGN.md §6): the store keeps an
incremental digest tree — every live slot owns a canonical 64-bit hash
(independent of column order, slot order and trailing zero columns), and
each of ``n_buckets`` key ranges holds the xor-fold of its slots' hashes,
updated in O(changed slots) on insert/kill (compaction moves slots but not
set membership, so digests are untouched).  Two replicas exchange
``StoreDigest`` snapshots, diff them down the tree, and ship only the
divergent buckets via ``payload(key_ranges=...)`` — wire and compute
proportional to divergence, not store size.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, FrozenSet, Iterable, List, Mapping, \
    Optional, Sequence, Tuple

import numpy as np

from ..core import batched as B
from ..core.dvv import DVV
from .context import CausalContext
from .version import Version

NO_DOT = B.NO_DOT

_INITIAL_SLOTS = 64
_INITIAL_REPLICAS = 4
_INITIAL_KEYS = 64

DIGEST_BUCKETS = 256          # initial leaf key-ranges of the digest tree
DIGEST_FANOUT = 16            # children per internal tree node
_SLOTS_PER_BUCKET = 4         # growth trigger: live slots per leaf
_MAX_BUCKETS = 1 << 20
_BUCKET_GROWTH = 4            # widen by 4x so rebuilds amortize

_U64 = np.uint64
_GOLD = _U64(0x9E3779B97F4A7C15)    # splitmix64 increment
_DOT_SALT = _U64(0xD07D07D07D07D07D)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 arrays (wraps mod 2^64)."""
    with np.errstate(over="ignore"):
        x = (np.asarray(x, _U64) + _GOLD)
        x = (x ^ (x >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> _U64(27))) * _U64(0x94D049BB133111EB)
        return x ^ (x >> _U64(31))


def _hash_str(s: str) -> int:
    """Stable (process-independent) 64-bit hash of an interning-table entry."""
    return int.from_bytes(
        hashlib.blake2b(s.encode(), digest_size=8).digest(), "little")


def _hash_value(value: Any) -> int:
    """Stable 64-bit hash of a slot's *value content* (priced at its repr,
    like every serialization stand-in in this codebase).  Feeds the store's
    value root — the content check that closes the §6.1 clock+key digest
    gap for non-protocol stores (see ``value_root``)."""
    return _hash_str(repr(value))


def ceiling_from_rows(vv: np.ndarray, dot_id: np.ndarray, dot_n: np.ndarray
                      ) -> np.ndarray:
    """Per-replica ceiling ⌈S⌉ over packed clock rows: column max with the
    dots folded in.  The one §5.4 compaction shared by GET-context
    production (``context_of``, the quorum merge).  The single-group view
    of ``core.batched.grouped_ceiling_np`` — the batched read plane calls
    the grouped form directly, one segment reduce for all keys."""
    return B.grouped_ceiling_np(vv, dot_id, dot_n,
                                np.zeros(vv.shape[0], np.int64), 1)[0]


def remap_rows(vv: np.ndarray, dot_id: np.ndarray, col_map: np.ndarray,
               R: int) -> Tuple[np.ndarray, np.ndarray]:
    """Land packed clock rows in a target universe with one gather:
    ``col_map[j]`` is the target column of source column ``j``.  Returns
    ``(vv int32[M, R], dot_id int32[M])`` with absent dots (``NO_DOT``)
    preserved.  The one remap shared by payload application, the quorum
    merge and read-repair payload assembly."""
    out = np.zeros((vv.shape[0], R), np.int32)
    if len(col_map):
        out[:, col_map] = vv
    did = np.where(dot_id != NO_DOT,
                   col_map[np.clip(dot_id, 0, None)] if len(col_map)
                   else dot_id,
                   NO_DOT).astype(np.int32)
    return out, did


def key_bucket(key: str, n_buckets: int = DIGEST_BUCKETS) -> int:
    """The digest leaf a key belongs to — a pure function of the key string,
    so every replica assigns identical ranges regardless of interning order."""
    return _hash_str(key) & (n_buckets - 1)


@dataclass(frozen=True)
class StoreDigest:
    """A digest-tree snapshot: ``leaves[b]`` is the xor-fold of the canonical
    slot hashes of every live version whose key falls in bucket ``b``.

    Equal content ⇒ equal digests; the converse holds up to 64-bit hash
    collisions (the full-payload round remains the correctness fallback —
    see the collision probe in tests/test_delta_sync.py).

    Widths are powers of two and *foldable*: because a key's bucket is
    ``hash & (W − 1)``, xor-folding a 2W-wide leaf vector in half yields
    exactly the W-wide digest of the same store, so trees of different
    widths (stores grow their width with size) diff at the narrower one.
    """

    leaves: np.ndarray                      # uint64[n_buckets]

    @property
    def n_buckets(self) -> int:
        return int(self.leaves.shape[0])

    def fold(self, width: int) -> "StoreDigest":
        """Exact down-projection to a narrower power-of-two width."""
        if width == self.n_buckets:
            return self
        if width > self.n_buckets or self.n_buckets % width:
            raise ValueError(
                f"cannot fold {self.n_buckets} leaves to width {width}")
        return StoreDigest(np.bitwise_xor.reduce(
            self.leaves.reshape(-1, width), axis=0))

    @property
    def root(self) -> int:
        return int(np.bitwise_xor.reduce(self.leaves)) if len(self.leaves) \
            else 0

    def levels(self) -> List[np.ndarray]:
        """Root-first xor-fold levels with fanout ``DIGEST_FANOUT``."""
        lvls = [self.leaves]
        while len(lvls[0]) > 1:
            a = lvls[0]
            pad = (-len(a)) % DIGEST_FANOUT
            if pad:
                a = np.pad(a, (0, pad))
            lvls.insert(0, np.bitwise_xor.reduce(
                a.reshape(-1, DIGEST_FANOUT), axis=1))
        return lvls

    def nbytes(self) -> int:
        """Phase-1 wire cost of shipping this digest (leaves + root)."""
        return int(self.leaves.nbytes) + 8

    def diff(self, other: "StoreDigest") -> np.ndarray:
        """Leaf buckets whose content differs, found by tree descent.

        Compares root first (the converged fast path is one 8-byte check),
        then only the children of differing internal nodes.  Mismatched
        widths are folded to the narrower side first; returned bucket ids
        are at that common width.
        """
        width = min(self.n_buckets, other.n_buckets)
        if self.n_buckets != other.n_buckets:
            return self.fold(width).diff(other.fold(width))
        mine, theirs = self.levels(), other.levels()
        cand = np.flatnonzero(mine[0] != theirs[0])
        for lvl in range(1, len(mine)):
            if len(cand) == 0:
                return cand
            children = (cand[:, None] * DIGEST_FANOUT
                        + np.arange(DIGEST_FANOUT)).ravel()
            children = children[children < len(mine[lvl])]
            cand = children[mine[lvl][children] != theirs[lvl][children]]
        return cand


@dataclass
class PackedPayload:
    """A bulk anti-entropy transfer: packed clocks + the sender's tables.

    ``key_ix`` indexes into ``keys``; ``vv`` columns follow ``replica_ids``.
    The receiver remaps columns into its own universe with one gather.
    """

    replica_ids: Tuple[str, ...]
    keys: Tuple[str, ...]
    vv: np.ndarray          # int32[M, R]
    dot_id: np.ndarray      # int32[M]
    dot_n: np.ndarray       # int32[M]
    key_ix: np.ndarray      # int32[M]
    values: Tuple[Any, ...]
    wall: Optional[np.ndarray] = None   # float64[M] PUT wall-times

    def __post_init__(self) -> None:
        if self.wall is None:
            self.wall = np.zeros(int(self.vv.shape[0]), np.float64)

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, PackedPayload):
            return NotImplemented
        return (self.replica_ids == other.replica_ids
                and self.keys == other.keys
                and np.array_equal(self.vv, other.vv)
                and np.array_equal(self.dot_id, other.dot_id)
                and np.array_equal(self.dot_n, other.dot_n)
                and np.array_equal(self.key_ix, other.key_ix)
                and np.array_equal(self.wall, other.wall)
                and self.values == other.values)

    def __len__(self) -> int:
        return int(self.vv.shape[0])

    def nbytes(self) -> int:
        """Wire size estimate: clock arrays + interning tables + values
        (values priced at their repr, the sim-transport's serialization)."""
        arrays = (self.vv.nbytes + self.dot_id.nbytes + self.dot_n.nbytes
                  + self.key_ix.nbytes + self.wall.nbytes)
        tables = (sum(len(k.encode()) for k in self.keys)
                  + sum(len(r.encode()) for r in self.replica_ids))
        values = sum(len(repr(v).encode()) for v in self.values)
        return int(arrays + tables + values)


def concat_payloads(payloads: Sequence[PackedPayload]) -> PackedPayload:
    """Concatenate payloads with disjoint key sets into one wire object
    under a union replica universe — the sender-side joiner for sharded
    stores (one ``("store", payload)`` message covering several shards)."""
    payloads = list(payloads)
    if len(payloads) == 1:
        return payloads[0]
    ids: List[str] = []
    index: Dict[str, int] = {}
    for p in payloads:
        for rid in p.replica_ids:
            if rid not in index:
                index[rid] = len(ids)
                ids.append(rid)
    Ru = len(ids)
    M = sum(len(p) for p in payloads)
    vv = np.zeros((M, Ru), np.int32)
    did = np.full(M, NO_DOT, np.int32)
    dn = np.zeros(M, np.int32)
    kix = np.zeros(M, np.int32)
    wall = np.zeros(M, np.float64)
    keys: List[str] = []
    values: List[Any] = []
    off = 0
    for p in payloads:
        koff = len(keys)
        keys.extend(p.keys)
        n = len(p)
        if not n:
            continue
        cols = np.asarray([index[r] for r in p.replica_ids], np.int64)
        vv[off: off + n], did[off: off + n] = \
            remap_rows(p.vv, p.dot_id, cols, Ru)
        dn[off: off + n] = p.dot_n
        wall[off: off + n] = p.wall
        kix[off: off + n] = p.key_ix + koff
        values.extend(p.values)
        off += n
    return PackedPayload(tuple(ids), tuple(keys), vv, did, dn, kix,
                         tuple(values), wall)


def split_payload(payload: PackedPayload, shards: int
                  ) -> Dict[int, PackedPayload]:
    """Partition a payload by key shard (top bits of the stable 64-bit key
    hash — ``sharding.shard_of_key``) — the receiver-side router that lets
    one wire payload land in per-shard stores.  Shards with no keys in the
    payload are absent from the result."""
    if shards <= 1:
        return {0: payload}
    from .sharding import shard_of_key
    key_shard = [shard_of_key(k, shards) for k in payload.keys]
    groups: Dict[int, List[int]] = {}
    for ix, s in enumerate(key_shard):
        groups.setdefault(s, []).append(ix)
    if len(groups) <= 1:
        return {s: payload for s in groups}
    out: Dict[int, PackedPayload] = {}
    n_keys = len(payload.keys)
    for s, kixs in groups.items():
        remap = np.full(n_keys, -1, np.int64)
        remap[kixs] = np.arange(len(kixs))
        rows = np.flatnonzero(remap[payload.key_ix] >= 0)
        out[s] = PackedPayload(
            replica_ids=payload.replica_ids,
            keys=tuple(payload.keys[i] for i in kixs),
            vv=payload.vv[rows],
            dot_id=payload.dot_id[rows],
            dot_n=payload.dot_n[rows],
            key_ix=remap[payload.key_ix[rows]].astype(np.int32),
            values=tuple(payload.values[int(r)] for r in rows),
            wall=payload.wall[rows])
    return out


class PackedVersionStore:
    """The resident packed store.  All mutation is numpy; bulk merges hand
    one [N, K, R] tensor to ``core.batched.sync_mask`` or the fused Pallas
    kernel (``kernels.dvv_ops.dvv_sync_mask``)."""

    def __init__(self, n_buckets: int = DIGEST_BUCKETS, *,
                 track_digests: bool = True) -> None:
        if n_buckets & (n_buckets - 1):
            raise ValueError("n_buckets must be a power of two")
        self.vv = np.zeros((_INITIAL_SLOTS, _INITIAL_REPLICAS), np.int32)
        self.dot_id = np.full(_INITIAL_SLOTS, NO_DOT, np.int32)
        self.dot_n = np.zeros(_INITIAL_SLOTS, np.int32)
        self.key_ix = np.full(_INITIAL_SLOTS, -1, np.int32)
        self.valid = np.zeros(_INITIAL_SLOTS, bool)
        self.values: List[Any] = [None] * _INITIAL_SLOTS
        self.wall = np.zeros(_INITIAL_SLOTS, np.float64)
        self.n_slots = 0                 # high-water mark
        self.n_dead = 0
        self.replica_ids: List[str] = []
        self._replica_index: Dict[str, int] = {}
        self.keys: List[str] = []
        self._key_index: Dict[str, int] = {}
        self._slots_by_key: Dict[int, List[int]] = {}
        # digest state: canonical per-slot hashes + per-bucket xor-folds and
        # live counts.  track_digests=False skips incremental upkeep (for
        # throwaway staging stores that never serve a delta round);
        # sync_digest()/bucket_counts() then rebuild from content on demand.
        self.n_buckets = n_buckets
        self.track_digests = track_digests
        self.slot_hash = np.zeros(_INITIAL_SLOTS, _U64)
        self.digest = np.zeros(n_buckets, _U64)
        self._bucket_live = np.zeros(n_buckets, np.int64)
        # tree root (xor of all live slot hashes — width-invariant), kept
        # incrementally so the sharded phase-0 probe is one int compare
        self._digest_root = 0
        # value root: xor-fold over live slots of mix(slot_hash ^ value
        # hash) — content equality beyond the clock+key digest (§6.1 covers
        # clocks only; clock-equal/value-different slots are invisible to
        # ``digest`` but flip this root).  Maintained with the digests.
        self.val_hash = np.zeros(_INITIAL_SLOTS, _U64)
        self._value_root = 0
        self._replica_hash: List[int] = []            # aligned with replica_ids
        self._key_hash = np.zeros(_INITIAL_KEYS, _U64)    # aligned with keys
        self._key_bucket = np.zeros(_INITIAL_KEYS, np.int32)
        # bucket → live-slot index (maintained unconditionally — it is what
        # makes payload(key_ranges=...) O(divergent slots) instead of
        # O(store); see DESIGN.md §6.3)
        self._bucket_slots: Dict[int, set] = {}
        # geo tier (DESIGN.md §12): running max over the live wall column
        # (an O(1)-amortized fold of the array max-reduce the stable
        # frontier needs), and an optional displacement hook —
        # ``shadow_hook(key, before_set)`` fires whenever a key's live
        # version set changes away from a non-empty prior set, so the geo
        # plane can retain displaced-but-snapshot-visible versions.
        self.max_wall = 0.0
        self.shadow_hook: Optional[Callable[
            [str, FrozenSet[Version]], None]] = None
        # durability tier (DESIGN.md §14): ``wal_hook(payload)`` fires after
        # every committed mutation with the *post-state* of the changed keys
        # (a per-key PackedPayload).  Store evolution is monotone in the
        # version-set lattice, so replaying these post-states in order
        # reconstructs the exact final sets — the last record per key wins.
        self.wal_hook: Optional[Callable[["PackedPayload"], None]] = None

    # -- interning / growth ------------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.replica_ids)

    def intern_replica(self, r: str) -> int:
        ix = self._replica_index.get(r)
        if ix is None:
            ix = len(self.replica_ids)
            self.replica_ids.append(r)
            self._replica_index[r] = ix
            self._replica_hash.append(_hash_str(r))
            if ix >= self.vv.shape[1]:
                grow = max(self.vv.shape[1], 4)
                self.vv = np.pad(self.vv, ((0, 0), (0, grow)))
        return ix

    def intern_key(self, k: str) -> int:
        ix = self._key_index.get(k)
        if ix is None:
            ix = len(self.keys)
            self.keys.append(k)
            self._key_index[k] = ix
            self._slots_by_key[ix] = []
            if ix >= len(self._key_hash):
                grow = len(self._key_hash)
                self._key_hash = np.pad(self._key_hash, (0, grow))
                self._key_bucket = np.pad(self._key_bucket, (0, grow))
            h = _hash_str(k)
            self._key_hash[ix] = h
            self._key_bucket[ix] = h & (self.n_buckets - 1)
        return ix

    def _ensure_capacity(self, extra: int) -> None:
        need = self.n_slots + extra
        cap = self.vv.shape[0]
        if need <= cap:
            return
        new_cap = max(need, 2 * cap)
        pad = new_cap - cap
        self.vv = np.pad(self.vv, ((0, pad), (0, 0)))
        self.dot_id = np.pad(self.dot_id, (0, pad), constant_values=NO_DOT)
        self.dot_n = np.pad(self.dot_n, (0, pad))
        self.key_ix = np.pad(self.key_ix, (0, pad), constant_values=-1)
        self.valid = np.pad(self.valid, (0, pad))
        self.slot_hash = np.pad(self.slot_hash, (0, pad))
        self.val_hash = np.pad(self.val_hash, (0, pad))
        self.wall = np.pad(self.wall, (0, pad))
        self.values.extend([None] * pad)

    def compact(self, *, force: bool = False) -> None:
        """Reclaim dead slots (stable order) when they outnumber live ones.

        Digests are untouched: compaction moves slots without changing the
        live set.  The per-key slot-list remap is one old→new index array
        (per-key lists only ever hold live slots, so every entry remaps).
        """
        live = self.n_slots - self.n_dead   # both counters are maintained
        if not force and self.n_dead <= max(live, _INITIAL_SLOTS):
            return
        keep = np.flatnonzero(self.valid[: self.n_slots])
        n = len(keep)
        self.vv[:n] = self.vv[keep]
        self.dot_id[:n] = self.dot_id[keep]
        self.dot_n[:n] = self.dot_n[keep]
        self.key_ix[:n] = self.key_ix[keep]
        self.slot_hash[:n] = self.slot_hash[keep]
        self.val_hash[:n] = self.val_hash[keep]
        self.wall[:n] = self.wall[keep]
        self.values[:n] = [self.values[s] for s in keep]
        self.valid[:n] = True
        self.valid[n:] = False
        self.key_ix[n:] = -1
        self.values[n:] = [None] * (len(self.values) - n)
        remap = np.full(self.n_slots, -1, np.int64)
        remap[keep] = np.arange(n)
        self.n_slots = n
        self.n_dead = 0
        for kix, slots in self._slots_by_key.items():
            if slots:
                new = remap[np.asarray(slots)]
                # lists must only ever hold live slots (kills prune them);
                # a -1 here means a kill path forgot to, which would
                # corrupt version sets silently downstream — fail loudly.
                assert (new >= 0).all(), (kix, slots)
                self._slots_by_key[kix] = new.tolist()
        # bucket→slot index holds only live slots, so every entry remaps
        self._bucket_slots = {
            b: {int(remap[s]) for s in slots}
            for b, slots in self._bucket_slots.items() if slots}

    # -- slot accessors ----------------------------------------------------

    def key_slots(self, key: str) -> List[int]:
        kix = self._key_index.get(key)
        if kix is None:
            return []
        return self._slots_by_key.get(kix, [])

    def key_clock_arrays(self, key: str
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(vv[K, R], dot_id[K], dot_n[K]) for one key — a view-copy slice."""
        slots = self.key_slots(key)
        R = self.n_replicas
        if not slots:
            return (np.zeros((0, R), np.int32), np.zeros(0, np.int32),
                    np.zeros(0, np.int32))
        s = np.asarray(slots)
        return self.vv[s, :R], self.dot_id[s], self.dot_n[s]

    def total_keys(self) -> int:
        return sum(1 for slots in self._slots_by_key.values() if slots)

    def total_versions(self) -> int:
        return int(self.valid[: self.n_slots].sum())

    def metadata_size(self, key: str) -> int:
        """Paper's space metric: 2 ints per plain component, 3 per dotted."""
        vv, dot_id, dot_n = self.key_clock_arrays(key)
        if vv.shape[0] == 0:
            return 0
        R = vv.shape[1]
        ar = np.arange(R, dtype=np.int32)
        plain = vv > 0
        dotted = (dot_id[:, None] == ar) & (dot_n[:, None] > 0)
        return int(2 * (plain & ~dotted).sum() + 3 * dotted.sum())

    # -- digest tree (delta anti-entropy, DESIGN.md §6) --------------------

    def _slot_hash_rows(self, vv: np.ndarray, dot_id: np.ndarray,
                        dot_n: np.ndarray, kix: np.ndarray) -> np.ndarray:
        """Canonical 64-bit hash per (clock, key) row, vectorized.

        The hash folds per-replica contributions keyed by the *replica-id
        string hash* (never the column index) with XOR, so it is invariant
        under column permutation, interning order and trailing zero columns
        — two replicas holding the same version of the same key always
        agree, whatever their universes look like.
        """
        vv = np.asarray(vv, np.int64)
        M, R = vv.shape
        rh = np.asarray(self._replica_hash[:R], _U64) if R else \
            np.zeros(0, _U64)
        with np.errstate(over="ignore"):
            if R:
                contrib = _mix64(rh[None, :] ^ (vv.astype(_U64) * _GOLD))
                contrib = np.where(vv > 0, contrib, _U64(0))
                h = np.bitwise_xor.reduce(contrib, axis=1)
            else:
                h = np.zeros(M, _U64)
            has_dot = np.asarray(dot_id) != NO_DOT
            safe = np.clip(dot_id, 0, max(R - 1, 0))
            dot_rh = rh[safe] if R else np.zeros(M, _U64)
            dot_h = _mix64(dot_rh ^ (np.asarray(dot_n, _U64) * _GOLD)
                           ^ _DOT_SALT)
            h ^= np.where(has_dot, dot_h, _U64(0))
            return _mix64(h ^ self._key_hash[np.asarray(kix)])

    def _digest_kill(self, slots: np.ndarray) -> None:
        """Remove ``slots`` from their buckets (xor out + live-count down)."""
        if not self.track_digests or not len(slots):
            return
        s = np.asarray(slots)
        b = self._key_bucket[self.key_ix[s]]
        np.bitwise_xor.at(self.digest, b, self.slot_hash[s])
        np.subtract.at(self._bucket_live, b, 1)
        self._digest_root ^= int(np.bitwise_xor.reduce(self.slot_hash[s]))
        self._value_root ^= int(np.bitwise_xor.reduce(
            _mix64(self.slot_hash[s] ^ self.val_hash[s])))

    def sync_digest(self) -> StoreDigest:
        """Snapshot the digest tree — phase 1 of a delta round.

        On a ``track_digests=False`` store this rebuilds from content first
        (O(live); such stores are staging scratch, not protocol peers)."""
        if not self.track_digests:
            self.rebuild_digests()
        return StoreDigest(self.digest.copy())

    def digest_root(self) -> int:
        """The tree root alone — the xor of all leaves, maintained
        incrementally.  The phase-0 probe of a sharded delta round: two
        stores whose roots (and value roots) agree are skipped for the
        cost of 16 bytes, without snapshotting either tree."""
        if not self.track_digests:
            self.rebuild_digests()
        return self._digest_root

    def value_root(self) -> int:
        """64-bit root of the store's *value content* (clock+key+value),
        maintained incrementally beside the digest tree.  Equal stores
        always agree; clock-equal/value-different slots — impossible under
        the protocol (a clock names one write), possible in stores fed
        arbitrary ``bulk_sync`` dicts — disagree here while the §6.1 clock
        digests collide, which is what routes delta rounds to the
        full-round fallback (DESIGN.md §6.1)."""
        if not self.track_digests:
            self.rebuild_digests()
        return self._value_root

    def bucket_counts(self, width: Optional[int] = None) -> np.ndarray:
        """Live slots per bucket at ``width`` (default: this store's) — the
        ranking signal for divergent-range requests (big ranges first).
        Maintained incrementally alongside the digests, so a delta round's
        ranking never sweeps the slot arrays."""
        width = width or self.n_buckets
        if not self.track_digests:
            live = self.valid[: self.n_slots]
            b = self._key_bucket[self.key_ix[: self.n_slots]] & (width - 1)
            return np.bincount(b[live], minlength=width)
        if width == self.n_buckets:
            return self._bucket_live.copy()
        return self._bucket_live.reshape(-1, width).sum(axis=0)

    def _maybe_grow_buckets(self) -> None:
        """Keep ~``_SLOTS_PER_BUCKET`` live slots per leaf: widen the tree
        as the store grows so delta-round granularity tracks store size.
        The O(live) digest rebuild amortizes over the inserts that
        triggered it; peers at the old width still diff via folding."""
        live = self.n_slots - self.n_dead
        grew = False
        while (live > self.n_buckets * _SLOTS_PER_BUCKET
               and self.n_buckets < _MAX_BUCKETS):
            self.n_buckets *= _BUCKET_GROWTH
            grew = True
        if grew:
            n = len(self.keys)
            self._key_bucket[:n] = (
                self._key_hash[:n] & _U64(self.n_buckets - 1)).astype(np.int32)
            self._rebuild_bucket_index()
            if self.track_digests:
                # width growth: slot/value hashes are width-invariant and
                # incrementally maintained — only re-bucket them
                self.rebuild_digests(values_too=False)

    def _rebuild_bucket_index(self) -> None:
        """Recompute the bucket→slot index from slot content (O(live))."""
        self._bucket_slots = {}
        live = np.flatnonzero(self.valid[: self.n_slots])
        buckets = self._key_bucket[self.key_ix[live]]
        for s, b in zip(live.tolist(), buckets.tolist()):
            self._bucket_slots.setdefault(int(b), set()).add(int(s))

    def check_bucket_index(self) -> bool:
        """True iff the incremental bucket→slot index matches a full scan."""
        live = np.flatnonzero(self.valid[: self.n_slots])
        buckets = self._key_bucket[self.key_ix[live]]
        expect: Dict[int, set] = {}
        for s, b in zip(live.tolist(), buckets.tolist()):
            expect.setdefault(int(b), set()).add(int(s))
        got = {b: set(v) for b, v in self._bucket_slots.items() if v}
        return expect == got

    def rebuild_digests(self, *, values_too: bool = True) -> np.ndarray:
        """Recompute buckets and live counts from slot content (in place).

        The incremental state must always equal this recomputation —
        ``check_digests`` asserts it in tests; calling this repairs a store
        whose digest state was corrupted (e.g. the collision probe).
        ``values_too=False`` trusts the incrementally-maintained per-slot
        value hashes (the bucket-width growth path: neither slot hashes
        nor value hashes depend on the width, but the per-value rehash is
        an O(live) Python loop worth skipping there).
        """
        live = np.flatnonzero(self.valid[: self.n_slots])
        R = self.n_replicas
        self.digest = np.zeros(self.n_buckets, _U64)
        self._bucket_live = np.zeros(self.n_buckets, np.int64)
        self._digest_root = 0
        self._value_root = 0
        if len(live):
            kixs = self.key_ix[live]
            hashes = self._slot_hash_rows(
                self.vv[live, :R], self.dot_id[live], self.dot_n[live], kixs)
            self.slot_hash[live] = hashes
            buckets = self._key_bucket[kixs]
            np.bitwise_xor.at(self.digest, buckets, hashes)
            np.add.at(self._bucket_live, buckets, 1)
            if values_too:
                self.val_hash[live] = np.asarray(
                    [_hash_value(self.values[int(s)]) for s in live], _U64)
            self._digest_root = int(np.bitwise_xor.reduce(hashes))
            self._value_root = int(np.bitwise_xor.reduce(
                _mix64(hashes ^ self.val_hash[live])))
        return self.digest

    def check_digests(self) -> bool:
        """True iff the incremental digest state matches a full recompute."""
        if not self.check_bucket_index():
            return False
        saved = (self.digest, self.slot_hash.copy(), self._bucket_live,
                 self.val_hash.copy(), self._value_root, self._digest_root)
        try:
            rebuilt = self.rebuild_digests()
            return (np.array_equal(rebuilt, saved[0])
                    and np.array_equal(self._bucket_live, saved[2])
                    and self._value_root == saved[4]
                    and self._digest_root == saved[5])
        finally:
            (self.digest, self.slot_hash, self._bucket_live,
             self.val_hash, self._value_root, self._digest_root) = saved

    # -- boundary codec (object clocks at the client API edge only) --------

    def encode_clock(self, clock: DVV) -> Tuple[np.ndarray, int, int]:
        """Encode one object clock into *this store's* universe (growing it)."""
        for r in clock.ids():
            self.intern_replica(r)
        R = self.n_replicas
        vv = np.zeros(R, np.int32)
        dot_id, dot_n = NO_DOT, 0
        for (r, m, n) in clock.components:
            col = self._replica_index[r]
            vv[col] = m
            if n:
                if dot_id != NO_DOT:
                    raise ValueError("packed store supports at most one dot")
                dot_id, dot_n = col, n
        return vv, dot_id, dot_n

    def decode_slot(self, slot: int) -> DVV:
        vv = self.vv[slot]
        return B.decode(vv[: self.n_replicas], int(self.dot_id[slot]),
                        int(self.dot_n[slot]), self.replica_ids)

    def versions(self, key: str) -> FrozenSet[Version]:
        """Client-edge decode of one key's live versions."""
        return frozenset(
            Version(self.decode_slot(s), self.values[s],
                    wall=float(self.wall[s]))
            for s in self.key_slots(key))

    def context_of(self, key: str) -> CausalContext:
        """The GET context token for one key, straight from the int32
        columns: per-replica ceiling ⌈S⌉ (max of ranges and dots) over the
        key's live slots.  Zero object-clock decodes — this is the packed
        backend's §5.4 compaction, O(siblings·R) integer max, O(R) output.
        """
        slots = self.key_slots(key)
        if not slots:
            return CausalContext()
        s = np.asarray(slots)
        R = self.n_replicas
        ceil = ceiling_from_rows(self.vv[s, :R], self.dot_id[s],
                                 self.dot_n[s])
        return CausalContext(entries=tuple(sorted(
            (self.replica_ids[c], int(ceil[c]))
            for c in range(R) if ceil[c] > 0)))

    def ceiling_of_entries(self, entries: Iterable[Tuple[str, int]]
                           ) -> np.ndarray:
        """A token's ceiling entries as a vv row in local columns (growing
        the universe for unseen replica ids).  The token-native twin of
        ``context_ceiling`` — no clock objects anywhere."""
        items = list(entries)
        for rid, _ in items:
            self.intern_replica(rid)
        vv = np.zeros(self.n_replicas, np.int32)
        for rid, n in items:
            col = self._replica_index[rid]
            vv[col] = max(vv[col], n)
        return vv

    # -- per-key mutation (control plane: PUT / replication messages) ------

    def _insert_slot(self, kix: int, vv: np.ndarray, dot_id: int, dot_n: int,
                     value: Any, wall: float = 0.0) -> int:
        self._ensure_capacity(1)
        s = self.n_slots
        self.vv[s, : len(vv)] = vv
        self.vv[s, len(vv):] = 0
        self.dot_id[s] = dot_id
        self.dot_n[s] = dot_n
        self.key_ix[s] = kix
        self.valid[s] = True
        self.values[s] = value
        self.wall[s] = wall
        if wall > self.max_wall:
            self.max_wall = wall
        self.n_slots += 1
        self._slots_by_key.setdefault(kix, []).append(s)
        bucket = int(self._key_bucket[kix])
        self._bucket_slots.setdefault(bucket, set()).add(s)
        if self.track_digests:
            R = self.n_replicas
            self.slot_hash[s] = self._slot_hash_rows(
                self.vv[s: s + 1, :R], self.dot_id[s: s + 1],
                self.dot_n[s: s + 1], self.key_ix[s: s + 1])[0]
            self.digest[bucket] ^= self.slot_hash[s]
            self._bucket_live[bucket] += 1
            self._digest_root ^= int(self.slot_hash[s])
            self.val_hash[s] = _U64(_hash_value(value))
            self._value_root ^= int(_mix64(self.slot_hash[s]
                                           ^ self.val_hash[s]))
        return s

    def _index_kill(self, slots: np.ndarray) -> None:
        """Drop ``slots`` from the bucket→slot index (before valid flips)."""
        buckets = self._key_bucket[self.key_ix[np.asarray(slots)]]
        for s, b in zip(np.asarray(slots).tolist(), buckets.tolist()):
            self._bucket_slots[int(b)].discard(int(s))

    def _kill_slots(self, kix: int, dead: Sequence[int]) -> None:
        if not len(dead):
            return
        self._digest_kill(np.asarray(dead))
        self._index_kill(np.asarray(dead))
        self.valid[np.asarray(dead)] = False
        self.n_dead += len(dead)
        deadset = set(int(d) for d in dead)
        self._slots_by_key[kix] = [
            s for s in self._slots_by_key[kix] if s not in deadset]

    def sync_key(self, key: str, inc_vv: np.ndarray, inc_dot_id: np.ndarray,
                 inc_dot_n: np.ndarray, inc_values: Sequence[Any],
                 inc_walls: Optional[Sequence[float]] = None) -> bool:
        """Merge incoming clocks (already in local columns) into one key.

        Pure numpy — the per-key path taken by PUT and replication-message
        delivery.  Local slots are listed first so duplicates keep the
        resident copy.  Returns True iff the key's version set changed.
        """
        kix = self.intern_key(key)
        slots = self._slots_by_key.get(kix, [])
        R = self.n_replicas
        L, M = len(slots), int(inc_vv.shape[0])
        if M == 0:
            return False
        before = self.versions(key) if self.shadow_hook is not None else None
        K = L + M
        vvs = np.zeros((K, R), np.int32)
        dids = np.full(K, NO_DOT, np.int32)
        dns = np.zeros(K, np.int32)
        if L:
            s = np.asarray(slots)
            vvs[:L] = self.vv[s, :R]
            dids[:L] = self.dot_id[s]
            dns[:L] = self.dot_n[s]
        vvs[L:, : inc_vv.shape[1]] = inc_vv
        dids[L:] = inc_dot_id
        dns[L:] = inc_dot_n

        mask = B.sync_mask_np(vvs, dids, dns, np.ones(K, bool))
        changed = False
        dead = [slots[j] for j in range(L) if not mask[j]]
        if dead:
            self._kill_slots(kix, dead)
            changed = True
        for j in range(M):
            if mask[L + j]:
                self._insert_slot(
                    kix, inc_vv[j], int(inc_dot_id[j]), int(inc_dot_n[j]),
                    inc_values[j],
                    wall=float(inc_walls[j]) if inc_walls is not None
                    else 0.0)
                changed = True
        if changed and before:
            self.shadow_hook(key, before)
        self.compact()
        self._maybe_grow_buckets()
        if changed and self.wal_hook is not None:
            self.wal_hook(self.payload(keys=(key,)))
        return changed

    def sync_key_objects(self, key: str, versions: Iterable[Version]) -> bool:
        """Boundary codec + merge for object versions reaching one key (the
        control-plane path: replication messages, object-payload staging).

        The deterministic (repr(clock), repr(value)) ordering decides
        duplicate-clock tie-breaks; keep it in this one place.
        """
        ordered = sorted(versions,
                         key=lambda v: (repr(v.clock), repr(v.value)))
        if not ordered:
            self.intern_key(key)
            return False
        rows = [self.encode_clock(v.clock) for v in ordered]
        R = self.n_replicas
        vv = np.zeros((len(rows), R), np.int32)
        for i, (row_vv, _, _) in enumerate(rows):
            vv[i, : len(row_vv)] = row_vv
        return self.sync_key(
            key, vv, np.asarray([r[1] for r in rows], np.int32),
            np.asarray([r[2] for r in rows], np.int32),
            [v.value for v in ordered], [v.wall for v in ordered])

    def update_key(self, key: str, ctx_vv: np.ndarray, coordinator: str,
                   value: Any, wall: float = 0.0
                   ) -> Tuple[np.ndarray, int, int]:
        """Paper §5.3 update, entirely in arrays.

        ``ctx_vv`` is the context ceiling ⌈S⌉ already in local columns
        (length ≤ R; zero-padded).  Mints the new clock with the dot at the
        coordinator, syncs it into the key, returns the new clock arrays.
        """
        r_ix = self.intern_replica(coordinator)
        R = self.n_replicas
        vv = np.zeros(R, np.int32)
        vv[: len(ctx_vv)] = ctx_vv
        lvv, ldid, ldn = self.key_clock_arrays(key)
        local_max = B.effective_ceil_np(lvv, ldid, ldn, r_ix) \
            if lvv.shape[0] else 0
        # Mirrors core.dvv.update: m = ⌈S⌉_r from the context, n = ⌈Sr⌉_r + 1.
        # The §5.4 invariant guarantees n > m (all r-events are known at r).
        dot_n = local_max + 1
        self.sync_key(key, vv[None, :], np.asarray([r_ix], np.int32),
                      np.asarray([dot_n], np.int32), [value], [wall])
        return vv, r_ix, dot_n

    def update_keys(self, updates: Sequence[Tuple[str, Iterable[Tuple[str,
                    int]], Any, float]], coordinator: str, *,
                    mask_fn=None) -> Tuple[np.ndarray, int, np.ndarray]:
        """Batched §5.3 update: mint one clock per key, then merge all of
        them with ONE grouped ``apply_payload`` pass (one scatter, one
        ``sync_mask`` evaluation — optionally the shape-bucketed jit/Pallas
        cache via ``mask_fn``) instead of K independent ``sync_key`` walks.

        ``updates`` is ``[(key, ceiling_entries, value, wall), ...]`` with
        *distinct* keys (a batch is a set of independent writes; two writes
        to one key have a client-side causal order and must be two calls).
        Returns ``(vv[M, R], r_ix, dot_n[M])`` for the minted clocks,
        aligned with ``updates``.
        """
        keys = [u[0] for u in updates]
        if len(set(keys)) != len(keys):
            raise ValueError("update_keys requires distinct keys per batch")
        r_ix = self.intern_replica(coordinator)
        for _, entries, _, _ in updates:
            for rid, _ in entries:
                self.intern_replica(rid)
        R = self.n_replicas
        M = len(updates)
        vv = np.zeros((M, R), np.int32)
        for i, (_, entries, _, _) in enumerate(updates):
            row = self.ceiling_of_entries(entries)   # universe pre-grown
            vv[i, : len(row)] = row
        # ⌈Sr⌉_r per key over the resident slots, one grouped scatter.
        kixs = [self.intern_key(k) for k in keys]
        lists = [self._slots_by_key.get(kx, []) for kx in kixs]
        loc_rows = np.asarray([s for l in lists for s in l], np.int64)
        loc_group = np.repeat(np.arange(M), [len(l) for l in lists])
        local_max = B.grouped_ceil_at_np(
            self.vv[loc_rows, r_ix], self.dot_id[loc_rows],
            self.dot_n[loc_rows], loc_group, M, r_ix)
        dot_n = (local_max + 1).astype(np.int32)
        minted = PackedPayload(
            replica_ids=tuple(self.replica_ids),
            keys=tuple(keys),
            vv=vv,
            dot_id=np.full(M, r_ix, np.int32),
            dot_n=dot_n,
            key_ix=np.arange(M, dtype=np.int32),
            values=tuple(u[2] for u in updates),
            wall=np.asarray([u[3] for u in updates], np.float64))
        self.apply_payload(minted, mask_fn=mask_fn)
        return vv, r_ix, dot_n

    def context_ceiling(self, context: Iterable[DVV]) -> np.ndarray:
        """⌈S⌉ of a client context (object clocks — the API edge), in local
        columns, growing the universe for unseen replica ids."""
        clocks = list(context)
        for c in clocks:
            for r in c.ids():
                self.intern_replica(r)
        vv = np.zeros(self.n_replicas, np.int32)
        for c in clocks:
            for (r, m, n) in c.components:
                col = self._replica_index[r]
                vv[col] = max(vv[col], m, n)
        return vv

    # -- bulk anti-entropy (the hot path: arrays in, arrays out) -----------

    def payload(self, keys: Optional[Iterable[str]] = None, *,
                key_ranges: Optional[Sequence[int]] = None,
                ranges_width: Optional[int] = None) -> PackedPayload:
        """Extract the live slots for ``keys`` (default: all) as one payload.

        ``key_ranges`` selects by digest bucket instead: only live slots
        whose key hashes into one of the given buckets are shipped — the
        phase-2 slice of a delta round, gathered from the incremental
        bucket→slot index in O(selected slots), not O(store).
        ``ranges_width`` interprets the bucket ids at a narrower
        power-of-two width (a peer with a smaller tree; must divide this
        store's width).  Pure array slicing — zero object decode either
        way.
        """
        R = self.n_replicas
        if keys is not None and key_ranges is not None:
            raise ValueError("pass keys or key_ranges, not both")
        if key_ranges is not None:
            width = ranges_width or self.n_buckets
            if width > self.n_buckets or self.n_buckets % width:
                raise ValueError(
                    f"ranges_width {width} incompatible with "
                    f"{self.n_buckets} buckets")
            # A narrow bucket ``b`` at ``width`` is the fold of the local
            # buckets {b + j·width}; union their slot sets from the index.
            cand: List[int] = []
            for b in key_ranges:
                for j in range(self.n_buckets // width):
                    slots = self._bucket_slots.get(int(b) + j * width)
                    if slots:
                        cand.extend(slots)
            rows = np.asarray(sorted(cand), dtype=np.int64)
            uniq, inv = np.unique(self.key_ix[rows], return_inverse=True) \
                if len(rows) else (np.zeros(0, np.int64), np.zeros(0,
                                                                   np.int64))
            sel_keys = [self.keys[int(kx)] for kx in uniq]
            out_kix = inv.astype(np.int32)
        elif keys is None:
            rows = np.flatnonzero(self.valid[: self.n_slots])
            kixs = self.key_ix[rows]
            sel_keys = self.keys
            out_kix = kixs.astype(np.int32)
        else:
            want = [self._key_index[k] for k in keys if k in self._key_index]
            sel_keys = [self.keys[kx] for kx in want]
            rows_l: List[int] = []
            out_l: List[int] = []
            for out_ix, kx in enumerate(want):
                for s in self._slots_by_key.get(kx, []):
                    rows_l.append(s)
                    out_l.append(out_ix)
            rows = np.asarray(rows_l, dtype=np.int64)
            out_kix = np.asarray(out_l, dtype=np.int32)
        if len(rows) == 0:
            return PackedPayload(tuple(self.replica_ids), tuple(sel_keys),
                                 np.zeros((0, R), np.int32),
                                 np.zeros(0, np.int32), np.zeros(0, np.int32),
                                 np.zeros(0, np.int32), ())
        return PackedPayload(
            replica_ids=tuple(self.replica_ids),
            keys=tuple(sel_keys),
            vv=self.vv[rows, :R].copy(),
            dot_id=self.dot_id[rows].copy(),
            dot_n=self.dot_n[rows].copy(),
            key_ix=out_kix,
            values=tuple(self.values[int(s)] for s in rows),
            wall=self.wall[rows].copy(),
        )

    def _remap_columns(self, payload: PackedPayload
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Map payload columns into the local universe with one gather."""
        col_map = np.asarray(
            [self.intern_replica(r) for r in payload.replica_ids], np.int64)
        return remap_rows(payload.vv, payload.dot_id, col_map,
                          self.n_replicas)

    def apply_payload(self, payload: PackedPayload, *,
                      mask_fn=None) -> int:
        """One anti-entropy round: remap → group → sync_mask → write-back.

        ``mask_fn(vvs[N, K, R], dot_ids[N, K], dot_ns[N, K], valid[N, K])
        -> bool[N, K]`` defaults to the numpy reference twin of
        ``core.batched.sync_mask``; pass ``kernels.dvv_ops.dvv_sync_mask``
        for the fused Pallas kernel.  Returns the number of keys whose
        version set changed.

        Fully vectorized: grouping is one stable sort + two fancy-index
        scatters; write-back is one masked kill + one bulk append.  No
        per-key DVV objects, no per-key numpy calls.
        """
        M = len(payload)
        if M == 0:
            return 0
        inc_vv, inc_did = self._remap_columns(payload)
        inc_dn = payload.dot_n
        # Collapse duplicate payload keys to one group each (a caller can
        # legitimately request the same key twice, e.g. antientropy with a
        # repeated key list); two groups for one key would double-insert.
        key_ixs_all = np.asarray(
            [self.intern_key(k) for k in payload.keys], np.int64)
        key_ixs, inverse = np.unique(key_ixs_all, return_inverse=True)
        R = self.n_replicas
        N = len(key_ixs)
        before_sets = None
        if self.shadow_hook is not None:
            before_sets = [self.versions(self.keys[int(kx)])
                           for kx in key_ixs]

        # One group per payload key; local resident slots occupy the first
        # positions (duplicates keep the resident copy), incoming rows
        # follow in payload order.
        local_lists = [self._slots_by_key.get(int(kx), []) for kx in key_ixs]
        loc_counts = np.asarray([len(l) for l in local_lists], np.int64)
        loc_rows = np.asarray(
            [s for l in local_lists for s in l], dtype=np.int64)
        loc_group = np.repeat(np.arange(N), loc_counts)
        loc_start = np.zeros(N + 1, np.int64)
        np.cumsum(loc_counts, out=loc_start[1:])
        loc_pos = np.arange(len(loc_rows)) - loc_start[loc_group]

        inc_group = inverse[payload.key_ix]
        order = np.argsort(inc_group, kind="stable")
        sorted_g = inc_group[order]
        run_start = np.searchsorted(sorted_g, np.arange(N))
        inc_pos = np.empty(M, np.int64)
        inc_pos[order] = np.arange(M) - run_start[sorted_g]
        inc_pos += loc_counts[inc_group]

        counts = loc_counts + np.bincount(inc_group, minlength=N)
        K = int(counts.max(initial=1))
        vvs = np.zeros((N, K, R), np.int32)
        dids = np.full((N, K), NO_DOT, np.int32)
        dns = np.zeros((N, K), np.int32)
        valid = np.zeros((N, K), bool)
        if len(loc_rows):
            vvs[loc_group, loc_pos] = self.vv[loc_rows, :R]
            dids[loc_group, loc_pos] = self.dot_id[loc_rows]
            dns[loc_group, loc_pos] = self.dot_n[loc_rows]
            valid[loc_group, loc_pos] = True
        vvs[inc_group, inc_pos] = inc_vv
        dids[inc_group, inc_pos] = inc_did
        dns[inc_group, inc_pos] = inc_dn
        valid[inc_group, inc_pos] = True

        if mask_fn is None:
            mask = B.sync_mask_np(vvs, dids, dns, valid)
        else:
            mask = np.asarray(mask_fn(vvs, dids, dns, valid))

        # -- write-back: masked kill of local slots ------------------------
        changed_groups = np.zeros(N, bool)
        if len(loc_rows):
            loc_keep = mask[loc_group, loc_pos]
            dead_rows = loc_rows[~loc_keep]
            if len(dead_rows):
                self._digest_kill(dead_rows)
                self._index_kill(dead_rows)
                self.valid[dead_rows] = False
                self.n_dead += len(dead_rows)
                dead_set = set(dead_rows.tolist())
                for g in np.unique(loc_group[~loc_keep]):
                    kix = int(key_ixs[g])
                    self._slots_by_key[kix] = [
                        s for s in self._slots_by_key[kix]
                        if s not in dead_set]
                changed_groups[loc_group[~loc_keep]] = True

        # -- write-back: bulk append of surviving incoming rows ------------
        new_rows = np.flatnonzero(mask[inc_group, inc_pos])
        n_new = len(new_rows)
        if n_new:
            self._ensure_capacity(n_new)
            s0 = self.n_slots
            dst = s0 + np.arange(n_new)
            self.vv[dst, :R] = inc_vv[new_rows]
            self.vv[dst, R:] = 0
            self.dot_id[dst] = inc_did[new_rows]
            self.dot_n[dst] = inc_dn[new_rows]
            self.wall[dst] = payload.wall[new_rows]
            new_max = float(payload.wall[new_rows].max())
            if new_max > self.max_wall:
                self.max_wall = new_max
            groups_new = inc_group[new_rows]
            kix_new = key_ixs[groups_new]
            self.key_ix[dst] = kix_new
            self.valid[dst] = True
            new_buckets = self._key_bucket[kix_new]
            if self.track_digests:
                new_hashes = self._slot_hash_rows(
                    inc_vv[new_rows], inc_did[new_rows], inc_dn[new_rows],
                    kix_new)
                self.slot_hash[dst] = new_hashes
                np.bitwise_xor.at(self.digest, new_buckets, new_hashes)
                np.add.at(self._bucket_live, new_buckets, 1)
                self._digest_root ^= int(np.bitwise_xor.reduce(new_hashes))
                vhs = np.asarray([_hash_value(payload.values[int(r)])
                                  for r in new_rows], _U64)
                self.val_hash[dst] = vhs
                self._value_root ^= int(np.bitwise_xor.reduce(
                    _mix64(new_hashes ^ vhs)))
            for i, row in enumerate(new_rows):
                self.values[s0 + i] = payload.values[int(row)]
                self._slots_by_key[int(kix_new[i])].append(s0 + i)
                self._bucket_slots.setdefault(
                    int(new_buckets[i]), set()).add(s0 + i)
            self.n_slots += n_new
            changed_groups[groups_new] = True

        if before_sets is not None:
            for g in np.flatnonzero(changed_groups):
                bs = before_sets[int(g)]
                if bs:
                    self.shadow_hook(self.keys[int(key_ixs[int(g)])], bs)
        self.compact()
        self._maybe_grow_buckets()
        if self.wal_hook is not None and changed_groups.any():
            changed_keys = [self.keys[int(key_ixs[int(g)])]
                            for g in np.flatnonzero(changed_groups)]
            self.wal_hook(self.payload(keys=changed_keys))
        return int(changed_groups.sum())

    # -- misc ---------------------------------------------------------------

    def clone(self) -> "PackedVersionStore":
        out = PackedVersionStore(n_buckets=self.n_buckets,
                                 track_digests=self.track_digests)
        out.vv = self.vv.copy()
        out.dot_id = self.dot_id.copy()
        out.dot_n = self.dot_n.copy()
        out.key_ix = self.key_ix.copy()
        out.valid = self.valid.copy()
        out.values = list(self.values)
        out.wall = self.wall.copy()
        out.max_wall = self.max_wall
        out.n_slots = self.n_slots
        out.n_dead = self.n_dead
        out.replica_ids = list(self.replica_ids)
        out._replica_index = dict(self._replica_index)
        out.keys = list(self.keys)
        out._key_index = dict(self._key_index)
        out._slots_by_key = {k: list(v) for k, v in self._slots_by_key.items()}
        out.slot_hash = self.slot_hash.copy()
        out.val_hash = self.val_hash.copy()
        out._value_root = self._value_root
        out._digest_root = self._digest_root
        out.digest = self.digest.copy()
        out._bucket_live = self._bucket_live.copy()
        out._replica_hash = list(self._replica_hash)
        out._key_hash = self._key_hash.copy()
        out._key_bucket = self._key_bucket.copy()
        out._bucket_slots = {b: set(v) for b, v in self._bucket_slots.items()}
        return out

    def __repr__(self) -> str:
        return (f"<PackedVersionStore keys={self.total_keys()} "
                f"versions={self.total_versions()} R={self.n_replicas}>")


# ---------------------------------------------------------------------------
# Quorum GET merge — arrays across stores, zero object-clock decodes.
# ---------------------------------------------------------------------------

def _clock_key(vv_row: Sequence[int], dot_col: int, dot_n: int,
               sorted_cols: Sequence[Tuple[str, int]]) -> str:
    """Canonical clock string from plain ints + a pre-sorted (rid, col)
    table — the inner loop of the batched read plane (the table is built
    once per quorum group, not once per row)."""
    parts = []
    for rid, col in sorted_cols:
        m = vv_row[col]
        n = dot_n if col == dot_col else 0
        if m or n:
            parts.append(f"({rid},{m})" if n == 0 else f"({rid},{m},{n})")
    return "{" + ", ".join(parts) + "}"


def _clock_sort_key(vv_row: np.ndarray, dot_col: int, dot_n: int,
                    ids: Sequence[str]) -> str:
    """A canonical string for one packed clock, equal by construction to
    ``repr(B.decode(...))`` — the resolution tie-break of GetResult.value,
    produced without building a DVV object."""
    return _clock_key([int(x) for x in vv_row], int(dot_col), int(dot_n),
                      sorted((ids[c], c) for c in range(len(ids))))


@dataclass
class MergedRead:
    """One key's merged quorum read, straight from the int32 columns.

    ``values``/``walls``/``clock_keys`` are row-aligned with the surviving
    clock rows ``vv``/``dot_id``/``dot_n`` (columns follow ``replica_ids``,
    the union universe of the key's quorum group); ``entries`` is the §5.4
    context ceiling of the survivors.  ``stale`` lists the indices — into
    the key's store list as passed to ``quorum_merge_many`` — of quorum
    members whose live row set for the key differs from the survivors
    (row identity = clock + value content): they are missing a surviving
    version, holding a dominated one, or carrying a divergent value under
    an equal clock.  That is the read-repair signal
    (``KVCluster.get_many(repair=True)``).
    """

    replica_ids: Tuple[str, ...]
    vv: np.ndarray          # int32[S, Ru] surviving rows
    dot_id: np.ndarray      # int32[S]
    dot_n: np.ndarray       # int32[S]
    values: List[Any]
    walls: List[float]
    clock_keys: List[str]
    entries: Tuple[Tuple[str, int], ...]
    stale: Tuple[int, ...] = ()


def quorum_merge_many(stores_by_key: Mapping[str,
                                             Sequence[PackedVersionStore]],
                      keys: Sequence[str], *,
                      mask_fn=None, sweep_fn=None,
                      track_stale: bool = True) -> Dict[str, "MergedRead"]:
    """Merge many keys' version sets across their read quorums in one sweep.

    The whole §4 read path, batched: keys are grouped by quorum set (the
    identity tuple of their contacted stores); per group, every store's
    slots for *all* group keys are remapped into one union replica universe
    with a single gather per store (the replica-id→union-column map is
    built once per store, not rebuilt per key), all rows are stacked into
    one grouped ``[N, K, R]`` tensor, survival is evaluated with a single
    ``sync_mask`` sweep (``mask_fn`` routes it through the §6.4 shape
    buckets — ``core.batched.BucketedSyncMask`` or ``kernels.dvv_ops.
    dvv_sync_mask_bucketed``; ``None`` is the numpy reference), and the
    per-key §5.4 ceilings come from one ``grouped_ceiling_np`` segment
    reduce.  ``sweep_fn`` (wins over ``mask_fn``) fuses both steps on
    device — a ``(vvs, dids, dns, valid) → (mask, ceil)`` callable like
    ``kernels.dvv_ops.dvv_read_sweep_bucketed``, the path
    ``use_kernel=True`` reads take.  No ``DVV`` object is created
    anywhere.

    Returns ``{key: MergedRead}`` — survivors plus the per-member staleness
    signal read-repair consumes (``track_stale=False`` skips that
    bookkeeping for pure reads).  Staleness is *content*-aware: row
    identity includes the value repr, so the clock-equal/value-different
    state (impossible under the protocol, reachable via non-protocol
    ``bulk_sync`` feeds — the §6.1 value-root gap) is flagged rather than
    silently reported converged, mirroring the delta round's fallback
    stance; like that fallback, sync itself cannot reconcile equal-clock
    values (the resident copy wins).  Byte-identical to the per-key
    ``quorum_merge_key`` (which is now a one-key wrapper over this).
    """
    out: Dict[str, MergedRead] = {}
    groups: Dict[Tuple[int, ...], List[str]] = {}
    for k in keys:
        groups.setdefault(
            tuple(id(st) for st in stores_by_key[k]), []).append(k)
    for gkeys in groups.values():
        stores = list(stores_by_key[gkeys[0]])
        N = len(gkeys)
        # Union replica universe + per-store column maps, built ONCE per
        # group — the per-key rebuild was the looped read path's tax.
        ids: List[str] = []
        index: Dict[str, int] = {}
        col_maps: List[np.ndarray] = []
        for st in stores:
            cols = np.empty(st.n_replicas, np.int64)
            for j, rid in enumerate(st.replica_ids):
                ix = index.get(rid)
                if ix is None:
                    ix = index[rid] = len(ids)
                    ids.append(rid)
                cols[j] = ix
            col_maps.append(cols)
        Ru = len(ids)
        # One gather per store: all of its rows for all group keys at once.
        chunk_vv, chunk_did, chunk_dn, chunk_wall = [], [], [], []
        chunk_group, chunk_src = [], []
        values: List[Any] = []
        for j, (st, cols) in enumerate(zip(stores, col_maps)):
            lists = [st.key_slots(k) for k in gkeys]
            rows = np.asarray([s for l in lists for s in l], np.int64)
            if not len(rows):
                continue
            cv, cdid = remap_rows(st.vv[rows, : st.n_replicas],
                                  st.dot_id[rows], cols, Ru)
            chunk_vv.append(cv)
            chunk_did.append(cdid)
            chunk_dn.append(st.dot_n[rows])
            chunk_wall.append(st.wall[rows])
            chunk_group.append(
                np.repeat(np.arange(N), [len(l) for l in lists]))
            chunk_src.append(np.full(len(rows), j, np.int64))
            values.extend(st.values[int(s)] for s in rows)
        if not chunk_vv:                      # no store holds any group key
            for key in gkeys:
                out[key] = MergedRead(tuple(ids), np.zeros((0, Ru), np.int32),
                                      np.zeros(0, np.int32),
                                      np.zeros(0, np.int32), [], [], [], ())
            continue
        vv = np.concatenate(chunk_vv)
        did = np.concatenate(chunk_did)
        dn = np.concatenate(chunk_dn)
        wall = np.concatenate(chunk_wall)
        group = np.concatenate(chunk_group)
        src = np.concatenate(chunk_src)
        # Stable sort by key: within a key, rows stay store-major in slot
        # order — the same duplicate tie-break as the per-key merge.
        order = np.argsort(group, kind="stable")
        vv, did, dn, wall = vv[order], did[order], dn[order], wall[order]
        group, src = group[order], src[order]
        values = [values[int(i)] for i in order]
        M = len(group)
        counts = np.bincount(group, minlength=N)
        starts = np.zeros(N + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        pos = np.arange(M) - starts[group]
        K = int(counts.max(initial=1))
        vvs = np.zeros((N, K, Ru), np.int32)
        dids = np.full((N, K), NO_DOT, np.int32)
        dns = np.zeros((N, K), np.int32)
        valid = np.zeros((N, K), bool)
        vvs[group, pos] = vv
        dids[group, pos] = did
        dns[group, pos] = dn
        valid[group, pos] = True
        ceil = None
        if sweep_fn is not None:              # fused survival + ceilings
            mask, ceil = sweep_fn(vvs, dids, dns, valid)
            mask, ceil = np.asarray(mask), np.asarray(ceil)
        elif mask_fn is None:
            mask = B.sync_mask_np(vvs, dids, dns, valid)
        else:
            mask = np.asarray(mask_fn(vvs, dids, dns, valid))
        surv = mask[group, pos]
        # One survivor gather for the whole group; per-key outputs are
        # contiguous slices of it (rows are group-sorted already).
        s_all = np.flatnonzero(surv)
        vv_s, did_s, dn_s = vv[s_all], did[s_all], dn[s_all]
        if ceil is None:
            ceil = B.grouped_ceiling_np(vv_s, did_s, dn_s, group[s_all], N)
        sb = np.zeros(N + 1, np.int64)
        np.cumsum(np.bincount(group[s_all], minlength=N), out=sb[1:])
        # plain-int views: the string/set building below is pure Python
        s_list = s_all.tolist()
        vv_l, did_l, dn_l = vv_s.tolist(), did_s.tolist(), dn_s.tolist()
        wall_l = wall[s_all].tolist()
        ceil_l = ceil.tolist()
        sorted_cols = sorted((rid, c) for c, rid in enumerate(ids))
        n_stores = len(stores)
        ids_t = tuple(ids)
        for g, key in enumerate(gkeys):
            lo, hi = int(sb[g]), int(sb[g + 1])
            stale: Tuple[int, ...] = ()
            if track_stale:
                surv_set = set()
                member: List[set] = [set() for _ in range(n_stores)]
                for i in range(int(starts[g]), int(starts[g + 1])):
                    # row identity = clock AND value content: the
                    # clock-equal/value-different state (§6.1 gap) must
                    # flag as stale, never read as converged
                    rk = (vv[i].tobytes(), int(did[i]), int(dn[i]),
                          repr(values[i]))
                    member[int(src[i])].add(rk)
                    if surv[i]:
                        surv_set.add(rk)
                stale = tuple(j for j in range(n_stores)
                              if member[j] != surv_set)
            cg = ceil_l[g]
            out[key] = MergedRead(
                replica_ids=ids_t,
                vv=vv_s[lo:hi],
                dot_id=did_s[lo:hi],
                dot_n=dn_s[lo:hi],
                values=[values[i] for i in s_list[lo:hi]],
                walls=wall_l[lo:hi],
                clock_keys=[_clock_key(vv_l[i], did_l[i], dn_l[i],
                                       sorted_cols) for i in range(lo, hi)],
                entries=tuple(sorted(
                    (ids_t[c], cg[c]) for c in range(Ru) if cg[c] > 0)),
                stale=stale)
    return out


def quorum_merge_key(stores: Sequence[PackedVersionStore], key: str
                     ) -> Tuple[List[Any], List[float], List[str],
                                Tuple[Tuple[str, int], ...]]:
    """Merge one key's version sets across a read quorum of packed stores:
    the single-key view of ``quorum_merge_many`` (one group, one key).
    Returns ``(values, walls, clock_keys, ceiling_entries)`` for the
    survivors — no ``DVV`` object is created anywhere (the acceptance
    criterion for packed GET)."""
    m = quorum_merge_many({key: tuple(stores)}, (key,))[key]
    return m.values, m.walls, m.clock_keys, m.entries
