"""Opaque causal-context tokens — the client-facing causality currency.

The paper's client workflow (§4.1, §5.4) is GET → (values, *opaque*
context) → PUT(context).  §5.4's key observation is that the context a
client carries between those two steps can be *compacted to the ceiling*
of the returned clock set — a single version vector ⌈S⌉ — without losing
any causality information for the subsequent update: ``update`` only ever
reads per-replica ceilings of the context, and GET contexts are downsets,
so the ceiling VV denotes exactly the union of the siblings' histories.

``CausalContext`` is that compaction reified as a wire token:

* ``entries`` — the compacted ceiling, a sorted ``(replica_id, n)`` tuple.
  O(R) in the replica universe, *independent of the sibling count* — five
  concurrent siblings over two replicas still cost two entries.
* ``residue`` — clocks of mechanisms with no VV ceiling (causal-history
  oracles, LWW stamps, plain VVs of the §3 baselines).  DVV clocks are
  always folded into ``entries``; the residue exists so the token stays a
  faithful context for every mechanism the store can run, not just DVV.

Tokens encode to ``bytes`` (``to_bytes``/``from_bytes``) so real clients
can carry them across processes; the DVV encoding is a fixed-layout binary
record (O(R)), while residues fall back to pickle (the token is a server
artifact, mirroring how Riak vclocks travel base64'd through clients that
must not interpret them).  Because tokens pass *through* clients, decoding
is defensive: any malformed token fails with a clean ``ValueError`` and
residue blobs are unpickled through a restricted loader that only admits
this package's clock classes and plain containers — never callables.

The token is deliberately *iterable as a clock set* — legacy code (and the
formal-condition property tests) that treats a context as a set of clocks
keeps working: iterating a DVV token yields the single ceiling clock,
whose history equals the union of the original siblings' histories.
"""
from __future__ import annotations

import io
import pickle
import struct
import warnings
from dataclasses import dataclass
from typing import Any, FrozenSet, Iterable, Iterator, Tuple

from ..core.dvv import DVV

_MAGIC = b"DCX1"                    # wire-format tag + version

#: Exactly the globals a residue blob may reference: the clock classes of
#: the pluggable mechanisms plus plain containers.  Never callables like
#: eval/exec/getattr, and never whole modules — pickle protocol ≥ 4
#: resolves *dotted* names through ``find_class``, so a prefix allowance
#: (e.g. all of ``repro.*``) would let ``repro.anything:os.system``
#: through via the module's own imports.  Exact (module, name) pairs
#: only, dots rejected.
_SAFE_RESIDUE_GLOBALS = frozenset({
    ("builtins", "frozenset"), ("builtins", "set"), ("builtins", "tuple"),
    ("builtins", "list"), ("builtins", "dict"), ("builtins", "int"),
    ("builtins", "float"), ("builtins", "complex"), ("builtins", "str"),
    ("builtins", "bytes"), ("builtins", "bool"), ("builtins", "NoneType"),
    ("repro.core.dvv", "DVV"),
    ("repro.core.version_vector", "VV"),
    ("repro.core.lww", "WallClock"),
    ("repro.core.lww", "LamportClock"),
    ("repro.core.causal_history", "CausalHistory"),
})


class _ResidueUnpickler(pickle.Unpickler):
    """Unpickler for token residues restricted to the exact clock classes
    and plain containers above.  Tokens are server artifacts, but they
    travel through clients — a crafted ``__reduce__`` gadget in the blob
    must be rejected, not executed."""

    def find_class(self, module: str, name: str):
        if "." not in name and (module, name) in _SAFE_RESIDUE_GLOBALS:
            return super().find_class(module, name)
        raise pickle.UnpicklingError(
            f"token residue may not reference {module}.{name}")


@dataclass(frozen=True)
class CausalContext:
    """An opaque, wire-serializable causal context (paper §5.4)."""

    entries: Tuple[Tuple[str, int], ...] = ()   # compacted ceiling ⌈S⌉
    residue: Tuple[Any, ...] = ()               # non-DVV clocks, verbatim
    # HLC watermark of the read this token came from (geo tier, DESIGN.md
    # §12): the max encoded wall among returned versions.  Coordinators
    # fold it into their hybrid clock before minting, so a write causally
    # after a read always carries a larger wall than everything the read
    # saw.  0.0 (the non-geo default) encodes to the exact pre-geo byte
    # layout.
    hlc: float = 0.0

    # -- construction ------------------------------------------------------

    @staticmethod
    def from_clocks(clocks: Iterable[Any]) -> "CausalContext":
        """Compact a clock set: DVV components fold into the ceiling VV
        (max of range top and dot — exact for §5.4 downset contexts);
        anything else rides along as residue."""
        ceiling = {}
        residue = []
        for c in clocks:
            if isinstance(c, DVV):
                for (r, m, n) in c.components:
                    ceiling[r] = max(ceiling.get(r, 0), m, n)
            else:
                residue.append(c)
        return CausalContext(
            entries=tuple(sorted(ceiling.items())),
            residue=tuple(sorted(residue, key=repr)))

    @classmethod
    def coerce(cls, context: Any) -> "CausalContext":
        """Normalize anything a caller may pass as a context.

        Accepts a token, its ``bytes`` encoding, ``None``, or — via the
        deprecation shim — a legacy set/frozenset of clock objects."""
        if context is None:
            return EMPTY_CONTEXT
        if isinstance(context, cls):
            return context
        if isinstance(context, (bytes, bytearray, memoryview)):
            return cls.from_bytes(bytes(context))
        if isinstance(context, (frozenset, set, tuple, list)):
            if context:   # the empty set doubles as "new session"; no nag
                warnings.warn(
                    "passing raw clock sets as PUT contexts is deprecated; "
                    "pass the GetResult.context token (or its to_bytes())",
                    DeprecationWarning, stacklevel=3)
            return cls.from_clocks(context)
        raise TypeError(f"cannot interpret {type(context).__name__} "
                        f"as a causal context")

    # -- views -------------------------------------------------------------

    @property
    def is_empty(self) -> bool:
        return not self.entries and not self.residue

    def __bool__(self) -> bool:
        return not self.is_empty

    def to_clock_set(self) -> FrozenSet[Any]:
        """The object-clock view ``mechanism.update`` consumes: one ceiling
        DVV (when any DVV state was compacted) plus the residue."""
        out = set(self.residue)
        if self.entries:
            out.add(DVV(tuple((r, n, 0) for r, n in self.entries)))
        return frozenset(out)

    def __iter__(self) -> Iterator[Any]:
        return iter(self.to_clock_set())

    def __len__(self) -> int:
        return len(self.to_clock_set())

    def ceiling_items(self) -> Tuple[Tuple[str, int], ...]:
        """Per-replica ceilings, with residue clocks folded in when they
        expose ``ids()/ceil()`` (DVV/VV-shaped).  This is what the packed
        store consumes — no clock object is ever constructed from it."""
        merged = dict(self.entries)
        for c in self.residue:
            if not hasattr(c, "ids") or not hasattr(c, "ceil"):
                raise TypeError(
                    f"clock {type(c).__name__} has no VV ceiling; this "
                    f"context cannot drive an array-native update")
            for r in c.ids():
                merged[r] = max(merged.get(r, 0), c.ceil(r))
        return tuple(sorted(merged.items()))

    # -- wire codec --------------------------------------------------------

    def to_bytes(self) -> bytes:
        """Encode for the wire.  O(R) for DVV contexts: a fixed header,
        then one length-prefixed id + uint64 per replica entry.  The header
        byte is a flag bitfield — bit 0: residue pickle appended, bit 1:
        an 8-byte HLC watermark follows the entries.  A zero watermark is
        simply not encoded, so pre-geo tokens are byte-identical.  Residues
        (non-DVV mechanisms only) append a pickle blob last."""
        flags = (1 if self.residue else 0) | (2 if self.hlc else 0)
        parts = [_MAGIC, struct.pack("<BH", flags, len(self.entries))]
        for r, n in self.entries:
            rid = r.encode()
            parts.append(struct.pack("<H", len(rid)))
            parts.append(rid)
            parts.append(struct.pack("<Q", n))
        if self.hlc:
            parts.append(struct.pack("<d", self.hlc))
        if self.residue:
            parts.append(pickle.dumps(self.residue))
        return b"".join(parts)

    @staticmethod
    def from_bytes(data: bytes) -> "CausalContext":
        """Decode a wire token.  Malformed input — empty, truncated at any
        field boundary, bad magic, trailing garbage, undecodable ids — is
        rejected with ``ValueError`` before any entry escapes: a client
        handing us a corrupt token gets a clean error, never a context
        holding half its causal history."""
        if len(data) < 4 or data[:4] != _MAGIC:
            raise ValueError("not a CausalContext token (bad magic)")
        if len(data) < 7:
            raise ValueError("truncated CausalContext token (header)")
        flags, count = struct.unpack_from("<BH", data, 4)
        if flags & ~3:
            raise ValueError("corrupt CausalContext token (flags)")
        has_residue, has_hlc = flags & 1, flags & 2
        off = 7
        entries = []
        for i in range(count):
            if off + 2 > len(data):
                raise ValueError(
                    f"truncated CausalContext token (entry {i} length)")
            (rlen,) = struct.unpack_from("<H", data, off)
            off += 2
            if off + rlen + 8 > len(data):
                raise ValueError(
                    f"truncated CausalContext token (entry {i} body)")
            try:
                rid = data[off: off + rlen].decode()
            except UnicodeDecodeError as e:
                raise ValueError(
                    f"corrupt CausalContext token (entry {i} id)") from e
            off += rlen
            (n,) = struct.unpack_from("<Q", data, off)
            off += 8
            entries.append((rid, n))
        hlc = 0.0
        if has_hlc:
            if off + 8 > len(data):
                raise ValueError(
                    "truncated CausalContext token (hlc watermark)")
            (hlc,) = struct.unpack_from("<d", data, off)
            off += 8
            if not (hlc > 0.0):     # also rejects NaN, -0.0 and negatives
                raise ValueError(
                    "corrupt CausalContext token (hlc watermark)")
        residue: Tuple[Any, ...] = ()
        if has_residue:
            stream = io.BytesIO(data[off:])
            try:
                residue = _ResidueUnpickler(stream).load()
            except Exception as e:
                raise ValueError(
                    "corrupt CausalContext token (residue)") from e
            if stream.read(1):       # pickle STOPs early on trailing bytes
                raise ValueError(
                    "corrupt CausalContext token (trailing bytes)")
            if not isinstance(residue, tuple):
                raise ValueError(
                    "corrupt CausalContext token (residue shape)")
        elif off != len(data):
            raise ValueError("corrupt CausalContext token (trailing bytes)")
        return CausalContext(entries=tuple(entries), residue=residue,
                             hlc=hlc)

    def __repr__(self) -> str:
        ent = ",".join(f"{r}:{n}" for r, n in self.entries)
        res = f"+{len(self.residue)}res" if self.residue else ""
        mark = f"@{self.hlc:g}" if self.hlc else ""
        return f"<ctx {ent or '∅'}{res}{mark}>"


#: The canonical "new session" context (no causal dependencies).
EMPTY_CONTEXT = CausalContext()
