"""Coalescing serving plane: cross-session batching over the vectorized
planes, plus the closed-loop workload engine that drives it (DESIGN.md §11).

PRs 3/5/6 made each *individual* ``get_many``/``put_many`` call one
vectorized sweep, but every caller still pays the plane's fixed cost
(grouping, union-universe gather, jit-bucket lookup, per-destination
payload assembly) by itself.  ``OpScheduler`` amortizes that cost across
callers, Okapi-style: concurrent sessions *submit* ops; the scheduler
accumulates them on the ``SimNetwork`` timer heap and flushes when either
``max_batch`` ops are queued or ``max_delay`` simulated ticks have passed
since the first — whichever comes first — executing the whole flush as a
handful of plane invocations shared by every session.

**Per-session semantics are preserved exactly** (conformance-tested in
tests/test_serving.py: byte-identical results and final replica state vs
executing each op alone, both backends):

* *Phase plan.*  Admitted ops are ordered into alternating GET/PUT phases
  (geo snapshot reads run before them as one shared frontier resolution —
  this flush's puts cannot lift the frontier, so the order is exact).
  A get must run after the last already-planned put on any of its keys; a
  put must run after any planned get or put on its keys.  Puts therefore
  never reorder relative to each other (global wall-clock assignment is
  identical to sequential execution — ``GetResult.value`` resolution
  depends on walls), same-key conflicts sequence into distinct put phases,
  and a session's put→get on one key observes the write even inside one
  flush.  Gets may float past puts on *other* keys: they mint no clocks
  and touch no rows those puts write.
* *One plane call per phase.*  A get phase executes as one
  ``cluster.get_many`` over the deduped union of its keys (per distinct
  (quorum, repair) setting), results split back per op — per-key merges
  are independent, so sharing the sweep is exact.  A put phase merges its
  ops' items into contiguous same-quorum runs, one ``cluster.put_many``
  each; within a phase keys are distinct across ops by construction.  DVV
  ``update`` ignores client identity, so cross-session write batches are
  semantically safe (per-client mechanisms like the §3 VV baseline should
  stay on the synchronous path).
* *Per-op failure isolation.*  The batch planes admit atomically, so the
  scheduler triages each op first via the cluster's non-raising probes:
  an op whose read quorum is short, or with no reachable coordinator,
  fails alone — exactly the set of ops that would raise ``Unavailable``
  sequentially — without poisoning the flush.  A put *predicted* to miss
  its write quorum runs as its own solo call (it still writes durably at
  the coordinator, then reports ``Unavailable`` — the single-call
  contract).  Predictions are exact at ``drop_rate == 0``; with random
  drops, error attribution within a merged run is best-effort.

``ClosedLoopEngine`` is the workload side: millions of *logical* sessions
(compact token records, not objects) issue zipfian-keyed GET → PUT(token)
steps under a fixed concurrency window, with think-time timers, scheduler
flush deadlines, replication pumping and (optionally) ``GossipDriver``
anti-entropy all interleaved on the one deterministic simulated clock.
It records per-op latency in sim ticks (the queueing cost coalescing
pays) against plane invocations and wire bytes per op (what it buys).
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Tuple

import numpy as np

from .client import KVClient
from .cluster import GetResult, KVCluster, PutAck
from .network import Unavailable


class PendingOp:
    """A submitted-but-not-yet-flushed op: the scheduler's future.

    ``result()`` returns what the synchronous call would have
    (``{key: GetResult}`` / ``{key: PutAck}``) or raises what it would
    have raised; ``latency`` is completion minus submission in simulated
    ticks — the queueing delay coalescing trades for plane sharing.
    """

    __slots__ = ("kind", "keys", "items", "quorum", "repair", "client_id",
                 "client_counter", "session", "submitted_at", "completed_at",
                 "_result", "error", "_callbacks", "_predicted_short")

    def __init__(self, kind: str, keys: Tuple[str, ...], *,
                 items: Optional[Dict[str, Tuple[Any, Any]]] = None,
                 quorum: int = 1, repair: bool = False,
                 client_id: str = "client", client_counter: int = 0,
                 session: Optional[str] = None, submitted_at: float = 0.0):
        self.kind = kind                  # "get" | "put" | "snapshot"
        self.keys = keys
        self.items = items                # puts: {key: (value, context)}
        self.quorum = quorum
        self.repair = repair
        self.client_id = client_id
        self.client_counter = client_counter
        self.session = session if session is not None else client_id
        self.submitted_at = submitted_at
        self.completed_at: Optional[float] = None
        self._result: Any = None
        self.error: Optional[Exception] = None
        self._callbacks: List[Callable[["PendingOp"], None]] = []
        self._predicted_short = False     # put: will miss its write quorum

    @property
    def done(self) -> bool:
        return self.completed_at is not None

    @property
    def latency(self) -> float:
        if self.completed_at is None:
            raise RuntimeError("op not completed yet")
        return self.completed_at - self.submitted_at

    def result(self) -> Any:
        if self.completed_at is None:
            raise RuntimeError("op not completed yet (flush pending)")
        if self.error is not None:
            raise self.error
        return self._result

    def on_done(self, callback: Callable[["PendingOp"], None]) -> None:
        """Run ``callback(op)`` at completion (immediately if already
        done) — how the closed-loop engine chains get → put → think."""
        if self.done:
            callback(self)
        else:
            self._callbacks.append(callback)

    def _complete(self, now: float) -> None:
        self.completed_at = now
        callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def __repr__(self) -> str:
        state = ("pending" if not self.done
                 else "failed" if self.error is not None else "ok")
        return (f"<PendingOp {self.kind} {list(self.keys)!r} "
                f"session={self.session} {state}>")


class OpScheduler:
    """Accumulates many sessions' ops; flushes them as shared plane calls.

    One scheduler serves one proxy (``via``).  Flush triggers:

    * **size** — the queue reaches ``max_batch`` (flushed synchronously at
      the triggering ``submit``);
    * **timer** — ``max_delay`` simulated ticks after the first op of a
      batch was enqueued (armed on the SimNetwork heap, cancelled when a
      size/manual flush drains first);
    * **manual** — ``flush()``.

    Ops submitted by completion callbacks *during* a flush land in the
    next batch (the flush loop drains again if they re-trip ``max_batch``
    before returning, so the size guarantee holds).
    """

    def __init__(self, cluster: KVCluster, *, via: Optional[str] = None,
                 max_batch: int = 64, max_delay: float = 2.0,
                 read_quorum: Optional[int] = None,
                 write_quorum: Optional[int] = None,
                 read_repair: bool = False, use_kernel: bool = False,
                 pump: bool = False):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_delay <= 0:
            raise ValueError("max_delay must be positive")
        self.cluster = cluster
        self.network = cluster.network
        self.via = via or next(iter(cluster.nodes))
        self.max_batch = max_batch
        self.max_delay = float(max_delay)
        self.read_quorum = read_quorum or cluster.read_quorum
        self.write_quorum = write_quorum or cluster.write_quorum
        self.read_repair = read_repair
        self.use_kernel = use_kernel
        # pump=True drains replication due by flush time before executing
        # (a server-side scheduler is co-located with the delivery loop);
        # without it, reads batched right behind hot-key writes see stale
        # quorum members and read-repair re-ships what replication already
        # has in flight.  Conformance tests leave it off so coalesced and
        # sequential schedules share the exact delivery points.
        self.pump = pump
        self._queue: List[PendingOp] = []
        self._timer: Optional[int] = None
        self._in_flush = False
        # accounting (the serving benchmark's meters)
        self.ops_submitted = 0
        self.ops_ok = 0
        self.ops_failed = 0
        self.flushes = 0
        self.flush_triggers: Counter = Counter()
        self.phases_run = 0
        self.get_calls = 0        # cluster.get_many invocations issued
        self.put_calls = 0        # cluster.put_many invocations issued
        self.snapshot_calls = 0   # cluster.snapshot_get_many invocations
        self.largest_flush = 0

    # -- submission ---------------------------------------------------------

    def submit_get(self, keys: Sequence[str], *,
                   quorum: Optional[int] = None,
                   repair: Optional[bool] = None,
                   client_id: str = "client",
                   session: Optional[str] = None) -> PendingOp:
        op = PendingOp(
            "get", tuple(keys),
            quorum=quorum or self.read_quorum,
            repair=self.read_repair if repair is None else repair,
            client_id=client_id, session=session,
            submitted_at=self.network.now)
        self._enqueue(op)
        return op

    def submit_put(self, items: Mapping[str, Tuple[Any, Any]], *,
                   quorum: Optional[int] = None, client_id: str = "client",
                   client_counter: int = 0,
                   session: Optional[str] = None) -> PendingOp:
        op = PendingOp(
            "put", tuple(items), items=dict(items),
            quorum=quorum or self.write_quorum,
            client_id=client_id, client_counter=client_counter,
            session=session, submitted_at=self.network.now)
        self._enqueue(op)
        return op

    def submit_snapshot_get(self, keys: Sequence[str], *,
                            client_id: str = "client",
                            session: Optional[str] = None) -> PendingOp:
        """Enqueue a causal snapshot GET (geo clusters only).  All snapshot
        ops admitted into one flush execute as ONE
        ``cluster.snapshot_get_many`` — a single frontier resolution shared
        across sessions."""
        op = PendingOp(
            "snapshot", tuple(keys),
            client_id=client_id, session=session,
            submitted_at=self.network.now)
        self._enqueue(op)
        return op

    def session(self, client_id: str, **kw: Any) -> KVClient:
        """A ``KVClient`` bound to this scheduler (and its proxy)."""
        kw.setdefault("via", self.via)
        kw.setdefault("use_kernel", self.use_kernel)
        return KVClient(self.cluster, client_id, scheduler=self, **kw)

    def _enqueue(self, op: PendingOp) -> None:
        self._queue.append(op)
        self.ops_submitted += 1
        if len(self._queue) >= self.max_batch and not self._in_flush:
            self.flush(trigger="size")
        elif self._timer is None and self._queue:
            self._arm()

    def _arm(self) -> None:
        self._timer = self.network.schedule(self.max_delay, self._on_timer)

    def _on_timer(self) -> None:
        self._timer = None
        self.flush(trigger="timer")

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- flushing -----------------------------------------------------------

    def flush(self, trigger: str = "manual") -> int:
        """Drain the queue through shared plane calls; returns the number
        of ops completed.  Reentrant-safe: a flush triggered from inside a
        completion callback is deferred to the outer drain loop."""
        if self._in_flush:
            return 0
        completed = 0
        self._in_flush = True
        try:
            while self._queue:
                ops, self._queue = self._queue, []
                if self._timer is not None:
                    self.network.cancel(self._timer)
                    self._timer = None
                self._run_flush(ops, trigger)
                completed += len(ops)
                if len(self._queue) < self.max_batch:
                    break               # stragglers wait for their timer
                trigger = "size"
        finally:
            self._in_flush = False
        return completed

    def _run_flush(self, ops: List[PendingOp], trigger: str) -> None:
        self.flushes += 1
        self.flush_triggers[trigger] += 1
        self.largest_flush = max(self.largest_flush, len(ops))
        if self.pump:
            self.cluster.deliver_replication(until=self.network.now)
        proxy = self.via
        admitted = self._admit(ops, proxy)
        # Snapshot ops run as their own phase FIRST: they read at the
        # Global Stable Frontier, and this flush's puts cannot lift it —
        # their replication messages / WAN backlog entries are obligations
        # the frontier folds — so snapshot results are order-insensitive
        # within the flush, and running them first keeps the plan's
        # get/put interleave untouched.
        snaps = [op for op in admitted if op.kind == "snapshot"]
        if snaps:
            self.phases_run += 1
            self._run_snapshot_phase(snaps, proxy)
            admitted = [op for op in admitted if op.kind != "snapshot"]
        for kind, phase_ops in self._plan(admitted):
            self.phases_run += 1
            if kind == "get":
                self._run_get_phase(phase_ops, proxy)
            else:
                self._run_put_phase(phase_ops, proxy)
        now = self.network.now
        for op in ops:                   # completion in submission order
            if op.error is None:
                self.ops_ok += 1
            else:
                self.ops_failed += 1
            op._complete(now)

    def _admit(self, ops: List[PendingOp], proxy: str) -> List[PendingOp]:
        """Per-op triage via the cluster's non-raising probes; failed ops
        get exactly the error their solo call would have raised.  Probe
        results are memoized per key for the flush (topology cannot change
        mid-flush — flushes run inside one timer callback)."""
        if proxy in self.network.down:
            err = Unavailable(f"proxy {proxy} is down")
            for op in ops:
                op.error = err
            return []
        read_ok: Dict[Tuple[str, int], bool] = {}
        write_probe: Dict[str, Tuple[Optional[str], int]] = {}
        snap_reason: Dict[str, Optional[str]] = {}
        admitted: List[PendingOp] = []
        for op in ops:
            if op.kind == "snapshot":
                blocked = None
                for k in op.keys:
                    if k not in snap_reason:
                        snap_reason[k] = self.cluster.probe_snapshot(
                            [k], via=proxy)
                    if snap_reason[k] is not None:
                        blocked = snap_reason[k]
                        break
                if blocked is not None:
                    op.error = Unavailable(
                        f"snapshot unavailable via {proxy}: {blocked}")
                    continue
            elif op.kind == "get":
                short = []
                for k in op.keys:
                    ok = read_ok.get((k, op.quorum))
                    if ok is None:
                        ok = read_ok[(k, op.quorum)] = self.cluster.probe_read(
                            k, via=proxy, quorum=op.quorum)
                    if not ok:
                        short.append(k)
                if short:
                    op.error = Unavailable(
                        f"read quorum {op.quorum} unreachable for "
                        f"{len(short)}/{len(op.keys)} keys via {proxy} "
                        f"(e.g. {short[:3]})")
                    continue
            else:
                dead = []
                predicted_short = False
                for k in op.keys:
                    probe = write_probe.get(k)
                    if probe is None:
                        probe = write_probe[k] = self.cluster.probe_write(
                            k, via=proxy)
                    coord, acks = probe
                    if coord is None:
                        dead.append(k)
                    elif acks < op.quorum:
                        predicted_short = True
                if dead:
                    op.error = Unavailable(
                        f"no reachable coordinator for {dead[0]!r}")
                    continue
                op._predicted_short = predicted_short
            admitted.append(op)
        return admitted

    @staticmethod
    def _plan(ops: List[PendingOp]
              ) -> List[Tuple[str, List[PendingOp]]]:
        """Order-preserving phase plan (see module docstring).  Invariants:
        puts keep global submission order; a get lands after the last put
        phase touching its keys; a put lands after every get/put phase
        touching its keys; within a put phase, keys are distinct across
        ops (an overlapping put is barred from joining that phase by its
        own key's ``last_put`` entry)."""
        phases: List[Tuple[str, List[PendingOp]]] = []
        last_put: Dict[str, int] = {}    # key -> last put phase index
        last_get: Dict[str, int] = {}    # key -> last get phase index
        last_put_ix = -1                 # most recent put phase overall
        for op in ops:
            if op.kind == "get":
                barrier = 0
                for k in op.keys:
                    barrier = max(barrier, last_put.get(k, -1) + 1)
                target = -1
                for i in range(barrier, len(phases)):
                    if phases[i][0] == "get":
                        target = i
                        break
                if target < 0:
                    phases.append(("get", []))
                    target = len(phases) - 1
                phases[target][1].append(op)
                for k in op.keys:
                    last_get[k] = max(last_get.get(k, -1), target)
            else:
                barrier = 0
                for k in op.keys:
                    barrier = max(barrier, last_put.get(k, -1) + 1,
                                  last_get.get(k, -1) + 1)
                # join the most recent put phase when the barrier allows —
                # later puts never land in an *earlier* phase than this
                # one, so global put submission order (and with it the
                # wall-clock assignment) is preserved; an interleaved get
                # phase after it is skipped, not a wall for other keys
                if last_put_ix >= barrier:
                    target = last_put_ix
                else:
                    phases.append(("put", []))
                    target = len(phases) - 1
                    last_put_ix = target
                phases[target][1].append(op)
                for k in op.keys:
                    last_put[k] = target
        return phases

    def _run_get_phase(self, ops: List[PendingOp], proxy: str) -> None:
        groups: Dict[Tuple[int, bool], List[PendingOp]] = {}
        for op in ops:
            groups.setdefault((op.quorum, op.repair), []).append(op)
        for (quorum, repair), grp in groups.items():
            union: List[str] = []
            seen = set()
            for op in grp:
                for k in op.keys:
                    if k not in seen:
                        seen.add(k)
                        union.append(k)
            self.get_calls += 1
            try:
                results = self.cluster.get_many(
                    union, via=proxy, quorum=quorum, repair=repair,
                    use_kernel=self.use_kernel)
            except Unavailable as e:     # admission raced only if topology
                for op in grp:           # shifted mid-flush (defensive)
                    op.error = e
                continue
            for op in grp:
                op._result = {k: results[k] for k in op.keys}

    def _run_snapshot_phase(self, ops: List[PendingOp], proxy: str) -> None:
        union: List[str] = []
        seen = set()
        for op in ops:
            for k in op.keys:
                if k not in seen:
                    seen.add(k)
                    union.append(k)
        self.snapshot_calls += 1
        try:
            results = self.cluster.snapshot_get_many(union, via=proxy)
        except (Unavailable, RuntimeError) as e:  # defensive: admission
            for op in ops:                        # already probed these
                op.error = e if isinstance(e, Unavailable) \
                    else Unavailable(str(e))
            return
        for op in ops:
            op._result = {k: results[k] for k in op.keys}

    def _run_put_phase(self, ops: List[PendingOp], proxy: str) -> None:
        # contiguous same-quorum runs; predicted-short ops run solo so
        # their Unavailable (write applied, quorum missed) stays theirs
        runs: List[List[PendingOp]] = []
        for op in ops:
            if runs and not op._predicted_short \
                    and not runs[-1][0]._predicted_short \
                    and runs[-1][0].quorum == op.quorum:
                runs[-1].append(op)
            else:
                runs.append([op])
        for run in runs:
            items: Dict[str, Tuple[Any, Any]] = {}
            for op in run:
                items.update(op.items)
            if len(run) == 1:            # solo: keep the session identity
                cid, cc = run[0].client_id, run[0].client_counter
            else:                        # merged: DVV ignores client ids
                cid, cc = "coalesced", 0
            self.put_calls += 1
            try:
                acks = self.cluster.put_many(
                    items, via=proxy, client_id=cid, client_counter=cc,
                    quorum=run[0].quorum, use_kernel=self.use_kernel)
            except Unavailable as e:
                for op in run:
                    op.error = e
            else:
                for op in run:
                    op._result = {k: self._normalize_ack(acks[k], k)
                                  for k in op.keys}

    def _normalize_ack(self, ack: PutAck, key: str) -> PutAck:
        """Re-sort ``replicated_to`` into the solo-call order (coordinator
        first, then the key's replica order) — a merged ``put_many``
        discovers destinations in whole-group key order, which would leak
        batch composition into per-op results."""
        members = set(ack.replicated_to)
        order = (ack.coordinator,) + tuple(
            r for r in self.cluster.replicas_for(key)
            if r != ack.coordinator and r in members)
        if order == ack.replicated_to:
            return ack
        return PutAck(clock=ack.clock, coordinator=ack.coordinator,
                      replicated_to=order)

    # -- introspection ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return {
            "ops_submitted": self.ops_submitted,
            "ops_ok": self.ops_ok,
            "ops_failed": self.ops_failed,
            "pending": len(self._queue),
            "flushes": self.flushes,
            "flush_triggers": dict(self.flush_triggers),
            "phases": self.phases_run,
            "get_calls": self.get_calls,
            "put_calls": self.put_calls,
            "snapshot_calls": self.snapshot_calls,
            "plane_calls": self.get_calls + self.put_calls
            + self.snapshot_calls,
            "largest_flush": self.largest_flush,
        }

    def __repr__(self) -> str:
        return (f"<OpScheduler via={self.via} pending={len(self._queue)} "
                f"flushes={self.flushes} "
                f"plane_calls={self.get_calls + self.put_calls}>")


class ClosedLoopEngine:
    """Zipfian closed-loop workload on the shared simulated clock.

    ``sessions`` logical sessions (token records keyed by session id — a
    million sessions is a dict, not a million client objects) take turns
    through a fixed ``concurrency`` window.  One *step* is the paper's
    client workflow: GET(key) → carry the token as wire bytes → PUT(key,
    value, token) → think-time timer → hand the slot to the next session.
    Keys are drawn zipfian (hot-key contention is the point: same-key
    conflicts must sequence, read-repair must fire); sessions uniformly.

    ``mode="coalesced"`` drives an ``OpScheduler``; ``mode="direct"`` is
    the per-session baseline — every op its own synchronous plane call,
    zero queueing latency.  Same seed ⇒ same key/session/think draws, so
    the two modes run the same workload.
    """

    def __init__(self, cluster: KVCluster, *, sessions: int = 1_000_000,
                 keys: int = 10_000, zipf_s: float = 1.1,
                 concurrency: int = 256, think_time: float = 8.0,
                 rmw_time: float = 1.0,
                 mode: str = "coalesced", via: Optional[str] = None,
                 seed: int = 0, read_repair: bool = True,
                 use_kernel: bool = False,
                 scheduler: Optional[OpScheduler] = None,
                 max_batch: int = 64, max_delay: float = 2.0,
                 pump_period: float = 5.0):
        if mode not in ("coalesced", "direct"):
            raise ValueError(f"unknown mode {mode!r}")
        self.cluster = cluster
        self.network = cluster.network
        self.sessions = sessions
        self.n_keys = keys
        self.zipf_s = zipf_s
        self.concurrency = concurrency
        self.think_time = float(think_time)
        # read-modify-write gap: a client reads, computes, then writes.
        # Both modes pay it identically — without it the direct baseline's
        # get→put is atomic (zero sibling pressure on hot keys), which
        # would overstate coalescing's byte cost rather than its real one.
        self.rmw_time = float(rmw_time)
        self.mode = mode
        self.via = via or next(iter(cluster.nodes))
        self.pump_period = pump_period
        import random
        self.rng = random.Random(seed)
        # zipf CDF over key ranks; one searchsorted per draw
        ranks = np.arange(1, keys + 1, dtype=np.float64)
        weights = 1.0 / np.power(ranks, zipf_s)
        self._cdf = np.cumsum(weights / weights.sum())
        self._keys = [f"k{i}" for i in range(keys)]
        self.scheduler: Optional[OpScheduler] = None
        if mode == "coalesced":
            self.scheduler = scheduler or OpScheduler(
                cluster, via=self.via, max_batch=max_batch,
                max_delay=max_delay, use_kernel=use_kernel, pump=True)
        self.client = KVClient(cluster, "engine", via=self.via,
                               read_repair=read_repair,
                               use_kernel=use_kernel,
                               scheduler=self.scheduler)
        self._tokens: Dict[int, bytes] = {}   # session id -> wire token
        self.steps_started = 0
        self.steps_done = 0
        self.ops_done = 0
        self.ops_failed = 0
        self._latencies: List[float] = []
        self._target_steps = 0
        self._pump_timer: Optional[int] = None

    # -- workload mechanics -------------------------------------------------

    def _pick_key(self) -> str:
        ix = int(np.searchsorted(self._cdf, self.rng.random()))
        return self._keys[min(ix, self.n_keys - 1)]

    def _op_finished(self, latency: float, ok: bool) -> None:
        self.ops_done += 1
        self._latencies.append(latency)
        if not ok:
            self.ops_failed += 1

    def _start_step(self) -> None:
        if self.steps_started >= self._target_steps:
            return                       # slot retires
        self.steps_started += 1
        sid = self.rng.randrange(self.sessions)
        key = self._pick_key()
        if self.mode == "coalesced":
            op = self.client.submit_get([key])
            op.on_done(lambda op, sid=sid, key=key:
                       self._after_get(op, sid, key))
        else:
            try:
                res: Any = self.client.get_many([key])[key]
            except Unavailable:
                res = None
            self._op_finished(0.0, res is not None)
            self._do_put(res, sid, key)

    def _after_get(self, op: PendingOp, sid: int, key: str) -> None:
        self._op_finished(op.latency, op.error is None)
        res = None if op.error is not None else op.result()[key]
        self._do_put(res, sid, key)

    def _do_put(self, res: Optional[GetResult], sid: int, key: str) -> None:
        if res is None:                  # get failed: retry after thinking
            self._finish_step(sid)
            return
        # carry the token as wire bytes — the codec memo's hot loop
        token = self.client.encode_context(res.context)
        self._tokens[sid] = token
        value = f"s{sid}.{self.steps_started}"
        if self.rmw_time:
            delay = self.rmw_time * (0.5 + self.rng.random())
            self.network.schedule(
                delay, lambda: self._issue_put(sid, key, value, token))
        else:
            self._issue_put(sid, key, value, token)

    def _issue_put(self, sid: int, key: str, value: str,
                   token: bytes) -> None:
        if self.mode == "coalesced":
            op = self.client.submit_put({key: (value, token)})
            op.on_done(lambda op, sid=sid: self._after_put(op, sid))
        else:
            try:
                self.client.put_many({key: (value, token)})
                ok = True
            except Unavailable:
                ok = False
            self._op_finished(0.0, ok)
            self._finish_step(sid)

    def _after_put(self, op: PendingOp, sid: int) -> None:
        self._op_finished(op.latency, op.error is None)
        self._finish_step(sid)

    def _finish_step(self, sid: int) -> None:
        self.steps_done += 1
        think = self.think_time * (0.5 + self.rng.random())
        self.network.schedule(think, self._start_step)

    def _pump(self) -> None:
        self.cluster.deliver_replication(until=self.network.now)
        self._pump_timer = self.network.schedule(self.pump_period,
                                                 self._pump)

    # -- driving ------------------------------------------------------------

    def run(self, steps: int, *, max_sim_time: Optional[float] = None
            ) -> Dict[str, Any]:
        """Run ``steps`` closed-loop steps (2 ops each); returns the
        metrics summary.  Event-driven: the loop hops straight to the next
        timer deadline (think, flush or pump) instead of polling."""
        self._target_steps = self.steps_started + steps
        sim0 = self.network.now
        wall0 = time.perf_counter()
        base_planes = self.cluster.plane_invocations
        base_bytes = self.network.bytes_sent
        ops0, fail0 = self.ops_done, self.ops_failed
        lat_from = len(self._latencies)
        if self._pump_timer is None and self.pump_period:
            self._pump_timer = self.network.schedule(self.pump_period,
                                                     self._pump)
        for _ in range(self.concurrency):
            self.network.schedule(self.rng.random() * self.think_time,
                                  self._start_step)
        horizon = None if max_sim_time is None else sim0 + max_sim_time
        while self.steps_done < self._target_steps:
            due = self.network.next_timer_due()
            if due is None or (horizon is not None and due > horizon):
                break
            self.network.advance(max(due - self.network.now, 0.0))
        if self.scheduler is not None:   # complete any stragglers
            self.scheduler.flush()
        self.cluster.deliver_replication(until=self.network.now)
        lat = sorted(self._latencies[lat_from:])
        ops = self.ops_done - ops0

        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p * (len(lat) - 1) + 0.5))]

        sim_ticks = self.network.now - sim0
        wall_s = time.perf_counter() - wall0
        planes = self.cluster.plane_invocations - base_planes
        nbytes = self.network.bytes_sent - base_bytes
        out: Dict[str, Any] = {
            "mode": self.mode,
            "sessions": self.sessions,
            "active_sessions": len(self._tokens),
            "keys": self.n_keys,
            "zipf_s": self.zipf_s,
            "concurrency": self.concurrency,
            "steps": self.steps_done,
            "ops": ops,
            "ops_failed": self.ops_failed - fail0,
            "sim_ticks": round(sim_ticks, 2),
            "wall_s": round(wall_s, 4),
            "ops_per_sec_wall": round(ops / wall_s, 1) if wall_s else 0.0,
            "ops_per_sim_tick": round(ops / sim_ticks, 3) if sim_ticks
            else 0.0,
            "p50_latency_ticks": round(pct(0.50), 3),
            "p99_latency_ticks": round(pct(0.99), 3),
            "plane_invocations": planes,
            "plane_per_1k_ops": round(1000.0 * planes / ops, 2) if ops
            else 0.0,
            "bytes_per_op": round(nbytes / ops, 1) if ops else 0.0,
            "codec": self.client.codec_info(),
        }
        if self.scheduler is not None:
            out["scheduler"] = self.scheduler.stats()
        return out


__all__ = ["PendingOp", "OpScheduler", "ClosedLoopEngine"]
