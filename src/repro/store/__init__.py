"""Replicated key-value store (paper §4.1) over a simulated network."""
from .bulk import DeltaSyncStats, delta_antientropy
from .client import KVClient
from .cluster import GetResult, KVCluster, PutAck
from .context import CausalContext, EMPTY_CONTEXT
from .failure import FailureDetector, MembershipController
from .geo import GeoPlane
from .gossip import GossipDriver, WanShipper, cluster_converged
from .network import SimNetwork, Unavailable
from .packed import MergedRead, PackedPayload, PackedVersionStore, \
    StoreDigest, concat_payloads, key_bucket, quorum_merge_many, \
    split_payload
from .replica import ReplicaNode
from .services import MEMBERSHIP_KEY, Lease, MemberView, MembershipService, \
    NodeStatus, WorkStealer, resolve_lease_siblings
from .serving import ClosedLoopEngine, OpScheduler, PendingOp
from .sharding import HashRing, key_hash64, shard_of_key
from .version import HybridClock, Version, clocks_of, hlc_decode, \
    hlc_encode, sync_versions, values_of
from .wal import CrashFS, CrashPoint, DurableLog, LocalFS, ReplayStats, \
    SegmentLog

__all__ = [
    "KVCluster", "KVClient", "GetResult", "PutAck",
    "CausalContext", "EMPTY_CONTEXT",
    "SimNetwork", "Unavailable",
    "GossipDriver", "WanShipper", "cluster_converged",
    "FailureDetector", "MembershipController",
    "GeoPlane", "HybridClock", "hlc_encode", "hlc_decode",
    "OpScheduler", "PendingOp", "ClosedLoopEngine",
    "ReplicaNode", "Version", "sync_versions", "clocks_of", "values_of",
    "PackedVersionStore", "PackedPayload", "MergedRead",
    "quorum_merge_many",
    "StoreDigest", "DeltaSyncStats", "delta_antientropy", "key_bucket",
    "HashRing", "key_hash64", "shard_of_key",
    "concat_payloads", "split_payload",
    "DurableLog", "SegmentLog", "ReplayStats",
    "LocalFS", "CrashFS", "CrashPoint",
    "MembershipService", "MemberView", "NodeStatus", "MEMBERSHIP_KEY",
    "WorkStealer", "Lease", "resolve_lease_siblings",
]
