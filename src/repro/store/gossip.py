"""Continuous, membership-aware gossip: the anti-entropy control loop.

``KVCluster.delta_antientropy_round`` (PR 2/3) gives one *hand-cranked*
digest-diffed push round; production anti-entropy is a loop that never
stops while the replica set itself churns.  ``GossipDriver`` closes that
loop off **simulated time** (GentleRain-style scheduling: rounds are tied
to ``SimNetwork.advance``, not wall clocks):

* **Per-node timers, seeded jitter** — every node owns an independent
  next-fire timer on the SimNetwork heap; fire times are jittered by a
  per-node ``random.Random(f"{seed}:{node}")`` stream so cadences desync
  without losing determinism (same seed ⇒ identical fire schedule).
* **Divergence-adaptive budgets** (the Okapi lesson: availability under
  failure hinges on anti-entropy cost tracking *observed* divergence, not
  a fixed cadence).  Each node's interval, ``fanout`` and ``max_ranges``
  budget adapt to its own ``DeltaSyncStats``: ticks whose digests all
  agree back the interval off multiplicatively (idle gossip decays to a
  cheap heartbeat of digest roots) and decay ramped budgets; divergent
  ticks snap the interval back to the base period; ticks that *saturate*
  the range budget (more divergent buckets than the cap let travel)
  double the budget and, at the cap, widen fanout — catch-up cost rises
  to meet a divergence spike, then decays away after it.
* **Churn-proof sampling** — peers come from ``KVCluster.gossip_peers``,
  which reads *current* membership at every tick: departed nodes drop
  out of the rotation naturally, joiners are picked up lazily (each fire
  arms timers for any node it has not seen), and a fire for a node that
  was removed is a no-op that disarms itself.  Down nodes stay armed at
  the base period so recovery resumes gossip without external help.

The driver is deliberately *pure control plane*: all data movement is the
existing two-phase delta round (digest exchange → ranked divergent ranges
→ sliced ``payload(key_ranges=...)`` apply), so everything the store layer
guarantees about those rounds (byte-identical to full rounds, bounded by
divergence) holds under the driver too.  See DESIGN.md §8.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .bulk import DeltaSyncStats
from .cluster import KVCluster


@dataclass
class NodeGossip:
    """Per-node adaptive scheduling state (all simulated-time units)."""

    interval: float               # current fire period (adapts)
    fanout: int                   # peers pushed to per tick (adapts)
    max_ranges: int               # per-push range budget (adapts)
    rng: random.Random            # seeded per-node jitter stream
    step: int = 0                 # rotation counter for gossip_peers
    timer: Optional[int] = None   # armed SimNetwork timer id
    fire_at: float = 0.0          # when that timer is due
    ticks: int = 0
    idle_ticks: int = 0           # consecutive all-converged ticks
    incarnation: int = 0          # process lifetime this state belongs to
    # Sharded clusters: per-shard budget overrides for shards whose rounds
    # saturated — a hot shard ramps alone, cold shards keep the base
    # budget, and idle ticks decay entries back out of the map.
    shard_ranges: Dict[int, int] = field(default_factory=dict)


class GossipDriver:
    """Runs delta anti-entropy continuously off ``SimNetwork`` time.

    Construct it over a cluster and ``network.advance(dt)`` (or
    ``driver.run_for(dt)``) does the rest: timers fire, nodes push deltas
    to rotating peer samples, budgets adapt, membership changes are picked
    up.  ``stop()`` cancels all timers (the driver can be restarted with
    ``start()``).
    """

    def __init__(self, cluster: KVCluster, *, period: float = 10.0,
                 max_period: Optional[float] = None, backoff: float = 1.6,
                 jitter: float = 0.25, fanout: int = 1, max_fanout: int = 3,
                 max_ranges: Optional[int] = None,
                 max_ranges_cap: int = 1024, adapt: bool = True,
                 deliver: bool = True, use_kernel: bool = False,
                 seed: Optional[int] = None, autostart: bool = True):
        if period <= 0:
            raise ValueError("period must be positive")
        if not 0 <= jitter < 1:
            # jitter >= 1 can yield zero/negative delays — a zero-delay
            # self-re-arming timer livelocks SimNetwork.advance
            raise ValueError("jitter must be in [0, 1)")
        if backoff < 1:
            raise ValueError("backoff must be >= 1")
        self.cluster = cluster
        self.period = float(period)
        self.max_period = float(max_period if max_period is not None
                                else 8.0 * period)
        if self.max_period < self.period:
            raise ValueError("max_period must be >= period")
        self.backoff = backoff
        self.jitter = jitter
        self.fanout = max(1, fanout)
        self.max_fanout = max(self.fanout, max_fanout)
        self.base_ranges = (cluster.delta_range_budget
                            if max_ranges is None else max_ranges)
        self.max_ranges_cap = max(self.base_ranges, max_ranges_cap)
        self.adapt = adapt
        self.deliver = deliver
        self.use_kernel = use_kernel
        self.seed = cluster.seed if seed is None else seed
        self._state: Dict[str, NodeGossip] = {}
        self._running = False
        # aggregate accounting (the churn benchmark's wire/round meter)
        self.ticks = 0
        self.rounds = 0
        self.digest_bytes = 0
        self.payload_bytes = 0
        self.payload_slots = 0
        self.fallbacks = 0
        self.divergent_ticks = 0
        self.suspect_probes = 0
        if autostart:
            self.start()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        self._running = True
        net = self.cluster.network
        if self._on_topology not in net.topology_listeners:
            net.topology_listeners.append(self._on_topology)
        self._adopt_new_nodes()
        # restart path: re-arm known nodes whose timers stop() cancelled
        for node, st in list(self._state.items()):
            if node in self.cluster.nodes and st.timer is None:
                self._arm(node)

    def stop(self) -> None:
        self._running = False
        net = self.cluster.network
        if self._on_topology in net.topology_listeners:
            net.topology_listeners.remove(self._on_topology)
        for st in self._state.values():
            if st.timer is not None:
                self.cluster.network.cancel(st.timer)
                st.timer = None

    def run_for(self, duration: float) -> None:
        """Advance simulated time, firing gossip along the way."""
        self.cluster.network.advance(duration)

    def run_until(self, t: float) -> None:
        """Advance to absolute simulated time ``t`` (no-op if in the
        past).  Gossip timers, scheduler flush deadlines and workload
        think-timers all live on the one SimNetwork heap, so any driver
        advancing the shared clock fires all of them in deterministic
        ``(fire_at, seq)`` order — the serving engine's interleave."""
        self.cluster.network.run_until(t)

    # -- scheduling --------------------------------------------------------

    def _adopt_new_nodes(self) -> None:
        """Arm timers for any cluster node the driver has not seen yet —
        how joiners enter the loop without the cluster knowing about us —
        and prune state of departed nodes (normally their own fire
        self-prunes, but a removal while the driver is stopped leaves a
        stale disarmed entry that would shadow a later re-join).

        State is also re-seeded when a node's *incarnation* changed — a
        warm restart (or a remove + re-add the driver never witnessed)
        means the adapted cadence/budgets and consumed jitter stream died
        with the old process; carrying them over would give the new
        process another process's schedule."""
        incarnation = getattr(self.cluster, "incarnation", {})
        for node in [n for n in self._state
                     if n not in self.cluster.nodes]:
            st = self._state.pop(node)
            if st.timer is not None:
                self.cluster.network.cancel(st.timer)
        for node in self.cluster.nodes:
            inc = incarnation.get(node, 0)
            st = self._state.get(node)
            if st is not None and st.incarnation != inc:
                if st.timer is not None:
                    self.cluster.network.cancel(st.timer)
                self._state.pop(node)
                st = None
            if st is None:
                self._state[node] = NodeGossip(
                    interval=self.period, fanout=self.fanout,
                    max_ranges=self.base_ranges,
                    rng=random.Random(f"{self.seed}:{node}"),
                    incarnation=inc)
                self._arm(node)

    def _arm(self, node: str, interval: Optional[float] = None) -> None:
        if not self._running:
            return
        st = self._state[node]
        base = st.interval if interval is None else interval
        delay = base * (1.0 + self.jitter * (2.0 * st.rng.random() - 1.0))
        st.timer = self.cluster.network.schedule(
            delay, lambda: self._fire(node))
        st.fire_at = self.cluster.network.now + delay

    def _wake(self, node: str) -> None:
        """Divergence wake-up: a round just proved ``node`` holds (or
        lacks) state its peer does not — snap its cadence back to the base
        period so reconciliation propagates at gossip speed instead of
        waiting out a backed-off timer.  Only ever *shortens* the wait, so
        repeated wakes cannot starve a node of its own fires."""
        st = self._state.get(node)
        if st is None or node not in self.cluster.nodes:
            return
        # Suspicion backoff (DESIGN.md §13): never snap cadences FOR a
        # suspect.  A flapping link fires topology wakes on every toggle;
        # without this filter each flap re-arms full-rate gossip toward a
        # peer the failure detector already distrusts — the wire-cost
        # difference the faults benchmark measures.
        mem = self.cluster.membership
        if mem is not None and mem.is_suspect(node,
                                              self.cluster.network.now):
            return
        st.interval = self.period
        st.idle_ticks = 0
        horizon = self.period * (1.0 + self.jitter)
        if st.timer is not None and \
                st.fire_at - self.cluster.network.now > horizon:
            self.cluster.network.cancel(st.timer)
            self._arm(node)

    def _on_topology(self) -> None:
        """Topology changed (join/partition/heal/fail/recover/depart):
        adopt any joiner immediately, and — when adapting — snap every
        backed-off cadence to the base period, since a healed link or a
        new member may be hiding fresh divergence.  Converged nodes pay
        one extra digest round and back straight off again."""
        if not self._running:
            return
        self._adopt_new_nodes()
        if not self.adapt:
            return
        for node in list(self._state):
            self._wake(node)

    def _fire(self, node: str) -> None:
        st = self._state.get(node)
        if st is None:
            return
        st.timer = None
        if node not in self.cluster.nodes:      # departed: disarm for good
            del self._state[node]
            return
        self._adopt_new_nodes()
        self.ticks += 1
        st.ticks += 1
        if self.deliver:
            # drain replication messages due by now — the driver doubles as
            # the cluster's background delivery pump
            self.cluster.deliver_replication(until=self.cluster.network.now)
        if node in self.cluster.network.down:
            # a down node cannot push; stay armed at the base period so
            # gossip resumes by itself on recovery
            self._arm(node, self.period)
            return
        rounds = []
        budget = st.max_ranges
        if st.shard_ranges and self.cluster.shards > 1:
            # ramped shards carry their own budget; the rest ride the base
            budget = {s: st.shard_ranges.get(s, st.max_ranges)
                      for s in range(self.cluster.shards)}
        # Suspicion steering (DESIGN.md §13): suspects leave this node's
        # regular rotation (skipped, never resampled — the seeded schedule
        # is untouched) and instead receive ONE dedicated base-budget
        # probe round per fire, aimed at the most-suspect reachable
        # member.  A suspect that is merely slow gets focused catch-up
        # attention; a genuinely dead one costs a reachability check, not
        # a round.
        mem = self.cluster.membership
        now = self.cluster.network.now
        suspects = frozenset(
            s for s in mem.suspect_nodes(now) if s != node) \
            if mem is not None else frozenset()
        for peer, r in self.cluster.gossip_tick(
                node, step=st.step, fanout=st.fanout,
                max_ranges=budget, use_kernel=self.use_kernel,
                exclude=suspects):
            rounds.append(r)
            if self.adapt and (r.buckets_divergent or r.changed):
                self._wake(peer)     # it knows it differs too: drain fast
        if suspects:
            probeable = [s for s in suspects
                         if s in self.cluster.nodes
                         and self.cluster.network.reachable(node, s)]
            if probeable:
                target = max(probeable,
                             key=lambda s: (mem.suspicion(s, now), s))
                rounds.append(self.cluster.delta_antientropy(
                    node, target, use_kernel=self.use_kernel,
                    max_ranges=self.base_ranges))
                self.suspect_probes += 1
        st.step += 1
        self._account(rounds)
        if self.adapt:
            self._adapt(st, rounds)
        self._arm(node)

    # -- adaptation --------------------------------------------------------

    def _account(self, rounds: Sequence[DeltaSyncStats]) -> None:
        self.rounds += len(rounds)
        for r in rounds:
            self.digest_bytes += r.digest_bytes
            self.payload_bytes += r.payload_bytes
            self.payload_slots += r.payload_slots
            if r.fallback:
                self.fallbacks += 1

    def _adapt(self, st: NodeGossip, rounds: Sequence[DeltaSyncStats]
               ) -> None:
        """Backoff when digests agree; snap back and ramp budgets when the
        observed divergence says one tick's budget was not enough.

        A fallback round that changed nothing is *convergence* evidence —
        object backends run every round as a full-payload fallback, and
        treating bare ``fallback`` as divergence would pin their cadence
        at the base period forever (full-store payloads per tick on an
        idle cluster).  The unreconcilable value-root case likewise backs
        off rather than re-shipping the store at full speed; the rounds
        keep reporting ``fallback=True`` for observability."""
        divergent = any(r.buckets_divergent > 0 or r.changed > 0
                        for r in rounds)
        # Saturation is judged where the budget was actually applied: a
        # sharded round reports per-shard stats, and only the hot shard's
        # budget ramps — its neighbours keep paying the base price.
        saturated = False
        for r in rounds:
            if r.per_shard:
                for p in r.per_shard:
                    used = st.shard_ranges.get(p.shard, st.max_ranges)
                    if p.buckets_sent >= used \
                            and p.buckets_divergent > p.buckets_sent:
                        if used < self.max_ranges_cap:
                            st.shard_ranges[p.shard] = min(
                                2 * used, self.max_ranges_cap)
                        else:
                            saturated = True   # at cap: widen fanout below
            elif r.buckets_sent >= st.max_ranges \
                    and r.buckets_divergent > r.buckets_sent:
                saturated = True
        if divergent:
            self.divergent_ticks += 1
            st.idle_ticks = 0
            st.interval = self.period
            if saturated:
                if st.max_ranges < self.max_ranges_cap:
                    st.max_ranges = min(2 * st.max_ranges,
                                        self.max_ranges_cap)
                else:                    # budget already maxed: go wider
                    st.fanout = min(st.fanout + 1, self.max_fanout)
        else:
            st.idle_ticks += 1
            st.interval = min(st.interval * self.backoff, self.max_period)
            # ramped budgets decay back toward the configured base
            st.max_ranges = max(self.base_ranges, st.max_ranges // 2)
            for s in list(st.shard_ranges):
                nxt = st.shard_ranges[s] // 2
                if nxt <= self.base_ranges:
                    del st.shard_ranges[s]
                else:
                    st.shard_ranges[s] = nxt
            if st.fanout > self.fanout:
                st.fanout -= 1

    # -- introspection -----------------------------------------------------

    def wire_bytes(self) -> int:
        """Total gossip wire cost so far (digest phase + payload phase)."""
        return self.digest_bytes + self.payload_bytes

    def node_state(self, node: str) -> NodeGossip:
        return self._state[node]

    def intervals(self) -> Dict[str, float]:
        return {n: st.interval for n, st in self._state.items()
                if n in self.cluster.nodes}

    def __repr__(self) -> str:
        return (f"<GossipDriver nodes={len(self._state)} ticks={self.ticks} "
                f"rounds={self.rounds} wire={self.wire_bytes()}B>")


@dataclass
class LinkState:
    """Per-WAN-link shipping cadence (all simulated-time units)."""

    interval: float
    rng: random.Random
    timer: Optional[int] = None
    fire_at: float = 0.0
    ticks: int = 0


class WanShipper:
    """The geo tier's cross-DC loop: per-WAN-link delta shipping timers on
    the same SimNetwork heap the LAN ``GossipDriver`` runs on.

    One link = one directed DC pair; a fire runs ``GeoPlane.wan_tick``
    (digest-diffed mirror slot-pair rounds, O(divergence) on the wire) and
    adapts like the LAN driver in miniature: ticks that shipped nothing
    back the link's cadence off multiplicatively, divergent or incomplete
    ticks snap it to the base period, and topology changes (a healed WAN
    cut) snap every link so backlogged writes ship at loop speed instead
    of waiting out a backoff.  Constructed by ``GeoPlane``.
    """

    def __init__(self, geo, *, period: float = 25.0,
                 max_period: Optional[float] = None, backoff: float = 1.6,
                 jitter: float = 0.25, seed: Optional[int] = None,
                 autostart: bool = True):
        if period <= 0:
            raise ValueError("period must be positive")
        if not 0 <= jitter < 1:
            raise ValueError("jitter must be in [0, 1)")
        self.geo = geo
        self.cluster = geo.cluster
        self.period = float(period)
        self.max_period = float(max_period if max_period is not None
                                else 4.0 * period)
        self.backoff = backoff
        self.jitter = jitter
        self.seed = self.cluster.seed if seed is None else seed
        self._state: Dict[tuple, LinkState] = {
            link: LinkState(
                interval=self.period,
                rng=random.Random(f"{self.seed}:wan:{link[0]}>{link[1]}"))
            for link in geo.links()}
        self._running = False
        self.ticks = 0
        if autostart:
            self.start()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        self._running = True
        net = self.cluster.network
        if self._on_topology not in net.topology_listeners:
            net.topology_listeners.append(self._on_topology)
        for link, st in self._state.items():
            if st.timer is None:
                self._arm(link)

    def stop(self) -> None:
        self._running = False
        net = self.cluster.network
        if self._on_topology in net.topology_listeners:
            net.topology_listeners.remove(self._on_topology)
        for st in self._state.values():
            if st.timer is not None:
                net.cancel(st.timer)
                st.timer = None

    # -- scheduling --------------------------------------------------------

    def _arm(self, link: tuple, interval: Optional[float] = None) -> None:
        if not self._running:
            return
        st = self._state[link]
        base = st.interval if interval is None else interval
        delay = base * (1.0 + self.jitter * (2.0 * st.rng.random() - 1.0))
        st.timer = self.cluster.network.schedule(
            delay, lambda: self._fire(link))
        st.fire_at = self.cluster.network.now + delay

    def _on_topology(self) -> None:
        """A healed link (or any topology shift) may have freed a WAN
        backlog: snap every link's cadence to the base period."""
        if not self._running:
            return
        horizon = self.period * (1.0 + self.jitter)
        for link, st in self._state.items():
            st.interval = self.period
            if st.timer is not None and \
                    st.fire_at - self.cluster.network.now > horizon:
                self.cluster.network.cancel(st.timer)
                self._arm(link)

    def _fire(self, link: tuple) -> None:
        st = self._state[link]
        st.timer = None
        st.ticks += 1
        self.ticks += 1
        # drain due replication first so shipped state reflects the
        # present, matching the LAN driver's delivery-pump discipline
        self.cluster.deliver_replication(until=self.cluster.network.now)
        stats, complete = self.geo.wan_tick(*link)
        shipped = any(r.buckets_divergent or r.changed for r in stats)
        if shipped or not complete:
            st.interval = self.period
        else:
            st.interval = min(st.interval * self.backoff, self.max_period)
        self._arm(link)

    def __repr__(self) -> str:      # pragma: no cover
        return (f"<WanShipper links={len(self._state)} ticks={self.ticks}>")


def cluster_converged(cluster: KVCluster) -> bool:
    """True iff every pair of live nodes holds identical state — digest
    trees (and value roots) for packed backends, version-set dicts for
    object backends.  The quiescence check churn tests and the benchmark
    poll between gossip ticks."""
    nodes = [cluster.nodes[n] for n in cluster.nodes
             if n not in cluster.network.down]
    if len(nodes) < 2:
        return True
    if all(n.is_packed for n in nodes):
        # compare shard by shard (one store per node at shards=1); the
        # reference node's digests are snapshotted once per shard
        refs = [(ref, ref.sync_digest(), ref.value_root())
                for ref in nodes[0].shard_stores]
        for other in nodes[1:]:
            for (_, ref_digest, ref_vroot), st in zip(refs,
                                                      other.shard_stores):
                if len(ref_digest.diff(st.sync_digest())) != 0:
                    return False
                if ref_vroot != st.value_root():
                    return False
        return True
    keys = set()
    for n in nodes:
        keys |= set(getattr(n.backend, "store", {}).keys())
    return all(n.versions(k) == nodes[0].versions(k)
               for k in keys for n in nodes[1:])


__all__ = ["GossipDriver", "LinkState", "NodeGossip", "WanShipper",
           "cluster_converged"]
