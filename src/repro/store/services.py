"""DVV-backed coordination services: the membership ledger and the
work-stealing lease ledger, running *through* the replicated store.

Promoted from the training-sim ``repro.cluster`` package (which keeps
compat shims): both services are pure clients of the store's get/put
surface and exist because their workloads are exactly the paper's
motivating anomalies —

* **Membership** (``MembershipService``): ``node_id -> (status, epoch)``
  stored under one key.  Elastic scale-up/down means *concurrent*
  membership writes through different coordinators — the workload where a
  per-server version vector linearizes concurrent joins (paper §3.2) and
  LWW drops one (paper §3.1).  Under DVV the divergent views surface as
  siblings and merge with a deterministic join (pointwise max epoch,
  status priority), written back with the full context so the merge
  dominates both branches.  This *ledger* complements the §13 liveness
  plane (``store.failure.MembershipController``): the controller decides
  who is reachable, the ledger records who is *administratively* in.

* **Leases** (``WorkStealer``): shards of work leased through the store.
  Two workers claiming the same shard through the same coordinator is the
  paper's Fig. 3 same-server concurrency — VV silently overwrites one
  claim and both workers think they own the shard; DVV surfaces both as
  siblings and ``resolve_lease_siblings`` picks one deterministic winner.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from enum import IntEnum
from typing import Dict, Optional, Tuple

from .cluster import KVCluster
from .network import Unavailable

MEMBERSHIP_KEY = "cluster/membership"


class NodeStatus(IntEnum):
    # ordered by reconciliation priority at equal epoch: dead > leaving > alive
    ALIVE = 0
    LEAVING = 1
    DEAD = 2


@dataclass(frozen=True)
class MemberView:
    """Immutable membership snapshot."""

    members: Tuple[Tuple[str, Tuple[int, int]], ...] = ()  # (node, (status, epoch))

    @staticmethod
    def from_dict(d: Dict[str, Tuple[int, int]]) -> "MemberView":
        return MemberView(tuple(sorted(d.items())))

    def to_dict(self) -> Dict[str, Tuple[int, int]]:
        return {k: tuple(v) for k, v in self.members}

    def serialize(self) -> str:
        return json.dumps(self.members, sort_keys=True)

    @staticmethod
    def deserialize(s: str) -> "MemberView":
        raw = json.loads(s)
        return MemberView(tuple((n, tuple(v)) for n, v in raw))

    def alive(self) -> Tuple[str, ...]:
        return tuple(n for n, (s, _) in self.members
                     if s == NodeStatus.ALIVE)

    @staticmethod
    def merge(views: "Tuple[MemberView, ...]") -> "MemberView":
        """Deterministic join of divergent sibling views."""
        out: Dict[str, Tuple[int, int]] = {}
        for view in views:
            for node, (status, epoch) in view.members:
                if node not in out:
                    out[node] = (status, epoch)
                else:
                    s0, e0 = out[node]
                    # higher epoch wins; at equal epoch the more terminal
                    # status wins (a node seen dead stays dead until it
                    # rejoins with a higher epoch)
                    if (epoch, status) > (e0, s0):
                        out[node] = (status, epoch)
        return MemberView.from_dict(out)


class MembershipService:
    """Client-side membership operations against the replicated store."""

    def __init__(self, store: KVCluster, self_id: str):
        self.store = store
        self.self_id = self_id

    def _read(self, via: Optional[str] = None):
        try:
            res = self.store.get(MEMBERSHIP_KEY, via=via or self.self_id)
        except (Unavailable, KeyError):
            return MemberView(), frozenset()
        if not res.values:
            return MemberView(), res.context
        views = tuple(MemberView.deserialize(v) for v in res.values)
        return MemberView.merge(views), res.context

    def view(self, via: Optional[str] = None) -> MemberView:
        return self._read(via)[0]

    def _transition(self, node: str, status: NodeStatus,
                    via: Optional[str] = None, bump_epoch: bool = True) -> MemberView:
        view, ctx = self._read(via)
        d = view.to_dict()
        _, epoch = d.get(node, (NodeStatus.ALIVE, -1))
        d[node] = (int(status), epoch + 1 if bump_epoch else epoch)
        new = MemberView.from_dict(d)
        self.store.put(MEMBERSHIP_KEY, new.serialize(), context=ctx,
                       via=via or self.self_id, client_id=self.self_id)
        return new

    def join(self, node: Optional[str] = None, via: Optional[str] = None):
        return self._transition(node or self.self_id, NodeStatus.ALIVE, via)

    def leave(self, node: Optional[str] = None, via: Optional[str] = None):
        return self._transition(node or self.self_id, NodeStatus.LEAVING, via)

    def mark_dead(self, node: str, via: Optional[str] = None):
        return self._transition(node, NodeStatus.DEAD, via)

    def reconcile(self, via: Optional[str] = None) -> MemberView:
        """Merge any sibling views and persist the join (reader-repair)."""
        view, ctx = self._read(via)
        if ctx:
            self.store.put(MEMBERSHIP_KEY, view.serialize(), context=ctx,
                           via=via or self.self_id, client_id=self.self_id)
        return view


# -- work-stealing lease ledger ---------------------------------------------


def _lease_key(shard: str) -> str:
    return f"lease/{shard}"


@dataclass(frozen=True)
class Lease:
    shard: str
    owner: str
    expires: float
    attempt: int

    def serialize(self) -> str:
        return json.dumps({"shard": self.shard, "owner": self.owner,
                           "expires": self.expires, "attempt": self.attempt})

    @staticmethod
    def deserialize(s: str) -> "Lease":
        return Lease(**json.loads(s))


def resolve_lease_siblings(leases: Tuple[Lease, ...]) -> Lease:
    """Deterministic winner among concurrent claims: highest attempt, then
    latest expiry, then lowest owner id (total, schedule-independent)."""
    return sorted(leases,
                  key=lambda l: (-l.attempt, -l.expires, l.owner))[0]


class WorkStealer:
    def __init__(self, store: KVCluster, worker_id: str,
                 lease_duration: float = 10.0):
        self.store = store
        self.worker_id = worker_id
        self.lease_duration = lease_duration

    def _read(self, shard: str, via: Optional[str] = None):
        try:
            res = self.store.get(_lease_key(shard), via=via)
        except Unavailable:
            return None, frozenset()
        if not res.values:
            return None, res.context
        leases = tuple(Lease.deserialize(v) for v in res.values)
        return resolve_lease_siblings(leases), res.context

    def try_claim(self, shard: str, now: float,
                  via: Optional[str] = None) -> bool:
        """Attempt to lease ``shard``.  Returns True iff after the write this
        worker is the resolved owner (the claim may race; we re-read)."""
        current, ctx = self._read(shard, via=via)
        if current is not None and current.owner != self.worker_id \
                and current.expires > now:
            return False  # actively held by someone else
        attempt = (current.attempt + 1) if current else 0
        lease = Lease(shard, self.worker_id, now + self.lease_duration, attempt)
        try:
            self.store.put(_lease_key(shard), lease.serialize(), context=ctx,
                           via=via, client_id=self.worker_id)
        except Unavailable:
            return False
        resolved, _ = self._read(shard, via=via)
        return resolved is not None and resolved.owner == self.worker_id

    def renew(self, shard: str, now: float, via: Optional[str] = None) -> bool:
        current, ctx = self._read(shard, via=via)
        if current is None or current.owner != self.worker_id:
            return False
        lease = Lease(shard, self.worker_id, now + self.lease_duration,
                      current.attempt)
        self.store.put(_lease_key(shard), lease.serialize(), context=ctx,
                       via=via, client_id=self.worker_id)
        return True

    def owner(self, shard: str, via: Optional[str] = None) -> Optional[str]:
        lease, _ = self._read(shard, via=via)
        return lease.owner if lease else None

    def steal_expired(self, shard: str, now: float,
                      via: Optional[str] = None) -> bool:
        """Straggler mitigation: take over a shard whose lease lapsed."""
        current, _ = self._read(shard, via=via)
        if current is None or current.expires > now:
            return False
        return self.try_claim(shard, now, via=via)


__all__ = [
    "MEMBERSHIP_KEY", "NodeStatus", "MemberView", "MembershipService",
    "Lease", "WorkStealer", "resolve_lease_siblings",
]
