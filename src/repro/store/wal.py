"""Durable write-ahead segment log + warm restart (DESIGN.md §14).

Every replica today is in-memory; a crash loses its dots and digest trees
and the only recovery is the PR-9 eviction → full O(store) re-bootstrap.
This module gives each node an append-only per-shard segment log so a
crashed process restarts *warm*: rebuild from the last packed-SoA snapshot
plus the log tail, then run exactly one PR-2 digest-diffed delta round to
fetch only what was missed while down.

Why logging post-states is sound: DVV store evolution is monotone in the
version-set join semilattice — every committed mutation's result dominates
what it replaced.  So the log records each changed key's *post-state*
(``REC_UPDATE``), and replaying records in order reconstructs the exact
final per-key sets (the last record per key dominates all earlier ones; a
periodic snapshot of the live store subsumes everything before it, so
replay cost is bounded by the tail, not history).

What durability means here: the store mutates *then* logs, so a crash
inside the very append that records a coordinated write loses that write
everywhere only if it was never replicated (``put`` raises before any
replication send).  The log is a *recovery accelerator* — replication
(W > 1) remains the durability story, and the §14 warm-restart protocol
closes any remaining gap with its one post-replay delta round against a
live peer.

Record framing (little-endian)::

    [u32 body_len][u32 crc32(kind ++ body)][u8 kind][body ...]

Bodies are pickled snapshots of wire-ready types (``PackedPayload``,
``Version`` sets).  Torn-tail rule: on open, a segment is replayed up to
the first incomplete or checksum-failing record and truncated there
(atomically, via rewrite-rename) — everything before that point was
fsynced before the writer acknowledged anything, everything after is the
crash's garbage.

Manifest layout (one JSON doc per (node, shard) directory, written
atomically): the sealed-segment table (file, record count, byte length,
``ckpt.manifest.content_checksum``), the active segment name, and at most
one snapshot blob reference (a ``ckpt.manifest.ShardRecord``).  Every
crash window is safe because the manifest is the *only* naming authority:
a blob or segment the manifest does not reference is invisible garbage,
and the manifest itself flips atomically.

``CrashFS`` is the fuzzing harness: it counts every byte the log writes
and, given a byte budget, writes exactly that prefix and raises
``CrashPoint`` — simulating a power cut at any offset of a recorded
schedule.  After a crash it keeps raising (the process is dead).
"""
from __future__ import annotations

import json
import os
import pickle
import struct
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Tuple

from ..ckpt.atomic import atomic_write_bytes
from ..ckpt.manifest import ShardRecord, content_checksum
from .packed import PackedPayload, PackedVersionStore
from .sharding import shard_of_key
from .version import Version

# -- record codec -----------------------------------------------------------

REC_UPDATE = 1    # post-state of changed keys (PackedPayload / (key, set))
REC_KILL = 2      # key dropped entirely (tombstone GC hook)
REC_COMPACT = 3   # informational: a snapshot subsumed the log prefix
REC_EPOCH = 4     # cluster membership epoch marker

_HEADER = struct.Struct("<IIB")
_PROTO = 4        # pickle protocol for record bodies / snapshot blobs


def encode_record(kind: int, body: bytes) -> bytes:
    crc = zlib.crc32(bytes([kind]) + body) & 0xFFFFFFFF
    return _HEADER.pack(len(body), crc, kind) + body


def decode_records(data: bytes) -> Tuple[List[Tuple[int, bytes]], int]:
    """Decode a segment's valid prefix.

    Returns ``(records, good_bytes)`` where ``good_bytes`` is the offset of
    the first incomplete or checksum-failing record — the torn-tail
    truncation point.
    """
    out: List[Tuple[int, bytes]] = []
    off, n = 0, len(data)
    while n - off >= _HEADER.size:
        length, crc, kind = _HEADER.unpack_from(data, off)
        end = off + _HEADER.size + length
        if end > n:
            break
        body = data[off + _HEADER.size:end]
        if zlib.crc32(bytes([kind]) + body) & 0xFFFFFFFF != crc:
            break
        out.append((kind, body))
        off = end
    return out, off


# -- filesystem layer -------------------------------------------------------


class CrashPoint(Exception):
    """The simulated power cut: raised by ``CrashFS`` mid-write once its
    byte budget is exhausted (and on every operation thereafter)."""


class LocalFS:
    """The plain filesystem ops the log writes through.

    Kept as an object (rather than bare calls) so ``CrashFS`` can sit in
    front of *exactly* the operations whose partial effects matter.
    """

    def append(self, path: str, data: bytes) -> None:
        with open(path, "ab") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())

    def write_atomic(self, path: str, data: bytes) -> None:
        atomic_write_bytes(path, data)

    def read(self, path: str) -> Optional[bytes]:
        try:
            with open(path, "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def remove(self, path: str) -> None:
        try:
            os.remove(path)
        except FileNotFoundError:
            pass


class CrashFS(LocalFS):
    """Byte-budgeted crash injector.

    ``budget=None`` is the *recording* mode: nothing crashes, but every
    write's byte extent is recorded so a fuzz driver can enumerate kill
    offsets.  With a budget, writes spend it byte by byte; the write that
    would exceed it persists only the affordable prefix (appends) or
    nothing (atomic writes — the temp file never gets renamed) and raises
    ``CrashPoint``.  A crashed fs stays crashed.
    """

    def __init__(self, budget: Optional[int] = None):
        self.budget = budget
        self.written = 0
        self.crashed = False
        #: (op, path, start, end) byte extents of every write issued.
        self.extents: List[Tuple[str, str, int, int]] = []

    def _allow(self, n: int) -> int:
        if self.crashed:
            raise CrashPoint("filesystem already crashed")
        if self.budget is None:
            return n
        return max(0, min(n, self.budget - self.written))

    def append(self, path: str, data: bytes) -> None:
        allow = self._allow(len(data))
        self.extents.append(
            ("append", path, self.written, self.written + len(data)))
        if allow:
            super().append(path, data[:allow])
        self.written += allow
        if allow < len(data):
            self.crashed = True
            raise CrashPoint(f"crash at byte {self.written} (torn append)")

    def write_atomic(self, path: str, data: bytes) -> None:
        allow = self._allow(len(data))
        self.extents.append(
            ("atomic", path, self.written, self.written + len(data)))
        if allow < len(data):
            # Temp file dies unrenamed: the target keeps its old content.
            self.written += allow
            self.crashed = True
            raise CrashPoint(f"crash at byte {self.written} (atomic write)")
        super().write_atomic(path, data)
        self.written += len(data)

    def read(self, path: str) -> Optional[bytes]:
        if self.crashed:
            raise CrashPoint("filesystem already crashed")
        return super().read(path)

    def remove(self, path: str) -> None:
        if self.crashed:
            raise CrashPoint("filesystem already crashed")
        super().remove(path)


# -- per-shard segment log --------------------------------------------------


@dataclass
class ReplayStats:
    """What a warm restore read back (per node, summed over shard logs)."""
    records: int = 0
    snapshot_bytes: int = 0
    tail_bytes: int = 0
    torn_bytes: int = 0
    epoch: int = 0

    def merge(self, other: "ReplayStats") -> None:
        self.records += other.records
        self.snapshot_bytes += other.snapshot_bytes
        self.tail_bytes += other.tail_bytes
        self.torn_bytes += other.torn_bytes
        self.epoch = max(self.epoch, other.epoch)


class SegmentLog:
    """One shard's append-only segments + snapshot + manifest.

    Directory layout (under ``root/node/shard-NN/``)::

        MANIFEST.json      atomic naming authority (see module docstring)
        seg-000003.log     sealed + active segments
        snap-000001.bin    at most one referenced snapshot blob

    ``snapshot_source`` is attached by ``DurableLog`` and returns the
    *live* full-state blob; because the store mutates before it logs, the
    blob taken right after appending record N subsumes records 1..N.
    """

    MANIFEST = "MANIFEST.json"

    def __init__(self, root: str, node_id: str, shard: int, *,
                 fs: Optional[LocalFS] = None,
                 snapshot_every: int = 64, seal_bytes: int = 1 << 15):
        self.dir = os.path.join(root, node_id, f"shard-{shard:02d}")
        # Directory creation is not crash-fuzzed: an empty directory
        # carries no state, so a crash around mkdir is trivially safe.
        os.makedirs(self.dir, exist_ok=True)
        self.fs = fs if fs is not None else LocalFS()
        self.node_id = node_id
        self.shard = shard
        self.snapshot_every = snapshot_every
        self.seal_bytes = seal_bytes
        self.snapshot_source: Optional[Callable[[], bytes]] = None
        self._open()

    # -- manifest ----------------------------------------------------------

    def _path(self, name: str) -> str:
        return os.path.join(self.dir, name)

    def _open(self) -> None:
        raw = self.fs.read(self._path(self.MANIFEST))
        if raw is None:
            self.segments: List[Dict[str, Any]] = []
            self.snapshot_rec: Optional[ShardRecord] = None
            self.next_seg = 1
            self.next_snap = 1
            self.active = "seg-000000.log"
            self.active_bytes = 0
            self.active_records = 0
            self.records_since_snapshot = 0
            return
        d = json.loads(raw.decode())
        self.segments = list(d["segments"])
        self.snapshot_rec = (
            ShardRecord(**dict(d["snapshot"],
                               shape=tuple(d["snapshot"]["shape"])))
            if d["snapshot"] else None)
        self.next_seg = d["next_seg"]
        self.next_snap = d["next_snap"]
        self.active = d["active"]
        # Counters for the active segment are recovered lazily by load();
        # until then assume the manifest's view (safe: sealing/snapshots
        # only ever under-fire before a load()).
        self.active_bytes = 0
        self.active_records = 0
        self.records_since_snapshot = 0

    def _write_manifest(self) -> None:
        d = {
            "node": self.node_id, "shard": self.shard,
            "segments": self.segments,
            "snapshot": (dict(vars(self.snapshot_rec),
                              shape=list(self.snapshot_rec.shape))
                         if self.snapshot_rec else None),
            "next_seg": self.next_seg, "next_snap": self.next_snap,
            "active": self.active,
        }
        self.fs.write_atomic(self._path(self.MANIFEST),
                             json.dumps(d, sort_keys=True).encode())

    # -- writing -----------------------------------------------------------

    def append_record(self, kind: int, body: bytes) -> None:
        data = encode_record(kind, body)
        self.fs.append(self._path(self.active), data)
        self.active_bytes += len(data)
        self.active_records += 1
        self.records_since_snapshot += 1
        if self.active_bytes >= self.seal_bytes:
            self._seal()
        if (self.snapshot_source is not None
                and self.records_since_snapshot >= self.snapshot_every):
            self.take_snapshot()

    def _seal(self) -> None:
        """Freeze the active segment: checksum it into the manifest and
        start a fresh one.  Crash anywhere here → the manifest still names
        the old active, whose content replays identically."""
        data = self.fs.read(self._path(self.active)) or b""
        self.segments.append({
            "file": self.active, "records": self.active_records,
            "nbytes": len(data), "checksum": content_checksum(data)})
        self.active = f"seg-{self.next_seg:06d}.log"
        self.next_seg += 1
        self.active_bytes = 0
        self.active_records = 0
        self._write_manifest()

    def take_snapshot(self) -> None:
        """Snapshot the live store and retire the log prefix it subsumes.

        Order matters for crash safety: (1) write the blob atomically
        (unreferenced until named), (2) flip the manifest to reference it
        with a fresh empty active segment (the atomic commit point),
        (3) GC the now-orphaned old files (crash here merely leaks
        unreferenced bytes).
        """
        if self.snapshot_source is None:
            return
        blob = self.snapshot_source()
        fname = f"snap-{self.next_snap:06d}.bin"
        self.next_snap += 1
        self.fs.write_atomic(self._path(fname), blob)
        old = [s["file"] for s in self.segments] + [self.active]
        if self.snapshot_rec is not None:
            old.append(self.snapshot_rec.file)
        self.snapshot_rec = ShardRecord(
            path=f"{self.node_id}/shard-{self.shard:02d}", file=fname,
            shape=(len(blob),), dtype="bytes",
            checksum=content_checksum(blob))
        self.segments = []
        self.active = f"seg-{self.next_seg:06d}.log"
        self.next_seg += 1
        self.active_bytes = 0
        self.active_records = 0
        self.records_since_snapshot = 0
        self._write_manifest()
        for f in old:
            self.fs.remove(self._path(f))
        self.append_record(REC_COMPACT, pickle.dumps(
            {"snapshot": fname, "nbytes": len(blob)}, _PROTO))

    # -- reading -----------------------------------------------------------

    def load(self) -> Tuple[Optional[bytes], List[Tuple[int, bytes]],
                            ReplayStats]:
        """Reopen: verify the snapshot, replay sealed segments, truncate
        the active segment's torn tail (checksum-gated) on disk."""
        stats = ReplayStats()
        snap: Optional[bytes] = None
        if self.snapshot_rec is not None:
            snap = self.fs.read(self._path(self.snapshot_rec.file))
            if snap is None or content_checksum(snap) != \
                    self.snapshot_rec.checksum:
                # The manifest only ever names fully-written blobs
                # (write_atomic precedes the manifest flip), so a mismatch
                # is real corruption, not a crash artifact.
                raise IOError(
                    f"wal snapshot {self.snapshot_rec.file}: bad checksum")
            stats.snapshot_bytes = len(snap)
        records: List[Tuple[int, bytes]] = []
        for seg in self.segments:
            data = self.fs.read(self._path(seg["file"])) or b""
            if content_checksum(data) != seg["checksum"]:
                raise IOError(f"wal segment {seg['file']}: bad checksum")
            recs, good = decode_records(data)
            records.extend(recs)
            stats.tail_bytes += good
        data = self.fs.read(self._path(self.active)) or b""
        recs, good = decode_records(data)
        if good < len(data):
            stats.torn_bytes = len(data) - good
            self.fs.write_atomic(self._path(self.active), data[:good])
        records.extend(recs)
        stats.tail_bytes += good
        stats.records = len(records)
        self.active_bytes = good
        self.active_records = len(recs)
        self.records_since_snapshot = len(recs)
        return snap, records, stats


# -- per-node durable log ---------------------------------------------------


class DurableLog:
    """All of one node's shard logs, plus backend attachment and restore.

    Packed backends get one ``SegmentLog`` per shard store (records are
    per-shard streams, matching the per-shard digest trees); object
    backends route every key through shard logs by the same stable key
    hash, so the on-disk layout is backend-agnostic.
    """

    def __init__(self, root: str, node_id: str, *,
                 fs: Optional[LocalFS] = None,
                 snapshot_every: int = 64, seal_bytes: int = 1 << 15):
        self.root = root
        self.node_id = node_id
        self.fs = fs if fs is not None else LocalFS()
        self.snapshot_every = snapshot_every
        self.seal_bytes = seal_bytes
        self._logs: List[SegmentLog] = []
        self.node: Optional[Any] = None
        self.last_epoch = 0

    def _ensure_logs(self, n: int) -> List[SegmentLog]:
        while len(self._logs) < n:
            self._logs.append(SegmentLog(
                self.root, self.node_id, len(self._logs), fs=self.fs,
                snapshot_every=self.snapshot_every,
                seal_bytes=self.seal_bytes))
        return self._logs[:n]

    def _logs_for(self, node: Any) -> List[SegmentLog]:
        return self._ensure_logs(node.shards if node.is_packed else 1)

    # -- attachment --------------------------------------------------------

    def attach(self, node: Any) -> None:
        """Hook the backend's mutation funnels so every committed change
        appends a post-state record to its shard's log."""
        self.detach()
        self.node = node
        logs = self._logs_for(node)
        if node.is_packed:
            for st, lg in zip(node.shard_stores, logs):
                lg.snapshot_source = (
                    lambda s=st: pickle.dumps(s.payload(), _PROTO))
                st.wal_hook = (
                    lambda payload, lg=lg: lg.append_record(
                        REC_UPDATE, pickle.dumps(payload, _PROTO)))
        else:
            be = node.backend
            n = len(logs)
            for i, lg in enumerate(logs):
                lg.snapshot_source = (
                    lambda be=be, i=i, n=n: pickle.dumps(
                        {"store": {k: v for k, v in be.store.items()
                                   if shard_of_key(k, n) == i},
                         "max_wall": be.max_wall}, _PROTO))

            def _hook(key: str, merged: FrozenSet[Version],
                      logs=logs, n=n) -> None:
                logs[shard_of_key(key, n)].append_record(
                    REC_UPDATE, pickle.dumps((key, merged), _PROTO))

            be.wal_hook = _hook

    def detach(self) -> None:
        """Unhook (the pre-restore state: replay must not re-log)."""
        if self.node is None:
            return
        if self.node.is_packed:
            for st in self.node.shard_stores:
                st.wal_hook = None
        else:
            self.node.backend.wal_hook = None
        for lg in self._logs:
            lg.snapshot_source = None
        self.node = None

    # -- non-update records ------------------------------------------------

    def log_epoch(self, epoch: int, members: Tuple[str, ...]) -> None:
        """Membership epoch marker (node-level → shard-0 stream)."""
        self.last_epoch = epoch
        self._ensure_logs(1)[0].append_record(
            REC_EPOCH, pickle.dumps((epoch, members), _PROTO))

    def log_kill(self, key: str) -> None:
        """Drop a key everywhere: live store + a KILL record.

        This is the tombstone-GC hook — the store itself never forgets a
        key today, so only explicit reclamation calls this.
        """
        if self.node is None:
            raise RuntimeError("log_kill requires an attached node")
        node = self.node
        if node.is_packed:
            st = node.store_for(key)
            _packed_drop_key(st, key)
            lg = self._logs[shard_of_key(key, node.shards)]
        else:
            node.backend.store.pop(key, None)
            lg = self._logs[shard_of_key(key, len(self._logs))]
        lg.append_record(REC_KILL, pickle.dumps(key, _PROTO))

    # -- restore -----------------------------------------------------------

    def set_fs(self, fs: LocalFS) -> None:
        """Swap the filesystem layer — a restarted process gets a fresh,
        uncrashed handle onto the same on-disk bytes (the fuzzer's
        post-``CrashPoint`` move)."""
        self.fs = fs
        for lg in self._logs:
            lg.fs = fs

    def restore_into(self, node: Any) -> ReplayStats:
        """Warm restart: truncate torn tails, rebuild ``node``'s backend
        from snapshot + tail, then re-attach the logging hooks.

        Shard logs are re-opened from the on-disk manifests: the crashed
        writer's in-memory segment state can run *ahead* of disk (a seal
        or snapshot whose manifest flip never landed), and recovery must
        see exactly what a freshly exec'd process would."""
        self.detach()
        self._logs = []
        logs = self._logs_for(node)
        total = ReplayStats()
        if node.is_packed:
            for st, lg in zip(node.shard_stores, logs):
                snap, records, stats = lg.load()
                total.merge(stats)
                if snap is not None:
                    st.apply_payload(pickle.loads(snap))
                for kind, body in records:
                    if kind == REC_UPDATE:
                        st.apply_payload(pickle.loads(body))
                    elif kind == REC_KILL:
                        _packed_drop_key(st, pickle.loads(body))
                    elif kind == REC_EPOCH:
                        total.epoch = max(total.epoch,
                                          pickle.loads(body)[0])
        else:
            be = node.backend
            for lg in logs:
                snap, records, stats = lg.load()
                total.merge(stats)
                if snap is not None:
                    state = pickle.loads(snap)
                    for k, v in state["store"].items():
                        be.replace_key(k, v)
                    be.max_wall = max(be.max_wall, state["max_wall"])
                for kind, body in records:
                    if kind == REC_UPDATE:
                        key, merged = pickle.loads(body)
                        be.replace_key(key, merged)
                    elif kind == REC_KILL:
                        be.store.pop(pickle.loads(body), None)
                    elif kind == REC_EPOCH:
                        total.epoch = max(total.epoch,
                                          pickle.loads(body)[0])
        self.last_epoch = total.epoch
        self.attach(node)
        return total

    def reset(self) -> None:
        """Wipe all shard logs (a *fresh* join of a previously-known id
        must not resurrect pre-departure state).  Wipes by directory scan,
        not via open logs — the files may belong to an incarnation this
        process object never opened."""
        self.detach()
        self._logs = []
        node_dir = os.path.join(self.root, self.node_id)
        if os.path.isdir(node_dir):
            for shard_dir in os.listdir(node_dir):
                full = os.path.join(node_dir, shard_dir)
                if os.path.isdir(full):
                    for f in os.listdir(full):
                        self.fs.remove(os.path.join(full, f))

    # -- introspection -----------------------------------------------------

    def log_bytes(self) -> int:
        """Total bytes currently referenced by the manifests (snapshot +
        sealed + active) — the bench's log-overhead metric."""
        total = 0
        for lg in self._logs:
            if lg.snapshot_rec is not None:
                total += lg.snapshot_rec.shape[0]
            total += sum(s["nbytes"] for s in lg.segments)
            total += lg.active_bytes
        return total


def _packed_drop_key(store: PackedVersionStore, key: str) -> None:
    """Remove every live slot of ``key`` (KILL replay / tombstone GC).
    Reaches package-internal surface: kill + compact keep the digest tree
    and bucket index coherent, as ``check_digests`` verifies."""
    kix = store._key_index.get(key)
    if kix is None:
        return
    slots = list(store._slots_by_key.get(kix, []))
    if slots:
        store._kill_slots(kix, slots)
        store.compact()


__all__ = [
    "REC_UPDATE", "REC_KILL", "REC_COMPACT", "REC_EPOCH",
    "encode_record", "decode_records",
    "CrashPoint", "LocalFS", "CrashFS",
    "ReplayStats", "SegmentLog", "DurableLog",
]
