"""Step functions (train / prefill / decode) + ShapeDtypeStruct input specs.

``input_specs(cfg, shape)`` builds weak-type-correct, shardable stand-ins
for every model input — the dry-run lowers against these without allocating
a byte.  The same builders are reused (with real arrays) by the runtime.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import ModelConfig, decode_step, init_cache, loss_fn
from ..models.lm import forward, init_params, param_specs
from ..optim import AdamWConfig, adamw_update, init_opt_state
from ..configs import ShapeSpec


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.input_mode == "tokens":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
    else:
        batch = {"embeddings": sds((B, S, cfg.d_model), jnp.bfloat16),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.mrope:
            batch["positions"] = sds((3, B, S), jnp.int32)
    return batch


def param_state_specs(cfg: ModelConfig, opt_cfg: AdamWConfig
                      ) -> Tuple[Any, Any]:
    p_specs = param_specs(cfg)
    o_specs = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), p_specs)
    return p_specs, o_specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if cfg.input_mode == "tokens":
        tok = sds((B,), jnp.int32)
    else:
        tok = sds((B, cfg.d_model), jnp.bfloat16)
    return {
        "cache": cache_specs(cfg, B, shape.seq_len),
        "tokens": tok,
        "pos": sds((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """All inputs for the shape's step kind, keyed by argument name."""
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs(cfg, shape)}
    return decode_input_specs(cfg, shape)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        out = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, out
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = forward(params, batch, cfg)
        return logits
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg)
    return serve_step
