"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs    / (chips × peak_FLOP/s)
    memory     = HLO_bytes    / (chips × HBM_bw)
    collective = coll_bytes   / (chips × link_bw)

``cost_analysis`` flops/bytes come from the *partitioned per-device*
module, so global = per-device × chips (verified in tests).  Collective
bytes are not in cost_analysis: we parse the compiled HLO text and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (also per-device payloads).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return a one-element list of dicts (one per computation);
    newer ones return the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[16,1088,5120]{2,1,0} all-gather(...)
#        ROOT %tuple ... = (f32[2,4]{...}, ...) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\s(.]")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_by_kind(hlo_text: str) -> Dict[str, int]:
    """Per-device payload bytes of each collective kind in the module."""
    out: Dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # fusions mentioning collectives in operands don't match: the regex
        # anchors on "= <shape> <kind>(" which only ops themselves produce.
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def collective_bytes_detailed(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """Per (collective kind, element dtype) payload bytes."""
    out: Dict[str, Dict[str, int]] = {}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        for dtype, dims in _SHAPE_RE.findall(shape_str):
            if dtype not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            slot = out.setdefault(kind, {})
            slot[dtype] = slot.get(dtype, 0) + n * _DTYPE_BYTES[dtype]
    return out


def correct_promoted_f32(detailed: Dict[str, Dict[str, int]]
                         ) -> Dict[str, int]:
    """XLA:CPU float-normalization promotes bf16 tensors to f32, so in a
    bf16-weights program every large f32 collective payload is logically
    bf16 (only loss scalars / norm stats are genuinely f32, and they are
    negligible).  Halve the f32 portion to recover the TPU-logical bytes.
    Applied ONLY for bf16-parameter variants (see EXPERIMENTS.md §Perf
    methodology); baseline fp32-parameter programs are reported raw.
    """
    out = {}
    for kind, per_dtype in detailed.items():
        total = 0
        for dtype, b in per_dtype.items():
            total += b // 2 if dtype == "f32" else b
        out[kind] = total
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N·D forward-only,
    with N = active params (MoE counts top-k experts only)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def roofline_report(*, cfg, shape, n_chips: int,
                    flops_per_device: float, bytes_per_device: float,
                    collective_bytes_per_device: float) -> Dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = flops_per_device * n_chips
    step_s = max(terms.values())
    useful_ratio = mf / hlo_flops_global if hlo_flops_global else 0.0
    # roofline fraction: useful model flops per second vs the machine peak,
    # if the step ran at the max-term estimate
    mfu_bound = (mf / step_s) / (n_chips * PEAK_FLOPS) if step_s else 0.0
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bound": bound,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": mfu_bound,
        "chips": n_chips,
    }
