"""Serving launcher: batched decode with DVV-replicated session state.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 8 --tokens 24

Implements continuous-batching-lite: a fixed decode batch of slots;
finished requests release their slot and queued requests claim it at the
next step boundary (cache slot re-initialized).  Session cursors persist
through the replicated store, so a different serving node can adopt any
session (see examples/serve_replicated.py for the failover drill).

``--store-workload`` skips the model entirely and drives the store's
coalescing serving plane with the closed-loop workload engine
(store/serving.py): zipfian GET → think → PUT(token) traffic from up to
millions of logical sessions, scheduler flush deadlines and (with
``--gossip-period``) continuous anti-entropy all on one simulated clock:

    PYTHONPATH=src python -m repro.launch.serve --store-workload \
        --store-mode both --sessions 1000000 --store-steps 1500
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..core import DVV_MECHANISM
from ..models import decode_step, init_cache, init_params
from ..store import KVCluster, SimNetwork


@dataclass
class Request:
    rid: int
    prompt_token: int
    max_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_tokens


class BatchScheduler:
    """Slot-based continuous batching over one shared decode cache."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int,
                 store: KVCluster, node: str):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.store = store
        self.node = node
        self.cache = init_cache(cfg, batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.pos = 0
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, self.cfg))

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def admit(self, queue: List[Request]) -> None:
        for slot in self._free_slots():
            if not queue:
                break
            req = queue.pop(0)
            req.slot = slot
            self.slot_req[slot] = req

    def step(self) -> None:
        toks = jnp.asarray(
            [r.generated[-1] if (r and r.generated) else
             (r.prompt_token if r else 0)
             for r in self.slot_req], jnp.int32)
        logits, self.cache = self._step(
            self.params, self.cache, toks, jnp.asarray(self.pos, jnp.int32))
        nxt = jnp.argmax(logits, axis=-1)
        self.pos += 1
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.generated.append(int(nxt[i]))
            if req.done:
                self._persist(req)
                self.slot_req[i] = None

    def _persist(self, req: Request) -> None:
        key = f"session/{req.rid}"
        res = self.store.get(key, via=self.node)
        self.store.put(key, json.dumps(
            {"tokens": req.generated, "pos": self.pos}),
            context=res.context, via=self.node, client_id=self.node)


def store_workload_main(args: argparse.Namespace) -> int:
    """Drive the coalescing serving plane with the closed-loop engine
    (no model in the loop); prints one JSON summary per mode."""
    from ..store import ClosedLoopEngine, GossipDriver

    modes = (("coalesced", "direct") if args.store_mode == "both"
             else (args.store_mode,))
    summaries = {}
    for mode in modes:
        net = SimNetwork(seed=7, jitter=0.0)
        cluster = KVCluster(tuple(f"n{i}" for i in range(5)),
                            DVV_MECHANISM, replication=3, network=net,
                            read_quorum=2, write_quorum=2, seed=7)
        driver = None
        if args.gossip_period > 0:
            driver = GossipDriver(cluster, period=args.gossip_period,
                                  seed=7)
            driver.start()          # timers interleave with the engine
        eng = ClosedLoopEngine(
            cluster, sessions=args.sessions, keys=args.keys,
            zipf_s=args.zipf, concurrency=args.concurrency,
            mode=mode, via="n0", seed=args.seed, read_repair=True,
            max_batch=args.max_batch, max_delay=args.max_delay)
        out = eng.run(args.store_steps)
        if driver is not None:
            out["gossip"] = {"rounds": driver.rounds,
                             "wire_bytes": driver.wire_bytes()}
            driver.stop()
        summaries[mode] = out
        print(json.dumps(out, indent=1))
    if len(summaries) == 2:
        d, c = summaries["direct"], summaries["coalesced"]
        if c["plane_per_1k_ops"]:
            print(f"plane ratio direct/coalesced: "
                  f"{d['plane_per_1k_ops'] / c['plane_per_1k_ops']:.1f}x, "
                  f"bytes/op {c['bytes_per_op']:.1f} vs "
                  f"{d['bytes_per_op']:.1f}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    g = ap.add_argument_group("store workload (no model in the loop)")
    g.add_argument("--store-workload", action="store_true",
                   help="run the closed-loop store workload engine")
    g.add_argument("--store-mode", default="both",
                   choices=["coalesced", "direct", "both"])
    g.add_argument("--sessions", type=int, default=1_000_000)
    g.add_argument("--keys", type=int, default=10_000)
    g.add_argument("--zipf", type=float, default=0.9)
    g.add_argument("--concurrency", type=int, default=256)
    g.add_argument("--store-steps", type=int, default=500)
    g.add_argument("--max-batch", type=int, default=256)
    g.add_argument("--max-delay", type=float, default=2.0)
    g.add_argument("--gossip-period", type=float, default=0.0,
                   help="anti-entropy period in sim ticks (0 = off)")
    g.add_argument("--seed", type=int, default=11)
    args = ap.parse_args()

    if args.store_workload:
        return store_workload_main(args)
    if args.arch is None:
        ap.error("--arch is required unless --store-workload is given")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if not cfg.is_decoder:
        print(f"{cfg.name} is encoder-only; nothing to decode",
              file=sys.stderr)
        return 2
    if cfg.input_mode != "tokens":
        print(f"{cfg.name} needs a modality frontend; serve the backbone "
              f"via examples/serve_replicated.py patterns", file=sys.stderr)
        return 2

    params = init_params(jax.random.key(0), cfg)
    store = KVCluster(("srv1", "srv2"), DVV_MECHANISM,
                      network=SimNetwork(seed=0))
    sched = BatchScheduler(cfg, params, args.batch_slots, args.max_len,
                           store, "srv1")
    queue = [Request(rid=i, prompt_token=i % cfg.vocab_size,
                     max_tokens=args.tokens)
             for i in range(args.requests)]
    completed = 0
    steps = 0
    while (queue or any(sched.slot_req)) and steps < args.max_len - 1:
        sched.admit(queue)
        before = sum(1 for r in sched.slot_req if r is None)
        sched.step()
        after = sum(1 for r in sched.slot_req if r is None)
        completed += max(after - before, 0)
        steps += 1
    print(f"served {args.requests} requests in {steps} decode steps "
          f"({args.batch_slots} slots, continuous batching)")
    for i in range(args.requests):
        res = store.get(f"session/{i}", via="srv1")
        toks = json.loads(res.values[0])["tokens"] if res.values else []
        print(f"  r{i}: {len(toks)} tokens {toks[:6]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
