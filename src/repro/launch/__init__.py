"""Launchers: mesh construction, sharding rules, dry-run, train/serve."""
from .mesh import make_mesh, make_production_mesh
from .sharding import Sharder

__all__ = ["make_production_mesh", "make_mesh", "Sharder"]
