"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

Selects an architecture config (``--smoke`` for the reduced CPU variant),
builds the DVV control plane (store + checkpoint manager), restores if a
manifest exists, trains, and checkpoints on the configured cadence.  On
real hardware the same entry point runs under a production mesh; this
container trains the smoke variants on CPU.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

from ..ckpt import CheckpointManager
from ..configs import ARCH_IDS, get_config
from ..core import DVV_MECHANISM
from ..data import PipelineConfig
from ..optim import AdamWConfig
from ..runtime.train_loop import Trainer, TrainerConfig
from ..store import KVCluster, SimNetwork


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced CPU config of the arch family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--run-id", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="fault-injection: raise after this step")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    elif cfg.param_count() > 1e9:
        print(f"WARNING: {cfg.name} has {cfg.param_count()/1e9:.1f}B params; "
              f"this container is CPU-only — use --smoke (or a TPU mesh).",
              file=sys.stderr)

    blob = args.ckpt_dir or tempfile.mkdtemp(prefix="repro-ckpt-")
    os.makedirs(blob, exist_ok=True)
    store = KVCluster(("cp1", "cp2", "cp3"), DVV_MECHANISM,
                      network=SimNetwork(seed=args.seed))
    run_id = args.run_id or f"{cfg.name}-train"
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
        PipelineConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                       global_batch=args.global_batch, seed=args.seed),
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      log_every=max(args.steps // 20, 1), seed=args.seed),
        CheckpointManager(store, blob, run_id, "cp1"))

    if trainer.try_restore():
        print(f"restored from step {trainer.step} (run {run_id})")
    else:
        trainer.init_fresh()
        print(f"fresh run {run_id}: {cfg.name}, "
              f"{cfg.param_count()/1e6:.1f}M params")
    stats = trainer.run(crash_at=args.crash_at)
    trainer.save()
    for row in trainer.metrics_log:
        print(f"  step {row['step']:>6d}  loss {row['loss']:.4f}  "
              f"gnorm {row['grad_norm']:.3f}")
    print(f"done: {stats}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
