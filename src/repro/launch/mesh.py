"""Mesh construction for the production topology.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing one
CPU device, while the dry-run process boots with 512 forced host devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(shape, axes)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (pod folds into DP when present)."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)
