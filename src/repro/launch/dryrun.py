import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.  Run:

    PYTHONPATH=src python -m repro.launch.dryrun --list
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --json out.json

Each cell jits the step with explicit in/out shardings, lowers against
ShapeDtypeStruct inputs (no allocation), compiles, and records
``memory_analysis`` / ``cost_analysis`` / the collective-bytes parse used
by EXPERIMENTS.md §Roofline.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs import CELLS, REGISTRY, SHAPES, cell_skip_reason, cells, get_config
from ..optim import AdamWConfig
from .mesh import make_production_mesh
from .roofline import collective_bytes_by_kind, roofline_report
from .sharding import Sharder
from .steps import (
    batch_specs, decode_input_specs, input_specs, make_decode_step,
    make_prefill_step, make_train_step, param_state_specs,
)

BIG_ARCH_THRESHOLD = 100e9   # params; above this use bf16 optimizer moments


def opt_config_for(cfg) -> AdamWConfig:
    moment = "bfloat16" if cfg.param_count() > BIG_ARCH_THRESHOLD else "float32"
    return AdamWConfig(moment_dtype=moment,
                       master_weights=(cfg.param_dtype == "bfloat16"))


def _named(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree (None leaves pass through)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


def lower_cell(cfg, shape, mesh, *, donate: bool = True,
               sharder: Optional[Sharder] = None, mode: str = "train"):
    """Returns (lowered, compiled, wallclock_seconds)."""
    from contextlib import ExitStack

    from ..models.sharding_ctx import activation_sharding
    from .mesh import data_axes

    sharder = sharder or Sharder(mesh, cfg, mode=mode)
    t0 = time.time()
    with activation_sharding(mesh, data_axes(mesh),
                             replicate_batch=(mode == "decode_tp")):
        return _lower_cell_inner(cfg, shape, mesh, donate, sharder, t0)


def _lower_cell_inner(cfg, shape, mesh, donate, sharder, t0):
    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        step = make_train_step(cfg, opt_cfg)
        p_specs, o_specs = param_state_specs(cfg, opt_cfg)
        p_sh = _named(mesh, sharder.param_pspecs())
        o_sh = _named(mesh, sharder.opt_pspecs(
            with_master=opt_cfg.master_weights))
        b_specs = batch_specs(cfg, shape)
        b_sh = _named(mesh, sharder.batch_pspecs(b_specs))
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = jitted.lower(p_specs, o_specs, b_specs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        p_specs, _ = param_state_specs(cfg, AdamWConfig())
        p_sh = _named(mesh, sharder.param_pspecs())
        b_specs = batch_specs(cfg, shape)
        b_sh = _named(mesh, sharder.batch_pspecs(b_specs))
        jitted = jax.jit(
            step, in_shardings=(p_sh, b_sh),
            out_shardings=_named(mesh, sharder.logits_pspec()))
        lowered = jitted.lower(p_specs, b_specs)
    else:  # decode
        step = make_decode_step(cfg)
        p_specs, _ = param_state_specs(cfg, AdamWConfig())
        p_sh = _named(mesh, sharder.param_pspecs())
        from jax.sharding import PartitionSpec as _P
        d = decode_input_specs(cfg, shape)
        c_sh = _named(mesh, sharder.cache_pspecs(d["cache"]))
        if sharder.mode == "decode_tp":
            # weight-stationary decode: tokens replicated (KB-scale)
            t_sh = _named(mesh, _P(*(None,) * len(d["tokens"].shape)))
        else:
            t_sh = _named(mesh, sharder.batch_pspecs({"t": d["tokens"]})["t"])
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, t_sh, None),
            out_shardings=(None, c_sh),
            donate_argnums=(1,) if donate else (),
        )
        lowered = jitted.lower(p_specs, d["cache"], d["tokens"], d["pos"])
    compiled = lowered.compile()
    return lowered, compiled, time.time() - t0


def extrapolated_costs(cfg, shape, mesh, mode: str = "train") -> Dict[str, Any]:
    """Exact per-device flops/bytes/collectives via two-point linear fit.

    ``cost_analysis`` counts while-loop bodies ONCE (verified in
    tests/test_roofline.py), so the real scanned program undercounts by
    ~n_groups×.  Costs are exactly linear in the group count, so we compile
    1-group and 2-group *unrolled* variants (tiny HLO, fast) and
    extrapolate: cost(G) = cost(1) + (cost(2) - cost(1)) · (G - 1).
    """
    from dataclasses import replace

    from .roofline import (
        collective_bytes_detailed, correct_promoted_f32, cost_analysis_dict,
    )

    L = len(cfg.pattern)
    points = []
    for k in (1, 2):
        small = replace(cfg, name=f"{cfg.name}~g{k}", n_layers=k * L,
                        scan_unroll=True)
        _, compiled, _ = lower_cell(small, shape, mesh, donate=False,
                                    mode=mode)
        cost = cost_analysis_dict(compiled)
        detailed = collective_bytes_detailed(compiled.as_text())
        if cfg.param_dtype == "bfloat16":
            # undo the XLA:CPU bf16->f32 promotion (see roofline.py)
            coll = correct_promoted_f32(detailed)
        else:
            coll = {k_: sum(v.values()) for k_, v in detailed.items()}
        points.append((float(cost.get("flops", 0.0)),
                       float(cost.get("bytes accessed", 0.0)), coll))
    (f1, b1, c1), (f2, b2, c2) = points
    G = cfg.n_groups
    kinds = set(c1) | set(c2)
    coll = {k: max(c1.get(k, 0) + (c2.get(k, 0) - c1.get(k, 0)) * (G - 1), 0)
            for k in kinds}
    return {
        "flops": f1 + (f2 - f1) * (G - 1),
        "bytes": b1 + (b2 - b1) * (G - 1),
        "collectives": coll,
    }


def analyze(cfg, shape, mesh_name, lowered, compiled, seconds,
            costs: Dict[str, Any]) -> Dict[str, Any]:
    mem = compiled.memory_analysis()
    n_chips = 512 if mesh_name == "multi" else 256
    report = roofline_report(
        cfg=cfg, shape=shape, n_chips=n_chips,
        flops_per_device=costs["flops"],
        bytes_per_device=costs["bytes"],
        collective_bytes_per_device=sum(costs["collectives"].values()),
    )
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": mesh_name,
        "compile_seconds": round(seconds, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "cost": {"flops": costs["flops"], "bytes accessed": costs["bytes"]},
        "collectives": costs["collectives"],
        "roofline": report,
    }


VARIANTS = ("baseline", "bf16w", "bf16w_cap1", "bf16w_nodp",
            "bf16w_remat", "bf16w_cap1_remat")


def apply_variant(cfg, variant: str):
    """Named optimization variants for the §Perf hillclimb."""
    from dataclasses import replace
    if variant == "baseline":
        return cfg
    if variant == "bf16w":
        # Iter-1: bf16 parameter storage (fp32 master in optimizer):
        # halves FSDP weight gathers + gradient reductions.
        return replace(cfg, param_dtype="bfloat16")
    if variant == "bf16w_cap1":
        # Iter-2 (MoE): capacity factor 1.25 -> 1.0 shrinks the dispatch/
        # combine one-hot tensors and expert buffers by 20%.
        return replace(cfg, param_dtype="bfloat16", capacity_factor=1.0)
    if variant == "bf16w_nodp":
        # Iter-2 (decode): weight-stationary 2-D tensor parallelism for
        # serving — weights never gathered per step.
        return replace(cfg, param_dtype="bfloat16")
    if variant == "bf16w_remat":
        # Iter-2/3 (trains): save matmul outputs in the remat stash — the
        # backward skips recomputing dots AND re-gathering their weights.
        return replace(cfg, param_dtype="bfloat16", remat_policy="dots")
    if variant == "bf16w_cap1_remat":
        return replace(cfg, param_dtype="bfloat16", capacity_factor=1.0,
                       remat_policy="dots")
    raise ValueError(variant)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             verbose: bool = True, variant: str = "baseline") -> Dict[str, Any]:
    cfg = apply_variant(get_config(arch), variant)
    shape = SHAPES[shape_name]
    reason = cell_skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": reason}
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    mode = ("decode_tp" if variant == "bf16w_nodp"
            and shape.kind == "decode" else "train")
    lowered, compiled, secs = lower_cell(cfg, shape, mesh, mode=mode)
    costs = extrapolated_costs(cfg, shape, mesh, mode=mode)
    result = analyze(cfg, shape, mesh_name, lowered, compiled, secs, costs)
    result["variant"] = variant
    if verbose:
        mem = result["memory"]
        rl = result["roofline"]
        print(f"[OK] {arch} × {shape_name} × {mesh_name}-pod "
              f"({secs:.1f}s compile)")
        print(f"     per-device bytes: args={_gb(mem['argument_bytes'])} "
              f"temp={_gb(mem['temp_bytes'])}")
        print(f"     roofline: compute={rl['compute_s']:.2e}s "
              f"memory={rl['memory_s']:.2e}s "
              f"collective={rl['collective_s']:.2e}s "
              f"-> bound={rl['bound']}")
    return result


def _gb(b: Optional[int]) -> str:
    return "?" if b is None else f"{b / 2**30:.2f}GiB"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=VARIANTS)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()

    if args.list:
        for cfg, shape, reason in cells(include_skipped=True):
            status = f"SKIP ({reason})" if reason else "run"
            print(f"{cfg.name:26s} {shape.name:12s} {status}")
        return 0

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    targets = []
    if args.all:
        targets = [(cfg.name, sh.name) for cfg, sh, _ in CELLS]
    else:
        archs = [args.arch] if args.arch else sorted(REGISTRY)
        shapes = [args.shape] if args.shape else list(SHAPES)
        targets = [(a, s) for a in archs for s in shapes]

    results, failures = [], 0
    for (arch, shape_name) in targets:
        cfg = get_config(arch)
        if cell_skip_reason(cfg, SHAPES[shape_name]):
            continue
        for mesh_name in meshes:
            try:
                results.append(run_cell(arch, shape_name, mesh_name,
                                        variant=args.variant))
            except Exception as e:   # a failing cell is a bug in the system
                failures += 1
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape_name,
                                "mesh": mesh_name, "error": repr(e)})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.json} ({len(results)} cells, {failures} failures)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
