"""Partition rules: map every parameter / activation / cache tensor to a
PartitionSpec on the (pod, data, model) production mesh.

Strategy (DESIGN.md §6):
  * batch dims shard over DP axes ("data", plus "pod" when present);
  * weight matrices shard Megatron-style over "model" (column-parallel in,
    row-parallel out) AND over the FSDP axes on the other dim (ZeRO-3-like
    — XLA all-gathers per layer inside the scan);
  * attention shards heads over "model" when the head count divides the
    axis, otherwise head_dim (interleaved RoPE keeps pairs shard-local);
  * MoE shards experts over "model" when E divides it, else each expert's
    d_ff;
  * SSD shards d_inner by whole heads (H % 16 == 0 for assigned archs);
  * every rule degrades to replication when a dim is indivisible — a spec
    is never invalid, only less sharded (and the roofline table shows the
    cost).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import ModelConfig
from ..models.lm import param_specs
from .mesh import data_axes


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


class Sharder:
    """``mode="train"`` (default): FSDP(data) × TP(model) — weights gather
    per layer, gradients reduce-scatter; right when every weight is touched
    by thousands of tokens per step.

    ``mode="decode_tp"``: weight-stationary 2-D tensor parallelism — every
    weight shards its *parallel* dim over BOTH mesh axes (data×model = 256
    ways) and never moves; layers finish with activation-sized psums (KB at
    decode batch sizes, vs GB-scale weight gathers).  Right when each
    weight is touched by ONE token per step (§Perf-3).
    """

    def __init__(self, mesh, cfg: ModelConfig, mode: str = "train"):
        self.mesh = mesh
        self.cfg = cfg
        self.mode = mode
        self.model_size = mesh.shape.get("model", 1)
        self.dp_axes = data_axes(mesh)
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp_axes]))
        self.fsdp = tuple(self.dp_axes)   # params' secondary shard axes
        # full-mesh tensor axis set for decode_tp
        self.all_axes = tuple(self.dp_axes) + ("model",)
        self.all_size = self.dp_size * self.model_size

    # -- helpers -------------------------------------------------------------
    def _m(self, dim: int) -> Optional[str]:
        """'model' if dim divides the model axis else None."""
        return "model" if _div(dim, self.model_size) else None

    def _f(self, dim: int):
        """FSDP axes if divisible by the full DP size, else progressively
        fewer axes, else None."""
        if _div(dim, self.dp_size):
            return self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]
        if len(self.fsdp) > 1 and _div(dim, self.mesh.shape["data"]):
            return "data"
        return None

    def _b(self, dim: int):
        """Batch sharding over DP axes (requires divisibility)."""
        if _div(dim, self.dp_size):
            return self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]
        if len(self.fsdp) > 1 and _div(dim, self.mesh.shape["data"]):
            return "data"
        return None

    def _all(self, dim: int):
        """Full-mesh (data×model) tensor sharding for decode_tp mode."""
        if _div(dim, self.all_size):
            return self.all_axes
        return self._m(dim)

    def _decode_tp_spec(self, path: str, shape: Tuple[int, ...]) -> Optional[P]:
        """Weight-stationary decode sharding; returns None to fall through
        to the train rules (small/1-D tensors just replicate)."""
        cfg = self.cfg
        blocked = path.startswith("blocks/")

        def with_group(*rest):
            return P(*((None,) + rest)) if blocked else P(*rest)

        name = path.split("/")[-1]
        H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        if name == "embed":                     # [V, d]
            return P(self._all(shape[0]), None)
        if name == "unembed":                   # [d, V]
            return P(None, self._all(shape[1]))
        if "attn" in path:
            if name == "wq":                    # [d, H, Dh] -> H×model, Dh×data
                return with_group(None, self._m(H), dp if _div(Dh, self.dp_size) else None)
            if name in ("wk", "wv"):            # [d, KV, Dh]
                kv_m = self._m(KV)
                return with_group(None, kv_m,
                                  dp if _div(Dh, self.dp_size) else None)
            if name == "wo":                    # [H, Dh, d]
                return with_group(self._m(H),
                                  dp if _div(Dh, self.dp_size) else None, None)
        if "mlp" in path or ("moe" in path and name in
                             ("w_gate", "w_up", "w_down")):
            E_sharded = "moe" in path and self._m(cfg.moe_experts)
            if name in ("w_gate", "w_up"):
                # [d, f] or [E, d, f]: f over (data,model) [or data if E×model]
                f_ax = dp if E_sharded else self._all(shape[-1])
                if "moe" in path:
                    return with_group(E_sharded or None, None,
                                      f_ax if _div(shape[-1], self.dp_size) or not E_sharded else None)
                return with_group(None, f_ax)
            if name == "w_down":                # [f, d] or [E, f, d]
                f_ax = dp if E_sharded else self._all(shape[-2])
                if "moe" in path:
                    return with_group(E_sharded or None,
                                      f_ax if _div(shape[-2], self.dp_size) or not E_sharded else None, None)
                return with_group(f_ax, None)
        if "mamba" in path:
            Din = cfg.d_inner
            if name in ("in_z", "in_x"):        # [d, Din]: heads over full mesh
                return with_group(None, self._all(Din))
            if name == "in_dt":
                return with_group(None, self._all(shape[-1]))
            if name == "conv_x":
                return with_group(None, self._all(shape[-1]))
            if name in ("conv_bias_x", "norm"):
                return with_group(self._all(shape[-1]))
            if name in ("dt_bias", "A_log", "D"):
                return with_group(self._all(shape[-1]))
            if name == "out_proj":              # [Din, d]
                return with_group(self._all(Din), None)
        return None

    # -- parameter rules ---------------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        cfg = self.cfg
        if self.mode == "decode_tp":
            spec = self._decode_tp_spec(path, shape)
            if spec is not None:
                return spec
            # fall through: small tensors replicate under train rules minus
            # the fsdp axis (no gathers wanted)
            rank = len(shape)
            return P(*(None,) * rank)
        # strip the leading group-stack dim for block params
        blocked = path.startswith("blocks/")
        dims: Tuple[Optional[Any], ...]

        def with_group(*rest):
            return P(*((None,) + rest)) if blocked else P(*rest)

        name = path.split("/")[-1]
        H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

        if name == "embed":                     # [V, d]
            return P(self._m(shape[0]), self._f(shape[1]))
        if name == "unembed":                   # [d, V]
            return P(self._f(shape[0]), self._m(shape[1]))
        if name == "final_norm":
            return P(None)

        if "attn" in path:
            if name == "wq":                    # [d, H, Dh]
                if self._m(H):
                    return with_group(self._f(cfg.d_model), "model", None)
                return with_group(self._f(cfg.d_model), None, self._m(Dh))
            if name in ("wk", "wv"):            # [d, KV, Dh]
                if self._m(KV):
                    return with_group(self._f(cfg.d_model), "model", None)
                return with_group(self._f(cfg.d_model), None, self._m(Dh))
            if name == "wo":                    # [H, Dh, d]
                if self._m(H):
                    return with_group("model", None, self._f(cfg.d_model))
                return with_group(None, self._m(Dh), self._f(cfg.d_model))
            if name in ("q_norm", "k_norm"):    # [Dh]
                return with_group(None)

        if "mlp" in path:
            if name in ("w_gate", "w_up"):      # [d, f]
                return with_group(self._f(shape[-2]), self._m(shape[-1]))
            if name == "w_down":                # [f, d]
                return with_group(self._m(shape[-2]), self._f(shape[-1]))

        if "moe" in path:
            E = cfg.moe_experts
            if name == "router":                # [d, E]
                return with_group(self._f(shape[-2]), None)
            if name in ("w_gate", "w_up"):      # [E, d, f]
                if self._m(E):
                    return with_group("model", self._f(shape[-2]), None)
                return with_group(None, self._f(shape[-2]), self._m(shape[-1]))
            if name == "w_down":                # [E, f, d]
                if self._m(E):
                    return with_group("model", None, self._f(shape[-1]))
                return with_group(None, self._m(shape[-2]), self._f(shape[-1]))

        if "mamba" in path:
            Din = cfg.d_inner
            if name in ("in_z", "in_x"):        # [d, Din]
                return with_group(self._f(cfg.d_model), self._m(Din))
            if name in ("in_B", "in_C"):        # [d, N]
                return with_group(self._f(cfg.d_model), None)
            if name == "in_dt":                 # [d, H_ssd]
                return with_group(self._f(cfg.d_model), self._m(shape[-1]))
            if name == "conv_x":                # [W, Din]
                return with_group(None, self._m(Din))
            if name in ("conv_B", "conv_C"):
                return with_group(None, None)
            if name == "conv_bias_x" or name == "norm":   # [Din]
                return with_group(self._m(Din))
            if name in ("conv_bias_B", "conv_bias_C"):
                return with_group(None)
            if name in ("dt_bias", "A_log", "D"):         # [H_ssd]
                return with_group(self._m(shape[-1]))
            if name == "out_proj":              # [Din, d]
                return with_group(self._m(Din), self._f(cfg.d_model))

        if name in ("pre_norm", "ffn_norm"):    # [d]
            return with_group(None)

        # fallback: replicate
        rank = len(shape) - (1 if blocked else 0)
        return with_group(*(None,) * rank)

    # -- trees ------------------------------------------------------------------
    def param_pspecs(self) -> Any:
        specs = param_specs(self.cfg)
        flat = jax.tree_util.tree_flatten_with_path(specs)
        out = []
        for path, leaf in flat[0]:
            name = "/".join(
                k.key if hasattr(k, "key") else str(k) for k in path)
            out.append(self.param_spec(name, leaf.shape))
        return jax.tree_util.tree_unflatten(flat[1], out)

    def param_shardings(self) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_pspecs())

    def opt_pspecs(self, with_master: bool = False) -> Any:
        """Mirror of param specs for m/v (+ fp32 master) + replicated step."""
        p = self.param_pspecs()
        out = {"m": p, "v": p, "step": P()}
        if with_master:
            out["master"] = p
        return out

    # -- batch / activations ----------------------------------------------------
    def batch_pspecs(self, batch_tree: Any) -> Any:
        def spec(path, leaf):
            name = "/".join(
                k.key if hasattr(k, "key") else str(k) for k in path)
            if "positions" in name and self.cfg.mrope:   # [3, B, S]
                return P(None, self._b(leaf.shape[1]), None)
            rest = (None,) * (len(leaf.shape) - 1)
            return P(self._b(leaf.shape[0]), *rest)

        flat = jax.tree_util.tree_flatten_with_path(batch_tree)
        out = [spec(path, leaf) for path, leaf in flat[0]]
        return jax.tree_util.tree_unflatten(flat[1], out)

    # -- decode cache --------------------------------------------------------------
    def cache_pspecs(self, cache_tree: Any) -> Any:
        """Cache leaves: [G, B, S, KV, Dh] (attn k/v), [G, B, W-1, Ch] (conv),
        [G, B, H, P, N] (ssd).  Batch shards over DP; for batch=1 (long_500k)
        the attention sequence dim shards over "model" instead; KV heads or
        head_dim shard over "model" when divisible."""
        cfg = self.cfg

        def spec(path, leaf):
            name = "/".join(
                k.key if hasattr(k, "key") else str(k) for k in path)
            shape = leaf.shape   # leading G
            b = self._b(shape[1])
            if name.endswith("k") or name.endswith("v"):     # [G,B,S,KV,Dh]
                kv_m = self._m(shape[3])
                dh_m = self._m(shape[4]) if not kv_m else None
                seq_m = None
                if b is None and not kv_m and not dh_m:
                    seq_m = self._m(shape[2])
                elif b is None:
                    # batch=1: shard seq AND heads? only one "model" axis —
                    # prefer the (much larger) sequence dim.
                    seq_m, kv_m, dh_m = self._m(shape[2]), None, None
                return P(None, b, seq_m, kv_m, dh_m)
            if "conv" in name:                               # [G,B,W-1,Ch]
                ch_m = self._m(shape[3]) if "conv_x" in name else None
                return P(None, b, None, ch_m)
            if name.endswith("ssd"):                         # [G,B,H,P,N]
                return P(None, b, self._m(shape[2]), None, None)
            return P(*(None,) * len(shape))

        flat = jax.tree_util.tree_flatten_with_path(cache_tree)
        out = [spec(path, leaf) for path, leaf in flat[0]]
        return jax.tree_util.tree_unflatten(flat[1], out)

    def logits_pspec(self) -> P:
        batch = self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]
        if self.cfg.seq_shard:
            return P(batch, "model", None)
        return P(batch, None, self._m(self.cfg.vocab_size))
