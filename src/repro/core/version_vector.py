"""Classic version vectors (paper §3.2 / §3.3 baselines).

Two flavours are implemented, matching the paper's survey:

* ``VV`` with **per-server entries** (Dynamo-style, §3.2).  Its ``update``
  increments the coordinating replica's own entry.  This is a *plausible
  clock*: two clients writing through the same replica produce totally
  ordered clocks, so one concurrent update is silently linearized (Fig. 3).

* ``VV`` with **per-client entries** (§3.3).  Correct when clients are
  stateful (or read-your-writes holds) but the vector grows with the client
  population, and the *stateless-inferred* mode loses updates when a client
  switches replicas (Fig. 4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, Tuple

from .causal_history import CausalHistory

Entry = Tuple[str, int]


@dataclass(frozen=True)
class VV:
    """An immutable version vector: mapping id -> max counter."""

    entries: Tuple[Entry, ...] = ()

    def __post_init__(self) -> None:
        seen = set()
        kept = []
        for (r, c) in self.entries:
            if r in seen:
                raise ValueError(f"duplicate id {r!r}")
            seen.add(r)
            if c < 0:
                raise ValueError("negative counter")
            if c > 0:
                kept.append((r, c))
        object.__setattr__(self, "entries", tuple(sorted(kept)))

    @staticmethod
    def zero() -> "VV":
        return VV(())

    @staticmethod
    def from_dict(d: Dict[str, int]) -> "VV":
        return VV(tuple(d.items()))

    def get(self, r: str) -> int:
        for (rr, c) in self.entries:
            if rr == r:
                return c
        return 0

    def ids(self) -> FrozenSet[str]:
        return frozenset(r for (r, _) in self.entries)

    def bump(self, r: str, to: int | None = None) -> "VV":
        new = dict(self.entries)
        new[r] = (self.get(r) + 1) if to is None else to
        return VV(tuple(new.items()))

    def merge(self, other: "VV") -> "VV":
        """Pointwise max (the join of the VV lattice)."""
        out = dict(self.entries)
        for (r, c) in other.entries:
            out[r] = max(out.get(r, 0), c)
        return VV(tuple(out.items()))

    # -- partial order -------------------------------------------------------
    def leq(self, other: "VV") -> bool:
        return all(c <= other.get(r) for (r, c) in self.entries)

    def lt(self, other: "VV") -> bool:
        return self.leq(other) and not other.leq(self)

    def concurrent(self, other: "VV") -> bool:
        return not self.leq(other) and not other.leq(self)

    def dominates(self, other: "VV") -> bool:
        return other.leq(self)

    # -- semantics (each entry (r, c) summarizes events r_1..r_c) -------------
    def to_history(self) -> CausalHistory:
        events = set()
        for (r, c) in self.entries:
            events.update((r, i) for i in range(1, c + 1))
        return CausalHistory(frozenset(events))

    def size(self) -> int:
        return 2 * len(self.entries)

    def __repr__(self) -> str:
        return "{" + ", ".join(f"({r},{c})" for (r, c) in self.entries) + "}"


def merge_all(vvs: Iterable[VV]) -> VV:
    acc = VV.zero()
    for v in vvs:
        acc = acc.merge(v)
    return acc


# ---------------------------------------------------------------------------
# §3.2 — per-server-entry update (Dynamo).  The coordinator merges the client
# context, then increments its *own* entry past everything it stores locally.
# The returned clock totally orders against any same-server sibling — the
# paper's false-dominance failure.
# ---------------------------------------------------------------------------

def update_per_server(context: VV, S_r: FrozenSet[VV], r: str) -> VV:
    local_max = max((v.get(r) for v in S_r), default=0)
    return context.bump(r, to=max(local_max, context.get(r)) + 1)


# ---------------------------------------------------------------------------
# §3.3 — per-client-entry update.
#   * stateful mode: the client supplies its own monotonic counter — accurate
#     but O(#clients) space.
#   * stateless/inferred mode: the server guesses the next counter from the
#     context plus local versions; switching replicas between writes repeats
#     a counter and loses an update (Fig. 4).
# ---------------------------------------------------------------------------

def update_per_client_stateful(context: VV, client: str, counter: int) -> VV:
    return context.bump(client, to=counter)


def update_per_client_inferred(context: VV, S_r: FrozenSet[VV], client: str) -> VV:
    local_max = max((v.get(client) for v in S_r), default=0)
    return context.bump(client, to=max(local_max, context.get(client)) + 1)


def sync_vv(S1: FrozenSet[VV], S2: FrozenSet[VV]) -> FrozenSet[VV]:
    """Generic §4 sync over the VV partial order."""
    keep1 = {x for x in S1 if not any(x.lt(y) for y in S2)}
    keep2 = {x for x in S2 if not any(x.lt(y) for y in S1)}
    return frozenset(keep1 | keep2)
