"""Dotted version vectors (paper §5).

A DVV is a mapping from replica ids to either ``(m,)`` — a contiguous event
range ``1..m`` — or ``(m, n)`` — a range ``1..m`` plus one isolated "dot"
``n > m``.  The semantic function ``to_history`` maps clocks to causal
histories (§5.1); the partial order (§5.2) is inclusion of those histories,
computed component-wise without materializing them.

Representation: an immutable sorted tuple of ``(id, m, n)`` triples where
``n == 0`` encodes a plain (dotless) component.  ``m == 0`` with ``n > 0``
encodes a bare dot.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, Optional, Set, Tuple

from .causal_history import CausalHistory

Component = Tuple[str, int, int]  # (id, m, n); n == 0 means "no dot"


@dataclass(frozen=True)
class DVV:
    components: Tuple[Component, ...] = ()

    def __post_init__(self) -> None:
        seen = set()
        kept = []
        for (r, m, n) in self.components:
            if r in seen:
                raise ValueError(f"duplicate id {r!r} in DVV")
            seen.add(r)
            if m < 0 or n < 0:
                raise ValueError(f"negative counter in component {(r, m, n)}")
            if n != 0 and n <= m:
                raise ValueError(f"dot must satisfy n > m, got {(r, m, n)}")
            if m == 0 and n == 0:
                continue  # empty component represents no events — normalize away
            kept.append((r, m, n))
        object.__setattr__(self, "components", tuple(sorted(kept)))

    # -- construction ------------------------------------------------------
    @staticmethod
    def zero() -> "DVV":
        return DVV(())

    @staticmethod
    def from_dict(entries: Dict[str, Tuple[int, ...]]) -> "DVV":
        comps = []
        for r, v in entries.items():
            if len(v) == 1:
                comps.append((r, v[0], 0))
            else:
                comps.append((r, v[0], v[1]))
        return DVV(tuple(comps))

    # -- accessors ----------------------------------------------------------
    def ids(self) -> FrozenSet[str]:
        return frozenset(r for (r, _, _) in self.components)

    def component(self, r: str) -> Optional[Component]:
        for c in self.components:
            if c[0] == r:
                return c
        return None

    def ceil(self, r: str) -> int:
        """⌈C⌉_r — the maximum integer mapped from id ``r`` (paper §5.3)."""
        c = self.component(r)
        if c is None:
            return 0
        _, m, n = c
        return max(m, n)

    # -- semantics (paper §5.1) ----------------------------------------------
    def to_history(self) -> CausalHistory:
        events: Set[Tuple[str, int]] = set()
        for (r, m, n) in self.components:
            events.update((r, i) for i in range(1, m + 1))
            if n:
                events.add((r, n))
        return CausalHistory(frozenset(events))

    # -- partial order (paper §5.2) -------------------------------------------
    @staticmethod
    def _comp_leq(x: Component, y: Component) -> bool:
        """x ≤ y for two components with the same id."""
        rx, mx, nx = x
        ry, my, ny = y
        assert rx == ry
        if nx == 0 and ny == 0:   # (r,m) ≤ (r,m')
            return mx <= my
        if nx == 0:               # (r,m) ≤ (r,m',n')
            return mx <= my or (mx == my + 1 and mx == ny)
        if ny == 0:               # (r,m,n) ≤ (r,m')
            return nx <= my
        #                          (r,m,n) ≤ (r,m',n')
        return nx <= my or (mx <= my and nx == ny)

    def leq(self, other: "DVV") -> bool:
        """X ≤ Y ⟺ ∀x ∈ X. ∃y ∈ Y (same id). x ≤ y."""
        for x in self.components:
            y = other.component(x[0])
            if y is None or not self._comp_leq(x, y):
                return False
        return True

    def lt(self, other: "DVV") -> bool:
        return self.leq(other) and not other.leq(self)

    def concurrent(self, other: "DVV") -> bool:
        return not self.leq(other) and not other.leq(self)

    def dominates(self, other: "DVV") -> bool:
        return other.leq(self)

    # -- size (for the paper's scalability claims) ----------------------------
    def size(self) -> int:
        """Number of stored integers (2 per plain entry, 3 per dotted one)."""
        return sum(2 if n == 0 else 3 for (_, _, n) in self.components)

    def __repr__(self) -> str:
        parts = []
        for (r, m, n) in self.components:
            parts.append(f"({r},{m})" if n == 0 else f"({r},{m},{n})")
        return "{" + ", ".join(parts) + "}"


# ---------------------------------------------------------------------------
# Kernel operations (paper §4 instantiated for DVV, §5.3).
# ---------------------------------------------------------------------------

def ceil_set(S: Iterable[DVV], r: str) -> int:
    """⌈S⌉_r over a set of clocks."""
    return max((c.ceil(r) for c in S), default=0)


def ids_set(S: Iterable[DVV]) -> FrozenSet[str]:
    out: Set[str] = set()
    for c in S:
        out |= c.ids()
    return frozenset(out)


def update(S: FrozenSet[DVV], Sr: FrozenSet[DVV], r: str) -> DVV:
    """Mint the clock for a new PUT (paper §5.3).

    ``S`` is the client-supplied context, ``Sr`` the coordinator's current
    version set, ``r`` the coordinator id.  The result carries one dotted
    component (for ``r``) and plain components summarizing the context.
    """
    comps = []
    for i in sorted(ids_set(S) - {r}):
        comps.append((i, ceil_set(S, i), 0))
    m = ceil_set(S, r)
    n = ceil_set(Sr, r) + 1
    comps.append((r, m, n))
    return DVV(tuple(comps))


def sync(S1: FrozenSet[DVV], S2: FrozenSet[DVV]) -> FrozenSet[DVV]:
    """Merge two clock sets, discarding obsolete versions (paper §4).

    sync(S1,S2) = {x ∈ S1 | ¬∃y ∈ S2. x < y} ∪ {x ∈ S2 | ¬∃y ∈ S1. x < y}
    """
    keep1 = {x for x in S1 if not any(x.lt(y) for y in S2)}
    keep2 = {x for x in S2 if not any(x.lt(y) for y in S1)}
    return frozenset(keep1 | keep2)


def downset(S: Iterable[DVV]) -> bool:
    """The §5.4 invariant: the union of histories is downward closed."""
    from .causal_history import union_all

    S = list(S)
    hist = union_all(c.to_history() for c in S)
    for i in ids_set(S):
        top = ceil_set(S, i)
        for k in range(1, top + 1):
            if (i, k) not in hist.events:
                return False
    return True
