"""Array-encoded DVV algebra — the TPU-native adaptation (DESIGN.md §3).

A production deployment tracks millions of keys; anti-entropy between two
replica nodes compares the clock sets of every transferred key.  Doing that
clock-by-clock in Python is the CPU-era formulation; on TPU we batch.

Encoding (per clock, replica universe of fixed size R):
    vv     : int32[R]   — vv[r] = m, the contiguous range 1..m for replica r
    dot_id : int32[]    — replica index of the single dot (−1 if none)
    dot_n  : int32[]    — the dot's event counter n (> vv[dot_id]; 0 if none)

Every clock the store keeps has at most one dot (paper §5.3: all stored
clocks have exactly one triple component), so this encoding is *exact*, not
an approximation.  ``repro.kernels.dvv_ops`` provides the Pallas TPU kernel
for the dominance sweep; this module is the jnp reference implementation
and the host-side conversion helpers.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dvv import DVV

NO_DOT = -1


# ---------------------------------------------------------------------------
# Host-side conversions (pure Python <-> arrays).
# ---------------------------------------------------------------------------

def encode(clock: DVV, universe: Sequence[str]) -> Tuple[np.ndarray, int, int]:
    index = {r: i for i, r in enumerate(universe)}
    vv = np.zeros(len(universe), dtype=np.int32)
    dot_id, dot_n = NO_DOT, 0
    for (r, m, n) in clock.components:
        if r not in index:
            raise ValueError(f"replica {r!r} outside universe {universe}")
        vv[index[r]] = m
        if n:
            if dot_id != NO_DOT:
                raise ValueError("array encoding supports at most one dot")
            dot_id, dot_n = index[r], n
    return vv, dot_id, dot_n


def decode(vv: np.ndarray, dot_id: int, dot_n: int,
           universe: Sequence[str]) -> DVV:
    comps: List[Tuple[str, int, int]] = []
    for i, r in enumerate(universe):
        m = int(vv[i])
        n = int(dot_n) if i == int(dot_id) else 0
        if m or n:
            comps.append((r, m, n))
    return DVV(tuple(comps))


def encode_batch(clocks: Sequence[DVV], universe: Sequence[str]):
    vvs = np.zeros((len(clocks), len(universe)), dtype=np.int32)
    dot_ids = np.full((len(clocks),), NO_DOT, dtype=np.int32)
    dot_ns = np.zeros((len(clocks),), dtype=np.int32)
    for k, c in enumerate(clocks):
        vvs[k], dot_ids[k], dot_ns[k] = encode(c, universe)
    return vvs, dot_ids, dot_ns


# ---------------------------------------------------------------------------
# Numpy twins of the clock algebra — used by the resident packed store
# (store/packed.py) for per-key control-plane operations where a device
# dispatch per PUT would dominate.  Semantics identical to the jnp versions
# below; both are conformance-tested against the pure-Python DVV objects.
# ---------------------------------------------------------------------------

def leq_np(vx: np.ndarray, ix: np.ndarray, nx: np.ndarray,
           vy: np.ndarray, iy: np.ndarray, ny: np.ndarray) -> np.ndarray:
    """history(x) ⊆ history(y), batched over leading dims (numpy)."""
    R = vx.shape[-1]
    if R == 0:
        # Empty replica universe: all histories are empty, hence equal.
        # (No dot can exist — a dot names a replica.)
        return np.ones(np.broadcast(np.asarray(ix), np.asarray(iy)).shape,
                       bool)
    ar = np.arange(R, dtype=np.int32)
    iy_b = np.asarray(iy)[..., None]
    ny_b = np.asarray(ny)[..., None]
    dot_extends = (iy_b == ar) & (vx == ny_b) & (vx == vy + 1)
    range_ok = np.all((vx <= vy) | dot_extends, axis=-1)

    has_dot = np.asarray(ix) != NO_DOT
    ix_safe = np.clip(ix, 0, R - 1)
    vy_at_ix = np.take_along_axis(
        np.asarray(vy), np.asarray(ix_safe)[..., None], axis=-1)[..., 0]
    dot_ok = (nx <= vy_at_ix) | ((iy == ix) & (nx == ny))
    dot_ok = np.where(has_dot, dot_ok, True)
    return range_ok & dot_ok


def sync_mask_np(vvs: np.ndarray, dot_ids: np.ndarray, dot_ns: np.ndarray,
                 valid: np.ndarray) -> np.ndarray:
    """Numpy twin of ``sync_mask`` (below): survival of a combined clock set.

    vvs [..., K, R]; dot_ids/dot_ns/valid [..., K].  Returns bool [..., K].
    """
    K = vvs.shape[-2]
    vx = vvs[..., :, None, :]
    vy = vvs[..., None, :, :]
    ix = dot_ids[..., :, None]
    iy = dot_ids[..., None, :]
    nx = dot_ns[..., :, None]
    ny = dot_ns[..., None, :]
    le = leq_np(vx, ix, nx, vy, iy, ny)
    ge = leq_np(vy, iy, ny, vx, ix, nx)
    strictly_below = le & ~ge
    equal = le & ge
    idx = np.arange(K, dtype=np.int32)
    dup_earlier = equal & (idx[..., None, :] < idx[..., :, None])
    other_valid = valid[..., None, :]
    dominated = np.any((strictly_below | dup_earlier) & other_valid, axis=-1)
    return valid & ~dominated


def grouped_ceil_at_np(vv_at_r: np.ndarray, dot_ids: np.ndarray,
                       dot_ns: np.ndarray, groups: np.ndarray,
                       n_groups: int, r_index: int) -> np.ndarray:
    """⌈S⌉_r per *group* over stacked clock rows — the batched twin of
    ``effective_ceil_np`` used by multi-key PUT minting.

    ``vv_at_r`` is the r-column of each row's vv; ``groups`` assigns each
    row to one of ``n_groups`` keys.  One ``np.maximum.at`` scatter per
    signal — no per-key Python loop.
    """
    out = np.zeros(n_groups, np.int32)
    if len(vv_at_r):
        np.maximum.at(out, groups, vv_at_r.astype(np.int32))
        at_r = np.asarray(dot_ids) == r_index
        if at_r.any():
            np.maximum.at(out, np.asarray(groups)[at_r],
                          np.asarray(dot_ns, np.int32)[at_r])
    return out


def grouped_ceiling_np(vvs: np.ndarray, dot_ids: np.ndarray,
                       dot_ns: np.ndarray, groups: np.ndarray,
                       n_groups: int) -> np.ndarray:
    """Per-*group* §5.4 ceiling ⌈S⌉ over stacked clock rows — the
    segment-reduced twin of ``store.packed.ceiling_from_rows`` used by the
    batched read plane (``quorum_merge_many``).

    ``vvs`` is int32[M, R]; ``groups`` assigns each row to one of
    ``n_groups`` keys.  Returns int64[n_groups, R]: per group, the column
    max of the rows with the dots folded in — two ``np.maximum.at``
    scatters, no per-key Python loop.
    """
    R = int(vvs.shape[-1])
    out = np.zeros((n_groups, R), np.int64)
    if vvs.shape[0] == 0 or R == 0:
        return out
    g = np.asarray(groups, np.int64)
    np.maximum.at(out, g, np.asarray(vvs, np.int64))
    has_dot = np.asarray(dot_ids) != NO_DOT
    if has_dot.any():
        flat = out.reshape(-1)               # view: scatters land in ``out``
        np.maximum.at(flat, g[has_dot] * R
                      + np.asarray(dot_ids, np.int64)[has_dot],
                      np.asarray(dot_ns, np.int64)[has_dot])
    return out


def effective_ceil_np(vvs: np.ndarray, dot_ids: np.ndarray,
                      dot_ns: np.ndarray, r_index: int) -> int:
    """⌈S⌉_r over a clock set given as arrays: max of vv[:, r] and any dot at r."""
    if vvs.shape[0] == 0:
        return 0
    top = int(vvs[:, r_index].max(initial=0))
    at_r = dot_ids == r_index
    if at_r.any():
        top = max(top, int(dot_ns[at_r].max(initial=0)))
    return top


# ---------------------------------------------------------------------------
# Vectorized clock algebra (jnp).  All functions are jit/vmap friendly and
# operate on batches: vv [..., R], dot_id [...], dot_n [...].
# ---------------------------------------------------------------------------

def leq(vx: jnp.ndarray, ix: jnp.ndarray, nx: jnp.ndarray,
        vy: jnp.ndarray, iy: jnp.ndarray, ny: jnp.ndarray) -> jnp.ndarray:
    """history(x) ⊆ history(y), batched over leading dims.

    Range coverage per replica r: 1..vx[r] ⊆ (1..vy[r] ∪ {ny if iy==r})
        ⟺ vx[r] ≤ vy[r]  ∨  (iy==r ∧ vx[r] == ny == vy[r]+1)
    Dot coverage (if ix != NO_DOT): nx ≤ vy[ix] ∨ (iy==ix ∧ nx==ny)
    """
    R = vx.shape[-1]
    ar = jnp.arange(R, dtype=jnp.int32)
    iy_b = iy[..., None]
    ny_b = ny[..., None]
    dot_extends = (iy_b == ar) & (vx == ny_b) & (vx == vy + 1)
    range_ok = jnp.all((vx <= vy) | dot_extends, axis=-1)

    has_dot = ix != NO_DOT
    # gather vy[ix] safely (ix may be -1; clamp and mask)
    ix_safe = jnp.clip(ix, 0, R - 1)
    vy_at_ix = jnp.take_along_axis(vy, ix_safe[..., None], axis=-1)[..., 0]
    dot_ok = (nx <= vy_at_ix) | ((iy == ix) & (nx == ny))
    dot_ok = jnp.where(has_dot, dot_ok, True)
    return range_ok & dot_ok


def dominates(vx, ix, nx, vy, iy, ny) -> jnp.ndarray:
    """x dominates y  ⟺  y ≤ x."""
    return leq(vy, iy, ny, vx, ix, nx)


def concurrent(vx, ix, nx, vy, iy, ny) -> jnp.ndarray:
    return ~leq(vx, ix, nx, vy, iy, ny) & ~leq(vy, iy, ny, vx, ix, nx)


def effective_vv(vv: jnp.ndarray, dot_id: jnp.ndarray,
                 dot_n: jnp.ndarray) -> jnp.ndarray:
    """Fold the dot into the vector *only where it is contiguous* (n == m+1).

    Used by ``merge_context``: the ⌈·⌉ ceiling of the paper takes max(m, n),
    which is safe when summarizing a *downset* context.
    """
    R = vv.shape[-1]
    ar = jnp.arange(R, dtype=jnp.int32)
    at_dot = dot_id[..., None] == ar
    return jnp.where(at_dot, jnp.maximum(vv, dot_n[..., None]), vv)


def merge_context(vvs: jnp.ndarray, dot_ids: jnp.ndarray, dot_ns: jnp.ndarray,
                  valid: jnp.ndarray) -> jnp.ndarray:
    """⌈S⌉ per replica over a clock *set* (axis -2), masked by ``valid``.

    Returns a plain vv[..., R] — the context summary used by ``update``.
    Relies on the §5.4 downset invariant of the context.
    """
    eff = effective_vv(vvs, dot_ids, dot_ns)
    eff = jnp.where(valid[..., None], eff, 0)
    return jnp.max(eff, axis=-2)


def update_clock(ctx_vv: jnp.ndarray, local_max_r: jnp.ndarray,
                 r_index: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Mint the new clock (paper §5.3) in array form.

    ctx_vv      : [..., R] — merged context ceiling ⌈S⌉
    local_max_r : [...]    — ⌈Sr⌉_r at the coordinator
    r_index     : [...]    — coordinator replica index
    Returns (vv, dot_id, dot_n) with vv = ctx_vv and the dot at r.
    """
    dot_n = jnp.maximum(local_max_r, 0) + 1
    return ctx_vv, r_index.astype(jnp.int32), dot_n.astype(jnp.int32)


def sync_mask(vvs: jnp.ndarray, dot_ids: jnp.ndarray, dot_ns: jnp.ndarray,
              valid: jnp.ndarray) -> jnp.ndarray:
    """Which clocks of a combined set survive sync (are not strictly dominated).

    vvs [..., K, R]; dot_ids/dot_ns/valid [..., K].  Returns bool [..., K].
    A clock survives iff no *other valid* clock strictly dominates it.
    Pairs of equal clocks (same history) keep the lowest index.
    """
    K = vvs.shape[-2]
    vx = vvs[..., :, None, :]
    vy = vvs[..., None, :, :]
    ix = dot_ids[..., :, None]
    iy = dot_ids[..., None, :]
    nx = dot_ns[..., :, None]
    ny = dot_ns[..., None, :]
    le = leq(vx, ix, nx, vy, iy, ny)          # [..., K, K]  x ≤ y
    ge = leq(vy, iy, ny, vx, ix, nx)          # x ≥ y
    strictly_below = le & ~ge
    equal = le & ge
    idx = jnp.arange(K, dtype=jnp.int32)
    dup_earlier = equal & (idx[..., None, :] < idx[..., :, None])  # equal to an earlier clock
    other_valid = valid[..., None, :]
    dominated = jnp.any((strictly_below | dup_earlier) & other_valid, axis=-1)
    return valid & ~dominated


# ---------------------------------------------------------------------------
# Shape-bucketed sync_mask dispatch (DESIGN.md §6).
#
# Delta anti-entropy rounds produce grouped [N, K, R] tensors of *arbitrary*
# small shapes — every distinct shape would re-trace the jitted sync_mask
# (or re-specialize the pallas_call).  Bucketing pads each dim to the next
# power of two (with small floors) so the whole sweep space collapses into a
# handful of shapes, each compiled once and warm thereafter.  Pad rows are
# inert by construction: ``valid`` is False, and an invalid clock can
# neither survive (mask = valid & …) nor dominate (domination is masked by
# ``other_valid``); zero-filled replica columns denote empty ranges, which
# is the exact meaning of an absent replica.
# ---------------------------------------------------------------------------

def _ceil_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def bucket_shape(n: int, k: int, r: int, *, min_n: int = 8, min_k: int = 2,
                 min_r: int = 8) -> Tuple[int, int, int]:
    """The power-of-two (N_block, K_pad, R_pad) bucket containing [n, k, r]."""
    return (max(min_n, _ceil_pow2(n)), max(min_k, _ceil_pow2(k)),
            max(min_r, _ceil_pow2(r)))


def pad_sync_args(vvs: np.ndarray, dot_ids: np.ndarray, dot_ns: np.ndarray,
                  valid: np.ndarray, shape: Tuple[int, int, int]):
    """Zero/NO_DOT/False-pad a grouped sync tensor up to ``shape``."""
    N, K, R = vvs.shape
    Nb, Kb, Rb = shape
    return (np.pad(vvs, ((0, Nb - N), (0, Kb - K), (0, Rb - R))),
            np.pad(dot_ids, ((0, Nb - N), (0, Kb - K)),
                   constant_values=NO_DOT),
            np.pad(dot_ns, ((0, Nb - N), (0, Kb - K))),
            np.pad(valid, ((0, Nb - N), (0, Kb - K))))


class BucketedSyncMask:
    """A ``mask_fn`` that shape-buckets its input and caches one compiled
    callable per bucket.

    ``impl`` is any sync_mask-compatible function ([N, K, R] + three [N, K]
    → bool [N, K]); the default is the jnp reference, wrapped in one shared
    ``jax.jit`` whose own cache is keyed by the bucketed shapes.  Pass
    ``jit=False`` for impls that manage their own compilation cache (the
    pallas wrapper) — bucketing is then what makes that cache hit.
    ``hits``/``misses`` count warm vs cold buckets, which the delta
    benchmark reports.
    """

    def __init__(self, impl=None, *, jit: bool = True):
        base = sync_mask if impl is None else impl
        self._fn = jax.jit(base) if jit else base
        self._seen: set = set()
        self.hits = 0
        self.misses = 0

    def __call__(self, vvs, dot_ids, dot_ns, valid) -> np.ndarray:
        vvs = np.asarray(vvs)
        dot_ids = np.asarray(dot_ids)
        dot_ns = np.asarray(dot_ns)
        valid = np.asarray(valid)
        N, K, R = vvs.shape
        if N == 0 or K == 0:
            return np.zeros((N, K), bool)
        key = bucket_shape(N, K, R)
        if key in self._seen:
            self.hits += 1
        else:
            self.misses += 1
            self._seen.add(key)
        args = pad_sync_args(vvs, dot_ids, dot_ns, valid, key)
        out = np.asarray(self._fn(*args))
        return out[:N, :K]

    def cache_info(self) -> Dict[str, object]:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": round(self.hits / total, 4) if total else 0.0,
                "buckets": sorted(self._seen)}

    def reset_stats(self) -> None:
        """Zero the hit/miss counters; the bucket set (and the compiled
        callables behind it) stays warm.  Lets the serving benchmark
        report cross-flush hit rates per measurement window."""
        self.hits = 0
        self.misses = 0


#: Module-level jnp-reference instance.  Product delta rounds use the numpy
#: twin (mask_fn=None) or the kernel instance (`kernels.dvv_ops.
#: dvv_sync_mask_bucketed`); this one serves conformance tests and callers
#: that want the jitted jnp path without building their own cache.
sync_mask_bucketed = BucketedSyncMask()
