"""Causal histories — the reference semantics for every clock mechanism.

Paper §3: "Causal histories are simply described by sets of unique update
event identifiers."  An event is ``(replica_id, counter)``; the partial order
is set inclusion.  Causal histories are exact but grow linearly with the
number of updates, so they serve as the *oracle* against which every compact
clock (version vectors, dotted version vectors, ...) is validated.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import FrozenSet, Iterable, Tuple

Event = Tuple[str, int]  # (replica_id, counter), counters start at 1


@dataclass(frozen=True)
class CausalHistory:
    """An immutable set of update event identifiers."""

    events: FrozenSet[Event] = field(default_factory=frozenset)

    # -- construction ------------------------------------------------------
    @staticmethod
    def empty() -> "CausalHistory":
        return CausalHistory(frozenset())

    @staticmethod
    def of(*events: Event) -> "CausalHistory":
        return CausalHistory(frozenset(events))

    def add(self, event: Event) -> "CausalHistory":
        return CausalHistory(self.events | {event})

    def union(self, other: "CausalHistory") -> "CausalHistory":
        return CausalHistory(self.events | other.events)

    # -- partial order (paper §3: set inclusion) ---------------------------
    def leq(self, other: "CausalHistory") -> bool:
        return self.events <= other.events

    def lt(self, other: "CausalHistory") -> bool:
        return self.events < other.events

    def concurrent(self, other: "CausalHistory") -> bool:
        """A || B iff A ⊄ B and B ⊄ A (and A != B)."""
        return not self.leq(other) and not other.leq(self)

    def dominates(self, other: "CausalHistory") -> bool:
        return other.events <= self.events

    # -- helpers -----------------------------------------------------------
    def max_counter(self, replica: str) -> int:
        """Largest counter registered by ``replica`` (0 if none)."""
        return max((c for (r, c) in self.events if r == replica), default=0)

    def ids(self) -> FrozenSet[str]:
        return frozenset(r for (r, _) in self.events)

    def is_downset(self) -> bool:
        """True iff for each replica the events form a contiguous 1..k range."""
        for r in self.ids():
            counters = sorted(c for (rr, c) in self.events if rr == r)
            if counters != list(range(1, len(counters) + 1)):
                return False
        return True

    def size(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:  # {a1, a2, b1}
        inner = ", ".join(f"{r}{c}" for (r, c) in sorted(self.events))
        return "{" + inner + "}"


def union_all(histories: Iterable[CausalHistory]) -> CausalHistory:
    acc: FrozenSet[Event] = frozenset()
    for h in histories:
        acc |= h.events
    return CausalHistory(acc)
