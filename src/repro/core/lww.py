"""Totally-ordered clocks (paper §3.1 baselines): real-time LWW and Lamport.

Both establish a total order *compliant with* causality but collapse all
concurrency — the paper's Fig. 2 run shows concurrent updates being silently
dropped under last-writer-wins.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet


@dataclass(frozen=True)
class WallClock:
    """Physical-timestamp clock (Cassandra v0.6 style).

    ``skew`` models a client with a persistently fast/slow clock; the paper
    notes such a client always wins / always loses.
    """

    t: float
    tiebreak: str = ""

    def leq(self, other: "WallClock") -> bool:
        return (self.t, self.tiebreak) <= (other.t, other.tiebreak)

    def lt(self, other: "WallClock") -> bool:
        return (self.t, self.tiebreak) < (other.t, other.tiebreak)

    def concurrent(self, other: "WallClock") -> bool:
        return False  # total order: nothing is ever concurrent

    def size(self) -> int:
        return 2


@dataclass(frozen=True)
class LamportClock:
    """(counter, site) pair ordered lexicographically (paper §3.1)."""

    counter: int
    site: str

    def leq(self, other: "LamportClock") -> bool:
        return (self.counter, self.site) <= (other.counter, other.site)

    def lt(self, other: "LamportClock") -> bool:
        return (self.counter, self.site) < (other.counter, other.site)

    def concurrent(self, other: "LamportClock") -> bool:
        return False

    def size(self) -> int:
        return 2


def lamport_update(context: FrozenSet[LamportClock], S_r: FrozenSet[LamportClock],
                   site: str) -> LamportClock:
    """Tag a new update: advance past everything seen locally or in context."""
    seen = max((c.counter for c in (context | S_r)), default=0)
    return LamportClock(seen + 1, site)


def lww_store(current, incoming):
    """Last-writer-wins register step: keep the larger clock's value."""
    cur_clock, _ = current
    inc_clock, _ = incoming
    return incoming if cur_clock.lt(inc_clock) else current
