"""Core causality-tracking library (the paper's contribution).

Exports the dotted-version-vector clock (paper §5), the §4 kernel
(sync/update + formal conditions), the §3 baseline mechanisms, and the
batched array encoding used by the TPU kernels.
"""
from .causal_history import CausalHistory, union_all
from .dvv import DVV, downset, sync, update
from .kernel import (
    ALL_MECHANISMS,
    DVV_MECHANISM,
    LAMPORT_MECHANISM,
    Mechanism,
    VV_CLIENT_INFERRED_MECHANISM,
    VV_CLIENT_MECHANISM,
    VV_SERVER_MECHANISM,
    WALLCLOCK_MECHANISM,
    antichain,
    generic_sync,
    sync_conditions_hold,
    update_conditions_hold_histories,
)
from .lww import LamportClock, WallClock, lamport_update
from .version_vector import VV, merge_all, sync_vv

__all__ = [
    "CausalHistory", "union_all",
    "DVV", "downset", "sync", "update",
    "VV", "merge_all", "sync_vv",
    "LamportClock", "WallClock", "lamport_update",
    "Mechanism", "ALL_MECHANISMS", "DVV_MECHANISM", "VV_SERVER_MECHANISM",
    "VV_CLIENT_MECHANISM", "VV_CLIENT_INFERRED_MECHANISM",
    "LAMPORT_MECHANISM", "WALLCLOCK_MECHANISM",
    "antichain", "generic_sync",
    "sync_conditions_hold", "update_conditions_hold_histories",
]
