"""The eventual-consistency kernel (paper §4).

Two operations on *sets of clocks* are the whole interface between a
key-value store and its causality mechanism:

* ``sync(S1, S2)``  — merge two divergent clock sets, discarding obsolete
  versions.  Generic over the partial order; implemented once.
* ``update(S, Sr, r)`` — mint the clock for a new PUT from the client context
  ``S``, the coordinator's current set ``Sr`` and its id ``r``.
  Representation-specific; each mechanism plugs its own.

This module also encodes the paper's *formal conditions* on both operations
as executable predicates — the hypothesis property tests drive random store
schedules through them.

``Mechanism`` bundles a clock implementation so the replicated store
(`repro.store`) and the benchmarks can swap mechanisms on identical
schedules and compare outcomes (lost updates, false concurrency, metadata
size) — reproducing the paper's §3 survey experimentally.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, FrozenSet, Generic, Iterable, Set, TypeVar

from . import dvv as _dvv
from . import version_vector as _vv
from .causal_history import CausalHistory, union_all

C = TypeVar("C")  # a clock type with .lt/.leq


def generic_sync(S1: FrozenSet[C], S2: FrozenSet[C]) -> FrozenSet[C]:
    """Paper §4: defined only in terms of the partial order on clocks."""
    keep1 = {x for x in S1 if not any(x.lt(y) for y in S2)}
    keep2 = {x for x in S2 if not any(x.lt(y) for y in S1)}
    return frozenset(keep1 | keep2)


def antichain(S: Iterable[C]) -> FrozenSet[C]:
    """Reduce a clock set to its maximal elements (defensive helper)."""
    S = list(S)
    return frozenset(
        x for i, x in enumerate(S)
        if not any(x.lt(y) for j, y in enumerate(S) if i != j)
    )


# ---------------------------------------------------------------------------
# Formal conditions (paper §4) as predicates, used by property tests.
# ---------------------------------------------------------------------------

def sync_conditions_hold(S1: FrozenSet[C], S2: FrozenSet[C],
                         S: FrozenSet[C]) -> bool:
    """Check the three conditions on S = sync(S1, S2).

    Condition 2 is read over clock *equivalence classes*: DVV
    representations are not canonical — e.g. ``{(a,2,3)}`` and ``{(a,3)}``
    denote the same causal history {a1,a2,a3} (found by hypothesis) — so
    "∀x,y ∈ S. x ≰ y" means no *strict* domination; mutually-≤ pairs are
    the same clock written two ways.  (The store itself never mints
    dotless version clocks, so such pairs cannot arise in protocol
    states — see tests/test_kernel_properties.py.)
    """
    both = S1 | S2
    # 1) every element of S comes from the inputs
    if not all(x in both for x in S):
        return False
    # 2) S is an antichain up to equivalence: no strict domination inside
    for x in S:
        for y in S:
            if x != y and x.leq(y) and not y.leq(x):
                return False
    # 3) everything in the inputs is dominated by something in S
    return all(any(x.leq(y) for y in S) for x in both)


def update_conditions_hold_histories(
    S_hist: FrozenSet[CausalHistory],
    all_replica_hists: FrozenSet[CausalHistory],
    u_hist: CausalHistory,
) -> bool:
    """Check the three §4 conditions on u = update(S, Sr, r), in history space.

    Working in causal-history space makes the join ⊔S simply the union of
    event sets, so the conditions are directly checkable for any mechanism
    that provides ``to_history``.
    """
    # 1) ∀x ∈ S. x ≤ u
    if not all(x.leq(u_hist) for x in S_hist):
        return False
    # 2) ∀x stored anywhere. x ≤ u ⇒ x ≤ ⊔S
    join_S = union_all(S_hist)
    for x in all_replica_hists:
        if x.leq(u_hist) and not x.leq(join_S):
            return False
    # 3) u is not dominated by the join of everything already in the system
    join_all = union_all(all_replica_hists)
    return not u_hist.leq(join_all)


# ---------------------------------------------------------------------------
# Mechanism registry — one entry per §3/§5 approach.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Mechanism(Generic[C]):
    """A pluggable causality mechanism for the replicated store.

    ``update(context_set, local_set, replica_id, client_id, client_counter,
    wall_time)`` returns the clock for a new version.  Mechanisms ignore the
    arguments they do not need.
    """

    name: str
    update: Callable[..., C]
    sync: Callable[[FrozenSet[C], FrozenSet[C]], FrozenSet[C]]
    zero_context: FrozenSet[C]
    tracks_concurrency: bool  # False for total orders (LWW / Lamport)


def _dvv_update(S, Sr, r, client, counter, wall_time):
    return _dvv.update(frozenset(S), frozenset(Sr), r)


def _vv_server_update(S, Sr, r, client, counter, wall_time):
    ctx = _vv.merge_all(S)
    return _vv.update_per_server(ctx, frozenset(Sr), r)


def _vv_client_stateful_update(S, Sr, r, client, counter, wall_time):
    ctx = _vv.merge_all(S)
    return _vv.update_per_client_stateful(ctx, client, counter)


def _vv_client_inferred_update(S, Sr, r, client, counter, wall_time):
    ctx = _vv.merge_all(S)
    return _vv.update_per_client_inferred(ctx, frozenset(Sr), client)


def _lamport_update(S, Sr, r, client, counter, wall_time):
    from .lww import lamport_update
    return lamport_update(frozenset(S), frozenset(Sr), r)


def _wallclock_update(S, Sr, r, client, counter, wall_time):
    from .lww import WallClock
    return WallClock(wall_time, client)


def _oracle_update(S, Sr, r, client, counter, wall_time):
    """Explicit causal histories (paper §3/Fig. 1) — the exact reference.

    The new event id ``(r, n)`` uses the same argument as DVV's dot: every
    r-event is minted at r and never evicted below r's local ceiling, so
    ``max_r(Sr) + 1`` is globally fresh.
    """
    ctx = union_all(S)
    n = max((h.max_counter(r) for h in Sr), default=0) + 1
    return ctx.add((r, n))


def _oracle_sync(S1, S2):
    keep1 = {x for x in S1 if not any(x.lt(y) for y in S2)}
    keep2 = {x for x in S2 if not any(x.lt(y) for y in S1)}
    return frozenset(keep1 | keep2)


def _lww_sync(S1, S2):
    """Total-order sync: keep only the single largest clock."""
    allc = list(S1 | S2)
    if not allc:
        return frozenset()
    best = allc[0]
    for c in allc[1:]:
        if best.lt(c):
            best = c
    return frozenset({best})


DVV_MECHANISM = Mechanism(
    name="dvv", update=_dvv_update, sync=_dvv.sync,
    zero_context=frozenset(), tracks_concurrency=True)

VV_SERVER_MECHANISM = Mechanism(
    name="vv_server", update=_vv_server_update, sync=_vv.sync_vv,
    zero_context=frozenset(), tracks_concurrency=True)

VV_CLIENT_MECHANISM = Mechanism(
    name="vv_client", update=_vv_client_stateful_update, sync=_vv.sync_vv,
    zero_context=frozenset(), tracks_concurrency=True)

VV_CLIENT_INFERRED_MECHANISM = Mechanism(
    name="vv_client_inferred", update=_vv_client_inferred_update, sync=_vv.sync_vv,
    zero_context=frozenset(), tracks_concurrency=True)

LAMPORT_MECHANISM = Mechanism(
    name="lamport", update=_lamport_update, sync=_lww_sync,
    zero_context=frozenset(), tracks_concurrency=False)

WALLCLOCK_MECHANISM = Mechanism(
    name="wallclock_lww", update=_wallclock_update, sync=_lww_sync,
    zero_context=frozenset(), tracks_concurrency=False)

ORACLE_MECHANISM = Mechanism(
    name="oracle", update=_oracle_update, sync=_oracle_sync,
    zero_context=frozenset(), tracks_concurrency=True)

ALL_MECHANISMS = {
    m.name: m for m in [
        DVV_MECHANISM, VV_SERVER_MECHANISM, VV_CLIENT_MECHANISM,
        VV_CLIENT_INFERRED_MECHANISM, LAMPORT_MECHANISM, WALLCLOCK_MECHANISM,
        ORACLE_MECHANISM,
    ]
}
