from .pipeline import MemmapTokens, PipelineConfig, SyntheticTokens

__all__ = ["PipelineConfig", "SyntheticTokens", "MemmapTokens"]
