"""Deterministic, shardable, resumable token pipeline.

Batches are a pure function of ``(seed, cursor)`` — the counter-mode design
means resume-from-checkpoint needs exactly one integer (the manifest's
``data_cursor``), replays are bitwise identical, and each DP rank draws its
disjoint slice without coordination.  A memmap-backed corpus reader with
the same interface is provided for real token files.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    dp_rank: int = 0
    dp_size: int = 1
    seed: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.dp_size == 0
        return self.global_batch // self.dp_size


class SyntheticTokens:
    """Counter-mode synthetic corpus: sequence i is threefry(seed, i)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.cursor = 0  # global sequences consumed

    def state(self) -> int:
        return self.cursor

    def restore(self, cursor: int) -> None:
        self.cursor = cursor

    def _sequence_ids(self) -> np.ndarray:
        """Global sequence ids for this step, sliced to this rank."""
        c = self.cfg
        start = self.cursor
        ids = start + np.arange(c.global_batch)
        return ids[c.dp_rank * c.local_batch:(c.dp_rank + 1) * c.local_batch]

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        ids = self._sequence_ids()
        key = jax.random.key(c.seed)
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.asarray(ids, jnp.uint32))
        toks = jax.vmap(lambda k: jax.random.randint(
            k, (c.seq_len + 1,), 0, c.vocab_size, dtype=jnp.int32))(keys)
        toks = np.asarray(toks)
        self.cursor += c.global_batch
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapTokens:
    """Token-file corpus with the same cursor/restore interface."""

    def __init__(self, cfg: PipelineConfig, path: str, dtype=np.int32):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.n_sequences = len(self.data) // (cfg.seq_len + 1)
        if self.n_sequences == 0:
            raise ValueError(f"{path}: shorter than one sequence")
        self.cursor = 0

    def state(self) -> int:
        return self.cursor

    def restore(self, cursor: int) -> None:
        self.cursor = cursor

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        ids = (self.cursor + np.arange(c.global_batch)) % self.n_sequences
        ids = ids[c.dp_rank * c.local_batch:(c.dp_rank + 1) * c.local_batch]
        L = c.seq_len + 1
        rows = np.stack([self.data[i * L:(i + 1) * L] for i in ids])
        rows = rows.astype(np.int32) % c.vocab_size
        self.cursor += c.global_batch
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
