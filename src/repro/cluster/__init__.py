"""Cluster control plane: membership, failure detection, elastic scaling,
straggler mitigation — all causality-tracked through the DVV store."""
from .elastic import Assignment, ElasticController
from .failure_detector import FailureDetector
from .membership import MEMBERSHIP_KEY, MemberView, MembershipService, NodeStatus
from .stealer import Lease, WorkStealer, resolve_lease_siblings

__all__ = [
    "MembershipService", "MemberView", "NodeStatus", "MEMBERSHIP_KEY",
    "FailureDetector", "ElasticController", "Assignment",
    "WorkStealer", "Lease", "resolve_lease_siblings",
]
