"""Compat shim: the DVV-backed membership ledger was promoted to the store
plane (``repro.store.services``), alongside the §13 liveness controller it
complements.  The training-sim runtime keeps importing it from here; new
code should import from ``repro.store``.
"""
from __future__ import annotations

from ..store.services import MEMBERSHIP_KEY, MemberView, MembershipService, \
    NodeStatus

__all__ = ["MEMBERSHIP_KEY", "MemberView", "MembershipService", "NodeStatus"]
