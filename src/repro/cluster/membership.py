"""DVV-backed cluster membership.

Membership is a map ``node_id -> (status, epoch)`` stored as a single key in
the replicated store.  Elastic scale-up/down means *concurrent* membership
writes through different coordinators — exactly the workload where a
per-server version vector linearizes concurrent joins (paper §3.2) and LWW
drops one (paper §3.1).  With DVV the divergent views surface as siblings
and are merged with a deterministic join (pointwise max epoch, status
priority), then written back with the full context so the merge dominates
both branches.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from enum import IntEnum
from typing import Dict, FrozenSet, Optional, Tuple

from ..store import KVCluster, Unavailable

MEMBERSHIP_KEY = "cluster/membership"


class NodeStatus(IntEnum):
    # ordered by reconciliation priority at equal epoch: dead > leaving > alive
    ALIVE = 0
    LEAVING = 1
    DEAD = 2


@dataclass(frozen=True)
class MemberView:
    """Immutable membership snapshot."""

    members: Tuple[Tuple[str, Tuple[int, int]], ...] = ()  # (node, (status, epoch))

    @staticmethod
    def from_dict(d: Dict[str, Tuple[int, int]]) -> "MemberView":
        return MemberView(tuple(sorted(d.items())))

    def to_dict(self) -> Dict[str, Tuple[int, int]]:
        return {k: tuple(v) for k, v in self.members}

    def serialize(self) -> str:
        return json.dumps(self.members, sort_keys=True)

    @staticmethod
    def deserialize(s: str) -> "MemberView":
        raw = json.loads(s)
        return MemberView(tuple((n, tuple(v)) for n, v in raw))

    def alive(self) -> Tuple[str, ...]:
        return tuple(n for n, (s, _) in self.members
                     if s == NodeStatus.ALIVE)

    @staticmethod
    def merge(views: "Tuple[MemberView, ...]") -> "MemberView":
        """Deterministic join of divergent sibling views."""
        out: Dict[str, Tuple[int, int]] = {}
        for view in views:
            for node, (status, epoch) in view.members:
                if node not in out:
                    out[node] = (status, epoch)
                else:
                    s0, e0 = out[node]
                    # higher epoch wins; at equal epoch the more terminal
                    # status wins (a node seen dead stays dead until it
                    # rejoins with a higher epoch)
                    if (epoch, status) > (e0, s0):
                        out[node] = (status, epoch)
        return MemberView.from_dict(out)


class MembershipService:
    """Client-side membership operations against the replicated store."""

    def __init__(self, store: KVCluster, self_id: str):
        self.store = store
        self.self_id = self_id

    def _read(self, via: Optional[str] = None):
        try:
            res = self.store.get(MEMBERSHIP_KEY, via=via or self.self_id)
        except (Unavailable, KeyError):
            return MemberView(), frozenset()
        if not res.values:
            return MemberView(), res.context
        views = tuple(MemberView.deserialize(v) for v in res.values)
        return MemberView.merge(views), res.context

    def view(self, via: Optional[str] = None) -> MemberView:
        return self._read(via)[0]

    def _transition(self, node: str, status: NodeStatus,
                    via: Optional[str] = None, bump_epoch: bool = True) -> MemberView:
        view, ctx = self._read(via)
        d = view.to_dict()
        _, epoch = d.get(node, (NodeStatus.ALIVE, -1))
        d[node] = (int(status), epoch + 1 if bump_epoch else epoch)
        new = MemberView.from_dict(d)
        self.store.put(MEMBERSHIP_KEY, new.serialize(), context=ctx,
                       via=via or self.self_id, client_id=self.self_id)
        return new

    def join(self, node: Optional[str] = None, via: Optional[str] = None):
        return self._transition(node or self.self_id, NodeStatus.ALIVE, via)

    def leave(self, node: Optional[str] = None, via: Optional[str] = None):
        return self._transition(node or self.self_id, NodeStatus.LEAVING, via)

    def mark_dead(self, node: str, via: Optional[str] = None):
        return self._transition(node, NodeStatus.DEAD, via)

    def reconcile(self, via: Optional[str] = None) -> MemberView:
        """Merge any sibling views and persist the join (reader-repair)."""
        view, ctx = self._read(via)
        if ctx:
            self.store.put(MEMBERSHIP_KEY, view.serialize(), context=ctx,
                           via=via or self.self_id, client_id=self.self_id)
        return view
