"""Elastic scaling: map live membership onto a device mesh.

The controller consumes the DVV membership view, decides the largest valid
mesh that the live nodes support, and emits an ``Assignment`` (node → mesh
coordinates).  On scale events the training runtime restores from the last
DVV-checkpoint manifest and re-shards (resharding is a pure relayout because
checkpoints store logical arrays + a shard table, not device buffers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..store.services import MemberView


@dataclass(frozen=True)
class Assignment:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    node_coords: Tuple[Tuple[str, Tuple[int, ...]], ...]

    def coords_of(self, node: str) -> Optional[Tuple[int, ...]]:
        for n, c in self.node_coords:
            if n == node:
                return c
        return None

    @property
    def size(self) -> int:
        out = 1
        for s in self.mesh_shape:
            out *= s
        return out


def _unravel(i: int, shape: Sequence[int]) -> Tuple[int, ...]:
    coords = []
    for s in reversed(shape):
        coords.append(i % s)
        i //= s
    return tuple(reversed(coords))


class ElasticController:
    """Chooses mesh shapes as nodes come and go.

    ``candidate_shapes`` is ordered largest-first; the controller picks the
    largest one that fits the live node count, preferring to keep the model
    axis intact (shrinking "model" would change the parameter sharding in
    ways that need a different partition rule table — we instead shed data
    parallelism first, the standard production response).
    """

    def __init__(self, candidate_shapes: Sequence[Tuple[Tuple[int, ...], Tuple[str, ...]]]):
        if not candidate_shapes:
            raise ValueError("need candidate shapes")
        self.candidate_shapes = list(candidate_shapes)

    def plan(self, view: MemberView) -> Optional[Assignment]:
        live = sorted(view.alive())
        for shape, names in self.candidate_shapes:
            size = 1
            for s in shape:
                size *= s
            if size <= len(live):
                coords = tuple(
                    (live[i], _unravel(i, shape)) for i in range(size))
                return Assignment(tuple(shape), tuple(names), coords)
        return None

    def replan_on_failure(self, view: MemberView,
                          current: Assignment) -> Tuple[Optional[Assignment], bool]:
        """Returns (new_assignment, changed?)."""
        new = self.plan(view)
        changed = (new is None or current is None
                   or new.mesh_shape != current.mesh_shape
                   or new.node_coords != current.node_coords)
        return new, changed
