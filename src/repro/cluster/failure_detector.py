"""Compat shim: ``FailureDetector`` was promoted to a first-class store
citizen (``repro.store.failure``), where it drives the self-driving
membership loop (DESIGN.md §13).  The training-sim runtime keeps importing
it from here; new code should import from ``repro.store``.
"""
from __future__ import annotations

from ..store.failure import FailureDetector

__all__ = ["FailureDetector"]
