"""Accrual-style failure detection over heartbeats.

Each worker stamps heartbeats into the local table (in a real deployment a
gossip channel; here the simulated cluster driver calls ``record``).  The
suspicion level is the normalized time since the last heartbeat; crossing
``suspect_threshold`` marks the node suspect (straggler candidate), crossing
``dead_threshold`` lets the elastic controller declare it dead through the
DVV membership store.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class FailureDetector:
    heartbeat_interval: float = 1.0
    suspect_threshold: float = 3.0   # intervals without a beat -> straggler
    dead_threshold: float = 8.0      # intervals without a beat -> dead
    last_beat: Dict[str, float] = field(default_factory=dict)
    history: Dict[str, List[float]] = field(default_factory=dict)

    def record(self, node: str, now: float) -> None:
        prev = self.last_beat.get(node)
        if prev is not None:
            self.history.setdefault(node, []).append(now - prev)
            # keep a bounded window for the adaptive interval estimate
            if len(self.history[node]) > 64:
                self.history[node] = self.history[node][-64:]
        self.last_beat[node] = now

    def _expected_interval(self, node: str) -> float:
        hist = self.history.get(node)
        if not hist:
            return self.heartbeat_interval
        return max(sum(hist) / len(hist), 1e-9)

    def suspicion(self, node: str, now: float) -> float:
        """0 = just heard from it; grows linearly in missed intervals."""
        if node not in self.last_beat:
            return float("inf")
        return (now - self.last_beat[node]) / self._expected_interval(node)

    def suspects(self, now: float) -> List[str]:
        return [n for n in self.last_beat
                if self.suspect_threshold <= self.suspicion(n, now)
                < self.dead_threshold]

    def dead(self, now: float) -> List[str]:
        return [n for n in self.last_beat
                if self.suspicion(n, now) >= self.dead_threshold]

    def alive(self, now: float) -> List[str]:
        return [n for n in self.last_beat
                if self.suspicion(n, now) < self.suspect_threshold]
