"""Straggler mitigation: a DVV-backed work-stealing ledger.

Data shards (or microbatch ranges, eval jobs, compile tasks...) are leased
through the replicated store.  Two workers claiming the same shard through
the *same* coordinator is precisely the paper's Fig. 3 same-server
concurrency: with per-server version vectors one claim silently overwrites
the other and both workers think they own the shard (duplicated work, or
worse, double-applied updates).  With DVV both claims surface as siblings
and the deterministic resolver picks one winner; the loser observes it lost
and moves on.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import FrozenSet, Optional, Tuple

from ..store import KVCluster, Unavailable


def _lease_key(shard: str) -> str:
    return f"lease/{shard}"


@dataclass(frozen=True)
class Lease:
    shard: str
    owner: str
    expires: float
    attempt: int

    def serialize(self) -> str:
        return json.dumps({"shard": self.shard, "owner": self.owner,
                           "expires": self.expires, "attempt": self.attempt})

    @staticmethod
    def deserialize(s: str) -> "Lease":
        return Lease(**json.loads(s))


def resolve_lease_siblings(leases: Tuple[Lease, ...]) -> Lease:
    """Deterministic winner among concurrent claims: highest attempt, then
    latest expiry, then lowest owner id (total, schedule-independent)."""
    return sorted(leases,
                  key=lambda l: (-l.attempt, -l.expires, l.owner))[0]


class WorkStealer:
    def __init__(self, store: KVCluster, worker_id: str,
                 lease_duration: float = 10.0):
        self.store = store
        self.worker_id = worker_id
        self.lease_duration = lease_duration

    def _read(self, shard: str, via: Optional[str] = None):
        try:
            res = self.store.get(_lease_key(shard), via=via)
        except Unavailable:
            return None, frozenset()
        if not res.values:
            return None, res.context
        leases = tuple(Lease.deserialize(v) for v in res.values)
        return resolve_lease_siblings(leases), res.context

    def try_claim(self, shard: str, now: float,
                  via: Optional[str] = None) -> bool:
        """Attempt to lease ``shard``.  Returns True iff after the write this
        worker is the resolved owner (the claim may race; we re-read)."""
        current, ctx = self._read(shard, via=via)
        if current is not None and current.owner != self.worker_id \
                and current.expires > now:
            return False  # actively held by someone else
        attempt = (current.attempt + 1) if current else 0
        lease = Lease(shard, self.worker_id, now + self.lease_duration, attempt)
        try:
            self.store.put(_lease_key(shard), lease.serialize(), context=ctx,
                           via=via, client_id=self.worker_id)
        except Unavailable:
            return False
        resolved, _ = self._read(shard, via=via)
        return resolved is not None and resolved.owner == self.worker_id

    def renew(self, shard: str, now: float, via: Optional[str] = None) -> bool:
        current, ctx = self._read(shard, via=via)
        if current is None or current.owner != self.worker_id:
            return False
        lease = Lease(shard, self.worker_id, now + self.lease_duration,
                      current.attempt)
        self.store.put(_lease_key(shard), lease.serialize(), context=ctx,
                       via=via, client_id=self.worker_id)
        return True

    def owner(self, shard: str, via: Optional[str] = None) -> Optional[str]:
        lease, _ = self._read(shard, via=via)
        return lease.owner if lease else None

    def steal_expired(self, shard: str, now: float,
                      via: Optional[str] = None) -> bool:
        """Straggler mitigation: take over a shard whose lease lapsed."""
        current, _ = self._read(shard, via=via)
        if current is None or current.expires > now:
            return False
        return self.try_claim(shard, now, via=via)
