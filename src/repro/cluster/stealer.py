"""Compat shim: the DVV-backed work-stealing lease ledger was promoted to
the store plane (``repro.store.services``).  The training-sim runtime keeps
importing it from here; new code should import from ``repro.store``.
"""
from __future__ import annotations

from ..store.services import Lease, WorkStealer, resolve_lease_siblings

__all__ = ["Lease", "WorkStealer", "resolve_lease_siblings"]
